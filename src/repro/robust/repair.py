"""Repair of corrupted *derived* structures by recomputation.

The flip side of ``robust.verify``: because every rank/select directory,
zero count, C table, and SA-sample directory is a deterministic function
of the level bitmaps (paper Theorems 5.1/5.2), a corrupted derived leaf
is repaired by recomputing it through the exact same builders the
original construction used — so a successful repair is *bit-identical*
to the pre-fault structure, not merely equivalent. Only corruption of
the primary bitmaps (``rank.words`` of a wavelet-matrix level, seam
windows) forces a shard rebuild from source tokens.

Leaf classification for checksum-failure triage lives here too:
``classify_bad_keys`` maps the '/'-joined pytree paths that
``IntegrityError`` reports onto derived-vs-primary, deciding repair vs
rebuild without any structural scan.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.core.rank_select import build_bitvector_levels
from repro.core.wavelet_matrix import WaveletMatrix

_I32 = jnp.int32


# --------------------------------------------------------------------------
# checksum-failure triage
# --------------------------------------------------------------------------

#: path fragments of primary leaves — everything else in the serving
#: pytrees is derivable from the bitmaps. ``mark/words`` is *derived*
#: (recomputable from the SA-sample walk), so the wm bitmap rule matches
#: on the bitvectors prefix, not bare "words".
_PRIMARY_FRAGMENTS = ("bitvectors/rank/words", "seam_windows")


def is_primary_key(key: str) -> bool:
    """Does this flattened-pytree path name primary (non-derivable) data?

    Keys are matched dot-stripped: attribute path tokens stringify as
    ``.name``, so the stored form of the wm bitmap leaf is
    ``".bitvectors/.rank/.words"``.
    """
    key = key.replace(".", "")
    return any(frag in key for frag in _PRIMARY_FRAGMENTS)


def classify_bad_keys(bad_keys: Iterable[str]) -> Tuple[list, list]:
    """Split checksum-failed leaf paths into (derived, primary)."""
    derived, primary = [], []
    for k in bad_keys:
        (primary if is_primary_key(k) else derived).append(k)
    return derived, primary


# --------------------------------------------------------------------------
# wavelet matrix / analytics engine
# --------------------------------------------------------------------------

def repair_wavelet_matrix(wm: WaveletMatrix) -> WaveletMatrix:
    """Recompute every derived leaf of one matrix from its level bitmaps.

    Rank superblock/block tables, both select sample directories, and the
    per-level ``zeros`` are rebuilt with the same batched
    ``build_bitvector_levels`` the fused construction uses — bit-identical
    output when the bitmaps are intact.
    """
    words = wm.bitvectors.rank.words                    # (nbits, W)
    sample_rate = wm.bitvectors.sel1.sample_rate
    bv = build_bitvector_levels(words, wm.n, sample_rate, use_kernels=False)
    ones = jax.vmap(lambda w: jnp.sum(bitops.popcount(w), dtype=_I32))(words)
    zeros = (jnp.asarray(wm.n, _I32) - ones).astype(_I32)
    return WaveletMatrix(bitvectors=bv, zeros=zeros, n=wm.n, nbits=wm.nbits)


def repair_analytics(engine):
    """Repair all shards of a ``ShardedAnalytics`` (stacked (S,) leaves).

    One vmap over the shard axis of the per-matrix repair; geometry and
    the availability mask pass through unchanged.
    """
    shards = engine.shards
    n, nbits = shards.n, shards.nbits
    sample_rate = shards.bitvectors.sel1.sample_rate
    words = shards.bitvectors.rank.words                # (S, nbits, W)

    def one(w):
        bv = build_bitvector_levels(w, n, sample_rate, use_kernels=False)
        ones = jax.vmap(lambda ww: jnp.sum(bitops.popcount(ww),
                                           dtype=_I32))(w)
        return bv, (jnp.asarray(n, _I32) - ones).astype(_I32)

    bv, zeros = jax.vmap(one)(words)
    fixed = WaveletMatrix(bitvectors=bv, zeros=zeros, n=n, nbits=nbits)
    return dataclasses.replace(engine, shards=fixed)


# --------------------------------------------------------------------------
# FM-index (full-text shards)
# --------------------------------------------------------------------------

def _rebuild_sa_directories(wm: WaveletMatrix, C: jax.Array, m: int,
                            sample_rate: int):
    """Recompute the sampled-SA directories from the BWT bitmaps alone.

    The suffix array is itself derivable from the FM-index: walking LF
    from row 0 (the sentinel suffix, text position m−1) visits the rows
    of positions m−1, m−2, …, 0 in order. One O(m)-step sequential walk
    (each step an access + rank) recovers, for every sampled position
    i·rate, the row that holds it — exactly the information
    ``build_fm_index`` takes from the explicit SA. Worst-case repair
    cost, reserved for corrupt ``mark``/``sa_sample`` leaves.
    """
    from repro.core.wavelet_matrix import wm_access, wm_rank
    num = (m + sample_rate - 1) // sample_rate

    def lf(j):
        c = wm_access(wm, j)
        return C[c] + wm_rank(wm, c, j)

    def body(t, state):
        row, rows = state
        pos = m - 1 - t
        slot = jnp.where(pos % sample_rate == 0, pos // sample_rate, num)
        rows = rows.at[slot].set(row, mode="drop")
        return lf(row), rows

    _, rows = jax.lax.fori_loop(
        0, m, body, (jnp.zeros((), _I32), jnp.zeros((num,), _I32)))
    # rows[i] = SA row holding text position i·rate → mark bitmap + the
    # row-order compaction build_fm_index produces
    marked = jnp.zeros((m,), jnp.uint8).at[rows].set(1)
    words = bitops.pack_bits(bitops.pad_bits(marked))
    cnt = jnp.cumsum(marked.astype(_I32)) - 1             # rank among marked
    sa_sample = jnp.zeros((num,), _I32).at[cnt[rows]].set(
        jnp.arange(num, dtype=_I32) * sample_rate)
    from repro.core.rank_select import build_binary_rank
    return build_binary_rank(words, m), sa_sample


def repair_fm_index(fm, deep: bool = True):
    """Recompute every derived leaf of one ``FMIndex`` from its bitmaps.

    Always rebuilds the wavelet-matrix directories and the C table (cheap,
    vectorized). ``deep=True`` additionally re-derives the sampled-SA
    directories via the O(m) LF walk — needed only when ``mark`` /
    ``sa_sample`` are suspect, so callers triaging a localized checksum
    failure can skip it.
    """
    from repro.core.wavelet_matrix import wm_rank
    from repro.index.fm_index import FMIndex
    wm = repair_wavelet_matrix(fm.wm)
    m = fm.m
    # C from the bitmap-encoded symbol histogram: count of symbol c is
    # wm_rank(c, m); exclusive-cumsum via the (σ+2,) boundary layout
    sigma_work = fm.sigma + 1
    counts = wm_rank(wm, jnp.arange(sigma_work, dtype=_I32),
                     jnp.full((sigma_work,), m, _I32))
    C = jnp.concatenate([jnp.zeros((1,), _I32),
                         jnp.cumsum(counts).astype(_I32)])
    if deep:
        mark, sa_sample = _rebuild_sa_directories(wm, C, m, fm.sample_rate)
    else:
        mark, sa_sample = fm.mark, fm.sa_sample
    return FMIndex(wm=wm, C=C, mark=mark, sa_sample=sa_sample, n=fm.n,
                   sigma=fm.sigma, sample_rate=fm.sample_rate)


def repair_sharded_index(idx, deep: bool = True):
    """Repair all shards of a ``ShardedTextIndex`` (seam windows are
    primary and pass through untouched)."""
    S = idx.num_shards
    fixed = [repair_fm_index(jax.tree.map(lambda l: l[s], idx.shards),
                             deep=deep)
             for s in range(S)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *fixed)
    return dataclasses.replace(idx, shards=stacked)


# --------------------------------------------------------------------------
# wavelet tree
# --------------------------------------------------------------------------

def repair_wavelet_tree(wt):
    """Recompute a ``WaveletTree``'s directories and ``node_starts`` from
    its level bitmaps.

    ``node_starts`` row l+1 follows from row l and the per-node zero
    counts of level l's bitmap (each node splits into its zero/one
    children); row 0 is [0, …]. The leaf row (the C array) falls out of
    the final split. Host numpy — repair is an incident path.
    """
    from repro.core.wavelet_tree import WaveletTree
    words = np.asarray(wt.bitvectors.rank.words)        # (nbits, W)
    n, nbits = wt.n, wt.nbits
    size = 1 << nbits
    starts = np.zeros((nbits + 1, size), np.int64)
    row = np.zeros(1, np.int64)                          # starts of 2^l nodes
    bits_cache = [np.unpackbits(np.ascontiguousarray(words[l])
                                .view(np.uint8), bitorder="little")[:n]
                  for l in range(nbits)]
    for l in range(nbits):
        starts[l, :row.shape[0]] = row   # tail stays 0 (builder's padding)
        bits = bits_cache[l]
        bounds = np.concatenate([row, [n]])
        ones_pref = np.concatenate([[0], np.cumsum(bits)])
        child = np.empty(row.shape[0] * 2, np.int64)
        for v in range(row.shape[0]):
            a, b = bounds[v], bounds[v + 1]
            z = (b - a) - (ones_pref[b] - ones_pref[a])
            child[2 * v] = a
            child[2 * v + 1] = a + z
        row = child
    starts[nbits, :row.shape[0]] = row
    sample_rate = wt.bitvectors.sel1.sample_rate
    bv = build_bitvector_levels(jnp.asarray(words), n, sample_rate,
                                use_kernels=False)
    return WaveletTree(bitvectors=bv,
                       node_starts=jnp.asarray(starts, _I32),
                       n=n, nbits=nbits)
