"""One clock abstraction for every deadline in the stack.

``with_retry``'s ``deadline_s``, the ingester's per-shard build budget,
and the serving front-end's per-request deadlines all measure the same
thing — monotonic elapsed time — and all need to be injectable so chaos
scenarios and unit tests can run deadline logic without real sleeps.
Before this module each caller threaded its own ``sleep=``/``now=``
kwargs; now they share :class:`Clock` (real monotonic time) and tests
inject :class:`FakeClock` (manually advanced, sleeps recorded).

The contract deadline users rely on:

* ``now()`` is monotonic — never steps backwards, unaffected by wall
  clock adjustments, so ``deadline = now() + budget`` comparisons are
  safe across NTP slews.
* ``sleep(s)`` advances ``now()`` by *at least* ``s`` (exactly ``s`` on
  the fake clock), so a sleep can never leave a deadline check behind
  the time it thinks it waited.
"""
from __future__ import annotations

import time
from typing import List


class Clock:
    """Real monotonic time. Stateless — share the module singleton."""

    def now(self) -> float:
        """Monotonic seconds (``time.monotonic`` epoch — only differences
        are meaningful)."""
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


#: the default clock every deadline-taking API shares.
SYSTEM_CLOCK = Clock()


class FakeClock(Clock):
    """Deterministic clock for tests and chaos scenarios.

    ``sleep`` records the request and advances virtual time instantly, so
    retry/backoff/deadline logic runs at full speed while every timing
    decision stays observable (``sleeps``) and controllable
    (``advance``).
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        #: every sleep duration requested, in order.
        self.sleeps: List[float] = []

    def now(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        self.sleeps.append(float(seconds))
        if seconds > 0:
            self._t += float(seconds)

    def advance(self, seconds: float) -> float:
        """Step virtual time forward; returns the new ``now()``."""
        self._t += float(seconds)
        return self._t
