"""Per-leaf integrity checksums for snapshot pytrees.

The paper's structures are deterministic functions of their inputs, so a
snapshot can carry a cheap content fingerprint per leaf: crc32 over the
raw bytes as stored, tagged with shape + dtype so a reshaped or re-typed
leaf never collides with its own data. ``checkpoint.save_checkpoint``
records these in ``meta.json`` at save time; ``restore_checkpoint``
re-hashes what it read and raises :class:`IntegrityError` naming the
corrupted leaves — the entry point of the verify → repair → rebuild
escalation in ``robust.repair``.

crc32 runs at memory bandwidth, so verification rides inside the
IO-bound restore at a few percent overhead (``benchmarks/bench_robust``
tracks it against the ≤10% budget).
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, List, Mapping

import jax
import numpy as np


class IntegrityError(Exception):
    """A snapshot failed checksum verification.

    ``bad_keys`` holds the '/'-joined pytree paths of every leaf whose
    stored bytes no longer match the checksum recorded at save time.
    """

    def __init__(self, bad_keys: List[str], where: str = "snapshot"):
        self.bad_keys = list(bad_keys)
        super().__init__(
            f"{where}: checksum mismatch on {len(self.bad_keys)} leaf/leaves: "
            f"{', '.join(self.bad_keys[:8])}"
            f"{' …' if len(self.bad_keys) > 8 else ''}")


def checksum_array(arr: Any) -> str:
    """crc32 fingerprint of one array: raw bytes + shape/dtype tag.

    Non-native dtypes (bfloat16, …) hash their byte view — the same
    representation ``checkpoint`` writes to ``arrays.npz`` — so the hash
    of an in-memory leaf equals the hash of its stored form.
    """
    a = np.ascontiguousarray(np.asarray(arr))
    if a.dtype.kind not in "biufc?":
        a = a.view(np.dtype(f"V{a.dtype.itemsize}"))
    h = zlib.crc32(f"{a.shape}:{a.dtype.str}".encode())
    h = zlib.crc32(a.tobytes(), h)
    return f"{h:08x}"


def checksum_flat(arrays: Mapping[str, Any]) -> Dict[str, str]:
    """Checksums for a flattened {path: array} dict (checkpoint layout)."""
    return {k: checksum_array(v) for k, v in arrays.items()}


def verify_flat(arrays: Mapping[str, Any],
                checksums: Mapping[str, str]) -> List[str]:
    """Compare arrays against recorded checksums → list of bad keys.

    Keys missing from either side are reported as bad (a dropped or
    phantom leaf is corruption, not a soft mismatch).
    """
    bad = [k for k in checksums if k not in arrays]
    for k, a in arrays.items():
        want = checksums.get(k)
        if want is None:
            bad.append(k)
        elif checksum_array(a) != want:
            bad.append(k)
    return sorted(set(bad))


def tree_checksums(tree: Any) -> Dict[str, str]:
    """Per-leaf checksums of a live pytree, keyed by '/'-joined path.

    Mirrors the flattening ``checkpoint.save_checkpoint`` uses, so the
    result is directly comparable with a snapshot's recorded checksums —
    the bit-identity test the repair round-trip suite relies on.
    """
    from repro.checkpoint.checkpoint import _flatten
    return checksum_flat(_flatten(tree)[0])


def trees_identical(a: Any, b: Any) -> bool:
    """True iff two pytrees have identical structure and leaf bytes."""
    la = jax.tree_util.tree_flatten(a)
    lb = jax.tree_util.tree_flatten(b)
    if la[1] != lb[1]:
        return False
    return all(checksum_array(x) == checksum_array(y)
               for x, y in zip(la[0], lb[0]))
