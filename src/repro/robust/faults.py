"""Seedable fault injection + bounded retry — the chaos harness.

Two fault surfaces, matching how corruption reaches a serving engine:

* **In-memory / stored-leaf faults** — flip bits in chosen pytree leaves,
  either on a live engine (``flip_leaf_bit``) or inside a snapshot's
  ``arrays.npz`` (``corrupt_snapshot_leaf`` rewrites the member so the
  zip container stays readable and only the *leaf checksum* catches it —
  the exact failure mode of silent disk/RAM corruption).
* **File-level faults** — truncate or delete snapshot files and plant
  stale ``.tmp`` partial writes (``truncate_file`` / ``delete_file`` /
  ``inject_partial_tmp``), the crash-mid-write failure modes
  ``checkpoint.latest_step`` must skip over.

Everything takes an explicit seed; tests and the ``launch.chaos`` CLI
replay identical fault sequences. ``with_retry`` is the bounded
retry/backoff wrapper the restore → rebuild escalation uses around shard
builds.
"""
from __future__ import annotations

import json
import shutil
import time
from pathlib import Path
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import obs

_SEP = "/"


def _norm(key: str) -> str:
    """Match-friendly leaf path: attribute tokens stringify as ``.name``
    (GetAttrKey), so strip the dots — ``leaf_match="rank/words"`` then
    matches the stored key ``".bitvectors/.rank/.words"``."""
    return key.replace(".", "")


def _flat_with_keys(tree: Any):
    """[(path, leaf)] with checkpoint-style '/'-joined path keys."""
    from repro.checkpoint.checkpoint import _path_token
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_SEP.join(_path_token(p) for p in path), leaf)
            for path, leaf in flat]


def leaf_keys(tree: Any) -> list:
    return [k for k, _ in _flat_with_keys(tree)]


def _flip_bit_in_array(arr: np.ndarray, rng: np.random.Generator
                       ) -> Tuple[np.ndarray, str]:
    """Flip one random bit of one random element; returns (copy, where)."""
    a = np.ascontiguousarray(np.asarray(arr)).copy()
    if a.size == 0:
        return a, "empty leaf (no-op)"
    view = a.view(np.uint8).reshape(-1)
    byte = int(rng.integers(0, view.size))
    bit = int(rng.integers(0, 8))
    view[byte] ^= np.uint8(1 << bit)
    return a, f"byte {byte} bit {bit} of {a.size}×{a.dtype} leaf"


def flip_leaf_bit(tree: Any, *, seed: int,
                  leaf_match: Optional[str] = None) -> Tuple[Any, str]:
    """Return a copy of ``tree`` with one bit flipped in one leaf.

    ``leaf_match`` restricts the choice to leaves whose '/'-joined path
    contains the substring (e.g. ``"rank/superblock"``); ``None`` picks
    any leaf. Returns ``(corrupted_tree, description)`` where the
    description names the leaf path — tests use it to assert detection
    localizes correctly.
    """
    rng = np.random.default_rng(seed)
    flat = _flat_with_keys(tree)
    candidates = [i for i, (k, leaf) in enumerate(flat)
                  if (leaf_match is None or _norm(leaf_match) in _norm(k))
                  and np.asarray(leaf).size > 0]
    if not candidates:
        raise ValueError(f"no leaf matches {leaf_match!r}")
    pick = candidates[int(rng.integers(0, len(candidates)))]
    key = flat[pick][0]
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    new_leaf, where = _flip_bit_in_array(leaves[pick], rng)
    leaves = list(leaves)
    leaves[pick] = jax.numpy.asarray(new_leaf)
    obs.counter("robust.fault", kind="leaf_bitflip").inc()
    obs.event("fault.leaf_bitflip", kind="fault", leaf=key, where=where,
              seed=seed)
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            f"{key}: {where}")


# --------------------------------------------------------------------------
# snapshot-file faults
# --------------------------------------------------------------------------

def _latest_step_dir(ckpt_dir: str | Path) -> Path:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    if not steps:
        raise FileNotFoundError(f"no step_* under {ckpt_dir}")
    return steps[-1]


def corrupt_snapshot_leaf(ckpt_dir: str | Path, *, seed: int,
                          leaf_match: Optional[str] = None) -> str:
    """Flip one bit of one stored leaf inside ``arrays.npz``, rewriting
    the archive so the zip container stays valid — only the per-leaf
    crc32 in ``meta.json`` can catch it (silent-corruption model)."""
    d = _latest_step_dir(ckpt_dir)
    rng = np.random.default_rng(seed)
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    keys = [k for k in arrays
            if (leaf_match is None or _norm(leaf_match) in _norm(k))
            and arrays[k].size]
    if not keys:
        raise ValueError(f"no stored leaf matches {leaf_match!r}")
    key = keys[int(rng.integers(0, len(keys)))]
    arrays[key], where = _flip_bit_in_array(arrays[key], rng)
    np.savez(d / "arrays.npz", **arrays)
    obs.counter("robust.fault", kind="snapshot_bitflip").inc()
    obs.event("fault.snapshot_bitflip", kind="fault", leaf=key, where=where,
              seed=seed, step_dir=d.name)
    return f"{key}: {where}"


def truncate_file(ckpt_dir: str | Path, name: str = "arrays.npz",
                  keep_frac: float = 0.5) -> Path:
    """Truncate a snapshot file to ``keep_frac`` of its size (torn write)."""
    d = _latest_step_dir(ckpt_dir)
    path = d / name
    size = path.stat().st_size
    with open(path, "r+b") as f:
        f.truncate(max(1, int(size * keep_frac)))
    obs.counter("robust.fault", kind="truncate").inc()
    obs.event("fault.truncate", kind="fault", file=str(path),
              keep_frac=keep_frac)
    return path


def delete_file(ckpt_dir: str | Path, name: str = "meta.json") -> Path:
    """Delete one file of the newest snapshot step (half-deleted dir)."""
    d = _latest_step_dir(ckpt_dir)
    (d / name).unlink()
    obs.counter("robust.fault", kind="delete_file").inc()
    obs.event("fault.delete_file", kind="fault", file=str(d / name))
    return d / name


def delete_step(ckpt_dir: str | Path) -> Path:
    """Remove the newest step directory entirely."""
    d = _latest_step_dir(ckpt_dir)
    shutil.rmtree(d)
    obs.counter("robust.fault", kind="delete_step").inc()
    obs.event("fault.delete_step", kind="fault", step_dir=str(d))
    return d


def inject_partial_tmp(ckpt_dir: str | Path, step: int = 99) -> Path:
    """Plant a stale ``.tmp_step_*`` partial write (writer died pre-publish)
    plus a bare ``step_*`` directory missing its arrays — both must be
    invisible to ``latest_step``."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)
    (tmp / "arrays.npz").write_bytes(b"PK\x03\x04 torn")
    bare = ckpt_dir / f"step_{step:08d}"
    bare.mkdir(exist_ok=True)
    (bare / "meta.json").write_text(json.dumps({"step": step}))
    obs.counter("robust.fault", kind="partial_tmp").inc()
    obs.event("fault.partial_tmp", kind="fault", tmp=str(tmp),
              bare=str(bare))
    return tmp


# --------------------------------------------------------------------------
# bounded retry / backoff
# --------------------------------------------------------------------------

def with_retry(fn: Callable, *, retries: int = 2, backoff_s: float = 0.05,
               exceptions: Sequence[type] = (Exception,),
               on_retry: Optional[Callable[[int, BaseException], None]]
               = None):
    """Call ``fn()`` with up to ``retries`` re-attempts and exponential
    backoff (backoff_s · 2^attempt between tries). Re-raises the last
    exception once the budget is spent. ``on_retry(attempt, exc)`` is
    invoked before each sleep — callers log through it.
    """
    last: BaseException | None = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except tuple(exceptions) as e:          # noqa: PERF203
            last = e
            if attempt == retries:
                obs.counter("robust.retry_exhausted").inc()
                raise
            obs.counter("robust.retry").inc()
            obs.event("retry", attempt=attempt, error=type(e).__name__)
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(backoff_s * (2 ** attempt))
    raise last  # unreachable; keeps type checkers honest
