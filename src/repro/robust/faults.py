"""Seedable fault injection + bounded retry — the chaos harness.

Two fault surfaces, matching how corruption reaches a serving engine:

* **In-memory / stored-leaf faults** — flip bits in chosen pytree leaves,
  either on a live engine (``flip_leaf_bit``) or inside a snapshot's
  ``arrays.npz`` (``corrupt_snapshot_leaf`` rewrites the member so the
  zip container stays readable and only the *leaf checksum* catches it —
  the exact failure mode of silent disk/RAM corruption).
* **File-level faults** — truncate or delete snapshot files and plant
  stale ``.tmp`` partial writes (``truncate_file`` / ``delete_file`` /
  ``inject_partial_tmp``), the crash-mid-write failure modes
  ``checkpoint.latest_step`` must skip over.
* **Crash points** — ``crash_after(step)`` arms a named protocol step;
  instrumented write paths (the ingest commit protocol) call
  ``check_crash_point(step)`` after each step and the armed one raises
  :class:`CrashInjected` — a ``BaseException`` so no ``except Exception``
  handler on the way out can "handle" a simulated process death. The
  chaos sweep kills the ingester after *every* step this way and asserts
  recovery converges to the clean-rebuild state.

* **Per-shard latency faults** — ``inject_shard_latency`` arms a delay
  against one shard id; instrumented per-shard probe paths (the serving
  front-end's circuit breakers) call ``shard_latency(s)`` and stall by
  that much — the "one slow replica" failure mode hedging must survive.

Everything takes an explicit seed; tests and the ``launch.chaos`` CLI
replay identical fault sequences. ``with_retry`` is the bounded
retry/backoff wrapper the restore → rebuild escalation uses around shard
builds — full-jitter exponential backoff under an optional wall-clock
``deadline_s``. All elapsed-time/sleep behaviour goes through one
injectable ``robust.Clock`` (``clock=FakeClock()`` makes every deadline
decision deterministic).
"""
from __future__ import annotations

import contextlib
import json
import shutil
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import obs

from .clock import SYSTEM_CLOCK, Clock

_SEP = "/"


def _norm(key: str) -> str:
    """Match-friendly leaf path: attribute tokens stringify as ``.name``
    (GetAttrKey), so strip the dots — ``leaf_match="rank/words"`` then
    matches the stored key ``".bitvectors/.rank/.words"``."""
    return key.replace(".", "")


def _flat_with_keys(tree: Any):
    """[(path, leaf)] with checkpoint-style '/'-joined path keys."""
    from repro.checkpoint.checkpoint import _path_token
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_SEP.join(_path_token(p) for p in path), leaf)
            for path, leaf in flat]


def leaf_keys(tree: Any) -> list:
    return [k for k, _ in _flat_with_keys(tree)]


def _flip_bit_in_array(arr: np.ndarray, rng: np.random.Generator
                       ) -> Tuple[np.ndarray, str]:
    """Flip one random bit of one random element; returns (copy, where)."""
    a = np.ascontiguousarray(np.asarray(arr)).copy()
    if a.size == 0:
        return a, "empty leaf (no-op)"
    view = a.view(np.uint8).reshape(-1)
    byte = int(rng.integers(0, view.size))
    bit = int(rng.integers(0, 8))
    view[byte] ^= np.uint8(1 << bit)
    return a, f"byte {byte} bit {bit} of {a.size}×{a.dtype} leaf"


def flip_leaf_bit(tree: Any, *, seed: int,
                  leaf_match: Optional[str] = None) -> Tuple[Any, str]:
    """Return a copy of ``tree`` with one bit flipped in one leaf.

    ``leaf_match`` restricts the choice to leaves whose '/'-joined path
    contains the substring (e.g. ``"rank/superblock"``); ``None`` picks
    any leaf. Returns ``(corrupted_tree, description)`` where the
    description names the leaf path — tests use it to assert detection
    localizes correctly.
    """
    rng = np.random.default_rng(seed)
    flat = _flat_with_keys(tree)
    candidates = [i for i, (k, leaf) in enumerate(flat)
                  if (leaf_match is None or _norm(leaf_match) in _norm(k))
                  and np.asarray(leaf).size > 0]
    if not candidates:
        raise ValueError(f"no leaf matches {leaf_match!r}")
    pick = candidates[int(rng.integers(0, len(candidates)))]
    key = flat[pick][0]
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    new_leaf, where = _flip_bit_in_array(leaves[pick], rng)
    leaves = list(leaves)
    leaves[pick] = jax.numpy.asarray(new_leaf)
    obs.counter("robust.fault", kind="leaf_bitflip").inc()
    obs.event("fault.leaf_bitflip", kind="fault", leaf=key, where=where,
              seed=seed)
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            f"{key}: {where}")


# --------------------------------------------------------------------------
# snapshot-file faults
# --------------------------------------------------------------------------

def _latest_step_dir(ckpt_dir: str | Path) -> Path:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    if not steps:
        raise FileNotFoundError(f"no step_* under {ckpt_dir}")
    return steps[-1]


def corrupt_snapshot_leaf(ckpt_dir: str | Path, *, seed: int,
                          leaf_match: Optional[str] = None) -> str:
    """Flip one bit of one stored leaf inside ``arrays.npz``, rewriting
    the archive so the zip container stays valid — only the per-leaf
    crc32 in ``meta.json`` can catch it (silent-corruption model)."""
    d = _latest_step_dir(ckpt_dir)
    rng = np.random.default_rng(seed)
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    keys = [k for k in arrays
            if (leaf_match is None or _norm(leaf_match) in _norm(k))
            and arrays[k].size]
    if not keys:
        raise ValueError(f"no stored leaf matches {leaf_match!r}")
    key = keys[int(rng.integers(0, len(keys)))]
    arrays[key], where = _flip_bit_in_array(arrays[key], rng)
    np.savez(d / "arrays.npz", **arrays)
    obs.counter("robust.fault", kind="snapshot_bitflip").inc()
    obs.event("fault.snapshot_bitflip", kind="fault", leaf=key, where=where,
              seed=seed, step_dir=d.name)
    return f"{key}: {where}"


def truncate_file(ckpt_dir: str | Path, name: str = "arrays.npz",
                  keep_frac: float = 0.5) -> Path:
    """Truncate a snapshot file to ``keep_frac`` of its size (torn write)."""
    d = _latest_step_dir(ckpt_dir)
    path = d / name
    size = path.stat().st_size
    with open(path, "r+b") as f:
        f.truncate(max(1, int(size * keep_frac)))
    obs.counter("robust.fault", kind="truncate").inc()
    obs.event("fault.truncate", kind="fault", file=str(path),
              keep_frac=keep_frac)
    return path


def delete_file(ckpt_dir: str | Path, name: str = "meta.json") -> Path:
    """Delete one file of the newest snapshot step (half-deleted dir)."""
    d = _latest_step_dir(ckpt_dir)
    (d / name).unlink()
    obs.counter("robust.fault", kind="delete_file").inc()
    obs.event("fault.delete_file", kind="fault", file=str(d / name))
    return d / name


def delete_step(ckpt_dir: str | Path) -> Path:
    """Remove the newest step directory entirely."""
    d = _latest_step_dir(ckpt_dir)
    shutil.rmtree(d)
    obs.counter("robust.fault", kind="delete_step").inc()
    obs.event("fault.delete_step", kind="fault", step_dir=str(d))
    return d


def inject_partial_tmp(ckpt_dir: str | Path, step: int = 99) -> Path:
    """Plant a stale ``.tmp_step_*`` partial write (writer died pre-publish)
    plus a bare ``step_*`` directory missing its arrays — both must be
    invisible to ``latest_step``."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)
    (tmp / "arrays.npz").write_bytes(b"PK\x03\x04 torn")
    bare = ckpt_dir / f"step_{step:08d}"
    bare.mkdir(exist_ok=True)
    (bare / "meta.json").write_text(json.dumps({"step": step}))
    obs.counter("robust.fault", kind="partial_tmp").inc()
    obs.event("fault.partial_tmp", kind="fault", tmp=str(tmp),
              bare=str(bare))
    return tmp


# --------------------------------------------------------------------------
# crash-point injection (simulated process death mid-protocol)
# --------------------------------------------------------------------------

class CrashInjected(BaseException):
    """A ``crash_after``-armed step was reached — the simulated SIGKILL.

    Deliberately a ``BaseException``: a real crash is not handled by
    ``except Exception`` cleanup/retry paths, and neither is this one, so
    the injected death exits the protocol exactly where the armed step
    ends — whatever is on disk at that instant is what recovery sees.
    """

    def __init__(self, step: str):
        self.step = step
        super().__init__(f"injected crash after step {step!r}")


_armed_crash_step: Optional[str] = None


@contextlib.contextmanager
def crash_after(step: Optional[str]):
    """Arm one named protocol step for the scope of the ``with`` block.

    The first ``check_crash_point(step)`` call for the armed step raises
    :class:`CrashInjected` (and disarms, so recovery code running in the
    same process is not re-killed). ``None`` arms nothing.
    """
    global _armed_crash_step
    prev = _armed_crash_step
    _armed_crash_step = step
    try:
        yield
    finally:
        _armed_crash_step = prev


def check_crash_point(step: str) -> None:
    """Instrumented protocol steps call this after completing ``step``."""
    global _armed_crash_step
    if _armed_crash_step is not None and _armed_crash_step == step:
        _armed_crash_step = None
        obs.counter("robust.fault", kind="crash_point").inc()
        obs.event("fault.crash_point", kind="fault", step=step)
        raise CrashInjected(step)


# --------------------------------------------------------------------------
# per-shard latency injection (slow-replica fault model)
# --------------------------------------------------------------------------

_shard_latency: Dict[int, float] = {}


@contextlib.contextmanager
def inject_shard_latency(shard: int, seconds: float):
    """Arm a latency fault against one shard id for the ``with`` scope.

    Instrumented per-shard paths (the front-end's circuit-breaker
    probes) call :func:`shard_latency` and stall by the armed amount —
    the "one slow replica stalls the fleet" failure mode that hedged
    timeouts must convert into degraded coverage instead of queue
    stalls. Nested injections against distinct shards compose.
    """
    prev = _shard_latency.get(shard)
    _shard_latency[shard] = float(seconds)
    obs.counter("robust.fault", kind="shard_latency").inc()
    obs.event("fault.shard_latency", kind="fault", shard=shard,
              seconds=seconds)
    try:
        yield
    finally:
        if prev is None:
            _shard_latency.pop(shard, None)
        else:
            _shard_latency[shard] = prev


def shard_latency(shard: int) -> float:
    """Armed extra latency (seconds) for ``shard``; 0.0 when unarmed."""
    return _shard_latency.get(int(shard), 0.0)


# --------------------------------------------------------------------------
# bounded retry / backoff
# --------------------------------------------------------------------------

def with_retry(fn: Callable, *, retries: int = 2, backoff_s: float = 0.05,
               exceptions: Sequence[type] = (Exception,),
               on_retry: Optional[Callable[[int, BaseException], None]]
               = None,
               jitter: bool = True,
               deadline_s: Optional[float] = None,
               rng: Optional[np.random.Generator] = None,
               clock: Clock = SYSTEM_CLOCK):
    """Call ``fn()`` with up to ``retries`` re-attempts, full-jitter
    exponential backoff, and an optional wall-clock deadline.

    Backoff before attempt ``a+1`` is drawn uniformly from
    ``[0, backoff_s · 2^a]`` (AWS-style *full jitter* — a fleet of
    retriers decorrelates instead of thundering in lockstep;
    ``jitter=False`` restores the deterministic cap). ``deadline_s``
    bounds the *total* time spent inside this call: once the elapsed time
    reaches it the last exception is re-raised even if the retry budget
    remains, and every sleep is clipped so the deadline is never
    overshot by a backoff. Re-raises the last exception once either
    budget is spent. ``on_retry(attempt, exc)`` is invoked before each
    sleep — callers log through it. ``rng`` and ``clock`` (the shared
    ``robust.Clock`` — elapsed time *and* sleeping) are injectable for
    deterministic tests.
    """
    rng = rng if rng is not None else np.random.default_rng()
    start = clock.now()
    last: BaseException | None = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except tuple(exceptions) as e:          # noqa: PERF203
            last = e
            elapsed = clock.now() - start
            out_of_time = (deadline_s is not None
                           and elapsed >= deadline_s)
            if attempt == retries or out_of_time:
                obs.counter("robust.retry_exhausted").inc()
                obs.event("retry_exhausted", attempt=attempt,
                          error=type(e).__name__,
                          deadline_hit=bool(out_of_time))
                raise
            obs.counter("robust.retry").inc()
            obs.event("retry", attempt=attempt, error=type(e).__name__)
            if on_retry is not None:
                on_retry(attempt, e)
            delay = backoff_s * (2 ** attempt)
            if jitter:
                delay = float(rng.uniform(0.0, delay))
            if deadline_s is not None:
                delay = min(delay, max(0.0, deadline_s - elapsed))
            clock.sleep(delay)
    raise last  # unreachable; keeps type checkers honest
