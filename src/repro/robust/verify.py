"""Structural self-checks over succinct structures.

The paper's central redundancy — rank directories, select samples,
per-level zero counts, C tables, and SA-sample directories are all
*derivable* from the underlying bitmaps — is what makes these checks
possible without any reference data: every derived structure is
recomputed (or an exact invariant of it is) and compared against what
the snapshot holds. A mismatch localizes corruption to one structure of
one level of one shard, and classifies it:

* ``derived=True``  — repairable in place by ``robust.repair`` (the
  source bitmap is intact, the directory is stale/corrupt);
* ``derived=False`` — primary data (the level bitmaps themselves, seam
  windows): only a rebuild from source tokens restores it.

Checks run in numpy on the host: verification is a restore-time /
incident-time path, not a query path, and host numpy keeps every check
an exact integer comparison with no tracing constraints.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import List

import jax
import numpy as np

from repro.core.rank_select import (BLOCK_BITS, BLOCK_WORDS,
                                    SUPERBLOCK_WORDS, BinaryRank,
                                    BinarySelect, BitVector)

_BLOCKS_PER_SB = SUPERBLOCK_WORDS // BLOCK_WORDS


@dataclass(frozen=True)
class Violation:
    """One failed invariant: where, what, and whether repair can fix it."""
    structure: str          # e.g. "shard3/level2/rank.superblock"
    kind: str               # invariant family, e.g. "rank_superblock"
    detail: str
    derived: bool = True    # recomputable from the bitmaps?

    def __str__(self) -> str:
        tag = "derived" if self.derived else "PRIMARY"
        return f"[{tag}] {self.structure}: {self.kind} — {self.detail}"


@dataclass
class VerifyReport:
    violations: List[Violation] = dc_field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def repairable(self) -> bool:
        """True iff every violation touches a derived (recomputable)
        structure — nothing requires a rebuild from source tokens."""
        return all(v.derived for v in self.violations)

    def add(self, structure: str, kind: str, detail: str,
            derived: bool = True) -> None:
        self.violations.append(Violation(structure, kind, detail, derived))

    def extend(self, other: "VerifyReport") -> None:
        self.violations.extend(other.violations)

    def summary(self) -> str:
        if self.ok:
            return "verify: OK"
        head = (f"verify: {len(self.violations)} violation(s), "
                f"{'all repairable' if self.repairable else 'REBUILD NEEDED'}")
        return "\n".join([head] + [f"  {v}" for v in self.violations[:16]])


def _np(x) -> np.ndarray:
    return np.asarray(x)


_POP8 = np.array([bin(i).count("1") for i in range(256)], np.uint8)


def _popcount32(words: np.ndarray) -> np.ndarray:
    """Vectorized per-word popcount via a byte table (host verification)."""
    v = np.ascontiguousarray(words.astype(np.uint32))
    return _POP8[v.view(np.uint8)].reshape(v.shape + (4,)) \
        .sum(axis=-1).astype(np.int64)


def _expected_rank_tables(words: np.ndarray):
    """Recompute Jacobson superblock/block tables from the bitmap words."""
    pc = _popcount32(words)
    prefix = np.concatenate([[0], np.cumsum(pc)[:-1]])
    superblock = prefix[::SUPERBLOCK_WORDS]
    blk_prefix = prefix[::BLOCK_WORDS]
    sb_of_blk = np.arange(blk_prefix.shape[0]) // _BLOCKS_PER_SB
    block = blk_prefix - superblock[sb_of_blk]
    return superblock.astype(np.uint32), block.astype(np.uint16)


def _expected_select_samples(words: np.ndarray, n: int, sample_rate: int,
                             zeros: bool) -> np.ndarray:
    """Recompute Clark sample hints (mirror of ``build_binary_select``)."""
    W = words.shape[0]
    nblk = (W + BLOCK_WORDS - 1) // BLOCK_WORDS
    pad = nblk * BLOCK_WORDS - W
    wp = np.concatenate([words, np.zeros(pad, np.uint32)]) if pad else words
    ones = _popcount32(wp.reshape(nblk, BLOCK_WORDS)).sum(axis=1)
    if zeros:
        valid = np.clip(n - np.arange(nblk) * BLOCK_BITS, 0, BLOCK_BITS)
        counts = valid - ones
    else:
        counts = ones
    cum = np.concatenate([[0], np.cumsum(counts)])
    num_samples = n // sample_rate + 2
    targets = np.arange(num_samples) * sample_rate
    return np.clip(np.searchsorted(cum, targets, side="right") - 1,
                   0, nblk - 1).astype(np.int32)


def _padding_bits_zero(words: np.ndarray, n: int) -> bool:
    """Bits at positions ≥ n must be 0 (every directory build assumes it)."""
    W = words.shape[0]
    nbits_cap = W * 32
    if n >= nbits_cap:
        return True
    mask = np.zeros(W * 32, bool)
    mask[n:] = True
    bits = np.unpackbits(
        np.ascontiguousarray(words).view(np.uint8), bitorder="little")
    return not bool(bits[mask].any())


def verify_binary_rank(rank: BinaryRank, name: str,
                       report: VerifyReport | None = None) -> VerifyReport:
    """Superblock/block tables must re-aggregate to bitmap popcounts."""
    report = report if report is not None else VerifyReport()
    words = _np(rank.words)
    if not _padding_bits_zero(words, rank.n):
        report.add(f"{name}.words", "padding_bits",
                   "nonzero bits past position n", derived=False)
    sb, blk = _expected_rank_tables(words)
    got_sb, got_blk = _np(rank.superblock), _np(rank.block)
    if got_sb.shape != sb.shape or not np.array_equal(got_sb, sb):
        report.add(f"{name}.rank.superblock", "rank_superblock",
                   "does not re-aggregate to bitmap popcounts")
    if got_blk.shape != blk.shape or not np.array_equal(got_blk, blk):
        report.add(f"{name}.rank.block", "rank_block",
                   "does not re-aggregate to bitmap popcounts")
    return report


def verify_binary_select(rank: BinaryRank, sel: BinarySelect, name: str,
                         report: VerifyReport | None = None) -> VerifyReport:
    """Every sample must point at the block containing its target bit."""
    report = report if report is not None else VerifyReport()
    want = _expected_select_samples(_np(rank.words), sel.n, sel.sample_rate,
                                    sel.zeros)
    got = _np(sel.sample)
    if got.shape != want.shape or not np.array_equal(got, want):
        which = "sel0" if sel.zeros else "sel1"
        report.add(f"{name}.{which}.sample", "select_sample",
                   "sample hints disagree with recomputed block positions")
    return report


def verify_bitvector(bv: BitVector, name: str,
                     report: VerifyReport | None = None) -> VerifyReport:
    report = report if report is not None else VerifyReport()
    verify_binary_rank(bv.rank, name, report)
    verify_binary_select(bv.rank, bv.sel1, name, report)
    verify_binary_select(bv.rank, bv.sel0, name, report)
    return report


def _level_bv(bitvectors: BitVector, l: int) -> BitVector:
    return jax.tree.map(lambda x: x[l], bitvectors)


def verify_wavelet_matrix(wm, name: str = "wm",
                          report: VerifyReport | None = None) -> VerifyReport:
    """All per-level directories + ``zeros`` must derive from the bitmaps.

    Structural checks alone cannot always tell a stale directory from a
    corrupt bitmap (the recomputation disagrees either way), so
    attribution uses the violation *pattern*: single-leaf directory
    corruption can make at most ONE derived family of a level disagree,
    while bitmap corruption typically breaks several at once (``zeros``
    always, rank/select tables usually). ≥2 families off → the level's
    bitmap is the common cause (primary, rebuild). The residual ambiguity
    of a one-family mismatch is why snapshots ALSO carry per-leaf
    checksums — the checksum names the corrupted leaf exactly, and
    ``load_analytics`` re-verifies any repair against them.
    """
    report = report if report is not None else VerifyReport()
    zeros = _np(wm.zeros)
    if zeros.shape != (wm.nbits,):
        report.add(f"{name}.zeros", "shape",
                   f"expected ({wm.nbits},), got {zeros.shape}")
        return report
    for l in range(wm.nbits):
        bv = _level_bv(wm.bitvectors, l)
        lname = f"{name}/level{l}"
        sub = VerifyReport()
        verify_bitvector(bv, lname, sub)
        ones = int(_popcount32(_np(bv.rank.words)).sum())
        if int(zeros[l]) != wm.n - ones:
            sub.add(f"{name}.zeros[{l}]", "zeros",
                    f"stored {int(zeros[l])}, bitmap says {wm.n - ones}")
        fams = {v.kind for v in sub.violations
                if v.kind in ("rank_superblock", "rank_block",
                              "select_sample", "zeros")}
        if len(fams) >= 2:
            report.add(f"{lname}.words", "bitmap_suspect",
                       f"{len(fams)} independent derived families disagree "
                       "with this level's bitmap at once — the bitmap "
                       "itself is the likely corruption", derived=False)
        else:
            report.extend(sub)
    return report


def verify_wavelet_tree(wt, name: str = "wt",
                        report: VerifyReport | None = None) -> VerifyReport:
    """Wavelet-tree invariants: per-level directories + ``node_starts``
    rows monotone non-decreasing, row 0 starting at 0, all entries in
    [0, n]."""
    report = report if report is not None else VerifyReport()
    for l in range(wt.nbits):
        verify_bitvector(_level_bv(wt.bitvectors, l), f"{name}/level{l}",
                         report)
    ns = _np(wt.node_starts)
    if ns[0, 0] != 0:
        report.add(f"{name}.node_starts", "node_starts_origin",
                   f"row 0 starts at {int(ns[0, 0])}, want 0")
    if ns.min() < 0 or ns.max() > wt.n:
        report.add(f"{name}.node_starts", "node_starts_range",
                   "entries outside [0, n]")
    for l in range(ns.shape[0]):
        row = ns[l, :max(1, min(1 << l, ns.shape[1]))]
        if np.any(np.diff(row) < 0):
            report.add(f"{name}.node_starts[{l}]", "node_starts_monotone",
                       "row not non-decreasing")
    return report


def verify_fm_index(fm, name: str = "fm",
                    report: VerifyReport | None = None) -> VerifyReport:
    """FM-index invariants (paper Section 2 redundancy):

    * wavelet-matrix directory checks over the BWT bitmaps;
    * ``C[]`` must be the exclusive cumsum of the symbol histogram the
      bitmaps themselves encode (recovered via ``wm_access``);
    * the mark directory must hold exactly ceil(m/rate) set bits and
      re-aggregate like any rank directory;
    * ``sa_sample`` must be a permutation of {0, rate, 2·rate, …} — the
      sampled SA positions are a fixed set regardless of row order.
    """
    from repro.core.wavelet_matrix import wm_access
    report = report if report is not None else VerifyReport()
    verify_wavelet_matrix(fm.wm, f"{name}/wm", report)
    m = fm.m
    # C table vs the symbol histogram encoded by the bitmaps
    syms = _np(wm_access(fm.wm, np.arange(m, dtype=np.int32)))
    hist = np.bincount(syms, minlength=fm.sigma + 1)[:fm.sigma + 1]
    want_C = np.concatenate([[0], np.cumsum(hist)]).astype(np.int64)
    got_C = _np(fm.C).astype(np.int64)
    if got_C.shape != want_C.shape or not np.array_equal(got_C, want_C):
        report.add(f"{name}.C", "c_table",
                   "C[] inconsistent with bitmap-derived symbol histogram")
    # mark directory
    verify_binary_rank(fm.mark, f"{name}/mark", report)
    num_samples = (m + fm.sample_rate - 1) // fm.sample_rate
    marked = int(_popcount32(_np(fm.mark.words)).sum())
    if marked != num_samples:
        report.add(f"{name}.mark", "mark_count",
                   f"{marked} marked rows, want {num_samples}")
    # sa_sample multiset
    got = np.sort(_np(fm.sa_sample))
    want = np.arange(num_samples) * fm.sample_rate
    if got.shape != want.shape or not np.array_equal(got, want):
        report.add(f"{name}.sa_sample", "sa_sample_multiset",
                   "values are not exactly {0, rate, 2·rate, …}")
    return report


def _shard_tree(stacked, s: int):
    return jax.tree.map(lambda l: l[s], stacked)


def verify_analytics(engine, report: VerifyReport | None = None
                     ) -> VerifyReport:
    """Structural verification of every shard of a ``ShardedAnalytics``."""
    report = report if report is not None else VerifyReport()
    for s in range(engine.num_shards):
        verify_wavelet_matrix(_shard_tree(engine.shards, s), f"shard{s}",
                              report)
    if engine.available is not None:
        av = _np(engine.available)
        if av.shape != (engine.num_shards,):
            report.add("available", "mask_shape",
                       f"mask shape {av.shape} vs {engine.num_shards} shards")
    return report


def verify_manifest(ingest_dir, report: VerifyReport | None = None,
                    deep: bool = True) -> VerifyReport:
    """Self-checks over an ingest directory's journaled shard manifest.

    The manifest is the write path's source of truth, so its invariants
    get the same treatment the serving structures get — recompute what
    each record claims and classify every violation:

    * **journal integrity** — a torn tail (single crashed append) is
      repairable (recovery drops it and upstream re-appends); a bad line
      before the tail is fatal corruption;
    * **generation monotonicity** — every INTENT/QUARANTINE must
      introduce a strictly increasing generation (the journal is a total
      order of the stream); violation is fatal;
    * **COMMIT ⇒ shard exists** — a committed generation whose file is
      missing is acked data loss: fatal;
    * **COMMIT ⇒ checksums agree** — ``deep=True`` re-hashes every
      committed shard file against its INTENT ``leaf_crc32`` map;
      disagreement is *repairable by re-append* (upstream replays the
      generation under a fresh gen — recovery quarantines it meanwhile);
    * **dangling INTENT** — an unresolved INTENT (no COMMIT/ABORT) means
      recovery has not run yet: repairable.
    """
    from pathlib import Path

    from repro.ingest.journal import (MANIFEST_NAME, JournalCorrupt,
                                      read_journal, replay)
    report = report if report is not None else VerifyReport()
    ingest_dir = Path(ingest_dir)
    journal = ingest_dir / MANIFEST_NAME
    try:
        records, torn = read_journal(journal, strict=True)
    except JournalCorrupt as e:
        report.add("manifest.jsonl", "journal_corrupt",
                   f"line {e.lineno}: {e.why} (before the tail — not a "
                   "crash artifact)", derived=False)
        records, torn = read_journal(journal, strict=False)
    if torn:
        report.add("manifest.jsonl", "journal_torn_tail",
                   "last line incomplete or checksum-failing — crashed "
                   "append; replay drops it")
    last_intro = -1
    for i, rec in enumerate(records):
        if rec["type"] in ("INTENT", "QUARANTINE") \
                and rec.get("gen", -1) not in \
                {r.get("gen") for r in records[:i]
                 if r["type"] in ("INTENT", "QUARANTINE")}:
            gen = int(rec.get("gen", -1))
            if gen <= last_intro:
                report.add(f"manifest.jsonl[{i}]", "generation_monotonicity",
                           f"record introduces gen {gen} after gen "
                           f"{last_intro}", derived=False)
            last_intro = max(last_intro, gen)
    st = replay(records, torn_tail=torn)
    shards_dir = ingest_dir / "shards"
    for e in st.committed:
        path = shards_dir / (e.file or "")
        if not e.file or not path.exists():
            report.add(f"gen{e.gen}", "commit_missing_shard",
                       f"COMMIT recorded but {e.file!r} is absent — acked "
                       "data loss", derived=False)
            continue
        if not deep:
            continue
        try:
            with np.load(path) as z:
                arrays = {k: z[k] for k in z.files}
        except Exception:                                 # noqa: BLE001
            report.add(f"gen{e.gen}", "commit_shard_unreadable",
                       f"{e.file} is not a readable npz — re-append")
            continue
        from repro.robust.integrity import verify_flat
        bad = verify_flat(arrays, e.leaf_crc32)
        if bad:
            report.add(f"gen{e.gen}", "commit_checksum_mismatch",
                       f"{len(bad)} leaf/leaves disagree with the INTENT "
                       f"crc32 map ({bad[0]}, …) — re-append")
    for e in st.pending:
        report.add(f"gen{e.gen}", "dangling_intent",
                   "INTENT without COMMIT/ABORT — recovery has not "
                   "replayed this journal yet")
    return report


def verify_sharded_index(idx, report: VerifyReport | None = None
                         ) -> VerifyReport:
    """Structural verification of every shard of a ``ShardedTextIndex``
    (+ seam-window range sanity — seam windows are primary data)."""
    report = report if report is not None else VerifyReport()
    for s in range(idx.num_shards):
        verify_fm_index(_shard_tree(idx.shards, s), f"shard{s}", report)
    seams = _np(idx.seam_windows)
    if seams.size and (seams.min() < -2 or seams.max() >= idx.sigma):
        report.add("seam_windows", "seam_range",
                   "window symbols outside [-2, sigma)", derived=False)
    return report
