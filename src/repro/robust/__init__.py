"""Fault-tolerance subsystem: integrity-verified, self-healing serving.

The paper's redundancy — every rank/select directory, select sample,
zero count, C table, and SA-sample directory is derivable from the
underlying bitmaps — turned into an operational property:

* ``integrity`` — per-leaf crc32 recorded in every snapshot's
  ``meta.json`` and re-verified on restore (``IntegrityError`` names the
  corrupted leaves).
* ``verify``    — structural self-checks that recompute each derived
  structure from the bitmaps and classify violations as repairable
  (derived) vs rebuild-needed (primary).
* ``repair``    — recomputation of corrupted derived leaves through the
  original builders: a successful repair is bit-identical to the
  pre-fault engine.
* ``faults``    — seedable chaos harness (leaf bit-flips, snapshot
  truncation/deletion, stale partial writes, per-shard latency) +
  bounded retry/backoff.
* ``clock``     — the one injectable monotonic ``Clock`` every deadline
  in the stack (retry budgets, ingest build deadlines, front-end
  request deadlines) measures against; ``FakeClock`` for tests.

Degraded-mode serving (per-shard availability masks, coverage-reported
answers) lives on the engines themselves — ``analytics.engine`` and
``index.sharded``.
"""
from .clock import SYSTEM_CLOCK, Clock, FakeClock
from .faults import (CrashInjected, corrupt_snapshot_leaf, crash_after,
                     check_crash_point, delete_file, delete_step,
                     flip_leaf_bit, inject_partial_tmp,
                     inject_shard_latency, shard_latency, truncate_file,
                     with_retry)
from .integrity import (IntegrityError, checksum_array, checksum_flat,
                        tree_checksums, trees_identical, verify_flat)
from .repair import (classify_bad_keys, is_primary_key, repair_analytics,
                     repair_fm_index, repair_sharded_index,
                     repair_wavelet_matrix, repair_wavelet_tree)
from .verify import (VerifyReport, Violation, verify_analytics,
                     verify_binary_rank, verify_binary_select,
                     verify_bitvector, verify_fm_index, verify_manifest,
                     verify_sharded_index, verify_wavelet_matrix,
                     verify_wavelet_tree)

__all__ = [
    "IntegrityError", "checksum_array", "checksum_flat", "tree_checksums",
    "trees_identical", "verify_flat",
    "VerifyReport", "Violation", "verify_analytics", "verify_binary_rank",
    "verify_binary_select", "verify_bitvector", "verify_fm_index",
    "verify_manifest", "verify_sharded_index", "verify_wavelet_matrix",
    "verify_wavelet_tree",
    "classify_bad_keys", "is_primary_key", "repair_analytics",
    "repair_fm_index", "repair_sharded_index", "repair_wavelet_matrix",
    "repair_wavelet_tree",
    "CrashInjected", "corrupt_snapshot_leaf", "crash_after",
    "check_crash_point", "delete_file", "delete_step", "flip_leaf_bit",
    "inject_partial_tmp", "inject_shard_latency", "shard_latency",
    "truncate_file", "with_retry",
    "Clock", "FakeClock", "SYSTEM_CLOCK",
]
