"""Shard-parallel full-text index: FM-index per shard, stacked leaf-wise.

Mirrors ``CompressedCorpus``'s layout exactly: every shard's ``FMIndex``
pytree has identical static geometry (power-of-two shard size, shared
alphabet), so the shards stack leaf-wise into ONE pytree with a leading
``(num_shards,)`` axis, and a batch of patterns against all shards is a
single ``vmap``-over-shards of the vmapped-over-patterns backward search —
one jitted kernel for the whole corpus.

The last shard is padded with the out-of-alphabet symbol σ (indexed with an
alphabet of σ+1), which cannot appear in a query, so padding never produces
phantom matches.

Cross-shard stitching: per-shard FM-indexes alone cannot see a match that
*spans a shard boundary*. ``count`` therefore adds a seam pass: every
internal boundary stores a ±``seam_overlap``-token window of the raw
stream, and a vectorized sliding compare counts the matches that genuinely
cross the boundary (within-shard matches are excluded by the crossing
condition, so nothing is double-counted). Counts are exact for pattern
lengths ≤ min(seam_overlap + 1, shard_size). ``locate`` still reports
within-shard positions only — seam hits are count-only for now (ROADMAP).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.data.shard_build import build_shards_stacked

from .fm_index import FMIndex, build_fm_index, fm_count, fm_locate

_I32 = jnp.int32

#: filler for seam-window slots outside the corpus. Distinct from the -1
#: that pattern sanitization emits, so masked query symbols can never
#: "match" masked window slots.
_SEAM_PAD = -2


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class ShardedTextIndex:
    """Stacked per-shard FM-indexes + seam windows + corpus geometry."""
    shards: FMIndex                # every leaf has a leading (S,) axis
    seam_windows: jax.Array        # (S-1, 2·seam_overlap) int32, _SEAM_PAD
    #                                filled outside [0, n)
    n: int = field(metadata=dict(static=True))       # true corpus length
    sigma: int = field(metadata=dict(static=True))   # raw vocab size
    shard_bits: int = field(metadata=dict(static=True))
    seam_overlap: int = field(metadata=dict(static=True))
    #: (S,) bool per-shard availability, or None for full availability.
    #: Degraded mode: unavailable shards contribute 0 within-shard matches,
    #: seams touching them are skipped, and their locate hits are masked —
    #: ``coverage()`` / ``count_bounds`` report how much corpus is served.
    available: jax.Array | None = None

    @property
    def shard_size(self) -> int:
        return 1 << self.shard_bits

    @property
    def num_shards(self) -> int:
        return jax.tree.leaves(self.shards)[0].shape[0]

    @property
    def degraded(self) -> bool:
        return self.available is not None

    # ---- availability management -------------------------------------
    def with_availability(self, available) -> "ShardedTextIndex":
        """Index serving only the shards where ``available`` is True
        (``None`` restores full availability)."""
        if available is not None:
            available = jnp.asarray(available, bool)
            if available.shape != (self.num_shards,):
                raise ValueError(
                    f"availability mask shape {available.shape} != "
                    f"({self.num_shards},)")
        return dataclasses.replace(self, available=available)

    def drop_shards(self, shard_ids) -> "ShardedTextIndex":
        """Mark the given shard indices unavailable (cumulative)."""
        mask = (jnp.ones((self.num_shards,), bool)
                if self.available is None else self.available)
        mask = mask.at[jnp.asarray(shard_ids, _I32)].set(False)
        return dataclasses.replace(self, available=mask)

    def _shard_sizes(self) -> jax.Array:
        """(S,) true (unpadded) token count of each shard."""
        starts = jnp.arange(self.num_shards, dtype=_I32) << self.shard_bits
        return jnp.clip(jnp.asarray(self.n, _I32) - starts, 0,
                        self.shard_size)

    def coverage(self) -> jax.Array:
        """Fraction of corpus positions on available shards (float32)."""
        if self.available is None:
            return jnp.float32(1.0)
        covered = jnp.sum(jnp.where(self.available, self._shard_sizes(), 0))
        return covered.astype(jnp.float32) / jnp.float32(max(1, self.n))

    def shard(self, s: jax.Array) -> FMIndex:
        return jax.tree.map(lambda l: l[s], self.shards)

    def probe_shard(self, s: int, clock=None) -> bool:
        """Liveness probe of one shard: a minimal single-shard backward
        search that honours any chaos-armed ``robust.faults.shard_latency``
        stall (slept on the injectable ``clock``). The serving front-end's
        circuit breakers hedge these probes under a timeout so a stuck
        shard degrades coverage instead of stalling the queue. Returns
        True on success.
        """
        from repro.robust.clock import SYSTEM_CLOCK
        from repro.robust.faults import shard_latency
        clock = clock if clock is not None else SYSTEM_CLOCK
        delay = shard_latency(s)
        if delay > 0:
            clock.sleep(delay)
        fm = self.shard(int(s))
        pat = jnp.zeros((1, 1), _I32)
        out = fm_count(fm, pat, jnp.ones((1,), _I32))
        return bool(jax.block_until_ready(out)[0] >= 0)

    # ---- incremental ingest / hot swap -------------------------------
    def add_shards(self, new_shards: FMIndex, new_seams: jax.Array,
                   added_tokens: int, new_available=None
                   ) -> "ShardedTextIndex":
        """Next-generation index with ``new_shards`` appended.

        ``new_shards``: stacked ``(K,)``-leaf FM-index pytree with this
        index's static geometry. ``new_seams``: the ``(K, 2·seam_overlap)``
        boundary windows *preceding* each new shard (the seam between the
        old tail and the first new shard, then between consecutive new
        shards — ``ingest.ShardIngester.seam_windows`` derives them from
        the journaled head/tail sidecars). ``added_tokens`` is the true
        token count added (only the final shard may be partial — the old
        corpus must end on a shard boundary). ``new_available`` masks
        quarantined shards. The result is a new value; publish it through
        ``GenerationServer.swap_generation`` for epoch-fenced hot swap.
        """
        if self.n != self.num_shards << self.shard_bits:
            raise ValueError(
                f"cannot append to an index with a partial tail shard "
                f"(n={self.n}, {self.num_shards} shards of "
                f"{self.shard_size})")
        K = jax.tree.leaves(new_shards)[0].shape[0]
        added_tokens = int(added_tokens)
        if not ((K - 1) << self.shard_bits) < added_tokens \
                <= (K << self.shard_bits):
            raise ValueError(
                f"added_tokens={added_tokens} does not fill {K} shard(s) "
                f"of {self.shard_size}")
        new_seams = jnp.asarray(new_seams, _I32)
        if new_seams.shape != (K, 2 * self.seam_overlap):
            raise ValueError(
                f"new_seams shape {new_seams.shape} != "
                f"({K}, {2 * self.seam_overlap})")
        merged = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                              self.shards, new_shards)
        seams = jnp.concatenate([self.seam_windows, new_seams], axis=0)
        if self.available is None and new_available is None:
            mask = None
        else:
            old = (jnp.ones((self.num_shards,), bool)
                   if self.available is None else self.available)
            new = (jnp.ones((K,), bool) if new_available is None
                   else jnp.asarray(new_available, bool).reshape((K,)))
            mask = jnp.concatenate([old, new])
            if bool(jnp.all(mask)):
                mask = None
        obs.counter("ingest.shard_swap", layer="index").inc()
        return dataclasses.replace(self, shards=merged, seam_windows=seams,
                                   n=self.n + added_tokens, available=mask)

    def bits_per_token(self) -> float:
        total = sum(l.size * l.dtype.itemsize * 8
                    for l in jax.tree.leaves(self.shards))
        return total / max(1, self.n)

    # ------------------------------------------------------------------
    def _sanitize(self, patterns: jax.Array, lengths: jax.Array):
        """Coerce shapes and mask symbols outside the *corpus* vocabulary.

        Shards are indexed with the widened alphabet σ+1 (pad symbol σ is
        in-alphabet for the per-shard FM-index), so out-of-vocab query
        symbols — σ included — are rewritten to -1 here, which the
        backward search treats as match-nothing. Without this, a query
        containing σ would count the tail shard's padding. Zero-length
        patterns become a 1-symbol match-nothing pattern: the empty query
        counts 0 at this layer (an unrestricted SA range over every shard
        — padding included — is never what a corpus caller wants).
        """
        patterns = jnp.atleast_2d(jnp.asarray(patterns, _I32))
        lengths = jnp.atleast_1d(jnp.asarray(lengths, _I32))
        in_vocab = (patterns >= 0) & (patterns < self.sigma)
        patterns = jnp.where(in_vocab, patterns, jnp.asarray(-1, _I32))
        empty = lengths <= 0
        patterns = patterns.at[:, 0].set(
            jnp.where(empty, jnp.asarray(-1, _I32), patterns[:, 0]))
        return patterns, jnp.where(empty, 1, lengths)

    def count(self, patterns: jax.Array, lengths: jax.Array) -> jax.Array:
        """Total matches per pattern, (B,) int32 — within-shard matches
        from the FM-indexes plus boundary-crossing matches from the seam
        windows. Exact for lengths ≤ min(seam_overlap + 1, shard_size).
        On a degraded index this counts surviving shards only (a lower
        bound on the true count — ``count_bounds`` brackets it)."""
        obs.counter("index.op", op="count",
                    path="degraded" if self.degraded else "full").inc()
        patterns = jnp.atleast_2d(jnp.asarray(patterns, _I32))
        within = jnp.sum(self.count_by_shard(patterns, lengths), axis=0)
        return within + self._seam_count(*self._sanitize(patterns, lengths))

    def count_bounds(self, patterns: jax.Array, lengths: jax.Array):
        """(lower, upper, coverage) bracketing the full-corpus count.

        ``lower`` is the degraded ``count``. Every missed match either
        starts on an unavailable shard (≤ its position count) or crosses
        a skipped seam (≤ length−1 starts per seam), so
        ``upper = lower + unavailable_positions + skipped_seams·(len−1)``.
        Fully-available indexes return lower == upper, coverage 1.0.
        """
        obs.counter("index.op", op="count_bounds",
                    path="degraded" if self.degraded else "full").inc()
        lower = self.count(patterns, lengths)
        if self.available is None:
            return lower, lower, jnp.float32(1.0)
        uncovered = jnp.sum(
            jnp.where(self.available, 0, self._shard_sizes()))
        seam_ok = self.available[:-1] & self.available[1:]
        skipped = jnp.sum(~seam_ok).astype(_I32)
        lengths = jnp.atleast_1d(jnp.asarray(lengths, _I32))
        extra = uncovered + skipped * jnp.maximum(lengths - 1, 0)
        return lower, lower + extra, self.coverage()

    def _seam_count(self, patterns: jax.Array,
                    lengths: jax.Array) -> jax.Array:
        """(B,) matches that cross a shard boundary (sanitized inputs).

        A length-l match at window offset o of a seam (boundary at window
        center ov) crosses iff o < ov < o + l; the sliding compare is one
        broadcast equality over (B patterns × seams × offsets × positions).
        Patterns longer than the exactness domain min(ov+1, shard_size)
        contribute 0 here (their count stays within-shard-only) rather
        than a partial crossing count: beyond ov+1 the window cannot hold
        every crossing start, and beyond shard_size a match could cross
        two seams and double-count.
        """
        ns, width = self.seam_windows.shape
        ov = self.seam_overlap
        B, L = patterns.shape
        if ns == 0 or ov == 0:
            return jnp.zeros((B,), _I32)
        lmax = min(ov + 1, self.shard_size)
        o = jnp.arange(width, dtype=_I32)                       # offsets
        t = jnp.arange(L, dtype=_I32)                           # positions
        idx = jnp.minimum(o[:, None] + t[None, :], width - 1)   # (O, L)
        win = self.seam_windows[:, idx]                         # (ns, O, L)
        pat = patterns[:, None, None, :]                        # (B,1,1,L)
        past_len = (t[None, :] >= lengths[:, None])[:, None, None, :]
        hit = jnp.all((win[None] == pat) | past_len, axis=-1)   # (B, ns, O)
        ol = o[None, :] + lengths[:, None]                      # (B, O)
        span = ((o[None, :] < ov) & (ol > ov) & (ol <= width)
                & (lengths[:, None] <= lmax))[:, None, :]
        crossing = hit & span
        if self.available is not None:
            # seam s spans shards s and s+1 — both must be available
            seam_ok = self.available[:-1] & self.available[1:]
            crossing = crossing & seam_ok[None, :, None]
        return jnp.sum(crossing, axis=(1, 2)).astype(_I32)

    def count_by_shard(self, patterns: jax.Array,
                       lengths: jax.Array) -> jax.Array:
        """(S, B) per-shard match counts (distribution analytics).

        One vmap over the stacked shard axis of the per-shard batched
        backward search. Unavailable shards report 0.
        """
        patterns, lengths = self._sanitize(patterns, lengths)
        per = jax.vmap(lambda fm: fm_count(fm, patterns, lengths))(
            self.shards)
        if self.available is not None:
            per = jnp.where(self.available[:, None], per, 0)
        return per

    def locate(self, patterns: jax.Array, lengths: jax.Array,
               max_hits_per_shard: int = 8) -> jax.Array:
        """Global match positions, (B, S·max_hits_per_shard) int32.

        Per-shard local hits are rebased by ``s · shard_size``; slots past
        each shard's true hit count are -1. Sorted ascending per pattern
        with the -1 padding swept to the back.
        """
        obs.counter("index.op", op="locate",
                    path="degraded" if self.degraded else "full").inc()
        patterns, lengths = self._sanitize(patterns, lengths)
        S = self.num_shards

        def per_shard(fm, base, ok):
            def one(p, l):
                local = fm_locate(fm, p, l, max_hits_per_shard)
                return jnp.where(ok & (local >= 0), local + base,
                                 jnp.asarray(-1, _I32))
            return jax.vmap(one)(patterns, lengths)        # (B, H)

        bases = jnp.arange(S, dtype=_I32) << self.shard_bits
        ok = (jnp.ones((S,), bool) if self.available is None
              else jnp.asarray(self.available, bool))
        hits = jax.vmap(per_shard)(self.shards, bases, ok)  # (S, B, H)
        flat = jnp.transpose(hits, (1, 0, 2)).reshape(patterns.shape[0], -1)
        big = jnp.where(flat < 0, jnp.asarray(jnp.iinfo(jnp.int32).max,
                                              _I32), flat)
        out = jnp.sort(big, axis=-1)
        return jnp.where(out == jnp.iinfo(jnp.int32).max,
                         jnp.asarray(-1, _I32), out)


def seam_windows_from_tokens(tokens: np.ndarray, num_shards: int,
                             shard_size: int, seam_overlap: int) -> np.ndarray:
    """(num_shards-1, 2·seam_overlap) raw-stream windows around each
    internal shard boundary, ``_SEAM_PAD``-filled outside [0, n)."""
    n = len(tokens)
    ns = max(0, num_shards - 1)
    width = 2 * seam_overlap
    win = np.full((ns, width), _SEAM_PAD, np.int32)
    for s in range(ns):
        p = (s + 1) * shard_size
        g0 = p - seam_overlap
        for o in range(width):
            g = g0 + o
            if 0 <= g < n:
                win[s, o] = tokens[g]
    return win


def build_sharded_index(tokens: np.ndarray, sigma: int, *,
                        shard_bits: int = 14, sample_rate: int = 32,
                        tau: int = 8, big_step: str = "compose",
                        bv_sample_rate: int = 512,
                        backend: str = "counting",
                        seam_overlap: int = 15,
                        parallel: str | bool = "auto") -> ShardedTextIndex:
    """Shard the token stream and run the full per-shard build pipeline
    (suffix array → BWT → wavelet matrix → SA samples) on every shard,
    stacking the resulting pytrees leaf-wise.

    Shard builds fan out over the device mesh via ``data.shard_build``
    (pmap across devices, vmap on one device when ``parallel=True``, else
    the sequential loop with its per-shard early exits). The tail shard is
    padded with the out-of-alphabet symbol σ. ``seam_overlap`` sets the
    half-width of the boundary windows that make ``count`` exact across
    shard seams for pattern lengths ≤ seam_overlap + 1 (0 disables).
    """
    n = int(len(tokens))
    shard_size = 1 << shard_bits
    num_shards = max(1, (n + shard_size - 1) // shard_size)
    pad = num_shards * shard_size - n
    toks = np.asarray(tokens, np.int64)
    if toks.size and (toks.min() < 0 or toks.max() >= sigma):
        raise ValueError(f"tokens outside [0, {sigma})")
    if pad:
        toks = np.concatenate([toks, np.full(pad, sigma, np.int64)])
    shards_np = toks.reshape(num_shards, shard_size)

    stacked = build_shards_stacked(
        lambda s: build_fm_index(s.astype(_I32), sigma + 1,
                                 sample_rate=sample_rate, tau=tau,
                                 big_step=big_step,
                                 bv_sample_rate=bv_sample_rate,
                                 backend=backend),
        shards_np, parallel=parallel)
    seams = seam_windows_from_tokens(np.asarray(tokens, np.int64),
                                     num_shards, shard_size, seam_overlap)
    return ShardedTextIndex(shards=stacked, seam_windows=jnp.asarray(seams),
                            n=n, sigma=sigma, shard_bits=shard_bits,
                            seam_overlap=seam_overlap)
