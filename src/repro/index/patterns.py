"""Query-pattern sampling shared by the index CLI, benches and examples.

Draws padded fixed-width pattern batches from a token stream: mostly real
substrings (guaranteed ≥1 within-shard match) with an optional fraction of
random patterns (miss-heavy traffic). Lengths are clamped to the corpus so
degenerate configs (pattern budget longer than the text) stay valid.
"""
from __future__ import annotations

import numpy as np


def sample_patterns(toks: np.ndarray, num: int, max_len: int, pad: int,
                    seed: int = 1, miss_every: int | None = 4,
                    min_len: int = 1):
    """(num, max_len) int32 padded patterns + (num,) true lengths.

    Every ``miss_every``-th pattern is uniform-random over the observed
    vocabulary (usually a miss); the rest are substrings of ``toks``.
    ``miss_every=None`` samples substrings only.
    """
    rng = np.random.default_rng(seed)
    pats = np.full((num, max_len), pad, np.int32)
    lens = rng.integers(min_len, max_len + 1, num).astype(np.int32)
    lens = np.minimum(lens, max(1, len(toks) - 1))
    vocab = int(toks.max()) + 1
    for i in range(num):
        if miss_every is not None and i % miss_every == miss_every - 1:
            pats[i, :lens[i]] = rng.integers(0, vocab, lens[i])
        else:
            s = int(rng.integers(0, len(toks) - lens[i]))
            pats[i, :lens[i]] = toks[s:s + lens[i]]
    return pats, lens
