"""Parallel suffix array construction by prefix doubling.

The classic Manber–Myers / Larsson–Sadakane prefix-doubling algorithm maps
exactly onto the paper's parallel toolkit: each doubling round is **one
stable integer sort** over (rank, rank-at-offset) pairs plus **one prefix
sum** to re-rank — the same two primitives (stable counting sort via prefix
sums, Section 2) that drive the wavelet-tree construction. Work is
O(n log n) sorts overall and every round is a fixed dataflow of histograms,
scans and gathers, so the whole build is jittable with static shapes.

TPU realization:

* The pair sort is two LSD passes of ``core.sort.radix_sort_stable`` (sort
  by the offset rank, then stably by the head rank), each itself an LSD
  radix over ⌈log₂(n+2)⌉ bits in ``bits_per_pass``-bit digits — never a
  σ-sized histogram, so memory stays O(n + 2^bits_per_pass) per pass.
* Re-ranking is a neighbour-difference flag + inclusive prefix sum over the
  sorted pair keys (the standard "name assignment" step).
* The driver loop runs at most ⌈log₂ n⌉ rounds; outside of a trace it
  early-exits once all ranks are distinct (the usual 2–4 rounds for
  Zipfian token text).

Follow-up direction (ROADMAP): a DC3/skew O(n)-work construction; prefix
doubling was chosen first because it reuses ``radix_sort_stable`` verbatim.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sort import radix_sort_stable

_I32 = jnp.int32
_U32 = jnp.uint32


def _rank_bits(n: int) -> int:
    """Bits needed for a doubling-round key: ranks live in [0, n+1]."""
    return max(1, math.ceil(math.log2(n + 2)))


@functools.partial(jax.jit, static_argnames=("key_bits", "bits_per_pass",
                                             "backend"))
def doubling_round(rank: jax.Array, offset: jax.Array, key_bits: int,
                   bits_per_pass: int = 8,
                   backend: str = "counting"):
    """One prefix-doubling round: sort suffixes by the pair
    ``(rank[i], rank[i + offset])`` and assign dense new ranks.

    ``rank``: (n,) int32 current rank of each suffix (by its first
    ``offset`` characters). ``offset`` is a traced scalar so every round
    shares one compiled executable per (n, key_bits). Returns
    ``(sa, new_rank)`` where ``sa`` is the suffix order under the pair key
    and ``new_rank`` the dense re-ranking (suffix-indexed). Suffixes
    running past the end compare smallest, via a 0 sentinel after a +1
    shift.
    """
    n = rank.shape[0]
    idx = jnp.arange(n, dtype=_I32)
    r1 = rank + 1
    tail = idx + jnp.asarray(offset, _I32)
    r2 = jnp.where(tail < n, rank[jnp.minimum(tail, n - 1)] + 1, 0)

    # stable pair sort = LSD over the two components (secondary first)
    r2s, (idx1, r1s) = radix_sort_stable(
        r2.astype(_U32), key_bits, values=(idx, r1),
        bits_per_pass=bits_per_pass, backend=backend)
    r1f, (sa, r2f) = radix_sort_stable(
        r1s.astype(_U32), key_bits, values=(idx1, r2s),
        bits_per_pass=bits_per_pass, backend=backend)

    # name assignment: new rank = # of distinct smaller pairs
    neq = (r1f != jnp.roll(r1f, 1)) | (r2f != jnp.roll(r2f, 1))
    neq = neq.at[0].set(False)
    names = jnp.cumsum(neq.astype(_I32))
    new_rank = jnp.zeros((n,), _I32).at[sa].set(names, unique_indices=True)
    return sa, new_rank


def suffix_array(seq: jax.Array, sigma: int | None = None, *,
                 bits_per_pass: int = 8,
                 backend: str = "counting",
                 max_rounds: int | None = None) -> jax.Array:
    """Suffix array of ``seq``: ``sa[j]`` = start of the j-th smallest
    suffix ``seq[sa[j]:]``. Suffix comparison treats running off the end as
    smaller than any symbol (so with a unique smallest terminator appended
    this is the textbook SA).

    Host-side driver over jitted rounds; early-exits once ranks are all
    distinct. To call under ``jax.jit`` (or pmap shard builds over a
    mesh), pass ``sigma`` (alphabet size — symbols in [0, σ)) so the
    initial key width is static, and ``max_rounds`` to pin the trip count;
    both default to host-side introspection of the concrete input.
    """
    seq = jnp.asarray(seq)
    n = int(seq.shape[0])
    if n == 0:
        return jnp.zeros((0,), _I32)
    if n == 1:
        return jnp.zeros((1,), _I32)
    kb = _rank_bits(n)

    # round 0: rank by first character. The character alphabet can be wide
    # (σ up to token vocab), so rank-compress via one pair sort with
    # offset 0 degenerate form: sort by (char, char) is just sort by char.
    if sigma is None:
        sigma = int(jnp.max(seq)) + 1       # host sync — concrete input only
    sym_bits = max(1, math.ceil(math.log2(max(2, sigma))))
    idx = jnp.arange(n, dtype=_I32)
    syms, (order,) = radix_sort_stable(
        seq.astype(_U32), sym_bits, values=(idx,),
        bits_per_pass=bits_per_pass, backend=backend)
    neq = (syms != jnp.roll(syms, 1)).at[0].set(False)
    names = jnp.cumsum(neq.astype(_I32))
    rank = jnp.zeros((n,), _I32).at[order].set(names, unique_indices=True)
    sa = order

    rounds = max_rounds if max_rounds is not None else math.ceil(
        math.log2(n)) + 1
    offset = 1
    for _ in range(rounds):
        if offset >= n:
            break
        sa, rank = doubling_round(rank, offset, kb,
                                  bits_per_pass=bits_per_pass,
                                  backend=backend)
        offset *= 2
        if max_rounds is None and not isinstance(rank, jax.core.Tracer):
            if int(rank[sa[-1]]) == n - 1:   # all ranks distinct → done
                break
    return sa.astype(_I32)


def suffix_array_naive(seq: np.ndarray) -> np.ndarray:
    """O(n² log n) numpy oracle (same end-of-string convention)."""
    s = list(np.asarray(seq).tolist())
    order = sorted(range(len(s)), key=lambda i: s[i:])
    return np.asarray(order, np.int32)
