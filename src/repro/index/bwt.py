"""Burrows–Wheeler transform + C[] boundary table from the suffix array.

Given the suffix array of ``T·$`` ($ = unique smallest terminator), the BWT
is a single gather — ``bwt[j] = T$[(sa[j] − 1) mod m]`` — and the C table
(``C[c]`` = # of symbols < c) is a histogram + exclusive prefix sum, both
O(n) work / O(log n) depth with the paper's primitives.

Alphabet convention used by the whole index subsystem: raw symbols in
[0, σ) are shifted up by one and the terminator takes id 0, so the working
alphabet is [0, σ] and the wavelet matrix over the BWT has ⌈log₂(σ+1)⌉
levels. ``SENTINEL_SHIFT`` documents the +1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import exclusive_sum

from .suffix_array import suffix_array

_I32 = jnp.int32

#: raw symbol c is stored as c + SENTINEL_SHIFT; the terminator is 0.
SENTINEL_SHIFT = 1


def append_sentinel(seq: jax.Array) -> jax.Array:
    """``T → T'·$``: shift symbols up by one, append terminator id 0."""
    shifted = jnp.asarray(seq, _I32) + SENTINEL_SHIFT
    return jnp.concatenate([shifted, jnp.zeros((1,), _I32)])


def bwt_from_sa(text: jax.Array, sa: jax.Array) -> jax.Array:
    """``bwt[j] = text[(sa[j] - 1) mod len(text)]`` — one vectorized gather."""
    m = text.shape[0]
    prev = jnp.where(sa == 0, m - 1, sa - 1)
    return text[prev]


def symbol_boundaries(text: jax.Array, sigma_work: int) -> jax.Array:
    """C table over the working alphabet: ``C[c]`` = # of symbols < c.

    Returns shape (sigma_work + 1,) so ``C[c+1] - C[c]`` is the count of c
    and ``C[sigma_work]`` = m. Histogram + exclusive sum (paper Section 2).
    """
    hist = jnp.zeros((sigma_work,), _I32).at[
        jnp.asarray(text, _I32)].add(1, mode="drop")
    cum = exclusive_sum(hist)
    total = jnp.asarray(text.shape[0], _I32)
    return jnp.concatenate([cum, total[None]])


def bwt_encode(seq: jax.Array, sigma: int | None = None, *,
               backend: str = "counting"):
    """Full BWT pipeline for raw symbols in [0, σ).

    Returns ``(bwt, sa, C)`` over the working alphabet [0, σ]: ``sa`` is
    the suffix array of the terminated text (length n+1), ``bwt`` its
    Burrows–Wheeler transform, ``C`` the (σ+2,)-entry boundary table.
    """
    seq = jnp.asarray(seq)
    if sigma is None:
        sigma = int(jnp.max(seq)) + 1 if seq.size else 1
    sigma_work = sigma + SENTINEL_SHIFT
    text = append_sentinel(seq)
    sa = suffix_array(text, sigma_work, backend=backend)
    bwt = bwt_from_sa(text, sa)
    C = symbol_boundaries(text, sigma_work)
    return bwt, sa, C


def bwt_decode(bwt: jax.Array, C: jax.Array) -> jax.Array:
    """Invert the BWT by repeated LF-mapping (numpy-grade reference path;
    O(m) sequential — for tests and the CLI round-trip check, not serving).
    """
    import numpy as np
    b = np.asarray(bwt)
    m = len(b)
    Cn = np.asarray(C)
    # occ[j] = # of b[j] among b[:j]  (stable per-symbol arrival order)
    occ = np.zeros(m, np.int64)
    seen: dict = {}
    for j, c in enumerate(b):
        occ[j] = seen.get(int(c), 0)
        seen[int(c)] = occ[j] + 1
    lf = Cn[b] + occ
    out = np.empty(m, b.dtype)
    j = 0                              # row of the terminator-rotated text
    for t in range(m - 1, -1, -1):
        out[t] = b[j]
        j = lf[j]
    # out is T'·$ rotated so $ is last; strip terminator, undo the shift
    return jnp.asarray(out[out != 0] - SENTINEL_SHIFT, _I32)
