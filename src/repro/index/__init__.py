"""Succinct full-text index subsystem (suffix array → BWT → FM-index).

The serving consumer of the paper's structures: substring ``count`` /
``locate`` over sharded token corpora, where every backward-search step is
a wavelet-matrix ``rank`` and every locate step an ``access`` + ``rank``.

Build pipeline (all on the paper's primitives):

1. ``suffix_array``  — prefix doubling; each round = one stable integer
   sort (``core.sort.radix_sort_stable``) + one prefix-sum re-rank.
2. ``bwt_encode``    — BWT gather + C[] boundary table (histogram + scan).
3. ``build_fm_index``— wavelet matrix over the BWT (Theorem 4.5) +
   sampled-SA locate directories.
4. ``build_sharded_index`` — per-shard indexes stacked leaf-wise, so a
   pattern batch against the whole corpus is one vmapped query.
"""
from .bwt import (SENTINEL_SHIFT, append_sentinel, bwt_decode, bwt_encode,
                  bwt_from_sa, symbol_boundaries)
from .fm_index import FMIndex, build_fm_index, fm_count, fm_locate
from .patterns import sample_patterns
from .sharded import ShardedTextIndex, build_sharded_index
from .suffix_array import doubling_round, suffix_array, suffix_array_naive

__all__ = [
    "SENTINEL_SHIFT", "append_sentinel", "bwt_decode", "bwt_encode",
    "bwt_from_sa", "symbol_boundaries",
    "FMIndex", "build_fm_index", "fm_count", "fm_locate",
    "ShardedTextIndex", "build_sharded_index", "sample_patterns",
    "doubling_round", "suffix_array", "suffix_array_naive",
]
