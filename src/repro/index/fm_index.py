"""FM-index: backward search over a wavelet-matrix BWT (count + locate).

This is *the* workload the paper's structures exist for: every step of
backward search is two `rank` queries on the BWT's wavelet matrix, so a
batch of B patterns of length L issues 2·B·L rank calls — all independent,
all vmapped. The index is a frozen-dataclass pytree (arrays are leaves,
sizes static), so it crosses ``jax.jit`` boundaries and vmaps like any
other operand.

Structure (Ferragina–Manzini, wavelet-matrix occ as in Claude & Navarro):

* ``wm``       — WaveletMatrix over the BWT of ``T·$`` (working alphabet
                 [0, σ]; raw symbol c stored as c+1, terminator 0).
* ``C``        — boundary table, C[c] = # of BWT symbols < c.
* ``mark``/``sa_sample`` — Clark-style sampled suffix array for ``locate``:
                 rows j with sa[j] ≡ 0 (mod sample_rate) are marked in a
                 rank bitvector and their sa values stored compacted in row
                 order; a locate walks LF at most sample_rate−1 steps to a
                 marked row (each step = 1 access + 1 rank on the wavelet
                 matrix), then reads the sample. Space for samples is
                 O(m/sample_rate) words — the index stays succinct.

TPU adaptations: backward search runs as a ``lax.fori_loop`` over pattern
positions with padded fixed-length patterns (padding masked by a length
vector, so ragged batches are one jitted call); the LF walk in ``locate``
is a fixed ``sample_rate``-trip loop with a done-mask instead of a
data-dependent while, keeping the schedule static for the compiler.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core.rank_select import (BinaryRank, access_bit,
                                    build_binary_rank, rank1)
from repro.core import bitops
from repro.core.wavelet_matrix import (WaveletMatrix, build_wavelet_matrix,
                                       wm_access, wm_rank)

from .bwt import SENTINEL_SHIFT, bwt_encode

_I32 = jnp.int32


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class FMIndex:
    """Succinct full-text index over one text shard. All-array pytree."""
    wm: WaveletMatrix       # BWT wavelet matrix, m = n+1 positions
    C: jax.Array            # (sigma+2,) int32 symbol boundaries
    mark: BinaryRank        # m bits: row j marked iff sa[j] % sample_rate == 0
    sa_sample: jax.Array    # (ceil(m/sample_rate),) int32, compacted row order
    n: int = field(metadata=dict(static=True))        # text length (no $)
    sigma: int = field(metadata=dict(static=True))    # raw alphabet size
    sample_rate: int = field(metadata=dict(static=True))

    @property
    def m(self) -> int:
        return self.n + 1

    # ------------------------------------------------------------------
    # queries (thin wrappers over the module functions)
    # ------------------------------------------------------------------
    def count(self, patterns: jax.Array, lengths: jax.Array) -> jax.Array:
        return fm_count(self, patterns, lengths)

    def locate(self, pattern: jax.Array, length: jax.Array,
               max_hits: int = 16) -> jax.Array:
        return fm_locate(self, pattern, length, max_hits)

    def bits_per_symbol(self) -> float:
        total = sum(l.size * l.dtype.itemsize * 8
                    for l in jax.tree.leaves(self))
        return total / max(1, self.n)


def build_fm_index(seq, sigma: int, *, sample_rate: int = 32,
                   tau: int = 8, big_step: str = "compose",
                   bv_sample_rate: int = 512,
                   backend: str = "counting") -> FMIndex:
    """Build the index: parallel SA (prefix doubling) → BWT gather → paper
    wavelet-matrix construction (Theorem 4.5) → sampled-SA directories.

    Fully trace-safe (no host syncs on data values), so whole-shard builds
    can run under ``vmap``/``pmap`` — see ``data.shard_build``. The
    out-of-alphabet validation only fires on concrete inputs.
    """
    seq = jnp.asarray(seq)
    concrete = not isinstance(seq, jax.core.Tracer)
    if concrete and seq.size and (int(jnp.min(seq)) < 0
                                  or int(jnp.max(seq)) >= sigma):
        # a symbol ≥ σ would be silently dropped from C and truncated by
        # the wavelet matrix — corrupt counts with no error downstream
        raise ValueError(f"symbols outside [0, {sigma})")
    bwt, sa, C = bwt_encode(seq, sigma, backend=backend)
    m = int(bwt.shape[0])
    sigma_work = sigma + SENTINEL_SHIFT
    # The builder picks its own kernel route (Pallas on TPU, mechanically
    # falling back to the batchable XLA fast path under vmapped shard
    # builds — see build_wavelet_matrix's use_kernels guard).
    wm = build_wavelet_matrix(bwt, sigma_work, tau=tau, big_step=big_step,
                              sample_rate=bv_sample_rate)

    marked = (sa % sample_rate) == 0
    # sa is a permutation of [0, m): exactly ceil(m/sample_rate) multiples,
    # compacted in row order by a scatter on the marked-prefix count
    num_samples = (m + sample_rate - 1) // sample_rate
    cnt = jnp.cumsum(marked.astype(_I32)) - 1
    sample_vals = jnp.zeros((num_samples,), _I32).at[
        jnp.where(marked, cnt, num_samples)].set(
            sa.astype(_I32), mode="drop")
    words = bitops.pack_bits(bitops.pad_bits(marked.astype(jnp.uint8)))
    mark = build_binary_rank(words, m)
    return FMIndex(wm=wm, C=C, mark=mark, sa_sample=sample_vals,
                   n=int(seq.shape[0]), sigma=sigma,
                   sample_rate=sample_rate)


# ----------------------------------------------------------------------
# backward search
# ----------------------------------------------------------------------

def _backward_range(fm: FMIndex, pattern: jax.Array,
                    length: jax.Array):
    """(lo, hi) of the SA range matching one padded pattern.

    ``pattern``: (L,) raw symbols in [0, σ), padding anywhere at t ≥ length.
    Out-of-alphabet "symbols" (e.g. σ used as padding) never match: their
    shifted id clips to the C-table edge and the range empties.
    """
    pattern = jnp.asarray(pattern, _I32)
    length = jnp.asarray(length, _I32)
    L = pattern.shape[0]
    m = jnp.asarray(fm.m, _I32)

    def body(t, state):
        lo, hi = state
        i = L - 1 - t                     # right-to-left
        c = jnp.clip(pattern[i] + SENTINEL_SHIFT, 0, fm.sigma + 1)
        in_alpha = (pattern[i] >= 0) & (pattern[i] < fm.sigma)
        active = i < length
        base = fm.C[c]
        hi2 = base + wm_rank(fm.wm, c, hi)
        # an out-of-alphabet symbol (e.g. shard padding) empties the range
        lo2 = jnp.where(in_alpha, base + wm_rank(fm.wm, c, lo), hi2)
        lo = jnp.where(active, lo2, lo)
        hi = jnp.where(active, hi2, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, L, body, (jnp.zeros((), _I32), m))
    return lo, hi


def fm_count(fm: FMIndex, patterns: jax.Array,
             lengths: jax.Array) -> jax.Array:
    """# of occurrences of each pattern in the text. Vmapped over batch.

    ``patterns``: (B, L) int32, padded; ``lengths``: (B,) true lengths.
    A zero-length pattern counts every position (m matches of the empty
    string, including before the terminator) — callers that want n+1 or 0
    should mask.
    """
    patterns = jnp.atleast_2d(jnp.asarray(patterns, _I32))
    lengths = jnp.atleast_1d(jnp.asarray(lengths, _I32))

    def one(p, l):
        lo, hi = _backward_range(fm, p, l)
        return hi - lo

    return jax.vmap(one)(patterns, lengths)


# ----------------------------------------------------------------------
# locate (sampled-SA LF walk)
# ----------------------------------------------------------------------

def _lf_step(fm: FMIndex, j: jax.Array) -> jax.Array:
    """LF(j): the row whose suffix starts one text position earlier."""
    c = wm_access(fm.wm, j)
    return fm.C[c] + wm_rank(fm.wm, c, j)


def _locate_row(fm: FMIndex, j: jax.Array) -> jax.Array:
    """Text position of SA row j: walk LF to the nearest marked row."""
    j = jnp.asarray(j, _I32)

    def body(_, state):
        j, steps, done = state
        done2 = done | (access_bit(fm.mark, j) > 0)
        j2 = jnp.where(done2, j, _lf_step(fm, j))
        steps2 = jnp.where(done2, steps, steps + 1)
        return j2, steps2, done2

    j, steps, _ = jax.lax.fori_loop(
        0, fm.sample_rate, body, (j, jnp.zeros((), _I32),
                                  jnp.zeros((), bool)))
    sample = fm.sa_sample[rank1(fm.mark, j)]
    return (sample + steps) % jnp.asarray(fm.m, _I32)


def fm_locate(fm: FMIndex, pattern: jax.Array, length: jax.Array,
              max_hits: int = 16) -> jax.Array:
    """Text positions of up to ``max_hits`` matches of one pattern.

    Returns (max_hits,) int32, sorted ascending, padded with -1 past the
    true match count. Each hit is an independent LF walk → vmapped.
    """
    lo, hi = _backward_range(fm, jnp.asarray(pattern, _I32),
                             jnp.asarray(length, _I32))
    ks = jnp.arange(max_hits, dtype=_I32)
    rows = jnp.minimum(lo + ks, jnp.asarray(fm.m - 1, _I32))
    pos = jax.vmap(lambda r: _locate_row(fm, r))(rows)
    valid = ks < (hi - lo)
    out = jnp.sort(jnp.where(valid, pos, jnp.asarray(fm.m, _I32)))
    return jnp.where(out >= fm.m, jnp.asarray(-1, _I32), out)
