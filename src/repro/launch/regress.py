"""Perf regression sentry CLI: render the per-commit bench trajectory and
gate on confirmed regressions.

PYTHONPATH=src python -m repro.launch.regress                 # everything
PYTHONPATH=src python -m repro.launch.regress --fast          # CI records
PYTHONPATH=src python -m repro.launch.regress --suite construction -v
PYTHONPATH=src python -m repro.launch.regress --fail-on none  # report only

Reads ``results/bench/history.jsonl`` (appended to by every
``benchmarks.run`` invocation via ``benchmarks/common.save``), groups it
into (suite, row, fast, backend) series, and prints one verdict row per
series from ``repro.obs.history.detect_regression``: median-of-last-K
baseline, MAD-scaled threshold (floored at ``--rel-floor`` relative), so
a single noisy run can't gate while a genuine step regression (e.g. a 2×
slowdown) trips immediately. ``drift`` (slow creep across many commits)
and ``improvement`` are reported but only ``--fail-on`` verdicts flip the
exit code — the default gates on confirmed step regressions only, which
is what ``scripts/ci.sh`` runs as the soft perf gate.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.obs.history import read_history, regress_report

#: repo-root results/bench/history.jsonl (this file lives at
#: src/repro/launch/regress.py).
DEFAULT_HISTORY = (Path(__file__).resolve().parents[3]
                   / "results" / "bench" / "history.jsonl")

_MARK = {"regression": "REGRESS", "drift": "drift", "improvement": "better",
         "ok": "ok", "new": "new"}


def render_regress_table(rows: list, verbose: bool = False) -> str:
    header = ["suite", "row", "mode", "runs", "baseline_us", "latest_us",
              "delta%", "verdict"]
    table = [header]
    for r in rows:
        table.append([
            r["suite"], r["row"], "fast" if r["fast"] else "full",
            str(r["runs"]),
            "-" if r["baseline"] is None else f"{r['baseline']:.1f}",
            f"{r['latest']:.1f}",
            "-" if r["delta_pct"] is None else f"{r['delta_pct']:+.1f}",
            _MARK.get(r["verdict"], r["verdict"])])
    widths = [max(len(row[i]) for row in table) for i in range(len(header))]
    lines = []
    for j, row in enumerate(table):
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    if verbose:
        for r in rows:
            if r["detail"]:
                lines.append(f"  {r['suite']}/{r['row']}: {r['detail']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="noise-aware perf regression gate over the per-commit "
                    "bench history")
    ap.add_argument("--history", type=Path, default=DEFAULT_HISTORY,
                    help=f"history JSONL (default {DEFAULT_HISTORY})")
    ap.add_argument("--suite", default=None,
                    help="restrict to one bench suite")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--fast", action="store_true",
                      help="only CI-sized (--fast) records")
    mode.add_argument("--full", action="store_true",
                      help="only full-size records")
    ap.add_argument("--last-k", type=int, default=5,
                    help="baseline window: median of the last K prior runs")
    ap.add_argument("--mad-scale", type=float, default=4.0,
                    help="threshold in robust stddevs (1.4826·MAD) above "
                         "the baseline median")
    ap.add_argument("--rel-floor", type=float, default=0.25,
                    help="minimum relative slack — a quiet series still "
                         "needs at least this fractional jump to gate")
    ap.add_argument("--min-history", type=int, default=3,
                    help="baseline runs required before gating (fewer → "
                         "'new', never gates)")
    ap.add_argument("--cross-host", action="store_true",
                    help="compare against baselines from other hosts too "
                         "(default: same-host only, so a slower CI box "
                         "doesn't read as a regression)")
    ap.add_argument("--fail-on", choices=["regression", "drift", "none"],
                    default="regression",
                    help="which verdicts flip the exit code: 'regression' "
                         "(default — confirmed steps only), 'drift' (also "
                         "gradual creep), 'none' (report only)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print per-row verdict details")
    args = ap.parse_args(argv)

    records = read_history(args.history)
    if not records:
        print(f"no bench history at {args.history} — run "
              f"`python -m benchmarks.run` (or --fast) to start the "
              f"trajectory", file=sys.stderr)
        return 2

    fast = True if args.fast else (False if args.full else None)
    rows = regress_report(records, last_k=args.last_k,
                          mad_scale=args.mad_scale,
                          rel_floor=args.rel_floor,
                          min_history=args.min_history,
                          same_host=not args.cross_host,
                          fast=fast, suite=args.suite)
    if not rows:
        print("no matching series in history", file=sys.stderr)
        return 2

    print(render_regress_table(rows, verbose=args.verbose))
    counts: dict[str, int] = {}
    for r in rows:
        counts[r["verdict"]] = counts.get(r["verdict"], 0) + 1
    summary = ", ".join(f"{v} {k}" for k, v in sorted(counts.items()))
    print(f"\n{len(rows)} series: {summary}")

    gate = {"regression"}
    if args.fail_on == "drift":
        gate.add("drift")
    elif args.fail_on == "none":
        gate = set()
    bad = [r for r in rows if r["verdict"] in gate]
    if bad:
        for r in bad:
            print(f"CONFIRMED {r['verdict'].upper()}: {r['suite']}/"
                  f"{r['row']} latest {r['latest']:.1f}us vs baseline "
                  f"{r['baseline']:.1f}us ({r['delta_pct']:+.1f}%) — "
                  f"{r['detail']}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
