"""Full-text index CLI: build a sharded FM-index over the synthetic corpus
and serve a batch of substring count/locate queries.

PYTHONPATH=src python -m repro.launch.index --smoke
PYTHONPATH=src python -m repro.launch.index --n 262144 --vocab 4096 \
    --shard-bits 14 --patterns 256 --pattern-len 8
PYTHONPATH=src python -m repro.launch.index --smoke --drop-shards 1,3
    # degraded-mode demo: lost shards are served around with an explicit
    # coverage fraction and lower/upper count bounds

Build: per-shard prefix-doubling suffix array → BWT → wavelet matrix
(paper Theorem 4.5) → sampled-SA directories. Query: one jitted
vmap-over-shards × vmap-over-patterns backward search; every step is two
wavelet-matrix ranks. A sample of counts is verified against naive numpy
substring search on the regenerated raw stream.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.data import make_corpus
from repro.index import build_sharded_index, sample_patterns


def naive_count(toks: np.ndarray, pat: np.ndarray, plen: int,
                shard_size: int, stitch_max: int) -> int:
    """Count oracle matching the index's guarantee: global sliding count
    when seam stitching covers the pattern (plen ≤ stitch_max), else the
    within-shard count (crossing matches are out of the exactness domain
    and deliberately uncounted)."""
    if plen == 0 or plen > len(toks):
        return 0
    if plen <= stitch_max:
        win = np.lib.stride_tricks.sliding_window_view(toks, plen)
        return int((win == pat[:plen]).all(axis=1).sum())
    total = 0
    for s0 in range(0, len(toks), shard_size):
        sh = toks[s0:s0 + shard_size]
        if plen > len(sh):
            continue
        win = np.lib.stride_tricks.sliding_window_view(sh, plen)
        total += int((win == pat[:plen]).all(axis=1).sum())
    return total


def naive_count_degraded(toks: np.ndarray, pat: np.ndarray, plen: int,
                         shard_size: int, stitch_max: int,
                         avail: np.ndarray) -> int:
    """Degraded-mode count oracle: within-shard matches on available
    shards, plus boundary-crossing matches (when stitching covers the
    pattern) at seams whose BOTH shards are available."""
    if plen == 0 or plen > len(toks):
        return 0
    total = 0
    starts = list(range(0, len(toks), shard_size))
    for s, s0 in enumerate(starts):
        if not avail[s]:
            continue
        sh = toks[s0:s0 + shard_size]
        if plen > len(sh):
            continue
        win = np.lib.stride_tricks.sliding_window_view(sh, plen)
        total += int((win == pat[:plen]).all(axis=1).sum())
    if 2 <= plen <= stitch_max:
        for s in range(len(starts) - 1):
            if not (avail[s] and avail[s + 1]):
                continue
            b = (s + 1) * shard_size
            for p0 in range(max(0, b - plen + 1), b):
                if p0 + plen > len(toks):
                    break
                if np.array_equal(toks[p0:p0 + plen], pat[:plen]):
                    total += 1
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized build + query + verification")
    ap.add_argument("--n", type=int, default=1 << 17)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--shard-bits", type=int, default=13)
    ap.add_argument("--patterns", type=int, default=128)
    ap.add_argument("--pattern-len", type=int, default=8)
    ap.add_argument("--sample-rate", type=int, default=32)
    ap.add_argument("--verify", type=int, default=16,
                    help="# of counts to check against naive numpy")
    ap.add_argument("--drop-shards", type=str, default=None,
                    help="comma-separated shard ids to mark unavailable — "
                         "degraded-mode demo: serves surviving shards with "
                         "an explicit coverage fraction and count bounds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-dir", type=str, default=None,
                    help="export obs metrics snapshot + JSONL events here "
                         "(inspect with `python -m repro.launch.obs`)")
    ap.add_argument("--profile-dir", type=str, default=None,
                    help="capture a jax.profiler device trace of the "
                         "query section into this directory")
    args = ap.parse_args()
    if args.metrics_dir:
        obs.configure(args.metrics_dir)
    if args.smoke:
        args.n = min(args.n, 1 << 14)
        args.shard_bits = min(args.shard_bits, 11)
        args.patterns = min(args.patterns, 64)

    toks = make_corpus(args.n, args.vocab, seed=args.seed)
    toks = np.asarray(toks, np.int64)

    sw = obs.Stopwatch()
    with obs.span("index.build", n=args.n, vocab=args.vocab,
                  shard_bits=args.shard_bits) as sp:
        idx = sp.sync(build_sharded_index(toks, args.vocab,
                                          shard_bits=args.shard_bits,
                                          sample_rate=args.sample_rate))
    jax.block_until_ready(jax.tree.leaves(idx.shards)[0])
    t_build = sw.lap()
    obs.gauge("serve.index.build_s").set(t_build)
    print(f"build: {args.n} tokens, vocab {args.vocab}, "
          f"{idx.num_shards} shards of {idx.shard_size} in {t_build:.2f}s "
          f"({args.n / t_build / 1e3:.0f} ktok/s, "
          f"{idx.bits_per_token():.1f} bits/token)")

    pats, lens = sample_patterns(toks, args.patterns, args.pattern_len,
                                 pad=args.vocab, seed=args.seed + 1)
    pj, lj = jnp.asarray(pats), jnp.asarray(lens)

    count = jax.jit(lambda ix, p, l: ix.count(p, l))
    with obs.trace(args.profile_dir):
        out, t_query, t_compile = obs.profiled_op(
            "index", "count", count, idx, pj, lj, batch=args.patterns)
    counts = np.asarray(out)
    print(f"count: {args.patterns} patterns in {t_query * 1e3:.1f} ms "
          f"({args.patterns / t_query:.0f} patterns/s; "
          f"compile {t_compile:.2f}s); hits: "
          f"min {counts.min()} median {int(np.median(counts))} "
          f"max {counts.max()}")
    if args.profile_dir:
        print(f"device trace → {args.profile_dir}")

    locate = jax.jit(lambda ix, p, l: ix.locate(p, l, 4))
    pos, _, t_loc = obs.timed_op("index", "locate", locate, idx, pj, lj,
                                 batch=args.patterns)
    pos = np.asarray(pos)
    print(f"locate: {args.patterns} patterns × ≤{4 * idx.num_shards} hits "
          f"in {t_loc:.2f}s (incl. compile)")

    bad = 0
    stitch_max = min(idx.seam_overlap + 1, idx.shard_size)
    for i in range(min(args.verify, args.patterns)):
        want = naive_count(toks, pats[i], int(lens[i]), idx.shard_size,
                           stitch_max)
        if int(counts[i]) != want:
            bad += 1
            print(f"  MISMATCH pattern {i}: got {counts[i]}, want {want}")
        first = pos[i][pos[i] >= 0][:1]
        if first.size:
            p0 = int(first[0])
            if not np.array_equal(toks[p0:p0 + int(lens[i])],
                                  pats[i, :int(lens[i])]):
                bad += 1
                print(f"  BAD LOCATE pattern {i} at {p0}")
    if bad:
        raise SystemExit(f"{bad} verification failures")
    print(f"verified {min(args.verify, args.patterns)} count/locate "
          f"samples against naive numpy ✓")

    if args.drop_shards:
        drop = sorted({int(x) for x in args.drop_shards.split(",") if x})
        out_of_range = [s for s in drop if not 0 <= s < idx.num_shards]
        if out_of_range:
            raise SystemExit(f"--drop-shards ids {out_of_range} outside "
                             f"[0, {idx.num_shards})")
        deg = idx.drop_shards(np.asarray(drop, np.int32))
        cov = float(deg.coverage())
        obs.gauge("serve.index.coverage").set(cov)
        print(f"degraded mode: dropped shards {drop} "
              f"({cov * 100:.1f}% coverage)")
        bounds = jax.jit(lambda ix, p, l: ix.count_bounds(p, l))
        (lower, upper, _), _, _ = obs.timed_op(
            "index", "count_bounds", bounds, deg, pj, lj,
            batch=args.patterns)
        lower, upper = np.asarray(lower), np.asarray(upper)
        avail = np.ones(idx.num_shards, bool)
        avail[drop] = False
        bad = 0
        for i in range(min(args.verify, args.patterns)):
            plen = int(lens[i])
            want_deg = naive_count_degraded(toks, pats[i], plen,
                                            idx.shard_size, stitch_max,
                                            avail)
            full = naive_count(toks, pats[i], plen, idx.shard_size,
                               stitch_max)
            if int(lower[i]) != want_deg:
                bad += 1
                print(f"  DEGRADED MISMATCH pattern {i}: got {lower[i]}, "
                      f"want {want_deg}")
            if not int(lower[i]) <= full <= int(upper[i]):
                bad += 1
                print(f"  BOUNDS VIOLATION pattern {i}: true {full} outside "
                      f"[{lower[i]}, {upper[i]}]")
        if bad:
            raise SystemExit(f"{bad} degraded-mode verification failures")
        print(f"degraded counts verified against surviving-shard oracle; "
              f"bounds bracket the full-corpus truth ✓")

    if args.metrics_dir:
        obs.write_snapshot()
        print(f"metrics → {args.metrics_dir}")


if __name__ == "__main__":
    main()
