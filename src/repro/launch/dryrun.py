"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the first import side effect: force 512 host placeholder devices
BEFORE jax initializes (single-pod mesh uses the first 256).
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import obs    # noqa: E402
from repro.configs.base import (ARCHITECTURES, SHAPES, get_config,  # noqa: E402
                                supports_shape)
from repro.launch.mesh import (dp_axes, make_production_mesh,  # noqa: E402
                               set_mesh)
from repro.models.model import (abstract_cache, abstract_params,  # noqa: E402
                                build_model, cache_specs, param_specs)
from repro.optim.adamw import abstract_opt_state, adamw_update  # noqa: E402
from repro.optim.schedule import cosine_schedule  # noqa: E402

RESULTS_DEFAULT = Path("results/dryrun")

# --------------------------------------------------------------------------
# Step functions
# --------------------------------------------------------------------------


def make_train_step(model, grad_accum: int = 1):
    extras_keys = tuple(model.extras_shapes(1).keys())

    def grads_of(params, batch):
        tokens = batch["tokens"]
        extras = {k: batch[k] for k in extras_keys} or None
        if grad_accum == 1:
            return jax.value_and_grad(model.loss_fn)(params, tokens, extras)
        b = tokens.shape[0]
        mb = b // grad_accum
        mb_tok = tokens.reshape(grad_accum, mb, *tokens.shape[1:])
        mb_ext = jax.tree.map(
            lambda x: x.reshape(grad_accum, mb, *x.shape[1:]),
            extras) if extras else None

        def body(carry, xs):
            aloss, ag = carry
            ext = {k: xs[k] for k in extras_keys} or None
            loss, g = jax.value_and_grad(model.loss_fn)(
                params, xs["tokens"], ext)
            return (aloss + loss, jax.tree.map(jnp.add, ag, g)), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        xs = {"tokens": mb_tok, **(mb_ext or {})}
        (ls, gs), _ = jax.lax.scan(body, (jnp.float32(0), zero), xs)
        inv = 1.0 / grad_accum
        return ls * inv, jax.tree.map(lambda g: g * inv, gs)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        lr = cosine_schedule(opt_state.step, 3e-4, 2000, 100_000)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state,
                                                    lr)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


# Microbatch counts for the train_4k cells: bounds per-device activation
# memory (grads accumulate across a lax.scan; collectives per optimizer
# step are unchanged). Chosen so peak HBM approaches the 16 GB v5e budget.
# capped at global_batch/data(=16): a microbatch below one example per
# data shard replicates activations and regresses memory.
GRAD_ACCUM = {
    "arctic_480b": 16, "dbrx_132b": 16, "llama_3_2_vision_90b": 16,
    "internlm2_20b": 8, "granite_3_8b": 8, "deepseek_7b": 8,
    "jamba_v0_1_52b": 8, "whisper_medium": 4, "qwen2_0_5b": 4,
    "mamba2_370m": 4,
}


def make_prefill_step(model):
    extras_keys = tuple(model.extras_shapes(1).keys())

    def prefill_step(params, batch):
        extras = {k: batch[k] for k in extras_keys} or None
        return model.prefill(params, batch["tokens"], extras)

    return prefill_step


def make_serve_step(model):
    def serve_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)

    return serve_step


# --------------------------------------------------------------------------
# Abstract inputs + shardings
# --------------------------------------------------------------------------

def input_specs(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    model = build_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32
    extras = {k: jax.ShapeDtypeStruct(shp, bf16)
              for k, shp in model.extras_shapes(b).items()}
    if shape.kind == "train":
        return {"batch": {"tokens": jax.ShapeDtypeStruct((b, s + 1), i32),
                          **extras}}
    if shape.kind == "prefill":
        return {"batch": {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                          **extras}}
    # decode: one token against a seq-length cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache": abstract_cache(cfg, b, s),
            "pos": jax.ShapeDtypeStruct((b,), i32)}


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_shardings(cfg, mesh, specs):
    from repro.models.model import fit_spec
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)

    def spec_for(path, s):
        spec = P(*((dp,) + (None,) * (len(s.shape) - 1)))
        return NamedSharding(mesh, fit_spec(spec, s.shape, sizes))

    return jax.tree_util.tree_map_with_path(spec_for, specs)


# --------------------------------------------------------------------------
# Lower + compile + analyze one cell
# --------------------------------------------------------------------------

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "u16": 2,
                "s16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8}


def collective_bytes_per_device(hlo_text: str, body_trip_counts=None) -> dict:
    """Per-device collective traffic, parsed from post-SPMD HLO.

    Returns {op_kind: bytes} using each op's *result* shape (≈ bytes a device
    receives). Ops inside while-loop bodies (scan over blocks) are multiplied
    by the trip count inferred from the loop's induction-variable compare,
    parsed from the loop condition computations.
    """
    # map condition-computation name -> trip count (from "count < N" compares)
    trip_by_cond = {}
    for m in re.finditer(
            r"%?([\w.\-]+)\s*\([^)]*\)\s*->\s*pred\[\]\s*{(.*?)\n}\n",
            hlo_text, re.S):
        name, body = m.group(1), m.group(2)
        c = re.search(r"compare\([^)]*\),\s*direction=LT", body)
        k = re.search(r"constant\((\d+)\)", body)
        if c and k:
            trip_by_cond[name] = int(k.group(1))

    # map body-computation name -> trip count via while ops
    trip_by_comp = {}
    for m in re.finditer(
            r"while\([^)]*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
            hlo_text):
        cond, body = m.group(1), m.group(2)
        trip_by_comp[body] = trip_by_cond.get(cond, 1)

    totals = {}
    current_comp = None
    current_trip = 1
    for line in hlo_text.splitlines():
        header = re.match(r"%?([\w.\-]+)\s*\([^)]*\)\s*->", line)
        if header and "{" in line:
            current_comp = header.group(1)
            current_trip = trip_by_comp.get(current_comp, 1)
            continue
        mm = _COLLECTIVE_RE.search(line)
        if not mm:
            continue
        dtype, dims, kind = mm.groups()
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        # XLA:CPU's FloatSupport promotes bf16 all-reduces to f32 (the
        # reducer is named "*promoted"); TPU reduces bf16 natively, so
        # count promoted ops at their true 2-byte width.
        if dtype == "f32" and "promoted" in line:
            nbytes //= 2
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        totals[kind] = totals.get(kind, 0) + numel * nbytes * current_trip
    return totals


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: Path | None = None) -> dict:
    from repro.models import shard_ctx
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    shard_ctx.set_mesh_context(dp, sizes)
    # decode steps use TP-only weight sharding (no per-token weight
    # gathers); train/prefill amortize FSDP gathers over the whole batch.
    pspecs = param_specs(cfg, sizes,
                         mode="decode" if shape.kind == "decode" else "train")
    pshard = _named(mesh, pspecs)
    specs = input_specs(cfg, shape)

    sw = obs.Stopwatch()
    mesh_ctx = set_mesh(mesh)
    mesh_ctx.__enter__()
    if shape.kind == "train":
        step = make_train_step(model, grad_accum=GRAD_ACCUM.get(arch, 1))
        oshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            abstract_opt_state_specs(pspecs), is_leaf=lambda x: isinstance(x, P))
        bshard = batch_shardings(cfg, mesh, specs["batch"])
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        abstract_opt = abstract_opt_state(abstract_params(cfg))
        lowered = fn.lower(abstract_params(cfg), abstract_opt,
                           specs["batch"])
    elif shape.kind == "prefill":
        from repro.models.model import fit_spec
        step = make_prefill_step(model)
        bshard = batch_shardings(cfg, mesh, specs["batch"])
        logits_spec = fit_spec(P(dp, "model"),
                               (shape.global_batch, cfg.padded_vocab), sizes)
        fn = jax.jit(step, in_shardings=(pshard, bshard),
                     out_shardings=NamedSharding(mesh, logits_spec))
        lowered = fn.lower(abstract_params(cfg), specs["batch"])
    else:  # decode
        from repro.models.model import fit_spec
        step = make_serve_step(model)
        b, s = shape.global_batch, shape.seq_len
        cshard = _named(mesh, cache_specs(cfg, dp, b, s, sizes,
                                          shard_seq=True))
        tok_spec = fit_spec(P(dp, None), (b, 1), sizes)
        pos_spec = fit_spec(P(dp), (b,), sizes)
        logits_spec = fit_spec(P(dp, "model"), (b, cfg.padded_vocab), sizes)
        fn = jax.jit(
            step,
            in_shardings=(pshard, NamedSharding(mesh, tok_spec),
                          cshard, NamedSharding(mesh, pos_spec)),
            out_shardings=(NamedSharding(mesh, logits_spec), cshard),
            donate_argnums=(2,))
        lowered = fn.lower(abstract_params(cfg), specs["tokens"],
                           specs["cache"], specs["pos"])
    t_lower = sw.lap()

    analyzed = _analyze_compiled(lowered, save_hlo)
    mesh_ctx.__exit__(None, None, None)
    shard_ctx.clear_mesh_context()

    mem = analyzed["memory"]
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": mesh.devices.size,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": analyzed["compile_s"],
        "flops_per_device": analyzed["flops"],
        "bytes_accessed_per_device": analyzed["bytes_accessed"],
        "collective_bytes_per_device": analyzed["collective_bytes"],
        "memory": {
            **mem,
            "peak_bytes": ((mem["argument_bytes"] or 0)
                           + (mem["temp_bytes"] or 0)),
        },
    }
    return result


def abstract_opt_state_specs(pspecs):
    from repro.optim.adamw import AdamWState
    return AdamWState(m=pspecs, v=pspecs, step=P())


# --------------------------------------------------------------------------
# Range-analytics cell: lower + compile the batched serving path and the
# fused Pallas quantile kernel so HLO/cost analysis covers the new
# subsystem alongside the model cells.
# --------------------------------------------------------------------------

def _analyze_compiled(lowered, save_hlo: Path | None = None) -> dict:
    sw = obs.Stopwatch()
    compiled = lowered.compile()
    t_compile = sw.lap()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):    # older jax returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    if save_hlo:
        save_hlo.write_text(hlo)
    return {
        "ok": True, "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": collective_bytes_per_device(hlo),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
    }


def run_analytics_cell(out_dir: Path, save_hlo: bool = False) -> dict:
    """Build a small analytics store and compile its serving programs:
    the four-op batched query path and the fused Pallas quantile kernel."""
    import numpy as np
    from repro.analytics import build_sharded_analytics
    from repro.data import make_corpus
    from repro.kernels.ops import wm_quantile_batch

    n, vocab, sb, B = 1 << 14, 1024, 12, 1024
    toks = np.asarray(make_corpus(n, vocab, seed=0), np.int64)
    eng = build_sharded_analytics(toks, vocab, shard_bits=sb)
    rng = np.random.default_rng(1)
    lo = jnp.asarray(rng.integers(0, n, B).astype(np.int32))
    hi = jnp.minimum(lo + jnp.asarray(
        rng.integers(1, n // 2, B).astype(np.int32)), n)
    k = jnp.asarray(rng.integers(0, n // 2, B).astype(np.int32))
    s0 = jnp.asarray(rng.integers(0, vocab, B).astype(np.int32))
    s1 = jnp.minimum(s0 + 32, vocab)

    serve = jax.jit(lambda e, a, b, c, x, y: (
        e.range_quantile(a, b, c), e.range_count(a, b, x, y),
        e.range_topk(a, b, 8), e.range_distinct(a, b)))
    sw = obs.Stopwatch()
    lowered = serve.lower(eng, lo, hi, k, s0, s1)
    cell_serve = _analyze_compiled(
        lowered, out_dir / "analytics__serve.hlo.txt" if save_hlo else None)
    cell_serve["lower_s"] = round(sw.lap(), 1)

    kern = jax.jit(lambda w, a, b, c: wm_quantile_batch(w, a, b, c))
    sw.lap()
    lowered = kern.lower(eng.shard(0), lo, hi, k)
    cell_kernel = _analyze_compiled(
        lowered,
        out_dir / "analytics__quantile_kernel.hlo.txt" if save_hlo else None)
    cell_kernel["lower_s"] = round(sw.lap(), 1)

    result = {
        "cell": "analytics", "ok": True,
        "n": n, "vocab": vocab, "batch": B,
        "num_shards": eng.num_shards,
        "serve_4op_batch": cell_serve,
        "fused_quantile_kernel": cell_kernel,
    }
    return result


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def cell_id(arch, shape_name, multi_pod):
    return f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--analytics", action="store_true",
                    help="also compile the range-analytics serving cell")
    ap.add_argument("--out", type=Path, default=RESULTS_DEFAULT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()
    args.out.mkdir(parents=True, exist_ok=True)

    if args.analytics or args.all:
        out_file = args.out / "analytics__serving.json"
        if out_file.exists() and not args.force:
            print("=== analytics (cached) ===", flush=True)
        else:
            print("=== analytics ===", flush=True)
            try:
                res = run_analytics_cell(args.out, save_hlo=args.save_hlo)
                out_file.write_text(json.dumps(res, indent=1))
                print(json.dumps({k: res[k] for k in
                                  ("serve_4op_batch",
                                   "fused_quantile_kernel")}), flush=True)
            except Exception as e:  # noqa: BLE001
                out_file.write_text(json.dumps(
                    {"cell": "analytics", "ok": False,
                     "error": repr(e)[:2000]}))
                print(f"FAILED: {e!r}"[:500], flush=True)
        if args.analytics and not args.all and not args.arch:
            return

    if args.all:
        archs = list(ARCHITECTURES)
        shapes = list(SHAPES)
        meshes = [False, True]
    else:
        archs = [args.arch]
        shapes = [args.shape] if args.shape else list(SHAPES)
        meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                cid = cell_id(arch, shape_name, mp)
                out_file = args.out / f"{cid}.json"
                if out_file.exists() and not args.force:
                    n_skip += 1
                    continue
                if not supports_shape(arch, shape_name):
                    out_file.write_text(json.dumps(
                        {"arch": arch, "shape": shape_name,
                         "mesh": "2x16x16" if mp else "16x16",
                         "ok": False, "skipped": "full-attention arch: "
                         "long_500k requires sub-quadratic mixing"}))
                    n_skip += 1
                    continue
                print(f"=== {cid} ===", flush=True)
                try:
                    hlo_path = (args.out / f"{cid}.hlo.txt"
                                if args.save_hlo else None)
                    res = run_cell(arch, shape_name, mp, save_hlo=hlo_path)
                    out_file.write_text(json.dumps(res, indent=1))
                    print(json.dumps({k: res[k] for k in
                                      ("compile_s", "flops_per_device",
                                       "memory")}), flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    out_file.write_text(json.dumps(
                        {"arch": arch, "shape": shape_name,
                         "mesh": "2x16x16" if mp else "16x16",
                         "ok": False, "error": repr(e)[:2000]}))
                    print(f"FAILED: {e!r}"[:500], flush=True)
                    n_fail += 1
    print(f"done ok={n_ok} fail={n_fail} skip={n_skip}")


if __name__ == "__main__":
    main()
