"""Query front-end CLI: drive a bursty request trace at the overload-
hardened serving front-end and report shed/degrade/latency behaviour.

(The *model*-serving CLI — prefill + decode — is ``repro.launch.serve``;
this CLI exercises ``repro.serving.QueryFrontend``, the analytics query
front-end.)

PYTHONPATH=src python -m repro.launch.frontend --smoke
PYTHONPATH=src python -m repro.launch.frontend --overload 5.0 \\
    --requests 2000 --deadline-ms 50
PYTHONPATH=src python -m repro.launch.frontend --smoke \\
    --record-trace /tmp/burst.jsonl                # record the trace
PYTHONPATH=src python -m repro.launch.frontend --smoke \\
    --replay /tmp/burst.jsonl --overload 5.0       # replay it 5× faster

The trace is a bursty arrival process (quiet base load with periodic
storm windows, seeded) of mixed count/quantile/top-k queries; ``--replay``
drives a recorded trace instead, and ``--overload X`` time-compresses
either by X (the same requests offered X× faster). Submission is paced on
the shared ``robust.Clock`` with catch-up semantics: if the submitter
falls behind schedule it submits immediately rather than silently
thinning the offered load.

``--metrics-dir`` exports the ``serve.frontend.*`` gauges/counters and
per-op latency histograms for ``repro.launch.obs`` (gate with
``--slo 'frontend.*:p99_ms<=...'``); ``--profile-dir`` wraps serving in a
``jax.profiler`` device trace.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro import obs
from repro.analytics.engine import build_sharded_analytics
from repro.data import make_corpus
from repro.ingest.serving import GenerationServer
from repro.serving import (BreakerConfig, FrontendConfig, QueryFrontend,
                           ShedError)


def make_trace(n: int, requests: int, seed: int, *, base_qps: float,
               burst_qps: float, burst_every_s: float, burst_len_s: float,
               deadline_s: float, topk_k: int) -> list:
    """Bursty arrival schedule: quiet base load punctuated by storm
    windows. Returns [{t, op, lo, hi, k, deadline_s}, ...] sorted by t."""
    rng = np.random.default_rng(seed)
    events, t = [], 0.0
    ops = ("count", "quantile", "topk")
    while len(events) < requests:
        in_burst = (t % burst_every_s) < burst_len_s
        rate = burst_qps if in_burst else base_qps
        t += float(rng.exponential(1.0 / rate))
        lo = int(rng.integers(0, max(1, n - 1)))
        hi = int(rng.integers(lo + 1, n + 1))
        op = ops[int(rng.integers(0, len(ops)))]
        events.append({
            "t": round(t, 6), "op": op, "lo": lo, "hi": hi,
            "k": (int(rng.integers(0, hi - lo)) if op == "quantile"
                  else (topk_k if op == "topk" else None)),
            "deadline_s": deadline_s,
        })
    return events


def load_trace(path: str) -> list:
    return [json.loads(ln) for ln in Path(path).read_text().splitlines()
            if ln.strip()]


def save_trace(path: str, trace: list) -> None:
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    Path(path).write_text("".join(json.dumps(e) + "\n" for e in trace))


def drive(fe: QueryFrontend, trace: list, overload: float, sigma: int):
    """Paced catch-up submission of the (time-compressed) trace; returns
    the tickets in submission order."""
    clock = fe.clock
    t0 = clock.now()
    tickets = []
    for ev in trace:
        target = t0 + ev["t"] / max(overload, 1e-9)
        lag = target - clock.now()
        if lag > 0:
            clock.sleep(lag)         # on schedule; behind ⇒ submit now
        kw = {"deadline_s": ev.get("deadline_s")}
        if ev["op"] == "quantile":
            kw["k"] = ev["k"]
        elif ev["op"] == "count":
            kw["sym_lo"], kw["sym_hi"] = 0, sigma
        tickets.append(fe.submit(ev["op"], ev["lo"], ev["hi"], **kw))
    return tickets


def report(fe: QueryFrontend, tickets: list, sw: obs.Stopwatch) -> dict:
    """Wait for every ticket; ``sw`` has been lapped at submit start so
    the final lap spans submit→last-result (the q/s denominator)."""
    lats, degraded, misses, served, shed = [], 0, 0, 0, 0
    for t in tickets:
        try:
            a = t.result(timeout=30.0)
        except ShedError:
            shed += 1
            continue
        served += 1
        lats.append(a.latency_s)
        degraded += bool(a.degraded)
        misses += not a.deadline_met
    wall_s = sw.lap()
    out = {
        "offered": len(tickets),
        "served": served,
        "shed": shed,
        "shed_rate": shed / max(1, len(tickets)),
        "degraded": degraded,
        "deadline_misses": misses,
        "qps": served / max(wall_s, 1e-9),
        "p50_ms": float(np.percentile(lats, 50)) * 1e3 if lats else 0.0,
        "p99_ms": float(np.percentile(lats, 99)) * 1e3 if lats else 0.0,
        "final_level": fe.ladder.level,
    }
    obs.gauge("serve.frontend.qps").set(out["qps"])
    obs.gauge("serve.frontend.shed_rate").set(out["shed_rate"])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized corpus + short trace")
    ap.add_argument("--n", type=int, default=1 << 16)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--shard-bits", type=int, default=12)
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--overload", type=float, default=1.0,
                    help="time-compress the trace by this factor "
                         "(5.0 ⇒ the same requests offered 5× faster)")
    ap.add_argument("--base-qps", type=float, default=200.0)
    ap.add_argument("--burst-qps", type=float, default=2000.0)
    ap.add_argument("--deadline-ms", type=float, default=250.0)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--topk-k", type=int, default=8)
    ap.add_argument("--replay", type=str, default=None,
                    help="drive a recorded trace (JSONL) instead of "
                         "generating one")
    ap.add_argument("--record-trace", type=str, default=None,
                    help="write the generated trace here (JSONL) for "
                         "later --replay")
    ap.add_argument("--metrics-dir", type=str, default=None,
                    help="export obs metrics snapshot + JSONL events here "
                         "(inspect with `python -m repro.launch.obs`)")
    ap.add_argument("--profile-dir", type=str, default=None,
                    help="capture a jax.profiler device trace of the "
                         "serving section into this directory")
    args = ap.parse_args()
    if args.metrics_dir:
        obs.configure(args.metrics_dir)
    if args.smoke:
        args.n = min(args.n, 1 << 13)
        args.vocab = min(args.vocab, 64)
        args.shard_bits = min(args.shard_bits, 10)
        args.requests = min(args.requests, 300)

    toks = np.asarray(make_corpus(args.n, args.vocab, seed=args.seed),
                      np.int64)
    sw = obs.Stopwatch()
    eng = build_sharded_analytics(toks, args.vocab,
                                  shard_bits=args.shard_bits)
    eng.probe_shard(0)   # compile the liveness probe before the circuit
    #                      breakers put it under a timeout
    print(f"engine: {args.n} tokens, {eng.num_shards} shards "
          f"in {sw.lap():.2f}s")

    if args.replay:
        trace = load_trace(args.replay)
        print(f"replaying {len(trace)} requests from {args.replay} "
              f"at {args.overload:.1f}× speed")
    else:
        trace = make_trace(args.n, args.requests, args.seed,
                           base_qps=args.base_qps,
                           burst_qps=args.burst_qps,
                           burst_every_s=2.0, burst_len_s=0.5,
                           deadline_s=args.deadline_ms / 1e3,
                           topk_k=args.topk_k)
        if args.record_trace:
            save_trace(args.record_trace, trace)
            print(f"trace → {args.record_trace} ({len(trace)} requests)")

    fe = QueryFrontend(
        GenerationServer(eng),
        config=FrontendConfig(
            capacity=args.capacity, topk_k=args.topk_k,
            # smoke keeps the compile surface small: every (op, level,
            # bucket) variant is warmed below, and each bucket is 6 more
            # compiles
            buckets=(8, 32) if args.smoke else (8, 32, 128),
            # real-clock probe timings: the library defaults (50ms
            # logical deadline, 250ms interval) are sized for FakeClock
            # chaos tests; a real CPU probe costs tens of ms, so keep a
            # healthy margin or every breaker opens spuriously.
            breaker=BreakerConfig(probe_timeout_s=2.0,
                                  probe_interval_s=5.0,
                                  reset_after_s=2.0)))
    # Warm the jit cache (every op × bucket at the exact level, plus the
    # degraded variants at the smallest bucket), then re-seed the
    # admission EWMA from a steady-state batch: warmup pumps feed
    # compile-dominated service times into the EWMA, which would
    # otherwise shed the whole trace as over_budget before it starts.
    # Metrics are off for the whole block so the exported latency
    # histograms (and the --slo gate reading them) see only the trace.
    with obs.disabled():
        warm = (("count", {"sym_hi": args.vocab}),
                ("quantile", {"k": 0}), ("topk", {}))
        for op, kw in warm:
            for bucket in fe.config.buckets:
                for _ in range(bucket):
                    fe.submit(op, 0, args.n, deadline_s=600.0, **kw)
                while fe.queue.depth:
                    fe.pump()
                # the degraded variants must be warm at every bucket too:
                # one mid-burst compile stalls the pump for seconds
                for level in (1, 2):
                    mode, fn = fe._op_fn(op, level)
                    fe.runner.run((op, level), fn, eng,
                                  np.zeros((4, bucket), np.int32), bucket)
        compiled, warm_s = fe.runner.compiled, sw.lap()
        batch = fe.runner.max_batch
        steady_s = 0.0
        for _ in range(2):       # first batch may absorb a probe refresh
            for _ in range(batch):
                fe.submit("count", 0, args.n, deadline_s=600.0,
                          sym_hi=args.vocab)
            sw.lap()
            fe.pump()
            steady_s = sw.lap()
        for _ in range(30):
            fe.queue.observe_service(steady_s, batch)
    print(f"warmup: {compiled} variants compiled in {warm_s:.2f}s "
          f"(steady batch {steady_s * 1e3:.2f}ms)")

    obs.start_trace(args.profile_dir)
    fe.start()
    sw.lap()
    with obs.span("frontend.drive", requests=len(trace),
                  overload=args.overload):
        tickets = drive(fe, trace, args.overload, args.vocab)
        out = report(fe, tickets, sw)
    fe.stop(drain=True)
    if obs.stop_trace():
        print(f"device trace → {args.profile_dir}")

    print(f"offered {out['offered']} requests "
          f"({args.overload:.1f}× pacing): served {out['served']} "
          f"({out['qps']:.0f} q/s), shed {out['shed']} "
          f"({out['shed_rate']:.0%}), {out['degraded']} degraded, "
          f"{out['deadline_misses']} deadline misses")
    print(f"accepted latency p50 {out['p50_ms']:.2f}ms / "
          f"p99 {out['p99_ms']:.2f}ms; final degrade level "
          f"{out['final_level']}; shed reasons "
          f"{fe.stats()['shed']}")
    if args.metrics_dir:
        obs.write_snapshot()
        print(f"metrics → {args.metrics_dir}")


if __name__ == "__main__":
    main()
