"""Range-analytics CLI: build a sharded analytics store over the synthetic
corpus and serve a batched mixed query stream with per-op reporting.

PYTHONPATH=src python -m repro.launch.analytics --smoke
PYTHONPATH=src python -m repro.launch.analytics --n 524288 --vocab 4096 \
    --shard-bits 14 --queries 1024
PYTHONPATH=src python -m repro.launch.analytics --smoke --metrics-dir /tmp/m
PYTHONPATH=src python -m repro.launch.obs /tmp/m     # then inspect

Build: wavelet-matrix shards via the paper's τ-chunked construction
(pmap/vmap over the mesh when devices allow — ``data.shard_build``).
Serve: each op is one jitted function vmapped over the query batch and
fanned across shards; a 1024-query mixed stream compiles each op once
(shapes are static) and reports per-op latency + queries/s. A sample of
every op is verified against numpy on the regenerated raw stream.

``--metrics-dir`` captures the run through ``repro.obs``: per-op
``serve.analytics.*`` latency histograms / q/s / compile cost, build and
restore spans, path-selection counters, and a JSONL event log — rendered
by ``repro.launch.obs``. Serving ops additionally run under
``obs.profiled_op``, so the snapshot carries the ``prof.*`` cost-model
gauges (FLOPs, bytes, roofline utilization, peak working set) per op;
``--profile-dir`` wraps the serving section in a ``jax.profiler`` device
trace.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analytics import (build_sharded_analytics, load_analytics,
                             save_analytics, snapshot_meta)
from repro.data import make_corpus
from repro.launch.mesh import make_host_mesh, set_mesh


def make_queries(n: int, num: int, seed: int):
    """(lo, hi, k) batches: mixed narrow/wide ranges over the corpus."""
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, max(1, n - 1), num).astype(np.int32)
    width = np.where(rng.random(num) < 0.5,
                     rng.integers(1, 256, num),
                     rng.integers(256, max(512, n // 4), num))
    hi = np.minimum(lo + width, n).astype(np.int32)
    k = rng.integers(0, np.maximum(hi - lo, 1)).astype(np.int32)
    return lo, hi, k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized build + query + verification")
    ap.add_argument("--n", type=int, default=1 << 18)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--shard-bits", type=int, default=14)
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--verify", type=int, default=16,
                    help="# of queries per op to check against numpy")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--snapshot-dir", type=str, default=None,
                    help="persisted analytics snapshot: restore from here "
                         "when present (skipping the build), else build "
                         "and save here")
    ap.add_argument("--metrics-dir", type=str, default=None,
                    help="export obs metrics snapshot + JSONL events here "
                         "(inspect with `python -m repro.launch.obs`)")
    ap.add_argument("--profile-dir", type=str, default=None,
                    help="capture a jax.profiler device trace of the "
                         "serving section into this directory")
    args = ap.parse_args()
    if args.metrics_dir:
        obs.configure(args.metrics_dir)
    if args.smoke:
        args.n = min(args.n, 1 << 14)
        args.vocab = min(args.vocab, 512)
        args.shard_bits = min(args.shard_bits, 12)
        args.queries = min(args.queries, 256)

    toks = np.asarray(make_corpus(args.n, args.vocab, seed=args.seed),
                      np.int64)

    sw = obs.Stopwatch()
    restored = False
    save_snapshot = bool(args.snapshot_dir)
    if args.snapshot_dir:
        # probe meta.json BEFORE restoring arrays: geometry AND corpus
        # identity (seed) must match what this invocation will verify
        # against, else a stale snapshot would serve the wrong corpus
        try:
            meta = snapshot_meta(args.snapshot_dir)
            got = (meta["n"], meta["sigma"], meta["shard_bits"],
                   meta.get("corpus_seed"))
            want = (args.n, args.vocab, args.shard_bits, args.seed)
            if got == want:
                # verified restore; derived-leaf corruption is repaired
                # in place, primary corruption raises → rebuild below
                eng = load_analytics(args.snapshot_dir)
                restored = True
            else:
                print(f"snapshot (n, vocab, shard_bits, seed)={got} does "
                      f"not match requested {want} — rebuilding")
        except FileNotFoundError:
            pass
        except ValueError as e:
            # foreign checkpoint in the directory: rebuild, but never
            # overwrite someone else's data with our snapshot
            print(f"ignoring --snapshot-dir: {e}")
            save_snapshot = False
        except Exception as e:
            # unusable snapshot (unrepairable corruption, torn write,
            # missing leaves, …): warn and rebuild from source — a bad
            # snapshot must never take serving down
            print(f"WARNING: snapshot restore failed ({type(e).__name__}: "
                  f"{e}) — rebuilding from source")
    if not restored:
        from repro.robust import with_retry
        with obs.span("analytics.build", n=args.n, vocab=args.vocab,
                      shard_bits=args.shard_bits) as sp:
            eng = sp.sync(with_retry(
                lambda: build_sharded_analytics(toks, args.vocab,
                                                shard_bits=args.shard_bits),
                retries=2, backoff_s=0.1,
                on_retry=lambda a, e: print(
                    f"build attempt {a + 1} failed ({e}) — retrying")))
    jax.block_until_ready(jax.tree.leaves(eng.shards)[0])
    t_build = sw.lap()
    obs.gauge("serve.analytics.build_s").set(t_build)
    obs.gauge("serve.analytics.tokens_per_s").set(args.n / max(t_build,
                                                               1e-9))
    verb = "restore" if restored else "build"
    print(f"{verb}: {args.n} tokens, vocab {args.vocab}, "
          f"{eng.num_shards} shards of {eng.shard_size} in {t_build:.2f}s "
          f"({args.n / t_build / 1e3:.0f} ktok/s, "
          f"{eng.bits_per_token():.1f} bits/token, "
          f"{jax.local_device_count()} device(s))")
    if save_snapshot and not restored:
        path = save_analytics(eng, args.snapshot_dir,
                              extra_meta={"corpus_seed": args.seed})
        print(f"snapshot saved → {path}")

    lo, hi, k = make_queries(args.n, args.queries, args.seed + 1)
    loj, hij, kj = jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(k)
    sym_lo = jnp.asarray(lo % args.vocab, jnp.int32)
    sym_hi = jnp.minimum(sym_lo + 64, args.vocab)
    B = args.queries
    obs.gauge("serve.analytics.coverage").set(float(eng.coverage(0, args.n)))

    # cost-model profile of the construction path (one shard-sized build)
    # and the Pallas kernel descent, so the snapshot relates build/serve
    # time to the hardware roofline alongside the serving ops below
    from repro.core.wavelet_matrix import build_wavelet_matrix
    shard0 = jnp.asarray(toks[:eng.shard_size], jnp.int32)
    _, cstats = obs.profile_op(
        "analytics.construct_shard",
        lambda s: build_wavelet_matrix(s, eng.sigma),
        shard0, work_elements=float(eng.shard_size))
    if "roofline_util" in cstats:
        print(f"construct_shard: roofline {cstats['roofline_util']:.1%} "
              f"({cstats.get('bound', '?')}-bound)")
    nk = min(16, B)
    _, kstats = obs.profile_op(
        "analytics.quantile_kernel",
        lambda e, a, b, c: e.range_quantile(a, b, c, use_kernel=True),
        eng, loj[:nk], hij[:nk], kj[:nk], work_elements=float(nk))
    if "error" in kstats:
        print(f"quantile_kernel profile skipped: {kstats['error']}")

    mesh_ctx = set_mesh(make_host_mesh())
    with mesh_ctx, obs.span("analytics.serve", queries=B), \
            obs.trace(args.profile_dir):
        ops = {
            "quantile": (jax.jit(lambda e, a, b, c: e.range_quantile(a, b, c)),
                         (eng, loj, hij, kj)),
            "count": (jax.jit(lambda e, a, b, s0, s1:
                              e.range_count(a, b, s0, s1)),
                      (eng, loj, hij, sym_lo, sym_hi)),
            "topk": (jax.jit(lambda e, a, b: e.range_topk(a, b, args.topk)),
                     (eng, loj, hij)),
            "distinct": (jax.jit(lambda e, a, b: e.range_distinct(a, b)),
                         (eng, loj, hij)),
        }
        results = {}
        for name, (fn, fargs) in ops.items():
            out, t, t_c = obs.profiled_op("analytics", name, fn, *fargs,
                                          batch=B)
            results[name] = out
            print(f"{name}: {B} queries in {t * 1e3:.1f} ms "
                  f"({B / t:.0f} q/s; compile {t_c:.2f}s)")
    if args.profile_dir:
        print(f"device trace → {args.profile_dir}")

    bad = 0
    nv = min(args.verify, B)
    for i in range(nv):
        sl = toks[lo[i]:hi[i]]
        want_q = np.sort(sl)[k[i]] if len(sl) else -1
        if int(np.asarray(results["quantile"])[i]) != want_q:
            bad += 1
            print(f"  QUANTILE MISMATCH query {i}")
        want_c = int(((sl >= int(sym_lo[i])) & (sl < int(sym_hi[i]))).sum())
        if int(np.asarray(results["count"])[i]) != want_c:
            bad += 1
            print(f"  COUNT MISMATCH query {i}")
        if int(np.asarray(results["distinct"])[i]) != len(np.unique(sl)):
            bad += 1
            print(f"  DISTINCT MISMATCH query {i}")
        bc = np.bincount(sl, minlength=args.vocab)
        want_top = np.sort(bc[bc > 0])[::-1][:args.topk]
        syms_i = np.asarray(results["topk"][0])[i]
        cnts_i = np.asarray(results["topk"][1])[i]
        if not np.array_equal(cnts_i[syms_i >= 0], want_top):
            bad += 1
            print(f"  TOPK MISMATCH query {i}")
    if bad:
        raise SystemExit(f"{bad} verification failures")
    print(f"verified {nv} samples of each op against numpy ✓")
    if args.metrics_dir:
        obs.write_snapshot()
        print(f"metrics → {args.metrics_dir}")


if __name__ == "__main__":
    main()
