"""Chaos CLI: inject every fault class against snapshots and live engines
and prove each one is detected, repaired bit-identically, or served
degraded with an honest coverage report — never a silent wrong answer.

PYTHONPATH=src python -m repro.launch.chaos --smoke
PYTHONPATH=src python -m repro.launch.chaos --seed 7 --dir /tmp/chaos

Scenario matrix (each seeded, each independently pass/fail, nonzero exit
on any failure):

* clean restore                   — bit-identical round trip
* derived-leaf corruption         — rank tables / select samples / zeros
  flipped inside ``arrays.npz``: detected by the leaf checksums, repaired
  by recomputation, restored engine bit-identical to the saved one
* primary-bitmap corruption       — detected, classified unrepairable,
  restore raises and the caller rebuilds from source
* truncated / half-deleted steps  — skipped by ``latest_step``; restore
  falls back (older valid step or rebuild), never reads a torn file
* stale partial ``.tmp`` writes   — invisible to step discovery
* in-memory corruption            — structural verify localizes it with
  no checksum at all, repair restores bit-identity
* FM-index corruption             — C table / mark / SA samples re-derived
  from the BWT bitmaps (O(m) LF-walk SA reconstruction)
* shard loss                      — degraded serving with exact coverage
  fraction and count bounds that bracket the full-corpus truth
* ingest crash points             — the ingester is killed after every
  step of the two-phase shard commit protocol; journal replay + re-feed
  must reconverge to a serving state bit-identical to a clean build
* torn journal tail               — a crashed manifest append is dropped,
  the stream resumes from the last durable offset
* ingest quarantine / hot swap    — permanently failing shard builds are
  quarantined (honest coverage bounds), and epoch-fenced generation
  swaps never show a query batch a mixed corpus
* overload (serving front-end)    — a 5× request storm against the
  bounded admission queue: shed requests get explicit rejections, served
  requests beat their deadline or carry a degraded-mode tag whose
  bounds/brackets contain the numpy oracle, accepted p99 stays within
  the declared SLO
* slow shard (front-end)          — chaos-injected per-shard latency
  times out the hedged probes, the circuit breaker opens, and answers
  match the availability-mask oracle until the half-open probe recovers
* deadline storm                  — every hopeless request is explicitly
  rejected before dispatch; nothing is silently dropped
* stuck generation swap           — a swap whose drain fence never
  clears stalls only the *swapper*: the front-end keeps serving (new
  epoch), the pinned old session keeps its generation's truth
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analytics import load_analytics, save_analytics
from repro.analytics.engine import build_sharded_analytics
from repro.data import make_corpus
from repro.index import build_sharded_index
from repro.robust import (IntegrityError, corrupt_snapshot_leaf, delete_file,
                          flip_leaf_bit, inject_partial_tmp,
                          repair_analytics, repair_sharded_index,
                          trees_identical, truncate_file, verify_analytics,
                          verify_sharded_index)


class Check:
    """Collects scenario outcomes; prints a pass/fail matrix at the end."""

    def __init__(self):
        self.rows = []

    def record(self, name: str, ok: bool, detail: str = ""):
        self.rows.append((name, ok, detail))
        obs.counter("chaos.scenario",
                    outcome="pass" if ok else "fail").inc()
        obs.event("scenario", scenario=name, ok=ok,
                  detail=detail or None)
        mark = "PASS" if ok else "FAIL"
        print(f"  [{mark}] {name}" + (f" — {detail}" if detail else ""))

    @property
    def failures(self) -> int:
        return sum(1 for _, ok, _ in self.rows if not ok)


def _fresh_snapshot(eng, directory: Path, seed: int) -> Path:
    if directory.exists():
        shutil.rmtree(directory)
    return save_analytics(eng, directory, extra_meta={"corpus_seed": seed})


def _queries_match(a, b, lo, hi, k) -> bool:
    qa = np.asarray(a.range_quantile(lo, hi, k))
    qb = np.asarray(b.range_quantile(lo, hi, k))
    ha = np.asarray(a.range_histogram(lo, hi))
    hb = np.asarray(b.range_histogram(lo, hi))
    return np.array_equal(qa, qb) and np.array_equal(ha, hb)


def run_snapshot_scenarios(eng, snap_dir: Path, seed: int, check: Check):
    lo = np.asarray([0, 17, 1000], np.int32)
    hi = np.asarray([64, 900, 4000], np.int32)
    k = np.asarray([3, 100, 7], np.int32)

    # -- clean restore ----------------------------------------------------
    with obs.span("chaos.scenario", scenario="clean_restore"):
        _fresh_snapshot(eng, snap_dir, seed)
        restored = load_analytics(snap_dir)
        check.record("clean restore bit-identical",
                     trees_identical(restored.shards, eng.shards))

    # -- derived-leaf corruption: detected + repaired bit-identically -----
    for frag in ("superblock", "block", "sel1/sample", "sel0/sample",
                 "zeros"):
        with obs.span("chaos.scenario", scenario="derived_corruption",
                      leaf=frag):
            _fresh_snapshot(eng, snap_dir, seed)
            where = corrupt_snapshot_leaf(snap_dir, seed=seed,
                                          leaf_match=frag)
            try:
                healed = load_analytics(snap_dir)
                ok = (trees_identical(healed.shards, eng.shards)
                      and _queries_match(healed, eng, lo, hi, k))
                check.record(f"derived corruption repaired [{frag}]", ok,
                             where)
            except IntegrityError as e:
                check.record(f"derived corruption repaired [{frag}]", False,
                             f"unexpected {e}")

    # -- primary corruption: detected, classified, rebuild signalled ------
    with obs.span("chaos.scenario", scenario="primary_corruption"):
        _fresh_snapshot(eng, snap_dir, seed)
        where = corrupt_snapshot_leaf(snap_dir, seed=seed,
                                      leaf_match="bitvectors/rank/words")
        try:
            load_analytics(snap_dir)
            check.record("primary corruption raises", False,
                         "corrupt bitmap restored without error")
        except IntegrityError as e:
            check.record("primary corruption raises", "primary" in str(e),
                         where)

    # -- truncated npz: step skipped, restore falls back ------------------
    _fresh_snapshot(eng, snap_dir, seed)
    truncate_file(snap_dir, "arrays.npz", keep_frac=0.25)
    try:
        load_analytics(snap_dir)
        check.record("truncated npz skipped", False,
                     "restored from a torn file")
    except FileNotFoundError:
        check.record("truncated npz skipped", True,
                     "no valid step → caller rebuilds from source")

    # -- deleted meta.json: same escalation -------------------------------
    _fresh_snapshot(eng, snap_dir, seed)
    delete_file(snap_dir, "meta.json")
    try:
        load_analytics(snap_dir)
        check.record("half-deleted step skipped", False,
                     "restored from a half-deleted step")
    except FileNotFoundError:
        check.record("half-deleted step skipped", True)

    # -- stale partial .tmp + bare step dir: invisible to discovery -------
    _fresh_snapshot(eng, snap_dir, seed)
    inject_partial_tmp(snap_dir, step=99)
    try:
        restored = load_analytics(snap_dir)
        check.record("partial .tmp write ignored",
                     trees_identical(restored.shards, eng.shards))
    except Exception as e:                                # noqa: BLE001
        check.record("partial .tmp write ignored", False, str(e))


def run_memory_scenarios(eng, seed: int, check: Check):
    # structural verify needs no checksum: corrupt a live engine's rank
    # directory, localize it, repair, and recover bit-identity
    bad, where = flip_leaf_bit(eng, seed=seed, leaf_match="rank/block")
    report = verify_analytics(bad)
    detected = (not report.ok) and report.repairable
    healed = repair_analytics(bad)
    ok = (detected and verify_analytics(healed).ok
          and trees_identical(healed.shards, eng.shards))
    check.record("in-memory corruption verify+repair", ok, where)

    # primary bitmap flip: structural verify must detect it, and the
    # checksum backstop must refuse any "repair" built on the corrupt
    # bitmap — the chain that makes silent wrong answers impossible
    from repro.robust import tree_checksums
    want = tree_checksums(eng.shards)
    bad, where = flip_leaf_bit(eng, seed=seed + 1, leaf_match="rank/words")
    report = verify_analytics(bad)
    attempted = repair_analytics(bad)
    got = tree_checksums(attempted.shards)
    caught = any(got[k] != want[k] for k in want)
    check.record("in-memory primary flip detected + repair refused",
                 (not report.ok) and caught, where)


def run_index_scenarios(seed: int, check: Check):
    rng = np.random.default_rng(seed)
    n, vocab = 1 << 11, 64
    toks = rng.integers(0, vocab, n).astype(np.int64)
    idx = build_sharded_index(toks, vocab, shard_bits=9, sample_rate=32,
                              seam_overlap=7)

    # FM-index derived leaves (C, mark, sa_sample) re-derive from bitmaps
    for frag in ("C", "mark", "sa_sample"):
        bad, where = flip_leaf_bit(idx, seed=seed, leaf_match=frag)
        report = verify_sharded_index(bad)
        healed = repair_sharded_index(bad, deep=True)
        ok = ((not report.ok) and report.repairable
              and trees_identical(healed.shards, idx.shards))
        check.record(f"fm-index corruption repaired [{frag}]", ok, where)

    # shard loss: degraded counts + honest bounds
    pat = toks[100:104].astype(np.int32)
    deg = idx.drop_shards(np.asarray([1], np.int32))
    lower, upper, cov = deg.count_bounds(pat[None, :], np.asarray([4]))
    full = int(idx.count(pat[None, :], np.asarray([4]))[0])
    win = np.lib.stride_tricks.sliding_window_view(toks, 4)
    hits = np.nonzero((win == pat).all(axis=1))[0]
    sh = hits >> 9
    end_sh = (hits + 3) >> 9
    want_deg = int(np.sum((sh != 1) & (end_sh != 1)))
    ok = (int(lower[0]) == want_deg
          and int(lower[0]) <= full <= int(upper[0])
          and 0.0 < float(cov) < 1.0)
    check.record("degraded index serves with bounds", ok,
                 f"coverage {float(cov):.2f}, "
                 f"count ∈ [{int(lower[0])}, {int(upper[0])}], true {full}")


def run_ingest_scenarios(seed: int, scratch: Path, check: Check):
    """Crash-point sweep over the two-phase shard commit protocol.

    For every protocol step: arm ``crash_after(step)``, feed the stream,
    die, then recover in a "new process" (fresh ingester, journal
    replay), re-feed from ``resume_offset``, and demand the served engine
    is *bit-identical* to a clean from-scratch build — plus torn-journal,
    quarantine-coverage and hot-swap generation checks.
    """
    from repro.analytics.engine import ShardedAnalytics
    from repro.data.compressed_store import build_compressed_corpus
    from repro.ingest import (COMMIT_STEPS, GenerationServer, ShardIngester,
                              analytics_ingester, read_journal)
    from repro.robust import CrashInjected, crash_after, verify_manifest

    rng = np.random.default_rng(seed)
    n, vocab, shard_bits = 1 << 11, 64, 8
    toks = rng.integers(0, vocab, n).astype(np.int64)
    ref = ShardedAnalytics.from_corpus(
        build_compressed_corpus(toks, vocab, shard_bits=shard_bits,
                                parallel=False))

    def fresh(d):
        return analytics_ingester(d, vocab, shard_bits=shard_bits,
                                  backoff_s=0.0)

    # -- crash after every protocol step → recover → bit-identical --------
    for step in COMMIT_STEPS:
        with obs.span("chaos.scenario", scenario="ingest_crash", step=step):
            d = scratch / f"ingest_{step}"
            ing = fresh(d)
            ing.recover()
            died = False
            try:
                with crash_after(step):
                    ing.append_tokens(toks)
                    ing.flush()
            except CrashInjected:
                died = True
            ing2 = fresh(d)
            rep = ing2.recover()
            ing2.append_tokens(toks[rep.resume_offset:])
            ing2.flush()
            eng = ing2.engine()
            ok = (died and eng.available is None
                  and trees_identical(eng.shards, ref.shards)
                  and verify_manifest(d).ok)
            check.record(f"ingest crash@{step} recovers bit-identical", ok,
                         rep.summary())

    # -- torn journal tail: dropped, stream resumes -----------------------
    with obs.span("chaos.scenario", scenario="ingest_torn_tail"):
        d = scratch / "ingest_torn"
        ing = fresh(d)
        ing.recover()
        ing.append_tokens(toks)
        ing.flush()
        j = d / "manifest.jsonl"
        j.write_bytes(j.read_bytes()[:-7])          # crash mid-append
        _, torn = read_journal(j, strict=False)
        ing2 = fresh(d)
        rep = ing2.recover()
        ing2.append_tokens(toks[rep.resume_offset:])
        ing2.flush()
        eng = ing2.engine()
        check.record("ingest torn journal tail dropped + resumed",
                     torn and trees_identical(eng.shards, ref.shards),
                     rep.summary())

    # -- permanent build failure: quarantined, served with bounds ---------
    with obs.span("chaos.scenario", scenario="ingest_quarantine"):
        d = scratch / "ingest_quarantine"
        calls = {"n": 0}

        def build(s):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("poisoned batch")
            from repro.core.wavelet_matrix import build_wavelet_matrix
            return build_wavelet_matrix(s, vocab, sample_rate=512)

        ing = ShardIngester(d, build, shard_bits, sigma=vocab,
                            kind="analytics", token_dtype=np.uint32,
                            retries=0, backoff_s=0.0)
        ing.recover()
        ing.append_tokens(toks)
        ing.flush()
        eng = ing.engine()
        lower, upper, cov = eng.range_count_bounds(0, n, 0, vocab // 2)
        truth = int(ref.range_count(0, n, 0, vocab // 2))
        ok = (eng.degraded
              and int(lower) <= truth <= int(upper)
              and 0.0 < float(cov) < 1.0
              and verify_manifest(d).ok)
        check.record("ingest quarantine serves honest bounds", ok,
                     f"coverage {float(cov):.2f}, "
                     f"count ∈ [{int(lower)}, {int(upper)}], true {truth}")

    # -- hot swap: fenced generation bump, no mixed-corpus answer ---------
    with obs.span("chaos.scenario", scenario="ingest_hot_swap"):
        d = scratch / "ingest_swap"
        ing = fresh(d)
        ing.recover()
        cut = (n >> shard_bits >> 1) << shard_bits
        ing.append_tokens(toks[:cut])
        srv = GenerationServer(ing.engine())
        with srv.session() as (gen0, eng0):
            ing.append_tokens(toks[cut:])
            ing.flush()
            new = ing.serve_entries()[cut >> shard_bits:]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[ing.shard_tree(e) for e in new])
            eng1 = eng0.add_shards(stacked, n - cut)
            srv.swap_generation(eng1, wait_drain=False)
            # the pinned session still sees the old corpus…
            old_n = int(eng0.range_count(0, eng0.n, 0, vocab))
        gen1, eng_now = srv.pin()
        ok = (old_n == cut and gen1 == gen0 + 1
              and eng_now.n == n
              and trees_identical(eng_now.shards, ref.shards))
        check.record("ingest hot swap fences generations", ok,
                     f"gen {gen0}→{gen1}, n {cut}→{eng_now.n}")


def run_overload_scenarios(seed: int, check: Check):
    """Serving front-end under overload, on a fake clock: request storms,
    slow shards, deadline storms, stuck generation swaps. Every decision
    (shed, degrade, breaker trip) is asserted against explicit rejections
    and numpy oracles — overload must produce bounded, honest answers,
    never silence or stalls.
    """
    import threading

    from repro.ingest.serving import GenerationServer
    from repro.robust import FakeClock, inject_shard_latency
    from repro.serving import (FrontendConfig, LadderConfig, QueryFrontend,
                               ShedError)

    rng = np.random.default_rng(seed)
    n, vocab, shard_bits = 1 << 11, 64, 8
    toks = rng.integers(0, vocab, n).astype(np.uint32)
    eng = build_sharded_analytics(toks, vocab, shard_bits=shard_bits)
    srt = np.sort(toks)
    half_exact = int(np.sum(toks < vocab // 2))

    # -- 5× request storm: bounded, honest, explicit ----------------------
    # One modeled worker: a batch of b requests occupies it b·service_s of
    # logical time, during which arrivals (at 5× the service rate) pile
    # into the bounded queue — the sustained-overload regime where every
    # defense (queue_full, over_budget, expired, the ladder) must engage.
    with obs.span("chaos.scenario", scenario="overload_storm"):
        clock = FakeClock()
        fe = QueryFrontend(
            GenerationServer(eng),
            config=FrontendConfig(capacity=64, buckets=(8, 32),
                                  probe_shards=False,
                                  ladder=LadderConfig(up_pressure=0.5)),
            clock=clock)
        service_s = 2e-3             # modeled per-request service cost
        slo_s = 0.08                 # per-request deadline = declared SLO
        for _ in range(30):          # converge the sojourn EWMA
            fe.queue.observe_service(8 * service_s, 8)
        arrival_s = service_s / 5.0  # 5× the modeled capacity
        storm = []
        next_free = 0.0
        for i in range(400):
            if i % 2 == 0:
                t = fe.submit("count", 0, n, sym_lo=0, sym_hi=vocab // 2,
                              deadline_s=slo_s)
                storm.append(("count", 0, t))
            else:
                k = int(rng.integers(0, n))
                t = fe.submit("quantile", 0, n, k=k, deadline_s=slo_s)
                storm.append(("quantile", k, t))
            clock.advance(arrival_s)
            if clock.now() >= next_free:
                served = fe.pump()
                next_free = clock.now() + served * service_s
        while True:                  # drain the tail
            if clock.now() < next_free:
                clock.advance(next_free - clock.now())
            served = fe.pump()
            if not served:
                break
            next_free = clock.now() + served * service_s
        st = fe.stats()
        reasons = set()
        lats, bad = [], []
        degraded = 0
        for op, k, t in storm:
            if t.shed:
                try:
                    t.result(0)
                except ShedError as e:
                    reasons.add(e.reason)
                continue
            a = t.result(0)
            lats.append(a.latency_s)
            if a.degraded:
                degraded += 1
            if not (a.deadline_met or a.degraded):
                bad.append((op, "late exact answer"))
            if op == "count":
                if a.mode == "exact":
                    ok_v = a.value == half_exact
                else:
                    lo_c, up_c = a.value
                    ok_v = lo_c <= half_exact <= up_c
            else:
                oracle = int(srt[k])
                if a.mode == "exact":
                    ok_v = a.value == oracle
                else:
                    lo_s, hi_s = a.value
                    ok_v = lo_s <= oracle < hi_s
            if not ok_v:
                bad.append((op, a.mode, a.value))
        accounted = (st["submitted"] == 400
                     and st["submitted"] == st["served"] + st["total_shed"]
                     and st["queued"] == 0)
        shed_rate = st["total_shed"] / 400
        p99 = float(np.percentile(lats, 99)) if lats else 0.0
        check.record(
            "overload storm: bounded queue, explicit sheds, full accounting",
            accounted and st["total_shed"] > 0
            and reasons <= {"queue_full", "over_budget", "expired"},
            f"served {st['served']}, shed {st['total_shed']} "
            f"({shed_rate:.0%}: {sorted(reasons)})")
        check.record(
            "overload answers honest: deadline met or degraded-tagged, "
            "bounds bracket oracle",
            not bad and degraded > 0,
            f"{degraded} degraded answers, {len(bad)} violations")
        check.record("overload accepted p99 within SLO",
                     bool(lats) and p99 <= slo_s,
                     f"p99 {p99 * 1e3:.1f}ms ≤ {slo_s * 1e3:.0f}ms "
                     f"over {len(lats)} accepted")
        fe.breakers.close_pool()

    # -- chaos shard latency: hedged timeout → breaker → mask oracle ------
    with obs.span("chaos.scenario", scenario="overload_slow_shard"):
        clock = FakeClock()
        fe = QueryFrontend(GenerationServer(eng),
                           config=FrontendConfig(probe_shards=True),
                           clock=clock)
        with inject_shard_latency(3, 9.0):
            for _ in range(fe.config.breaker.fail_threshold):
                fe.submit("count", 0, n, deadline_s=1e6)
                fe.pump()
        opened = fe.stats()["open_breakers"] == [3]
        t = fe.submit("count", 0, n, deadline_s=1e6)
        fe.pump()
        a = t.result(0)
        oracle = int(eng.drop_shards([3]).range_count(0, n, 0, vocab))
        clock.advance(fe.config.breaker.reset_after_s + 1.0)
        fe.submit("count", 0, n, deadline_s=1e6)
        fe.pump()
        recovered = fe.stats()["open_breakers"] == []
        check.record(
            "slow shard: breaker opens, answers match availability-mask "
            "oracle, half-open recovers",
            opened and recovered and a.degraded and a.value == oracle
            and float(a.coverage) < 1.0,
            f"coverage {float(a.coverage):.2f}, count {a.value} "
            f"(oracle {oracle})")
        fe.breakers.close_pool()

    # -- deadline storm: all hopeless work explicitly rejected ------------
    with obs.span("chaos.scenario", scenario="deadline_storm"):
        clock = FakeClock()
        fe = QueryFrontend(GenerationServer(eng),
                           config=FrontendConfig(probe_shards=False),
                           clock=clock)
        storm = [fe.submit("count", 0, n, deadline_s=0.01)
                 for _ in range(32)]
        clock.advance(1.0)           # every deadline blows while queued
        while fe.pump():
            pass
        reasons = set()
        for t in storm:
            try:
                t.result(0)
                reasons.add("SERVED")
            except ShedError as e:
                reasons.add(e.reason)
        check.record(
            "deadline storm: every request explicitly rejected pre-dispatch",
            all(t.shed for t in storm) and fe.stats()["served"] == 0
            and "SERVED" not in reasons,
            f"reasons {sorted(reasons)}")
        fe.breakers.close_pool()

    # -- stuck swap_generation: stalls the swapper, never the queue -------
    with obs.span("chaos.scenario", scenario="stuck_swap"):
        srv = GenerationServer(eng)
        clock = FakeClock()
        fe = QueryFrontend(srv, config=FrontendConfig(probe_shards=False),
                           clock=clock)
        eng2 = build_sharded_analytics(
            np.concatenate([toks, toks]), vocab, shard_bits=shard_bits)
        entered, release = threading.Event(), threading.Event()
        old_answer = []

        def holder():
            with srv.session() as (_, e0):
                old_answer.append(int(e0.range_count(0, e0.n, 0, vocab)))
                entered.set()
                release.wait(30)

        h = threading.Thread(target=holder)
        h.start()
        entered.wait(5)
        swap_done = threading.Event()

        def swapper():
            srv.swap_generation(eng2, wait_drain=True, timeout_s=30)
            swap_done.set()

        sw = threading.Thread(target=swapper)
        sw.start()
        answers = []
        for _ in range(4):           # swapper is fenced on the holder…
            t = fe.submit("count", 0, 2 * n, deadline_s=10.0)
            fe.pump()
            answers.append(t.result(5))
        stuck = not swap_done.is_set()
        release.set()
        h.join(10)
        sw.join(10)
        served_new = all(a.generation == 1 and a.value == 2 * n
                         for a in answers)
        check.record(
            "stuck swap: front-end serves on (new epoch), pinned session "
            "keeps old truth, fence completes on drain",
            stuck and served_new and old_answer == [n]
            and swap_done.is_set(),
            f"{len(answers)} answers served while fence blocked")
        fe.breakers.close_pool()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (sizes are already small)")
    ap.add_argument("--n", type=int, default=1 << 12)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--shard-bits", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dir", type=str, default=None,
                    help="scratch directory for snapshot faults "
                         "(default: a fresh tempdir)")
    ap.add_argument("--metrics-dir", type=str, default=None,
                    help="export obs metrics + the correlated "
                         "injection→detection→repair span tree here "
                         "(inspect with `python -m repro.launch.obs "
                         "<dir> --tree`)")
    ap.add_argument("--profile-dir", type=str, default=None,
                    help="capture a jax.profiler device trace of the "
                         "scenario run into this directory")
    args = ap.parse_args()
    if args.metrics_dir:
        obs.configure(args.metrics_dir)
    obs.start_trace(args.profile_dir)

    toks = np.asarray(make_corpus(args.n, args.vocab, seed=args.seed),
                      np.int64)
    eng = build_sharded_analytics(toks, args.vocab,
                                  shard_bits=args.shard_bits)
    jax.block_until_ready(jax.tree.leaves(eng.shards)[0])
    print(f"chaos target: {args.n} tokens, {eng.num_shards} shards, "
          f"seed {args.seed}")

    scratch = Path(args.dir) if args.dir else Path(
        tempfile.mkdtemp(prefix="chaos_"))
    snap_dir = scratch / "snapshot"
    check = Check()
    try:
        print("snapshot fault injection:")
        with obs.span("chaos.snapshot"):
            run_snapshot_scenarios(eng, snap_dir, args.seed, check)
        print("in-memory fault injection:")
        with obs.span("chaos.memory"):
            run_memory_scenarios(eng, args.seed, check)
        print("text-index fault injection:")
        with obs.span("chaos.index"):
            run_index_scenarios(args.seed, check)
        print("streaming-ingest crash injection:")
        with obs.span("chaos.ingest"):
            run_ingest_scenarios(args.seed, scratch / "ingest", check)
        print("serving front-end overload injection:")
        with obs.span("chaos.overload"):
            run_overload_scenarios(args.seed, check)
    finally:
        if not args.dir:
            shutil.rmtree(scratch, ignore_errors=True)

    total = len(check.rows)
    if obs.stop_trace():
        print(f"device trace → {args.profile_dir}")
    if args.metrics_dir:
        obs.write_snapshot()
        print(f"metrics → {args.metrics_dir}")
    if check.failures:
        raise SystemExit(
            f"chaos: {check.failures}/{total} scenarios FAILED")
    print(f"chaos: all {total} scenarios survived ✓")


if __name__ == "__main__":
    main()
