"""Telemetry summary CLI: render a ``--metrics-dir`` capture into a
per-op SLO table (p50/p95/p99/max latency, q/s, batch, compile cost) with
optional threshold checks, path-selection counters, and the correlated
span tree of a run.

PYTHONPATH=src python -m repro.launch.analytics --smoke --metrics-dir /tmp/m
PYTHONPATH=src python -m repro.launch.obs /tmp/m
PYTHONPATH=src python -m repro.launch.obs /tmp/m \
    --slo 'analytics.*:p99_ms<=2000' --slo 'analytics.quantile:qps>=100'
PYTHONPATH=src python -m repro.launch.obs /tmp/m --tree       # span tree
PYTHONPATH=src python -m repro.launch.obs /tmp/m --prometheus # text format
PYTHONPATH=src python -m repro.launch.obs /tmp/m --html /tmp/m/dash.html

``--html`` writes the self-contained dashboard page (SLO table, roofline
profile, span waterfall, and — with ``--history`` or the default
``results/bench/history.jsonl`` — per-commit bench-trajectory
sparklines).

Exit status is nonzero when any ``--slo`` check is violated, so the
command doubles as a CI gate on serving latency.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.obs import prometheus_text, read_events, read_snapshot
from repro.obs.history import read_history
from repro.obs.html import render_html
from repro.obs.report import check_slos, op_rows, render_span_tree, \
    render_table

DEFAULT_HISTORY = (Path(__file__).resolve().parents[3]
                   / "results" / "bench" / "history.jsonl")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="render an obs --metrics-dir capture into an SLO table")
    ap.add_argument("metrics_dir", type=Path)
    ap.add_argument("--slo", action="append", default=[],
                    help="threshold check '<op-glob>:<field><=|>=value', "
                         "e.g. 'analytics.*:p99_ms<=50' or "
                         "'index.count:qps>=100'; repeatable, any "
                         "violation exits nonzero")
    ap.add_argument("--tree", action="store_true",
                    help="also render the span tree from events.jsonl "
                         "(chaos runs: injection→detection→repair)")
    ap.add_argument("--counters", action="store_true",
                    help="also dump path-selection counters and gauges")
    ap.add_argument("--prometheus", action="store_true",
                    help="print the snapshot in Prometheus text format "
                         "and exit")
    ap.add_argument("--html", type=Path, default=None, metavar="OUT",
                    help="write the static HTML dashboard to OUT and exit")
    ap.add_argument("--history", type=Path, default=DEFAULT_HISTORY,
                    help="bench history JSONL for the dashboard's "
                         f"trajectory section (default {DEFAULT_HISTORY})")
    args = ap.parse_args(argv)

    try:
        snap = read_snapshot(args.metrics_dir)
    except FileNotFoundError:
        print(f"no {args.metrics_dir}/snapshot.json — run a CLI with "
              f"--metrics-dir first", file=sys.stderr)
        return 2

    if args.prometheus:
        print(prometheus_text(snap), end="")
        return 0

    if args.html is not None:
        page = render_html(snap=snap,
                           events=read_events(args.metrics_dir),
                           history=read_history(args.history),
                           slo_specs=args.slo or None)
        args.html.parent.mkdir(parents=True, exist_ok=True)
        args.html.write_text(page)
        print(f"wrote {args.html}")
        return 0

    rows = op_rows(snap)
    slo_results = check_slos(rows, args.slo) if args.slo else []
    if rows:
        print(render_table(rows, slo_results))
    else:
        print("no serve.* op metrics in snapshot")

    violations = [r for r in slo_results if not r.ok]
    if slo_results:
        print()
        for res in slo_results:
            mark = "ok " if res.ok else "FAIL"
            target = res.op or "(no match)"
            print(f"  [{mark}] {res.spec} @ {target}: {res.detail}")

    if args.counters:
        print("\ncounters:")
        for k, v in snap.get("counters", {}).items():
            print(f"  {k} = {v}")
        gauges = snap.get("gauges", {})
        if gauges:
            print("gauges:")
            for k, v in gauges.items():
                print(f"  {k} = {v}")

    if args.tree:
        events = read_events(args.metrics_dir)
        tree = render_span_tree(events)
        print("\nspan tree:")
        print(tree if tree else "  (no span events)")

    if violations:
        print(f"\n{len(violations)} SLO violation(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
