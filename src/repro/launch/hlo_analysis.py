"""Post-SPMD HLO analysis: per-device dot FLOPs and collective bytes.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports any scan-over-layers program by ~num_layers×. This module
re-derives both quantities from ``compiled.as_text()``:

* builds the computation call graph (while bodies via their
  ``backend_config known_trip_count``, fusions/calls/conditionals with
  multiplier 1),
* walks every computation with its execution multiplier,
* dot FLOPs: 2 × numel(result) × contraction size (operand shapes resolved
  through a per-computation symbol table),
* collective bytes: result-shape bytes of every all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (≈ bytes each device
  receives per step).
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "bf16": 2, "f16": 2, "u16": 2, "s16": 2,
                "f32": 4, "s32": 4, "u32": 4,
                "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
# computation headers are the only non-indented "%name (" lines (params may
# contain nested tuple parens, so only anchor on the name)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape(sig: str) -> Tuple[str, str]:
    m = _SHAPE_RE.search(sig)
    return (m.group(1), m.group(2)) if m else ("f32", "")


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "{" in line:
            cur = hdr.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line)
    return comps


def _entry_name(hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    return m.group(1) if m else next(iter(parse_computations(hlo)))


def analyze_hlo(hlo: str) -> Dict:
    comps = parse_computations(hlo)
    entry = _entry_name(hlo)

    # ---- per-computation: symbol table + edges + local costs ------------
    sym: Dict[str, Dict[str, Tuple[str, str]]] = {}
    edges: Dict[str, List[Tuple[str, int]]] = {}
    local_flops: Dict[str, float] = {}
    local_coll: Dict[str, Dict[str, int]] = {}

    for cname, lines in comps.items():
        table: Dict[str, Tuple[str, str]] = {}
        cedges: List[Tuple[str, int]] = []
        flops = 0.0
        coll: Dict[str, int] = {}
        for line in lines:
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            iname, rest = mi.groups()
            dt, dims = _first_shape(rest)
            table[iname] = (dt, dims)
            # ---- call edges ----
            if " while(" in rest:
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                trip = 1
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
                if mt:
                    trip = int(mt.group(1))
                if mb:
                    cedges.append((mb.group(1), trip))
                mc = re.search(r"condition=%?([\w.\-]+)", rest)
                if mc:
                    cedges.append((mc.group(1), trip))
            for mcall in re.finditer(
                    r"(?:calls=|to_apply=)%?([\w.\-]+)", rest):
                cedges.append((mcall.group(1), 1))
            for mbr in re.finditer(
                    r"(?:true_computation=|false_computation=|branch_computations=\{)"
                    r"%?([\w.\-]+)", rest):
                cedges.append((mbr.group(1), 1))
            # ---- collectives ----
            # XLA:CPU's FloatSupport promotes bf16 all-reduces to f32
            # (reducer named "*promoted"); TPU all-reduces bf16 natively,
            # so promoted ops are counted at their true 2-byte width.
            def _cbytes():
                b = _numel(dims) * _DTYPE_BYTES.get(dt, 4)
                if dt == "f32" and "promoted" in rest:
                    b //= 2
                return b

            for kind in _COLLECTIVES:
                if f" {kind}(" in rest or rest.startswith(f"{kind}("):
                    if f"{kind}-start" in rest or f"{kind}-done" in rest:
                        continue
                    coll[kind] = coll.get(kind, 0) + _cbytes()
                    break
            for kind in _COLLECTIVES:
                if f" {kind}-start(" in rest:
                    coll[kind] = coll.get(kind, 0) + _cbytes()
                    break
            # ---- dot flops ----
            if " dot(" in rest:
                ops = re.findall(r"%([\w.\-]+)", rest)
                lhs = ops[0] if ops else None
                mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                csize = 1
                if lhs and lhs in table and mcd:
                    ldims = table[lhs][1].split(",")
                    for ci in mcd.group(1).split(","):
                        if ci and int(ci) < len(ldims) and ldims[int(ci)]:
                            csize *= int(ldims[int(ci)])
                flops += 2.0 * _numel(dims) * csize
        sym[cname] = table
        edges[cname] = cedges
        local_flops[cname] = flops
        local_coll[cname] = coll

    # ---- propagate multipliers from entry -------------------------------
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        mult[name] = mult.get(name, 0.0) + m
        for child, trip in edges.get(name, ()):  # conditions counted too
            visit(child, m * trip)

    visit(entry, 1.0)

    total_flops = sum(local_flops.get(c, 0.0) * m for c, m in mult.items())
    total_coll: Dict[str, float] = {}
    for c, m in mult.items():
        for kind, b in local_coll.get(c, {}).items():
            total_coll[kind] = total_coll.get(kind, 0.0) + b * m
    return {"dot_flops_per_device": total_flops,
            "collective_bytes_per_device": total_coll,
            "num_computations": len(comps)}


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze_hlo(open(sys.argv[1]).read()), indent=1))
