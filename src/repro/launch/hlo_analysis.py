"""Back-compat shim: the post-SPMD HLO analysis moved into
``repro.obs.prof`` so the profiling layer (cost-model gauges, roofline
utilization, the dryrun roofline tables) shares one implementation.
Import :func:`repro.obs.prof.analyze_hlo` directly in new code.
"""
from __future__ import annotations

from repro.obs.prof import analyze_hlo, parse_computations  # noqa: F401

__all__ = ["analyze_hlo", "parse_computations"]

if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(analyze_hlo(open(sys.argv[1]).read()), indent=1))
