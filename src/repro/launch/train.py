"""Training CLI.

CPU (development):  PYTHONPATH=src python -m repro.launch.train \
    --arch qwen2_0_5b --smoke --steps 50
Mesh runs place params/opt-state with the same GSPMD shardings the dry-run
compiles (--mesh host uses a 1×1 mesh so the sharded code path is exercised
end-to-end on one chip).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.data import TokenBatcher, build_compressed_corpus, make_corpus
from repro.launch.mesh import dp_axes, make_host_mesh, set_mesh
from repro.models import shard_ctx
from repro.models.model import build_model, param_specs
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--compress-bits", type=int, default=0,
                    help="error-feedback bitplane gradient compression")
    ap.add_argument("--corpus-tokens", type=int, default=1 << 20)
    ap.add_argument("--compressed-corpus", action="store_true",
                    help="serve batches from the wavelet-matrix store")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={model_param_count(model):,}")

    toks = make_corpus(args.corpus_tokens, cfg.vocab_size, seed=args.seed)
    if args.compressed_corpus:
        corpus = build_compressed_corpus(toks, cfg.vocab_size)
        print(f"compressed corpus: {corpus.bits_per_token():.2f} bits/token "
              f"(raw 32)")
        batcher = TokenBatcher(corpus=corpus, batch=args.batch,
                               seq_len=args.seq, seed=args.seed)
    else:
        batcher = TokenBatcher(tokens=toks, batch=args.batch,
                               seq_len=args.seq, seed=args.seed)

    if args.mesh == "host":
        mesh = make_host_mesh()
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        shard_ctx.set_mesh_context(dp_axes(mesh), sizes)
        ctx = set_mesh(mesh)
        ctx.__enter__()

    trainer = Trainer(
        model, batcher, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, seed=args.seed,
        log_every=args.log_every, grad_accum=args.grad_accum,
        base_lr=args.lr, warmup=args.warmup, total_steps=args.steps,
        compress_bits=args.compress_bits)
    if args.resume:
        start = trainer.maybe_resume()
        print(f"resumed at step {start}")
    trainer.run(args.steps)
    if trainer.history:
        first, last = trainer.history[0], trainer.history[-1]
        print(f"loss {first['loss']:.4f} -> {last['loss']:.4f} over "
              f"{last['step'] - trainer.history[0]['step'] + trainer.log_every} steps")


def model_param_count(model) -> int:
    import math
    sizes = [math.prod(s.shape) for s in
             jax.tree.leaves(model.abstract_params())]
    return sum(sizes)


if __name__ == "__main__":
    main()
