"""*Model*-serving CLI: batched prefill + autoregressive decode.

(The analytics *query* front-end — admission control, load shedding,
degradation ladder over the sharded wavelet-matrix engine — has its own
CLI in ``repro.launch.frontend``.)

PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
    --batch 4 --prompt-len 64 --decode-steps 32

``--ckpt-dir`` restores params from an integrity-verified checkpoint
(first run saves one). Model weights are not derivable from anything, so
a failed verification cannot be repaired — the CLI warns and falls back
to fresh init rather than serving silently corrupted weights.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import get_config
from repro.data import make_corpus
from repro.models.model import build_model, zero_cache


def _params_with_checkpoint(model, seed: int, ckpt_dir: str | None):
    """Fresh-init params, replaced by a verified checkpoint restore when
    ``ckpt_dir`` holds one. Any restore failure — corruption, torn write,
    structure mismatch — warns and serves the fresh init; an empty
    directory is seeded with a checkpoint for the next run."""
    params = model.init(seed)
    if not ckpt_dir:
        return params, "init"
    from repro.checkpoint import (latest_step, restore_checkpoint,
                                  save_checkpoint)
    if latest_step(ckpt_dir) is None:
        save_checkpoint(ckpt_dir, 0, params,
                        extra_meta={"kind": "serve_params", "seed": seed})
        return params, "init (checkpoint saved)"
    try:
        restored, meta = restore_checkpoint(ckpt_dir, params)
        if meta.get("kind") not in (None, "serve_params"):
            raise ValueError(f"not a serve checkpoint "
                             f"(kind={meta.get('kind')!r})")
        return restored, "restore (verified)"
    except Exception as e:
        print(f"WARNING: checkpoint restore failed ({type(e).__name__}: "
              f"{e}) — serving fresh init")
        return params, "init (restore failed)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=0,
                    help="cache length (default prompt+decode)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", type=str, default=None,
                    help="params checkpoint: verified restore when "
                         "present, fresh init (saved here) otherwise")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-dir", type=str, default=None,
                    help="export obs metrics snapshot + JSONL events here "
                         "(inspect with `python -m repro.launch.obs`)")
    ap.add_argument("--profile-dir", type=str, default=None,
                    help="capture a jax.profiler device trace of "
                         "prefill+decode into this directory")
    args = ap.parse_args()
    if args.metrics_dir:
        obs.configure(args.metrics_dir)
    obs.start_trace(args.profile_dir)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params, origin = _params_with_checkpoint(model, args.seed, args.ckpt_dir)
    print(f"params: {origin}")
    b = args.batch
    max_seq = args.max_seq or (args.prompt_len + args.decode_steps)

    toks = make_corpus(args.prompt_len * b * 4, cfg.vocab_size,
                       seed=args.seed)
    prompts = toks[:b * args.prompt_len].reshape(b, args.prompt_len)
    prompts = jnp.asarray(prompts, jnp.int32)
    extras = {k: jnp.zeros(shp, jnp.bfloat16)
              for k, shp in model.extras_shapes(b).items()} or None

    # ---- prefill: batch forward, last-position logits --------------------
    prefill = jax.jit(lambda p, t: model.prefill(p, t, extras))
    sw = obs.Stopwatch()
    logits = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = sw.lap()
    obs.histogram("serve.model.prefill.latency_s").observe(t_prefill)
    obs.gauge("serve.model.prefill.batch").set(b)
    print(f"prefill: {b}×{args.prompt_len} tokens in {t_prefill*1e3:.1f} ms "
          f"({b*args.prompt_len/t_prefill:.0f} tok/s)")

    # ---- warm the cache with the prompt (teacher-forced decode) ----------
    decode = jax.jit(model.decode_step)
    cache = zero_cache(cfg, b, max_seq)
    for i in range(args.prompt_len):
        _, cache = decode(params, prompts[:, i:i + 1], cache,
                          jnp.full((b,), i, jnp.int32))

    # ---- autoregressive decode -------------------------------------------
    key = jax.random.PRNGKey(args.seed)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    sw.lap()
    for s in range(args.decode_steps - 1):
        pos = jnp.full((b,), args.prompt_len + s, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature, axis=-1)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
        tok = tok.astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_dec = sw.lap()
    obs.histogram("serve.model.decode.latency_s").observe(t_dec)
    obs.gauge("serve.model.decode.batch").set(b)
    obs.gauge("serve.model.decode.qps").set(
        b * (args.decode_steps - 1) / max(t_dec, 1e-9))
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decode: {b}×{args.decode_steps} tokens in {t_dec*1e3:.1f} ms "
          f"({b*(args.decode_steps-1)/max(t_dec,1e-9):.0f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())
    obs.record_memory_gauges()
    if obs.stop_trace():
        print(f"device trace → {args.profile_dir}")
    if args.metrics_dir:
        obs.write_snapshot()
        print(f"metrics → {args.metrics_dir}")


if __name__ == "__main__":
    main()
