"""Production mesh definitions.

Built lazily (functions, not module constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before anything
initializes jax.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; the multi-pod mesh adds a 2-pod DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The batch/data-parallel axes of a mesh (pod axis included if present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes exist, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` where it exists (jax ≥ 0.6); on older releases the
    Mesh object itself is the context manager that sets the thread-local
    physical mesh, which is all the jit/sharding paths here need.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
