"""Unified telemetry layer: metrics, trace spans, path-selection counters,
exporters and SLO reporting — the substrate every serving PR reports
through (ROADMAP item 2).

Zero-dependency inside the repo (imports nothing from ``repro.*``), so
``core``/``kernels``/``robust``/``analytics``/``index``/``launch`` can all
instrument themselves without cycles.

* :mod:`repro.obs.metrics` — process-global registry of counters, gauges
  and streaming log-bucket histograms; true no-ops when disabled.
* :mod:`repro.obs.spans`   — nested ``span()`` context manager forwarding
  to ``jax.profiler.TraceAnnotation``/``named_scope``.
* :mod:`repro.obs.timing`  — ``time_compiled``/``timed_op`` (the one timer
  the CLIs and benchmarks share; compile_s separated from steady-state)
  and ``track_shapes`` jit-recompile tracking.
* :mod:`repro.obs.export`  — JSONL event log + snapshot (+ Prometheus
  text) behind the CLIs' ``--metrics-dir``.
* :mod:`repro.obs.report`  — snapshot → per-op SLO table + span tree
  (rendered by ``python -m repro.launch.obs``).
* :mod:`repro.obs.prof`    — device-level profiling: HLO cost-model stats,
  roofline-utilization and device-memory gauges (``prof.*``), opt-in
  ``jax.profiler`` trace capture (``--profile-dir``), and the post-SPMD
  ``analyze_hlo`` (absorbed from ``launch.hlo_analysis``).
* :mod:`repro.obs.history` — append-only per-commit bench history
  (``results/bench/history.jsonl``) + noise-aware regression detection
  behind ``python -m repro.launch.regress``.
* :mod:`repro.obs.html`    — zero-dependency static HTML dashboard
  (``python -m repro.launch.obs --html``).

Counter semantics under jit: Python-side increments fire at *trace* time,
so path-selection counters (``core.build``, ``analytics.path``, …) count
traced decisions, not per-call volume — exactly what "which path actually
executed / compiled" needs. Per-call volume lives in the ``serve.*``
family recorded by the CLIs around jitted calls.
"""
from .export import (configure, emit_event, metrics_dir, prometheus_text,
                     read_events, read_snapshot, snapshot_dict,
                     write_snapshot)
from .history import (append_history, detect_regression, read_history,
                      regress_report)
from .html import render_html
from .metrics import (REGISTRY, Counter, Gauge, Histogram, MetricsRegistry,
                      counter, disable, disabled, enable, enabled, gauge,
                      histogram, parse_key)
from .prof import (analyze_hlo, hw_model, live_memory_stats, profile_op,
                   profiled_op, record_memory_gauges, start_trace,
                   stop_trace, trace)
from .spans import current_span, event, span
from .timing import (Stopwatch, reset_shape_tracking, time_compiled,
                     timed_op, track_shapes)

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "counter", "gauge", "histogram", "parse_key",
    "enable", "disable", "disabled", "enabled",
    "span", "current_span", "event",
    "Stopwatch", "time_compiled", "timed_op", "track_shapes",
    "reset_shape_tracking",
    "configure", "metrics_dir", "emit_event", "write_snapshot",
    "snapshot_dict", "read_snapshot", "read_events", "prometheus_text",
    "profile_op", "profiled_op", "record_memory_gauges",
    "live_memory_stats", "hw_model", "analyze_hlo",
    "start_trace", "stop_trace", "trace",
    "append_history", "read_history", "detect_regression",
    "regress_report", "render_html",
]
