"""Unified telemetry layer: metrics, trace spans, path-selection counters,
exporters and SLO reporting — the substrate every serving PR reports
through (ROADMAP item 2).

Zero-dependency inside the repo (imports nothing from ``repro.*``), so
``core``/``kernels``/``robust``/``analytics``/``index``/``launch`` can all
instrument themselves without cycles.

* :mod:`repro.obs.metrics` — process-global registry of counters, gauges
  and streaming log-bucket histograms; true no-ops when disabled.
* :mod:`repro.obs.spans`   — nested ``span()`` context manager forwarding
  to ``jax.profiler.TraceAnnotation``/``named_scope``.
* :mod:`repro.obs.timing`  — ``time_compiled``/``timed_op`` (the one timer
  the CLIs and benchmarks share; compile_s separated from steady-state)
  and ``track_shapes`` jit-recompile tracking.
* :mod:`repro.obs.export`  — JSONL event log + snapshot (+ Prometheus
  text) behind the CLIs' ``--metrics-dir``.
* :mod:`repro.obs.report`  — snapshot → per-op SLO table + span tree
  (rendered by ``python -m repro.launch.obs``).

Counter semantics under jit: Python-side increments fire at *trace* time,
so path-selection counters (``core.build``, ``analytics.path``, …) count
traced decisions, not per-call volume — exactly what "which path actually
executed / compiled" needs. Per-call volume lives in the ``serve.*``
family recorded by the CLIs around jitted calls.
"""
from .export import (configure, emit_event, metrics_dir, prometheus_text,
                     read_events, read_snapshot, snapshot_dict,
                     write_snapshot)
from .metrics import (REGISTRY, Counter, Gauge, Histogram, MetricsRegistry,
                      counter, disable, disabled, enable, enabled, gauge,
                      histogram, parse_key)
from .spans import current_span, event, span
from .timing import (Stopwatch, reset_shape_tracking, time_compiled,
                     timed_op, track_shapes)

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "counter", "gauge", "histogram", "parse_key",
    "enable", "disable", "disabled", "enabled",
    "span", "current_span", "event",
    "Stopwatch", "time_compiled", "timed_op", "track_shapes",
    "reset_shape_tracking",
    "configure", "metrics_dir", "emit_event", "write_snapshot",
    "snapshot_dict", "read_snapshot", "read_events", "prometheus_text",
]
