"""Nested trace spans: host-side timing that lines up with device profiles.

``span("restore.verify", shards=8)`` times a block, nests (a thread-local
stack gives every span a ``/``-joined path), records the duration into the
``span.<name>`` histogram, appends a structured event to the JSONL event
log when an exporter is configured, and forwards the name to
``jax.profiler.TraceAnnotation`` + ``jax.named_scope`` so the same block
shows up in device profiles under the same label.

Async dispatch makes naive host timing lie: a jitted call returns before
the device finishes. ``sp.sync(out)`` registers the call's output, and the
span blocks on it (``jax.block_until_ready``) at exit *before* reading the
clock — opt-in, because blocking inside a pipelined serving loop would
serialize it.

When metrics are disabled the context manager yields a shared no-op span
and touches nothing.
"""
from __future__ import annotations

import contextlib
import threading
import time
import uuid

from . import export as _export
from .metrics import _state, histogram

_tls = threading.local()


def _stack():
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


class Span:
    __slots__ = ("name", "path", "attrs", "t0", "ts", "dur_s", "span_id",
                 "parent_id", "_sync")

    def __init__(self, name: str, path: str, attrs: dict,
                 parent_id: str | None):
        self.name = name
        self.path = path
        self.attrs = attrs
        self.span_id = uuid.uuid4().hex[:12]
        self.parent_id = parent_id
        self.ts = time.time()
        self.t0 = time.perf_counter()
        self.dur_s = None
        self._sync = None

    def set(self, key: str, value) -> None:
        """Attach an attribute discovered mid-span (exported at exit)."""
        self.attrs[key] = value

    def sync(self, value):
        """Register device work to block on at span exit; returns it."""
        self._sync = value
        return value


class _NullSpan:
    """Disabled-mode stand-in: every method is a no-op."""
    __slots__ = ()

    def set(self, key, value):
        pass

    def sync(self, value):
        return value


_NULL = _NullSpan()


def current_span() -> Span | None:
    st = _stack()
    return st[-1] if st else None


def event(name: str, kind: str = "event", **attrs) -> None:
    """Emit a structured event correlated to the currently open span (the
    fault-injection hook: a fault fired inside a chaos scenario's span
    shows up inside that span's subtree)."""
    if not _state.enabled:
        return
    sp = current_span()
    _export.emit_event(kind, name,
                       span_id=sp.span_id if sp is not None else None,
                       attrs=attrs or None)


@contextlib.contextmanager
def span(name: str, **attrs):
    """Context manager timing a nested, attributed span (see module doc)."""
    if not _state.enabled:
        yield _NULL
        return
    import jax
    st = _stack()
    parent = st[-1] if st else None
    path = f"{parent.path}/{name}" if parent else name
    sp = Span(name, path, dict(attrs),
              parent.span_id if parent else None)
    st.append(sp)
    try:
        with jax.profiler.TraceAnnotation(name), jax.named_scope(name):
            yield sp
    finally:
        if sp._sync is not None:
            try:
                jax.block_until_ready(sp._sync)
            except Exception:                                 # noqa: BLE001
                pass    # a failed computation still ends the span
        sp.dur_s = time.perf_counter() - sp.t0
        st.pop()
        histogram("span." + name).observe(sp.dur_s)
        _export.emit_event("span", name, ts=sp.ts, dur_s=sp.dur_s,
                           path=sp.path, span_id=sp.span_id,
                           parent_id=sp.parent_id,
                           attrs=sp.attrs or None)
