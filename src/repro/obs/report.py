"""Snapshot → human-readable reports: per-op SLO table, span tree.

The SLO table collects every ``serve.<layer>.<op>.latency_s`` histogram
in a snapshot together with its sibling gauges/counters (qps, batch,
compile_s, calls) and renders one row per op. Threshold checks are
``"<op-glob>:<field><op><value>"`` specs, e.g.::

    analytics.*:p99_ms<=50      index.count:qps>=100

evaluated against every matching row; a spec matching no rows is itself a
violation (an SLO on an op that never ran is not "met").

The span tree stitches ``events.jsonl`` span records back into their
nesting (span_id/parent_id) — a chaos run renders injection → detection →
repair as one correlated tree.
"""
from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass
from typing import List, Optional

_SLO_RE = re.compile(r"^(?P<pat>[^:]+):(?P<field>[a-z0-9_]+)"
                     r"(?P<op><=|>=|<|>)(?P<value>[0-9.eE+-]+)$")


@dataclass
class OpRow:
    op: str
    calls: int
    batch: Optional[float]
    qps: Optional[float]
    compile_s: Optional[float]
    p50_ms: Optional[float]
    p95_ms: Optional[float]
    p99_ms: Optional[float]
    max_ms: Optional[float]

    def field(self, name: str) -> Optional[float]:
        return getattr(self, name, None)


def op_rows(snap: dict) -> List[OpRow]:
    """One row per ``serve.<layer>.<op>`` metric family in the snapshot."""
    hists = snap.get("histograms", {})
    gauges = snap.get("gauges", {})
    counters = snap.get("counters", {})
    rows = []
    for key, h in sorted(hists.items()):
        if not (key.startswith("serve.") and key.endswith(".latency_s")):
            continue
        prefix = key[: -len(".latency_s")]
        op = prefix[len("serve."):]

        def ms(v):
            return None if v is None else v * 1e3

        rows.append(OpRow(
            op=op,
            calls=counters.get(prefix + ".calls", h.get("count", 0)),
            batch=gauges.get(prefix + ".batch"),
            qps=gauges.get(prefix + ".qps"),
            compile_s=gauges.get(prefix + ".compile_s"),
            p50_ms=ms(h.get("p50")), p95_ms=ms(h.get("p95")),
            p99_ms=ms(h.get("p99")), max_ms=ms(h.get("max"))))
    return rows


@dataclass
class SloResult:
    spec: str
    op: str          # matched op ("" when the spec matched nothing)
    ok: bool
    detail: str


def parse_slo(spec: str):
    m = _SLO_RE.match(spec.strip())
    if not m:
        raise ValueError(
            f"bad SLO spec {spec!r} (want '<op-glob>:<field><=|>=|<|>"
            f"<value>', e.g. 'analytics.*:p99_ms<=50')")
    return (m["pat"], m["field"], m["op"], float(m["value"]))


def check_slos(rows: List[OpRow], specs: List[str]) -> List[SloResult]:
    ops = {"<=": lambda a, b: a <= b, ">=": lambda a, b: a >= b,
           "<": lambda a, b: a < b, ">": lambda a, b: a > b}
    out = []
    for spec in specs:
        pat, field, op, value = parse_slo(spec)
        matched = [r for r in rows if fnmatch.fnmatch(r.op, pat)]
        if not matched:
            out.append(SloResult(spec, "", False, "no op matched"))
            continue
        for r in matched:
            got = r.field(field)
            if got is None:
                out.append(SloResult(spec, r.op, False,
                                     f"{field} not recorded"))
            else:
                out.append(SloResult(
                    spec, r.op, ops[op](got, value),
                    f"{field}={got:.4g} vs {op}{value:g}"))
    return out


def _fmt(v, nd=2, dash="-"):
    if v is None:
        return dash
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def render_table(rows: List[OpRow], slo_results=None) -> str:
    """Fixed-width per-op SLO table (the ``repro.launch.obs`` output)."""
    slo_by_op: dict[str, bool] = {}
    for res in slo_results or []:
        if res.op:
            slo_by_op[res.op] = slo_by_op.get(res.op, True) and res.ok
    header = ["op", "calls", "batch", "p50_ms", "p95_ms", "p99_ms",
              "max_ms", "q/s", "compile_s"]
    if slo_by_op:
        header.append("slo")
    table = [header]
    for r in rows:
        line = [r.op, str(r.calls), _fmt(r.batch, 0), _fmt(r.p50_ms, 3),
                _fmt(r.p95_ms, 3), _fmt(r.p99_ms, 3), _fmt(r.max_ms, 3),
                _fmt(r.qps, 0), _fmt(r.compile_s, 2)]
        if slo_by_op:
            line.append({True: "ok", False: "VIOLATED"}.get(
                slo_by_op.get(r.op), "-"))
        table.append(line)
    widths = [max(len(row[i]) for row in table)
              for i in range(len(header))]
    lines = []
    for j, row in enumerate(table):
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def render_span_tree(events: List[dict]) -> str:
    """Indented span tree from ``events.jsonl`` records, in start order.

    Non-span events (faults, …) attach under the span that was open when
    they were emitted (their ``span_id`` names it), so a chaos scenario
    shows injection → detection → repair as one correlated subtree.
    """
    spans = [e for e in events if e.get("kind") == "span"]
    others = [e for e in events if e.get("kind") != "span"]
    children: dict[Optional[str], list] = {}
    for e in spans:
        children.setdefault(e.get("parent_id"), []).append(e)
    attached: dict[Optional[str], list] = {}
    for e in others:
        attached.setdefault(e.get("span_id"), []).append(e)
    for v in children.values():
        v.sort(key=lambda e: e.get("ts", 0))

    lines: List[str] = []

    def fmt_attrs(e):
        a = e.get("attrs")
        return " " + ", ".join(f"{k}={v}" for k, v in a.items()) if a else ""

    def walk(parent_id, depth):
        for e in children.get(parent_id, []):
            dur = e.get("dur_s")
            lines.append("  " * depth + f"{e['name']} "
                         f"[{dur * 1e3:.1f} ms]{fmt_attrs(e)}"
                         if dur is not None else
                         "  " * depth + e["name"] + fmt_attrs(e))
            for o in sorted(attached.get(e.get("span_id"), []),
                            key=lambda x: x.get("ts", 0)):
                lines.append("  " * (depth + 1)
                             + f"* {o.get('kind')}:{o.get('name')}"
                             + fmt_attrs(o))
            walk(e.get("span_id"), depth + 1)

    walk(None, 0)
    for o in sorted(attached.get(None, []), key=lambda x: x.get("ts", 0)):
        lines.append(f"* {o.get('kind')}:{o.get('name')}{fmt_attrs(o)}")
    return "\n".join(lines)
