"""Static HTML dashboard: the metrics/events/history artifacts rendered as
one self-contained page (``python -m repro.launch.obs <dir> --html``).

Zero dependencies — plain string templating plus inline SVG sparklines.
Sections (each skipped when its input is absent):

* **Bench trajectories** — one sparkline per (suite, row, fast, backend)
  series from ``results/bench/history.jsonl``, annotated with the
  regression verdict from :mod:`repro.obs.history` (confirmed regressions
  show red, improvements green).
* **Serving SLO table** — the ``serve.*`` per-op latency table from
  :func:`repro.obs.report.op_rows`, with pass/fail when SLO specs given.
* **Roofline profile** — the ``prof.*{op=...}`` gauge family pivoted into
  one row per op: FLOPs, bytes, arithmetic intensity, achieved rates,
  roofline utilization, peak working set.
* **Counters / gauges** — the rest of the registry, verbatim.
* **Span waterfall** — ``events.jsonl`` spans as nested bars scaled to
  wall time, non-span events as ticks on their enclosing span.
"""
from __future__ import annotations

import html as _html
import json
from typing import Dict, List, Optional

from .metrics import parse_key
from .report import OpRow, check_slos, op_rows

_CSS = """
body { font-family: system-ui, sans-serif; margin: 1.5rem; color: #222; }
h1 { font-size: 1.3rem; } h2 { font-size: 1.05rem; margin-top: 1.6rem;
     border-bottom: 1px solid #ddd; padding-bottom: .2rem; }
table { border-collapse: collapse; font-size: .82rem; margin-top: .5rem; }
th, td { padding: .18rem .55rem; text-align: right;
         border-bottom: 1px solid #eee; }
th { background: #f5f5f5; } td.l, th.l { text-align: left; }
tr.bad td { background: #fdecea; } tr.good td { background: #eaf7ec; }
tr.warn td { background: #fff8e1; }
.spark { vertical-align: middle; }
.meta { color: #777; font-size: .78rem; }
.bar { fill: #4a90d9; } .bar:hover { fill: #2b6cb0; }
.tick { stroke: #d9534f; stroke-width: 2; }
.lbl { font-size: 9px; fill: #333; }
code { background: #f5f5f5; padding: 0 .2rem; }
"""


def _esc(v) -> str:
    return _html.escape(str(v), quote=True)


def _fmt(v, nd: int = 3) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v != 0 and (abs(v) >= 1e5 or abs(v) < 1e-3):
            return f"{v:.3g}"
        return f"{v:.{nd}f}"
    return str(v)


def sparkline(values: List[float], width: int = 160, height: int = 36,
              flag: str = "") -> str:
    """Inline SVG sparkline of a series (latest point emphasized; ``flag``
    'regression'/'improvement' colors it red/green)."""
    if not values:
        return ""
    vmin, vmax = min(values), max(values)
    span = (vmax - vmin) or 1.0
    pad = 3
    n = len(values)
    xs = [pad + i * (width - 2 * pad) / max(n - 1, 1) for i in range(n)]
    ys = [height - pad - (v - vmin) / span * (height - 2 * pad)
          for v in values]
    pts = " ".join(f"{x:.1f},{y:.1f}" for x, y in zip(xs, ys))
    dot = {"regression": "#d9534f", "improvement": "#2e9e44"}.get(
        flag, "#4a90d9")
    return (f'<svg class="spark" width="{width}" height="{height}">'
            f'<polyline points="{pts}" fill="none" stroke="#888" '
            f'stroke-width="1.2"/>'
            f'<circle cx="{xs[-1]:.1f}" cy="{ys[-1]:.1f}" r="3" '
            f'fill="{dot}"/></svg>')


def _table(header: List[str], rows: List[List[str]],
           left_cols: int = 1, row_classes: Optional[List[str]] = None,
           raw_cols: tuple = ()) -> str:
    """rows are already-formatted strings; cells in ``raw_cols`` are
    trusted HTML (sparklines), the rest are escaped."""
    out = ["<table><tr>"]
    for i, h in enumerate(header):
        cls = ' class="l"' if i < left_cols else ""
        out.append(f"<th{cls}>{_esc(h)}</th>")
    out.append("</tr>")
    for j, row in enumerate(rows):
        cls = row_classes[j] if row_classes else ""
        out.append(f'<tr class="{cls}">' if cls else "<tr>")
        for i, c in enumerate(row):
            td = ' class="l"' if i < left_cols else ""
            body = c if i in raw_cols else _esc(c)
            out.append(f"<td{td}>{body}</td>")
        out.append("</tr>")
    out.append("</table>")
    return "".join(out)


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------

def history_section(records: List[dict], last_k: int = 5) -> str:
    """Sparkline-per-series bench trajectory table with verdicts."""
    from .history import detect_regression, group_history
    if not records:
        return ""
    rows, classes = [], []
    for key, recs in sorted(group_history(records).items()):
        suite, row, fast, backend = key
        vals = [r["us_per_call"] for r in recs
                if isinstance(r.get("us_per_call"), (int, float))]
        if not vals:
            continue
        vd = detect_regression(vals, last_k=last_k)
        commit = str(recs[-1].get("commit", ""))[:9]
        rows.append([
            suite, row, "fast" if fast else "full", backend,
            sparkline(vals, flag=vd.verdict), str(len(vals)),
            _fmt(vd.baseline, 1), _fmt(vd.latest, 1),
            "-" if vd.delta_pct is None else f"{vd.delta_pct:+.1f}%",
            vd.verdict, commit])
        classes.append({"regression": "bad", "improvement": "good",
                        "drift": "warn"}.get(vd.verdict, ""))
    if not rows:
        return ""
    return ("<h2>Bench trajectories (us/call, per commit)</h2>"
            + _table(["suite", "row", "mode", "backend", "trend", "runs",
                      "baseline", "latest", "delta", "verdict", "commit"],
                     rows, left_cols=4, row_classes=classes,
                     raw_cols=(4,)))


def slo_section(snap: dict, slo_specs: Optional[List[str]] = None) -> str:
    rows = op_rows(snap)
    if not rows:
        return ""
    slo_by_op: Dict[str, bool] = {}
    if slo_specs:
        for res in check_slos(rows, slo_specs):
            if res.op:
                slo_by_op[res.op] = slo_by_op.get(res.op, True) and res.ok
    header = ["op", "calls", "batch", "p50_ms", "p95_ms", "p99_ms",
              "max_ms", "q/s", "compile_s"]
    if slo_by_op:
        header.append("slo")
    out_rows, classes = [], []
    for r in rows:
        line = [r.op, str(r.calls), _fmt(r.batch, 0), _fmt(r.p50_ms),
                _fmt(r.p95_ms), _fmt(r.p99_ms), _fmt(r.max_ms),
                _fmt(r.qps, 0), _fmt(r.compile_s, 2)]
        cls = ""
        if slo_by_op:
            ok = slo_by_op.get(r.op)
            line.append("-" if ok is None else ("ok" if ok else "VIOLATED"))
            cls = "" if ok is None else ("good" if ok else "bad")
        out_rows.append(line)
        classes.append(cls)
    return ("<h2>Serving SLOs</h2>"
            + _table(header, out_rows, row_classes=classes))


#: prof gauge field -> column header, in display order.
_PROF_COLS = [("steady_s", "steady_s"), ("flops", "flops"),
              ("bytes_accessed", "bytes"), ("ai", "AI"),
              ("achieved_flops_s", "FLOP/s"),
              ("achieved_bytes_s", "B/s"),
              ("melem_per_s", "Melem/s"),
              ("roofline_util", "roofline"),
              ("peak_bytes", "peak_mem")]


def prof_rows(snap: dict) -> Dict[str, Dict[str, float]]:
    """Pivot the ``prof.<field>{op=...}`` gauges into op -> field -> value."""
    out: Dict[str, Dict[str, float]] = {}
    for key, v in snap.get("gauges", {}).items():
        name, labels = parse_key(key)
        if not name.startswith("prof.") or "op" not in labels:
            continue
        out.setdefault(labels["op"], {})[name[len("prof."):]] = v
    return out


def prof_section(snap: dict) -> str:
    pivot = prof_rows(snap)
    if not pivot:
        return ""
    rows = []
    for op in sorted(pivot):
        fields = pivot[op]
        rows.append([op] + [_fmt(fields.get(f)) for f, _ in _PROF_COLS])
    mem = {k: v for k, v in snap.get("gauges", {}).items()
           if k.startswith("prof.mem.")}
    memline = ""
    if mem:
        memline = ('<p class="meta">device memory: '
                   + ", ".join(f"{_esc(k[len('prof.mem.'):])}={_fmt(v, 0)}"
                               for k, v in sorted(mem.items())) + "</p>")
    return ("<h2>Roofline profile (per op)</h2>"
            + _table(["op"] + [h for _, h in _PROF_COLS], rows)
            + memline)


def registry_section(snap: dict) -> str:
    parts = []
    counters = {k: v for k, v in snap.get("counters", {}).items()}
    gauges = {k: v for k, v in snap.get("gauges", {}).items()
              if not k.startswith("prof.")}
    if counters:
        parts.append("<h2>Counters</h2>" + _table(
            ["counter", "value"],
            [[k, str(v)] for k, v in sorted(counters.items())]))
    if gauges:
        parts.append("<h2>Gauges</h2>" + _table(
            ["gauge", "value"],
            [[k, _fmt(v)] for k, v in sorted(gauges.items())]))
    return "".join(parts)


def span_section(events: List[dict], width: int = 760) -> str:
    """Span waterfall: nested bars scaled to wall time."""
    spans = [e for e in events if e.get("kind") == "span"
             and e.get("dur_s") is not None]
    if not spans:
        return ""
    others = [e for e in events if e.get("kind") != "span"]
    t_end = max(e.get("ts", 0) for e in spans)
    t_start = min(e.get("ts", 0) - e.get("dur_s", 0) for e in spans)
    total = max(t_end - t_start, 1e-9)
    children: Dict[Optional[str], list] = {}
    for e in spans:
        children.setdefault(e.get("parent_id"), []).append(e)
    for v in children.values():
        v.sort(key=lambda e: e.get("ts", 0))
    attached: Dict[Optional[str], list] = {}
    for e in others:
        attached.setdefault(e.get("span_id"), []).append(e)

    row_h, rows = 16, []

    def walk(parent_id, depth):
        for e in children.get(parent_id, []):
            dur = e.get("dur_s", 0.0)
            x0 = (e.get("ts", 0) - dur - t_start) / total * width
            w = max(dur / total * width, 1.5)
            y = len(rows) * row_h
            ticks = []
            for o in attached.get(e.get("span_id"), []):
                tx = (o.get("ts", 0) - t_start) / total * width
                ticks.append(
                    f'<line class="tick" x1="{tx:.1f}" y1="{y + 2}" '
                    f'x2="{tx:.1f}" y2="{y + row_h - 4}">'
                    f'<title>{_esc(o.get("kind"))}:{_esc(o.get("name"))}'
                    f'</title></line>')
            label = f"{e['name']} [{dur * 1e3:.1f} ms]"
            rows.append(
                f'<rect class="bar" x="{x0:.1f}" y="{y + 2}" '
                f'width="{w:.1f}" height="{row_h - 5}">'
                f'<title>{_esc(label)}</title></rect>'
                f'<text class="lbl" x="{x0 + w + 4:.1f}" '
                f'y="{y + row_h - 6}">{_esc(label)}</text>'
                + "".join(ticks))
            walk(e.get("span_id"), depth + 1)

    walk(None, 0)
    h = len(rows) * row_h + 4
    return ("<h2>Span waterfall</h2>"
            f'<svg width="{width + 240}" height="{h}">'
            + "".join(rows) + "</svg>")


def render_html(snap: Optional[dict] = None,
                events: Optional[List[dict]] = None,
                history: Optional[List[dict]] = None,
                slo_specs: Optional[List[str]] = None,
                title: str = "repro observability") -> str:
    """Assemble the full dashboard page from whatever artifacts exist."""
    meta = (snap or {}).get("meta", {})
    body = [f"<h1>{_esc(title)}</h1>"]
    if meta:
        body.append('<p class="meta">'
                    + _esc(json.dumps(meta, default=str)) + "</p>")
    if history:
        body.append(history_section(history))
    if snap:
        body.append(slo_section(snap, slo_specs))
        body.append(prof_section(snap))
    if events:
        body.append(span_section(events))
    if snap:
        body.append(registry_section(snap))
    return ("<!doctype html><html><head><meta charset='utf-8'>"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            "<body>" + "".join(body) + "</body></html>")
