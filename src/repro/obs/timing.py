"""Timing helpers deduplicating the stack's hand-rolled timers, plus
jit-recompile tracking keyed by (op, input shapes).

``time_compiled`` is THE timer: first call timed separately (compile +
first run — what jit actually costs a cold serving process), then
``iters`` steady-state calls with ``block_until_ready``, median reported.
``launch/analytics``'s ``_timed``, ``launch/index``'s inline pairs and
``benchmarks/common.time_fn`` all collapse onto it.

``timed_op`` wraps one serving-op execution into the standard per-op
metric family::

    serve.<layer>.<op>.latency_s   histogram (steady-state seconds)
    serve.<layer>.<op>.compile_s   gauge     (first-call cost)
    serve.<layer>.<op>.qps         gauge     (batch / steady seconds)
    serve.<layer>.<op>.batch       gauge
    serve.<layer>.<op>.calls       counter

``track_shapes`` counts *distinct input-shape signatures* per op — every
new signature is a jit retrace/recompile on a shape-polymorphic serving
path, which is exactly the signal the future pad-and-bucket request
coalescer needs (ROADMAP item 2): a high ``jit.shapes``-to-traffic ratio
means ragged batches are shredding the compile cache.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Tuple

from .metrics import _state, counter, gauge, histogram


class Stopwatch:
    """Tiny perf_counter wrapper so call sites need no ad-hoc ``time``
    arithmetic (the launch/ lint bans raw perf_counter there)."""

    def __init__(self):
        self.t0 = time.perf_counter()
        self._start = self.t0

    def lap(self) -> float:
        """Seconds since construction or the previous ``lap``."""
        now = time.perf_counter()
        dt = now - self.t0
        self.t0 = now
        return dt

    def total(self) -> float:
        """Seconds since construction (laps don't reset this)."""
        return time.perf_counter() - self._start


def time_compiled(fn: Callable, *args, iters: int = 1,
                  block=None) -> Tuple[object, float, float]:
    """Run ``fn(*args)`` once (timed: compile + first run), then ``iters``
    steady-state repeats; returns ``(out, steady_s, compile_s)`` with
    ``steady_s`` the median. ``block`` overrides what to block on (for
    functions whose output is host data already)."""
    import jax

    def _wait(out):
        jax.block_until_ready(out if block is None else block(out))
        return out

    sw = Stopwatch()
    out = _wait(fn(*args))
    compile_s = sw.lap()
    ts = []
    for _ in range(max(1, iters)):
        sw.lap()
        out = _wait(fn(*args))
        ts.append(sw.lap())
    ts.sort()
    return out, ts[len(ts) // 2], compile_s


def timed_op(layer: str, op: str, fn: Callable, *args, batch: int = 1,
             iters: int = 1):
    """One instrumented serving-op execution (see module doc for the
    metric family). Returns ``(out, steady_s, compile_s)``."""
    prefix = f"serve.{layer}.{op}"
    out, steady_s, compile_s = time_compiled(fn, *args, iters=iters)
    track_shapes(f"{layer}.{op}", *args)
    counter(prefix + ".calls").inc(1 + max(1, iters))
    histogram(prefix + ".latency_s").observe(steady_s)
    gauge(prefix + ".compile_s").set(compile_s)
    gauge(prefix + ".batch").set(batch)
    if steady_s > 0:
        gauge(prefix + ".qps").set(batch / steady_s)
    return out, steady_s, compile_s


_shape_lock = threading.Lock()
_seen_shapes: dict[str, set] = {}


def _signature(x) -> tuple:
    shape = getattr(x, "shape", None)
    if shape is not None:
        return (tuple(shape), str(getattr(x, "dtype", "?")))
    return ("py", type(x).__name__)


def track_shapes(op: str, *args) -> bool:
    """Record the shape signature of one call to ``op``; returns True (and
    bumps ``jit.shapes{op=...}`` + ``jit.recompile``) when it is new.
    Counts leaves through pytrees, so engine/index handles work too."""
    if not _state.enabled:
        return False
    import jax
    sig = tuple(_signature(l) for a in args for l in jax.tree.leaves(a))
    with _shape_lock:
        seen = _seen_shapes.setdefault(op, set())
        new = sig not in seen
        if new:
            seen.add(sig)
    counter("jit.calls", op=op).inc()
    if new:
        counter("jit.shapes", op=op).inc()
        counter("jit.recompile").inc()
    return new


def reset_shape_tracking() -> None:
    with _shape_lock:
        _seen_shapes.clear()
