"""Process-global metrics registry: counters, gauges, streaming histograms.

Zero-dependency (stdlib only — no repro imports, so every layer of the
stack can instrument itself without cycles). Three instrument kinds:

* ``Counter``   — monotone event count (path selections, fault injections,
                  jit recompiles, op invocations).
* ``Gauge``     — last-written value (coverage fraction, q/s, batch size,
                  compile seconds).
* ``Histogram`` — streaming latency/value distribution over fixed
                  log-spaced buckets. The first ``raw_cap`` observations
                  are additionally kept verbatim, so p50/p95/p99/max
                  export is *exact* for runs under the cap (every CLI in
                  this repo) and falls back to bucket-resolution estimates
                  (relative error ≤ the bucket growth factor) beyond it.
                  min/max/sum/count are always exact.

Instruments are identified by ``name`` plus sorted ``key=value`` labels,
canonicalized as ``name{k=v,...}``. ``counter()/gauge()/histogram()`` are
get-or-create and thread-safe; each instrument carries its own lock (jit
tracing, ``vmap``/``pmap`` shard builds and background threads may all
record concurrently).

Disabled mode (``disable()`` / env ``REPRO_OBS=0``) is a true no-op: every
record path returns before touching any state — no locks, no attribute
writes — so instrumented hot paths cost one global-flag read.
"""
from __future__ import annotations

import math
import os
import threading
from typing import Dict, Iterator, List, Optional, Tuple


class _State:
    enabled: bool = os.environ.get("REPRO_OBS", "1").lower() not in (
        "0", "off", "false")


_state = _State()


def enabled() -> bool:
    """True when metric recording is active (the global on/off flag)."""
    return _state.enabled


def enable() -> None:
    _state.enabled = True


def disable() -> None:
    _state.enabled = False


class disabled:
    """Context manager: metrics off inside the block (for overhead
    benches and disabled-mode tests). Restores the prior state."""

    def __enter__(self):
        self._prev = _state.enabled
        _state.enabled = False
        return self

    def __exit__(self, *exc):
        _state.enabled = self._prev
        return False


def _key(name: str, labels: Dict[str, object]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def parse_key(key: str) -> Tuple[str, Dict[str, str]]:
    """Inverse of the canonical encoding: ``name{k=v,...}`` → (name, labels)."""
    if not key.endswith("}") or "{" not in key:
        return key, {}
    name, _, inner = key.partition("{")
    labels = {}
    for part in inner[:-1].split(","):
        if "=" in part:
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


class Counter:
    __slots__ = ("key", "value", "_lock")

    def __init__(self, key: str):
        self.key = key
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if not _state.enabled:
            return
        with self._lock:
            self.value += n


class Gauge:
    __slots__ = ("key", "value", "_lock")

    def __init__(self, key: str):
        self.key = key
        self.value: Optional[float] = None
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        if not _state.enabled:
            return
        with self._lock:
            self.value = float(value)


# log-spaced bucket geometry shared by every histogram: 16 buckets per
# octave (relative width 2^(1/16) ≈ 4.4%) spanning [1e-7, 1e4) — ns-scale
# kernel launches up to multi-hour builds — plus under/overflow buckets.
_BUCKET_LO = 1e-7
_BUCKET_HI = 1e4
_BUCKETS_PER_OCTAVE = 16
_NBUCKETS = int(math.ceil(
    math.log2(_BUCKET_HI / _BUCKET_LO) * _BUCKETS_PER_OCTAVE))
_GROWTH = 2.0 ** (1.0 / _BUCKETS_PER_OCTAVE)


def bucket_upper_edge(i: int) -> float:
    """Upper value edge of log bucket ``i`` (0-based, underflow excluded)."""
    return _BUCKET_LO * (_GROWTH ** (i + 1))


class Histogram:
    """Streaming distribution: fixed log-spaced buckets + exact raw head.

    ``observe`` is O(1): one log2, one list index. Quantiles are exact
    (nearest-rank over the raw buffer) while ``count ≤ raw_cap``, else
    bucket-resolution (geometric midpoint of the covering bucket,
    relative error ≤ 2^(1/16)).
    """

    __slots__ = ("key", "count", "sum", "min", "max", "_buckets", "_raw",
                 "raw_cap", "_lock")

    def __init__(self, key: str, raw_cap: int = 8192):
        self.key = key
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._buckets: Optional[List[int]] = None   # lazy: underflow + N + overflow
        self._raw: List[float] = []
        self.raw_cap = raw_cap
        self._lock = threading.Lock()

    @staticmethod
    def bucket_index(value: float) -> int:
        """0 = underflow (< 1e-7, incl. ≤0), 1..N = log buckets, N+1 = overflow."""
        if value < _BUCKET_LO:
            return 0
        if value >= _BUCKET_HI:
            return _NBUCKETS + 1
        return 1 + min(_NBUCKETS - 1,
                       int(math.log2(value / _BUCKET_LO)
                           * _BUCKETS_PER_OCTAVE))

    def observe(self, value: float) -> None:
        if not _state.enabled:
            return
        value = float(value)
        b = self.bucket_index(value)
        with self._lock:
            if self._buckets is None:
                self._buckets = [0] * (_NBUCKETS + 2)
            self._buckets[b] += 1
            self.count += 1
            self.sum += value
            self.min = value if self.min is None else min(self.min, value)
            self.max = value if self.max is None else max(self.max, value)
            if len(self._raw) < self.raw_cap:
                self._raw.append(value)

    @property
    def exact(self) -> bool:
        """True while quantiles come from the verbatim raw buffer."""
        return self.count <= self.raw_cap

    def quantile(self, q: float) -> Optional[float]:
        """Nearest-rank quantile: the ceil(q·count)-th smallest observation
        (numpy's ``method="inverted_cdf"``). Exact under ``raw_cap``."""
        with self._lock:
            if self.count == 0:
                return None
            rank = max(1, math.ceil(q * self.count))
            if self.count <= self.raw_cap:
                return sorted(self._raw)[rank - 1]
            cum = 0
            for i, c in enumerate(self._buckets):
                cum += c
                if cum >= rank:
                    if i == 0:
                        return self.min
                    if i == _NBUCKETS + 1:
                        return self.max
                    lo = _BUCKET_LO * (_GROWTH ** (i - 1))
                    return lo * math.sqrt(_GROWTH)      # geometric midpoint
            return self.max

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.sum / self.count if self.count else None,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "exact": self.exact,
        }


class MetricsRegistry:
    """Flat name→instrument map with get-or-create accessors."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        key = _key(name, labels)
        with self._lock:
            inst = self.counters.get(key)
            if inst is None:
                inst = self.counters[key] = Counter(key)
        return inst

    def gauge(self, name: str, **labels) -> Gauge:
        key = _key(name, labels)
        with self._lock:
            inst = self.gauges.get(key)
            if inst is None:
                inst = self.gauges[key] = Gauge(key)
        return inst

    def histogram(self, name: str, raw_cap: int = 8192, **labels) -> Histogram:
        key = _key(name, labels)
        with self._lock:
            inst = self.histograms.get(key)
            if inst is None:
                inst = self.histograms[key] = Histogram(key, raw_cap=raw_cap)
        return inst

    def iter_counters(self) -> Iterator[Tuple[str, int]]:
        for k in sorted(self.counters):
            yield k, self.counters[k].value

    def snapshot(self) -> dict:
        """Point-in-time view of every instrument (plain JSON types)."""
        with self._lock:
            counters = dict(self.counters)
            gauges = dict(self.gauges)
            hists = dict(self.histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())
                       if g.value is not None},
            "histograms": {k: h.summary() for k, h in sorted(hists.items())
                           if h.count},
        }

    def reset(self) -> None:
        """Drop every instrument (tests and fresh CLI runs)."""
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()


#: the process-global registry every instrumented layer records into.
REGISTRY = MetricsRegistry()


def counter(name: str, **labels) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return REGISTRY.histogram(name, **labels)
