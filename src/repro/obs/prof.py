"""Device-level profiling: HLO cost-model stats, roofline utilization,
device-memory gauges, and opt-in ``jax.profiler`` trace capture.

This is the layer that relates a *measured* op time to the *hardware
ceiling* — the accounting Fischer–Kurpicz (1702.07578) and Labeit et al.
(1407.8142) win by (memory traffic per level), generalized from the old
``benchmarks/roofline.py`` / ``launch/hlo_analysis.py`` pair into a
reusable ``repro.obs`` facility every instrumented op shares.

Three ingredients:

* **Cost model** — ``compiled_cost``/``compiled_memory`` read XLA's
  ``cost_analysis()`` (FLOPs, bytes accessed) and ``memory_analysis()``
  (argument/output/temp bytes → peak working set) off an AOT-compiled
  executable. ``analyze_hlo`` (moved here from ``launch/hlo_analysis``)
  re-derives dot FLOPs and collective bytes from the post-SPMD HLO text
  with ``known_trip_count`` multipliers, because ``cost_analysis`` counts
  while-loop bodies ONCE (a scan-over-levels program under-reports by
  ~num_levels×).
* **Roofline gauges** — ``profile_op``/``profiled_op`` compile, read the
  cost model, time steady-state, and record the ``prof.*{op=...}`` gauge
  family: flops, bytes_accessed, peak_bytes, arithmetic intensity,
  achieved FLOP/s and B/s, and ``prof.roofline_util`` = (cost-model bound
  time) / (measured time) — 1.0 means the op runs as fast as the hardware
  model allows, ≪1 means there is headroom the kernels are leaving on the
  table. The per-backend hardware model is deliberately coarse
  (documented constants, env-overridable) — utilization is a *trend*
  metric for the regression sentry, not a certificate.
* **Memory gauges** — ``record_memory_gauges`` snapshots
  ``jax.live_arrays()`` (count + bytes actually held alive) and, where
  the backend exposes it, ``device.memory_stats()`` peak/in-use bytes.

Opt-in device tracing: every serving CLI takes ``--profile-dir``;
``start_trace``/``stop_trace`` (or the ``trace`` context manager) wrap
the serving section in ``jax.profiler`` capture so the spans recorded by
``obs.span`` line up with the device timeline.

Zero repro-internal imports (jax is imported lazily inside functions), so
any layer can profile itself without cycles.
"""
from __future__ import annotations

import contextlib
import os
import re
from typing import Dict, List, Optional, Tuple

from .metrics import counter, gauge
from .timing import Stopwatch, time_compiled, timed_op, track_shapes

# ---------------------------------------------------------------------------
# hardware model
# ---------------------------------------------------------------------------

#: per-backend (peak FLOP/s, HBM bandwidth B/s) per device. TPU row is the
#: v5e-class part the dryrun roofline always used (197 TFLOP/s bf16,
#: 819 GB/s HBM); GPU is an A100-class placeholder; CPU is an
#: order-of-magnitude container estimate (a few AVX cores + DDR). Override
#: with REPRO_PEAK_FLOPS / REPRO_HBM_BW when you know your part.
HW_MODELS: Dict[str, Tuple[float, float]] = {
    "tpu": (197e12, 819e9),
    "gpu": (312e12, 2.0e12),
    "cpu": (2.0e11, 5.0e10),
}

#: ICI link bandwidth (B/s/link) for the collective term of the dryrun
#: roofline (TPU v5e-class).
LINK_BW = 50e9


def hw_model(backend: str | None = None) -> Tuple[float, float]:
    """(peak FLOP/s, HBM B/s) for ``backend`` (default: the jax backend),
    with ``REPRO_PEAK_FLOPS`` / ``REPRO_HBM_BW`` env overrides."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    peak, bw = HW_MODELS.get(backend, HW_MODELS["cpu"])
    peak = float(os.environ.get("REPRO_PEAK_FLOPS", peak))
    bw = float(os.environ.get("REPRO_HBM_BW", bw))
    return peak, bw


# ---------------------------------------------------------------------------
# compiled-executable cost/memory stats
# ---------------------------------------------------------------------------

def compiled_cost(compiled) -> Dict[str, float]:
    """FLOPs / bytes-accessed from ``compiled.cost_analysis()``.

    Handles both the list-of-dicts (older jax) and flat-dict forms;
    returns {} when the backend exposes no cost model. NOTE: while-loop
    bodies are counted once — for loop-heavy programs prefer
    ``analyze_hlo`` on ``compiled.as_text()``.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:                                         # noqa: BLE001
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    out = {}
    for key, name in (("flops", "flops"),
                      ("bytes accessed", "bytes_accessed"),
                      ("transcendentals", "transcendentals")):
        v = ca.get(key)
        if v is not None:
            out[name] = float(v)
    return out


def compiled_memory(compiled) -> Dict[str, float]:
    """Argument/output/temp/code bytes from ``compiled.memory_analysis()``
    plus ``peak_bytes`` (the executable's device working set: arguments +
    outputs + temporaries − aliased)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:                                         # noqa: BLE001
        return {}
    if ma is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr.replace("_size_in_bytes", "_bytes")] = float(v)
    if out:
        out["peak_bytes"] = (out.get("argument_bytes", 0.0)
                             + out.get("output_bytes", 0.0)
                             + out.get("temp_bytes", 0.0)
                             - out.get("alias_bytes", 0.0))
    return out


def live_memory_stats() -> Dict[str, float]:
    """Live device memory: count/bytes of arrays currently held alive
    (``jax.live_arrays``) and, when the backend reports allocator stats
    (TPU/GPU), in-use and peak bytes."""
    import jax
    arrs = jax.live_arrays()
    total = 0
    for a in arrs:
        try:
            total += int(a.size) * a.dtype.itemsize
        except Exception:                                     # noqa: BLE001
            continue
    stats: Dict[str, float] = {"live_arrays": float(len(arrs)),
                               "live_bytes": float(total)}
    try:
        ms = jax.devices()[0].memory_stats()
    except Exception:                                         # noqa: BLE001
        ms = None
    if ms:
        if ms.get("bytes_in_use") is not None:
            stats["device_bytes_in_use"] = float(ms["bytes_in_use"])
        if ms.get("peak_bytes_in_use") is not None:
            stats["device_peak_bytes"] = float(ms["peak_bytes_in_use"])
    return stats


def record_memory_gauges() -> Dict[str, float]:
    """Snapshot ``live_memory_stats`` into the ``prof.mem.*`` gauges."""
    stats = live_memory_stats()
    for k, v in stats.items():
        gauge("prof.mem." + k).set(v)
    return stats


# ---------------------------------------------------------------------------
# roofline profiling of one op
# ---------------------------------------------------------------------------

def _record_cost_gauges(op: str, compiled, steady_s: float,
                        work_elements: Optional[float] = None) -> dict:
    """Read the cost model off ``compiled`` and record the ``prof.*``
    gauge family for ``op``; returns the stats as a dict."""
    cost = compiled_cost(compiled)
    mem = compiled_memory(compiled)
    peak_flops, hbm_bw = hw_model()
    flops = cost.get("flops", 0.0)
    nbytes = cost.get("bytes_accessed", 0.0)
    stats: dict = {"op": op, "steady_s": steady_s, **cost}
    if mem:
        stats["peak_bytes"] = mem["peak_bytes"]
        gauge("prof.peak_bytes", op=op).set(mem["peak_bytes"])
    if flops:
        gauge("prof.flops", op=op).set(flops)
    if nbytes:
        gauge("prof.bytes_accessed", op=op).set(nbytes)
    if flops and nbytes:
        stats["ai"] = flops / nbytes
        gauge("prof.ai", op=op).set(stats["ai"])
    t_compute = flops / peak_flops
    t_memory = nbytes / hbm_bw
    roofline_s = max(t_compute, t_memory)
    stats["compute_s"] = t_compute
    stats["memory_s"] = t_memory
    if steady_s > 0:
        if flops:
            stats["achieved_flops_s"] = flops / steady_s
            gauge("prof.achieved_flops_s", op=op).set(flops / steady_s)
        if nbytes:
            stats["achieved_bytes_s"] = nbytes / steady_s
            gauge("prof.achieved_bytes_s", op=op).set(nbytes / steady_s)
        if work_elements:
            stats["melem_per_s"] = work_elements / steady_s / 1e6
            gauge("prof.melem_per_s", op=op).set(stats["melem_per_s"])
        if roofline_s > 0:
            # fraction of the hardware ceiling achieved: bound-time /
            # measured-time. 1.0 = at the roofline; ≪1 = headroom.
            stats["roofline_util"] = roofline_s / steady_s
            stats["bound"] = ("compute" if t_compute >= t_memory
                              else "memory")
            gauge("prof.roofline_util", op=op).set(stats["roofline_util"])
            counter("prof.bound", op=op, term=stats["bound"]).inc()
    gauge("prof.steady_s", op=op).set(steady_s)
    return stats


def _aot(fn, *args):
    """AOT lower+compile ``fn`` (jitting it first when needed)."""
    import jax
    jfn = fn if hasattr(fn, "lower") else jax.jit(fn)
    return jfn.lower(*args).compile()


def profile_op(name: str, fn, *args, iters: int = 1,
               work_elements: Optional[float] = None, strict: bool = False):
    """Compile ``fn(*args)`` ahead-of-time, read its HLO cost model, time
    steady-state executions, and record the ``prof.*{op=name}`` roofline
    gauge family (+ the ``prof.mem.*`` device-memory gauges).

    Returns ``(out, stats)`` — ``stats`` holds flops / bytes / peak_bytes
    / roofline_util / achieved rates (whatever the backend exposes).
    ``work_elements`` (e.g. sequence length, query count) additionally
    derives ``prof.melem_per_s``. With ``strict=False`` (the CLI default)
    any failure degrades to ``(None, {"op": name, "error": ...})`` and a
    ``prof.error`` counter instead of raising — profiling must never take
    serving down.
    """
    try:
        sw = Stopwatch()
        compiled = _aot(fn, *args)
        compile_s = sw.lap()
        out, steady_s, _ = time_compiled(compiled, *args, iters=iters)
        stats = _record_cost_gauges(name, compiled, steady_s,
                                    work_elements=work_elements)
        stats["compile_s"] = compile_s
        record_memory_gauges()
        return out, stats
    except Exception as e:                                    # noqa: BLE001
        if strict:
            raise
        counter("prof.error", op=name).inc()
        return None, {"op": name, "error": f"{type(e).__name__}: {e}"}


def profiled_op(layer: str, op: str, fn, *args, batch: int = 1,
                iters: int = 1):
    """``obs.timed_op`` + roofline profiling in one AOT compile.

    Emits the standard ``serve.<layer>.<op>.*`` metric family (latency
    histogram, compile_s/batch/qps gauges, calls counter, shape tracking)
    AND the ``prof.*{op=<layer>.<op>}`` cost-model gauges, compiling only
    once. Falls back to plain ``timed_op`` (no prof gauges) when the
    function cannot be AOT-lowered. Returns ``(out, steady_s,
    compile_s)`` — drop-in for ``timed_op``.
    """
    name = f"{layer}.{op}"
    prefix = f"serve.{name}"
    try:
        sw = Stopwatch()
        compiled = _aot(fn, *args)
        compile_s = sw.lap()
    except Exception:                                         # noqa: BLE001
        counter("prof.error", op=name).inc()
        return timed_op(layer, op, fn, *args, batch=batch, iters=iters)
    out, steady_s, _ = time_compiled(compiled, *args, iters=iters)
    track_shapes(name, *args)
    counter(prefix + ".calls").inc(1 + max(1, iters))
    from .metrics import histogram
    histogram(prefix + ".latency_s").observe(steady_s)
    gauge(prefix + ".compile_s").set(compile_s)
    gauge(prefix + ".batch").set(batch)
    if steady_s > 0:
        gauge(prefix + ".qps").set(batch / steady_s)
    _record_cost_gauges(name, compiled, steady_s, work_elements=batch)
    record_memory_gauges()
    return out, steady_s, compile_s


# ---------------------------------------------------------------------------
# opt-in jax.profiler trace capture (--profile-dir on the serving CLIs)
# ---------------------------------------------------------------------------

_trace_active = False


def start_trace(profile_dir) -> bool:
    """Start a ``jax.profiler`` trace into ``profile_dir`` (no-op and
    False on a falsy dir or if a trace is already running)."""
    global _trace_active
    if not profile_dir or _trace_active:
        return False
    import jax
    jax.profiler.start_trace(str(profile_dir))
    _trace_active = True
    return True


def stop_trace() -> bool:
    """Stop the running trace (no-op and False when none is active)."""
    global _trace_active
    if not _trace_active:
        return False
    import jax
    try:
        jax.profiler.stop_trace()
    finally:
        _trace_active = False
    return True


@contextlib.contextmanager
def trace(profile_dir):
    """Context manager form of start/stop_trace; no-op on a falsy dir."""
    started = start_trace(profile_dir)
    try:
        yield
    finally:
        if started:
            stop_trace()


# ---------------------------------------------------------------------------
# post-SPMD HLO analysis (absorbed from launch/hlo_analysis)
# ---------------------------------------------------------------------------
# XLA's cost_analysis() counts while-loop bodies ONCE, which under-reports
# any scan-over-layers program by ~num_layers×. analyze_hlo re-derives dot
# FLOPs and collective bytes from compiled.as_text(): it builds the
# computation call graph (while bodies weighted by their backend_config
# known_trip_count), walks every computation with its execution
# multiplier, prices dots as 2·numel(result)·contraction (operand shapes
# resolved through a per-computation symbol table) and collectives as
# result-shape bytes.

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "bf16": 2, "f16": 2, "u16": 2, "s16": 2,
                "f32": 4, "s32": 4, "u32": 4,
                "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
# computation headers are the only non-indented "%name (" lines (params may
# contain nested tuple parens, so only anchor on the name)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape(sig: str) -> Tuple[str, str]:
    m = _SHAPE_RE.search(sig)
    return (m.group(1), m.group(2)) if m else ("f32", "")


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "{" in line:
            cur = hdr.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line)
    return comps


def _entry_name(hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    return m.group(1) if m else next(iter(parse_computations(hlo)))


def analyze_hlo(hlo: str) -> Dict:
    """Per-device dot FLOPs and collective bytes from post-SPMD HLO text
    (see the section comment above for why cost_analysis is not enough)."""
    comps = parse_computations(hlo)
    entry = _entry_name(hlo)

    # ---- per-computation: symbol table + edges + local costs ------------
    sym: Dict[str, Dict[str, Tuple[str, str]]] = {}
    edges: Dict[str, List[Tuple[str, int]]] = {}
    local_flops: Dict[str, float] = {}
    local_coll: Dict[str, Dict[str, int]] = {}

    for cname, lines in comps.items():
        table: Dict[str, Tuple[str, str]] = {}
        cedges: List[Tuple[str, int]] = []
        flops = 0.0
        coll: Dict[str, int] = {}
        for line in lines:
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            iname, rest = mi.groups()
            dt, dims = _first_shape(rest)
            table[iname] = (dt, dims)
            # ---- call edges ----
            if " while(" in rest:
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                trip = 1
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
                if mt:
                    trip = int(mt.group(1))
                if mb:
                    cedges.append((mb.group(1), trip))
                mc = re.search(r"condition=%?([\w.\-]+)", rest)
                if mc:
                    cedges.append((mc.group(1), trip))
            for mcall in re.finditer(
                    r"(?:calls=|to_apply=)%?([\w.\-]+)", rest):
                cedges.append((mcall.group(1), 1))
            for mbr in re.finditer(
                    r"(?:true_computation=|false_computation=|branch_computations=\{)"
                    r"%?([\w.\-]+)", rest):
                cedges.append((mbr.group(1), 1))
            # ---- collectives ----
            # XLA:CPU's FloatSupport promotes bf16 all-reduces to f32
            # (reducer named "*promoted"); TPU all-reduces bf16 natively,
            # so promoted ops are counted at their true 2-byte width.

            def _cbytes():
                b = _numel(dims) * _DTYPE_BYTES.get(dt, 4)
                if dt == "f32" and "promoted" in rest:
                    b //= 2
                return b

            for kind in _COLLECTIVES:
                if f" {kind}(" in rest or rest.startswith(f"{kind}("):
                    if f"{kind}-start" in rest or f"{kind}-done" in rest:
                        continue
                    coll[kind] = coll.get(kind, 0) + _cbytes()
                    break
            for kind in _COLLECTIVES:
                if f" {kind}-start(" in rest:
                    coll[kind] = coll.get(kind, 0) + _cbytes()
                    break
            # ---- dot flops ----
            if " dot(" in rest:
                ops = re.findall(r"%([\w.\-]+)", rest)
                lhs = ops[0] if ops else None
                mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                csize = 1
                if lhs and lhs in table and mcd:
                    ldims = table[lhs][1].split(",")
                    for ci in mcd.group(1).split(","):
                        if ci and int(ci) < len(ldims) and ldims[int(ci)]:
                            csize *= int(ldims[int(ci)])
                flops += 2.0 * _numel(dims) * csize
        sym[cname] = table
        edges[cname] = cedges
        local_flops[cname] = flops
        local_coll[cname] = coll

    # ---- propagate multipliers from entry -------------------------------
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        mult[name] = mult.get(name, 0.0) + m
        for child, trip in edges.get(name, ()):  # conditions counted too
            visit(child, m * trip)

    visit(entry, 1.0)

    total_flops = sum(local_flops.get(c, 0.0) * m for c, m in mult.items())
    total_coll: Dict[str, float] = {}
    for c, m in mult.items():
        for kind, b in local_coll.get(c, {}).items():
            total_coll[kind] = total_coll.get(kind, 0.0) + b * m
    return {"dot_flops_per_device": total_flops,
            "collective_bytes_per_device": total_coll,
            "num_computations": len(comps)}
