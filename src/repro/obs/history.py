"""Append-only per-commit bench history + noise-aware regression detection.

``results/bench/*.json`` artifacts are single snapshots — the latest run
overwrites the previous one, so the cross-commit *trajectory* (the thing
a perf PR must not regress) was invisible. This module keeps it:
``benchmarks/common.save`` appends one JSONL record per (suite, row) to
``results/bench/history.jsonl`` on every run, stamped with the run's
provenance (git commit, dirty flag, backend, host, fast/full, seed).

Detection is deliberately noise-aware so a single noisy run can't gate:

* the **baseline** is the median of the last ``last_k`` prior runs of the
  same (suite, row, fast, backend) series (same host by default — CI
  containers of different speeds must not gate against each other);
* the **threshold** is ``mad_scale`` robust standard deviations
  (1.4826·MAD of the baseline window) above the baseline median, floored
  at ``rel_floor`` relative — a flat-but-noisy series grows its own
  tolerance, a quiet series still needs a real (≥ rel_floor) jump;
* a latest run above the threshold is a confirmed **regression** (the
  hard gate), below the mirrored threshold an **improvement**;
* a series whose recent median crept ``rel_floor`` above its oldest
  window without ever tripping the step test is flagged **drift**
  (reported, not gated — each individual step was within noise).

``repro.launch.regress`` renders the verdict table and exits nonzero on
confirmed regressions; ``scripts/ci.sh`` runs it as the perf gate.

Records are plain JSON lines; a torn trailing line (crashed writer) is
skipped on read exactly like ``obs.read_events``.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from pathlib import Path
from statistics import median
from typing import Dict, List, Optional, Tuple

HISTORY_FILE = "history.jsonl"

#: fields copied from a suite's run meta into every history record.
_META_FIELDS = ("seed",)


def history_records(suite: str, rows: List[dict], meta: dict) -> List[dict]:
    """One history record per bench row: provenance + the row's headline
    ``us_per_call`` + every other numeric derived field under ``metrics``."""
    base = {
        "suite": suite,
        "commit": meta.get("git_commit", "unknown"),
        "dirty": bool(meta.get("git_dirty", False)),
        "backend": meta.get("backend", "unknown"),
        "host": meta.get("host", "unknown"),
        "fast": bool(meta.get("fast", False)),
        "ts": meta.get("timestamp"),
    }
    for f in _META_FIELDS:
        if meta.get(f) is not None:
            base[f] = meta[f]
    out = []
    for row in rows:
        rec = dict(base)
        rec["row"] = str(row.get("name", "unnamed"))
        us = row.get("us_per_call")
        if us is not None:
            rec["us_per_call"] = float(us)
        metrics = {k: (float(v) if not isinstance(v, bool) else v)
                   for k, v in row.items()
                   if k not in ("name", "us_per_call")
                   and isinstance(v, (int, float, bool))}
        if metrics:
            rec["metrics"] = metrics
        out.append(rec)
    return out


def append_history(path, suite: str, rows: List[dict],
                   meta: dict) -> List[dict]:
    """Append one record per row to the JSONL history at ``path``."""
    recs = history_records(suite, rows, meta)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with p.open("a", encoding="utf-8") as fh:
        for rec in recs:
            fh.write(json.dumps(rec, default=float) + "\n")
        fh.flush()
    return recs


def read_history(path) -> List[dict]:
    """Parse the JSONL history, skipping blank and torn lines (a crashed
    writer must not poison the whole trajectory)."""
    p = Path(path)
    if not p.exists():
        return []
    out = []
    for line in p.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict):
            out.append(rec)
    return out


#: series identity: same suite+row, fast and full runs never compared,
#: nor runs from different backends.
Key = Tuple[str, str, bool, str]


def group_key(rec: dict) -> Key:
    return (str(rec.get("suite", "?")), str(rec.get("row", "?")),
            bool(rec.get("fast", False)), str(rec.get("backend", "?")))


def group_history(records: List[dict]) -> Dict[Key, List[dict]]:
    """Records grouped per series, preserving append (= time) order."""
    groups: Dict[Key, List[dict]] = {}
    for rec in records:
        groups.setdefault(group_key(rec), []).append(rec)
    return groups


@dataclass
class Verdict:
    verdict: str                    # new | ok | drift | regression | improvement
    latest: float
    baseline: Optional[float]       # median of the baseline window
    threshold: Optional[float]      # regression trip point
    delta_pct: Optional[float]      # latest vs baseline median
    n_baseline: int
    detail: str = ""


def detect_regression(values: List[float], last_k: int = 5,
                      mad_scale: float = 4.0, rel_floor: float = 0.25,
                      min_history: int = 3) -> Verdict:
    """Gate verdict for the latest value of one series (see module doc).

    ``values`` is the full series in time order (latest last, in the
    metric's "lower is better" orientation — us_per_call).
    """
    latest = float(values[-1])
    base = [float(v) for v in values[:-1][-last_k:]]
    n = len(base)
    if n < min_history:
        med = median(base) if base else None
        return Verdict("new", latest, med, None, None, n,
                       f"only {n} baseline run(s), need {min_history}")
    med = median(base)
    mad = median(abs(b - med) for b in base)
    sigma = 1.4826 * mad                       # MAD → robust stddev
    slack = max(mad_scale * sigma, rel_floor * med)
    threshold = med + slack
    delta_pct = 100.0 * (latest - med) / med if med else None
    if latest > threshold:
        return Verdict("regression", latest, med, threshold, delta_pct, n,
                       f"latest {latest:.4g} > {threshold:.4g} "
                       f"(median {med:.4g} + max({mad_scale}·1.4826·MAD, "
                       f"{rel_floor:.0%}))")
    if latest < med - slack:
        return Verdict("improvement", latest, med, threshold, delta_pct, n,
                       f"latest {latest:.4g} < {med - slack:.4g}")
    # gradual drift: no single step tripped, but the recent median crept
    # above the oldest window by the relative floor
    if len(values) >= 2 * last_k:
        old_med = median(float(v) for v in values[:last_k])
        recent_med = median(float(v) for v in values[-last_k:])
        if old_med > 0 and recent_med > old_med * (1.0 + rel_floor):
            return Verdict(
                "drift", latest, med, threshold, delta_pct, n,
                f"recent median {recent_med:.4g} vs oldest window "
                f"{old_med:.4g} (+{100 * (recent_med / old_med - 1):.0f}%)")
    return Verdict("ok", latest, med, threshold, delta_pct, n, "")


def regress_report(records: List[dict], last_k: int = 5,
                   mad_scale: float = 4.0, rel_floor: float = 0.25,
                   min_history: int = 3, same_host: bool = True,
                   fast: Optional[bool] = None,
                   suite: Optional[str] = None) -> List[dict]:
    """Per-series verdict rows over a parsed history.

    ``fast=True/False`` restricts to fast/full records (None = both);
    ``suite`` filters by suite name; ``same_host`` (default) compares the
    latest run only against baseline records from the same host, so a
    trajectory seeded on a different machine reads as "new" instead of a
    phantom regression.
    """
    rows = []
    for key, recs in group_history(records).items():
        ksuite, krow, kfast, kbackend = key
        if fast is not None and kfast is not fast:
            continue
        if suite is not None and ksuite != suite:
            continue
        latest = recs[-1]
        if same_host:
            recs = [r for r in recs
                    if r.get("host") == latest.get("host")]
        vals = [r["us_per_call"] for r in recs
                if isinstance(r.get("us_per_call"), (int, float))]
        if not vals:
            continue
        vd = detect_regression(vals, last_k=last_k, mad_scale=mad_scale,
                               rel_floor=rel_floor,
                               min_history=min_history)
        rows.append({"suite": ksuite, "row": krow, "fast": kfast,
                     "backend": kbackend, "runs": len(vals),
                     "commit": latest.get("commit", "unknown"),
                     **asdict(vd)})
    rows.sort(key=lambda r: (r["suite"], r["row"], r["fast"]))
    return rows
