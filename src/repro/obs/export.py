"""Exporters: JSONL event log + point-in-time snapshot (+ Prometheus text).

``configure(metrics_dir)`` attaches a file exporter: structured events
(spans, fault injections, path decisions worth correlating) append to
``<dir>/events.jsonl`` as they happen, and ``write_snapshot()`` renders
the registry into ``<dir>/snapshot.json``. A snapshot is also written
automatically at interpreter exit so a crashed-late CLI still leaves its
metrics behind. The CLIs expose this as ``--metrics-dir``;
``repro.launch.obs`` renders the artifacts back into an SLO table.

Event schema (one JSON object per line, all lines share this shape)::

    {"ts": <unix float>, "kind": "span"|"fault"|"event", "name": <str>,
     ...kind-specific fields: dur_s, path, span_id, parent_id, attrs}

Snapshot schema::

    {"meta": {...provenance...},
     "counters":   {key: int},
     "gauges":     {key: float},
     "histograms": {key: {count, sum, mean, min, max, p50, p95, p99, exact}}}

Everything no-ops (cheaply) until ``configure`` is called, and while
metrics are disabled.
"""
from __future__ import annotations

import atexit
import json
import os
import re
import threading
import time
from pathlib import Path
from typing import Optional

from .metrics import REGISTRY, _state

_lock = threading.Lock()
_dir: Optional[Path] = None
_events_fh = None
_atexit_registered = False

EVENTS_FILE = "events.jsonl"
SNAPSHOT_FILE = "snapshot.json"


def metrics_dir() -> Optional[Path]:
    return _dir


def configure(directory: str | Path | None) -> Optional[Path]:
    """Point the file exporter at ``directory`` (created if needed).

    ``None`` detaches the exporter (closing the event log). Re-configuring
    to a new directory rolls the event stream over.
    """
    global _dir, _events_fh, _atexit_registered
    with _lock:
        if _events_fh is not None:
            _events_fh.close()
            _events_fh = None
        if directory is None:
            _dir = None
            return None
        _dir = Path(directory)
        _dir.mkdir(parents=True, exist_ok=True)
        _events_fh = (_dir / EVENTS_FILE).open("a", encoding="utf-8")
        if not _atexit_registered:
            atexit.register(_atexit_snapshot)
            _atexit_registered = True
        return _dir


def _atexit_snapshot() -> None:
    try:
        if _dir is not None:
            write_snapshot()
    except Exception:                                         # noqa: BLE001
        pass


def emit_event(kind: str, name: str, ts: float | None = None,
               **fields) -> None:
    """Append one structured event line (no-op unless configured+enabled)."""
    if not _state.enabled or _events_fh is None:
        return
    rec = {"ts": time.time() if ts is None else ts, "kind": kind,
           "name": name}
    for k, v in fields.items():
        if v is not None:
            rec[k] = v
    line = json.dumps(rec, default=str)
    with _lock:
        if _events_fh is None:
            return
        _events_fh.write(line + "\n")
        _events_fh.flush()


def snapshot_dict() -> dict:
    """Registry snapshot + provenance meta (a plain-JSON dict)."""
    try:
        import jax
        runtime = {"jax_version": jax.__version__,
                   "backend": jax.default_backend(),
                   "device_count": jax.local_device_count()}
    except Exception:                                         # noqa: BLE001
        runtime = {}
    snap = REGISTRY.snapshot()
    snap["meta"] = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
                    "pid": os.getpid(), **runtime}
    return snap


def write_snapshot(directory: str | Path | None = None) -> Optional[Path]:
    """Render the registry into ``snapshot.json`` (atomic replace)."""
    d = Path(directory) if directory is not None else _dir
    if d is None:
        return None
    d.mkdir(parents=True, exist_ok=True)
    path = d / SNAPSHOT_FILE
    tmp = d / (SNAPSHOT_FILE + ".tmp")
    tmp.write_text(json.dumps(snapshot_dict(), indent=1, default=float))
    os.replace(tmp, path)
    return path


def read_snapshot(directory: str | Path) -> dict:
    return json.loads((Path(directory) / SNAPSHOT_FILE).read_text())


def read_events(directory: str | Path) -> list[dict]:
    """Parse ``events.jsonl`` (skipping any torn trailing line)."""
    path = Path(directory) / EVENTS_FILE
    if not path.exists():
        return []
    out = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            continue
    return out


#: Prometheus metric names are [a-zA-Z_:][a-zA-Z0-9_:]*; label names drop
#: the colon. Anything else maps to "_".
_PROM_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")
_PROM_LABEL_BAD = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    name = _PROM_NAME_BAD.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def _prom_label_value(v: str) -> str:
    # exposition-format escaping: backslash, double quote, newline
    return (str(v).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def prometheus_text(snap: dict | None = None) -> str:
    """Render a snapshot in Prometheus exposition format (counters and
    gauges as-is; histograms as _count/_sum + quantile gauges)."""
    from .metrics import parse_key
    snap = snap if snap is not None else snapshot_dict()

    def fmt(key: str, suffix: str = "") -> str:
        name, labels = parse_key(key)
        name = _prom_name(name + suffix)
        if labels:
            inner = ",".join(
                f'{_PROM_LABEL_BAD.sub("_", k)}="{_prom_label_value(v)}"'
                for k, v in sorted(labels.items()))
            return f"{name}{{{inner}}}"
        return name

    lines = []
    for k, v in snap.get("counters", {}).items():
        lines.append(f"{fmt(k, '_total')} {v}")
    for k, v in snap.get("gauges", {}).items():
        lines.append(f"{fmt(k)} {v}")
    for k, h in snap.get("histograms", {}).items():
        lines.append(f"{fmt(k, '_count')} {h['count']}")
        lines.append(f"{fmt(k, '_sum')} {h['sum']}")
        for q in ("p50", "p95", "p99"):
            if h.get(q) is not None:
                lines.append(f"{fmt(k, '_' + q)} {h[q]}")
    return "\n".join(lines) + "\n"
