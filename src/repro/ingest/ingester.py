"""Crash-safe incremental shard ingest: the two-phase commit protocol.

The paper's domain decomposition makes per-shard builds independent, so a
streaming corpus grows one shard at a time. What this module adds is the
*durability* half: every shard reaches the serving set through a journaled
two-phase commit whose every step is crash-survivable —

::

      build (with_retry; permanent failure → QUARANTINE record)
        │
        ▼
      [1] write_tmp   shard npz → shards/.tmp_shard_<gen>.npz
      [2] checksum    per-leaf crc32 (robust.integrity.checksum_flat)
      [3] fsync       file + directory durability barrier
      [4] intent      INTENT journal record (file, n_tokens, crc32 map)
      [5] rename      atomic os.replace → shards/shard_<gen>.npz
      [6] commit      COMMIT journal record — the shard is serveable

A crash after steps 1–3 leaves only a ``.tmp`` orphan (recovery deletes
it; the journal never heard of the shard). A crash after 4 or 5 leaves a
dangling INTENT: recovery quarantines the unpublished/unverified file,
appends an ABORT record, and tells the caller the stream offset to
re-append from. Only after step 6 is the generation committed — and then
it is committed *forever* (COMMIT ⇒ file exists and matches its INTENT
checksums; ``robust.verify.verify_manifest`` audits exactly that).

Generations are monotone and never reused: an aborted generation stays
aborted and its data re-enters under a fresh generation, so the journal
is a faithful total order of everything that ever reached disk.

``robust.faults.check_crash_point`` instruments every protocol step (and
the QUARANTINE append), so the chaos sweep can kill the ingester after
each one and assert recovery → serve ≡ clean rebuild.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.checkpoint import _flatten, _path_token
from repro.robust.clock import SYSTEM_CLOCK, Clock
from repro.robust.faults import check_crash_point, with_retry
from repro.robust.integrity import IntegrityError, checksum_flat, verify_flat

from .journal import MANIFEST_NAME, ManifestState, ShardEntry, append_record, \
    load_manifest

_SEP = "/"

#: the six commit-protocol steps, in order — the crash-point sweep and the
#: recovery matrix iterate exactly this tuple.
COMMIT_STEPS = ("write_tmp", "checksum", "fsync", "intent", "rename",
                "commit")

#: extra crash-able journal append outside the happy path.
QUARANTINE_STEP = "quarantine"


class IngestError(Exception):
    """Unrecoverable ingest-layer failure (no shards, geometry drift)."""


@dataclass
class RecoveryReport:
    """What one journal replay found and did."""
    committed: List[int] = field(default_factory=list)    # gens serveable
    aborted: List[int] = field(default_factory=list)      # INTENT w/o COMMIT
    quarantined: List[int] = field(default_factory=list)  # unserveable gens
    stray_tmps: int = 0
    torn_tail: bool = False
    #: stream offset (token count) the upstream feed must resume from.
    resume_offset: int = 0

    def summary(self) -> str:
        return (f"recovery: {len(self.committed)} committed, "
                f"{len(self.aborted)} aborted, "
                f"{len(self.quarantined)} quarantined, "
                f"{self.stray_tmps} stray tmp(s), "
                f"torn_tail={self.torn_tail}, "
                f"resume@{self.resume_offset}")


def _fsync_path(path: Path) -> None:
    with open(path, "rb+") as f:
        os.fsync(f.fileno())


def _fsync_dir(path: Path) -> None:
    flags = os.O_RDONLY | getattr(os, "O_DIRECTORY", 0)
    fd = os.open(path, flags)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class ShardIngester:
    """Journaled streaming ingest of one shard stream into ``directory``.

    ``build_shard(tokens)`` maps a padded ``(shard_size,)`` token array to
    the shard pytree (wavelet matrix, FM-index, …). Tokens arrive through
    :meth:`append_tokens` in arbitrary batches; whole shards commit as
    they fill, :meth:`flush` commits the padded tail. Construction does
    NOT touch the journal — call :meth:`recover` first (the startup
    replay), then resume feeding from ``RecoveryReport.resume_offset``.

    Crash model: the in-memory buffer is volatile by design; upstream
    re-feeds everything past the last committed/quarantined generation
    (at-least-once delivery + idempotent monotone generations = exactly
    -once corpus). ``retries``/``backoff_s``/``deadline_s`` bound the
    per-shard build (full-jitter backoff, measured on the injectable
    ``clock``); a permanently failing build is quarantined — the stream
    keeps flowing and serving degrades to coverage < 1 instead of
    crashing.
    """

    def __init__(self, directory: str | Path, build_shard: Callable,
                 shard_bits: int, *, sigma: int, kind: str = "analytics",
                 pad_value: int = 0, token_dtype=np.uint32,
                 seam_overlap: int = 0, jit_build: bool = False,
                 retries: int = 2, backoff_s: float = 0.01,
                 deadline_s: Optional[float] = None,
                 clock: Clock = SYSTEM_CLOCK,
                 fsync: bool = True,
                 extra_meta: Optional[dict] = None):
        self.directory = Path(directory)
        self.shards_dir = self.directory / "shards"
        self.quarantine_dir = self.directory / "quarantine"
        self.manifest = self.directory / MANIFEST_NAME
        self.shard_bits = int(shard_bits)
        self.shard_size = 1 << self.shard_bits
        self.sigma = int(sigma)
        self.kind = kind
        self.pad_value = pad_value
        self.token_dtype = np.dtype(token_dtype)
        self.seam_overlap = int(seam_overlap)
        self.retries = retries
        self.backoff_s = backoff_s
        self.deadline_s = deadline_s
        self.clock = clock
        self.fsync = fsync
        self.extra_meta = dict(extra_meta or {})
        self._raw_build = build_shard
        self._build = jax.jit(build_shard) if jit_build else build_shard
        self._struct = None                      # lazy eval_shape target
        self._placeholder = None                 # lazy quarantine filler
        self._buf = np.zeros((0,), self.token_dtype)
        self._state = ManifestState()
        self._finalized = False
        for d in (self.shards_dir, self.quarantine_dir):
            d.mkdir(parents=True, exist_ok=True)

    # ---- journal-backed state ------------------------------------------
    @property
    def state(self) -> ManifestState:
        return self._state

    @property
    def committed_tokens(self) -> int:
        """Stream offset of the next token to feed (committed +
        quarantined positions — both consumed their upstream data)."""
        return self._state.committed_tokens

    @property
    def next_gen(self) -> int:
        return self._state.next_gen

    # ---- recovery (startup replay) -------------------------------------
    def recover(self, verify_committed: bool = True) -> RecoveryReport:
        """Replay the journal, resolve every crash window, resume.

        * dangling INTENT (no COMMIT): the file — published or still
          ``.tmp`` — is quarantined/deleted and an ABORT record appended;
        * stray ``.tmp`` files the journal never heard of are deleted;
        * committed shards are re-verified against their INTENT checksums
          (``verify_committed=True``); a corrupt or missing committed
          file is demoted to QUARANTINE — serving degrades to
          coverage < 1 instead of crashing on an acked generation.

        Idempotent: a second replay (or a crash *during* recovery, which
        at worst leaves a resolved generation un-ABORTed) converges to
        the same state.
        """
        with obs.span("ingest.recover", dir=str(self.directory)) as sp:
            obs.counter("ingest.replay").inc()
            st = load_manifest(self.directory)
            rep = RecoveryReport(torn_tail=st.torn_tail)
            for e in st.pending:                # INTENT without COMMIT
                final = self.shards_dir / (e.file or "")
                tmp = self.shards_dir / f".tmp_{e.file}"
                if e.file and final.exists():
                    shutil.move(str(final),
                                str(self.quarantine_dir / e.file))
                if e.file and tmp.exists():
                    tmp.unlink()
                append_record(self.manifest,
                              {"type": "ABORT", "gen": e.gen,
                               "reason": "intent_without_commit"},
                              fsync=self.fsync)
                obs.counter("ingest.quarantine",
                            reason="intent_without_commit").inc()
                rep.aborted.append(e.gen)
                e.status = "aborted"
            known = {f".tmp_{e.file}" for e in st.entries.values() if e.file}
            for t in self.shards_dir.glob(".tmp_shard_*.npz"):
                if t.name not in known:
                    t.unlink()
                    rep.stray_tmps += 1
            if verify_committed:
                for e in st.committed:
                    bad = self._committed_defect(e)
                    if bad:
                        if (self.shards_dir / e.file).exists():
                            shutil.move(str(self.shards_dir / e.file),
                                        str(self.quarantine_dir / e.file))
                        append_record(
                            self.manifest,
                            {"type": "QUARANTINE", "gen": e.gen,
                             "n_tokens": e.n_tokens, "reason": bad,
                             "extra": e.extra}, fsync=self.fsync)
                        obs.counter("ingest.quarantine",
                                    reason="corrupt_committed").inc()
                        obs.event("ingest.corrupt_committed", gen=e.gen,
                                  why=bad)
                        e.status = "quarantined"
                        rep.quarantined.append(e.gen)
            rep.committed = [e.gen for e in st.committed]
            rep.quarantined += [e.gen for e in st.quarantined
                                if e.gen not in rep.quarantined]
            rep.resume_offset = st.committed_tokens
            self._state = st
            sp.set("committed", len(rep.committed))
            sp.set("aborted", len(rep.aborted))
            obs.gauge("ingest.generation").set(float(st.last_gen))
            obs.event("ingest.recovered", **{
                "committed": len(rep.committed),
                "aborted": len(rep.aborted),
                "quarantined": len(rep.quarantined),
                "resume_offset": rep.resume_offset})
            return rep

    def _committed_defect(self, e: ShardEntry) -> str:
        path = self.shards_dir / (e.file or "")
        if not e.file or not path.exists():
            return "committed_file_missing"
        try:
            with np.load(path) as z:
                arrays = {k: z[k] for k in z.files}
        except Exception:                                 # noqa: BLE001
            return "committed_file_unreadable"
        if verify_flat(arrays, e.leaf_crc32):
            return "committed_checksum_mismatch"
        return ""

    # ---- streaming append ----------------------------------------------
    def append_tokens(self, tokens) -> List[int]:
        """Buffer a token batch; commit every whole shard that fills.

        Returns the generations resolved by this call (committed or
        quarantined). Raises the tokens' own build failure only after the
        retry budget AND the quarantine path are exhausted — i.e. never,
        short of journal IO errors.
        """
        if self._finalized:
            raise IngestError("ingester already flushed (stream finalized)")
        raw = np.asarray(tokens).reshape(-1)
        if raw.size and (int(raw.min()) < 0
                         or int(raw.max()) >= self.sigma):
            raise ValueError(f"tokens outside [0, {self.sigma})")
        self._buf = np.concatenate([self._buf,
                                    raw.astype(self.token_dtype)])
        gens = []
        while self._buf.size >= self.shard_size:
            head, self._buf = (self._buf[:self.shard_size],
                               self._buf[self.shard_size:])
            gens.append(self._commit_shard(head))
        return gens

    def flush(self) -> List[int]:
        """Commit the partial tail shard (padded with ``pad_value``) and
        finalize the stream. No-op on an empty buffer."""
        gens = []
        if self._buf.size:
            tail, self._buf = self._buf, np.zeros((0,), self.token_dtype)
            gens.append(self._commit_shard(tail))
        self._finalized = True
        return gens

    @property
    def buffered_tokens(self) -> int:
        return int(self._buf.size)

    # ---- the two-phase commit protocol ---------------------------------
    def _shard_extra(self, true_tokens: np.ndarray) -> dict:
        """Per-shard sidecar facts the serving assembly needs (seam
        windows for the text index)."""
        extra = {}
        if self.seam_overlap > 0:
            ov = self.seam_overlap
            extra["head"] = [int(t) for t in true_tokens[:ov]]
            extra["tail"] = [int(t) for t in true_tokens[-ov:]]
        return extra

    def _padded(self, true_tokens: np.ndarray) -> np.ndarray:
        pad = self.shard_size - true_tokens.size
        if pad:
            true_tokens = np.concatenate(
                [true_tokens,
                 np.full(pad, self.pad_value, self.token_dtype)])
        return true_tokens

    def _commit_shard(self, true_tokens: np.ndarray) -> int:
        """Run one generation through the 6-step protocol; returns gen."""
        gen = self._state.next_gen
        extra = self._shard_extra(true_tokens)
        with obs.span("ingest.commit", gen=gen,
                      n_tokens=int(true_tokens.size)) as sp:
            try:
                tree = with_retry(
                    lambda: self._built(true_tokens),
                    retries=self.retries, backoff_s=self.backoff_s,
                    deadline_s=self.deadline_s, clock=self.clock)
            except Exception as e:                        # noqa: BLE001
                # permanent build failure: the stream must keep flowing —
                # journal the hole and serve around it (coverage < 1)
                append_record(self.manifest,
                              {"type": "QUARANTINE", "gen": gen,
                               "n_tokens": int(true_tokens.size),
                               "reason": f"build_failed: {type(e).__name__}",
                               "extra": extra}, fsync=self.fsync)
                check_crash_point(QUARANTINE_STEP)
                obs.counter("ingest.quarantine", reason="build_failed").inc()
                obs.counter("ingest.shard_commit",
                            outcome="quarantined").inc()
                sp.set("outcome", "quarantined")
                self._state.entries[gen] = ShardEntry(
                    gen=gen, status="quarantined",
                    n_tokens=int(true_tokens.size),
                    reason=f"build_failed: {type(e).__name__}", extra=extra)
                self._state.last_gen = gen
                return gen

            arrays, dtypes = _flatten(tree)
            fname = f"shard_{gen:08d}.npz"
            tmp = self.shards_dir / f".tmp_{fname}"
            np.savez(tmp, **arrays)                            # [1]
            check_crash_point("write_tmp")
            crcs = checksum_flat(arrays)                       # [2]
            check_crash_point("checksum")
            if self.fsync:                                     # [3]
                _fsync_path(tmp)
                _fsync_dir(self.shards_dir)
            check_crash_point("fsync")
            append_record(self.manifest,                       # [4]
                          {"type": "INTENT", "gen": gen, "file": fname,
                           "n_tokens": int(true_tokens.size),
                           "dtypes": dtypes, "leaf_crc32": crcs,
                           "extra": extra}, fsync=self.fsync)
            check_crash_point("intent")
            os.replace(tmp, self.shards_dir / fname)           # [5]
            check_crash_point("rename")
            append_record(self.manifest,                       # [6]
                          {"type": "COMMIT", "gen": gen},
                          fsync=self.fsync)
            check_crash_point("commit")
            obs.counter("ingest.shard_commit", outcome="committed").inc()
            obs.gauge("ingest.generation").set(float(gen))
            sp.set("outcome", "committed")
            self._state.entries[gen] = ShardEntry(
                gen=gen, status="committed", file=fname,
                n_tokens=int(true_tokens.size), leaf_crc32=crcs,
                dtypes=dtypes, extra=extra)
            self._state.last_gen = gen
            return gen

    def _built(self, true_tokens: np.ndarray) -> Any:
        tree = self._build(jnp.asarray(self._padded(true_tokens)))
        jax.block_until_ready(jax.tree.leaves(tree)[0])
        return tree

    # ---- shard loading / serving assembly ------------------------------
    def _shard_struct(self):
        if self._struct is None:
            probe = jnp.zeros((self.shard_size,),
                              jnp.asarray(np.zeros(1, self.token_dtype))
                              .dtype)
            self._struct = jax.eval_shape(self._raw_build, probe)
        return self._struct

    def _placeholder_tree(self):
        """Structure-valid filler for quarantined generations: a shard
        built from all-``pad_value`` tokens. Served masked-out, so its
        content never reaches an answer — it only keeps the stacked
        pytree rectangular."""
        if self._placeholder is None:
            self._placeholder = self._built(
                np.zeros((0,), self.token_dtype))
        return self._placeholder

    def shard_tree(self, entry: ShardEntry, verify: bool = True):
        """Load one committed generation's pytree (checksum-verified)."""
        if entry.status != "committed":
            return self._placeholder_tree()
        path = self.shards_dir / entry.file
        with np.load(path) as z:
            raw = {k: z[k] for k in z.files}
        if verify:
            bad = verify_flat(raw, entry.leaf_crc32)
            if bad:
                raise IntegrityError(bad, where=str(path))
        flat = jax.tree_util.tree_flatten_with_path(self._shard_struct())
        leaves = []
        for path_t, tgt in flat[0]:
            key = _SEP.join(_path_token(p) for p in path_t)
            if key not in raw:
                raise IntegrityError([key], where=str(path))
            arr = raw[key]
            if arr.dtype.kind == "V" and key in entry.dtypes:
                arr = arr.view(np.dtype(entry.dtypes[key]))
            leaves.append(jnp.asarray(arr.astype(tgt.dtype)))
        return jax.tree_util.tree_unflatten(flat[1], leaves)

    def serve_entries(self) -> List[ShardEntry]:
        """Generation-ordered committed + quarantined entries — the
        position layout of the serveable corpus."""
        return [e for _, e in sorted(self._state.entries.items())
                if e.status in ("committed", "quarantined")]

    def load_stacked(self, verify: bool = True):
        """(stacked pytree, n_tokens, availability mask or None, entries).

        Quarantined generations occupy their corpus slot with a masked
        placeholder so serving stays honest about coverage; with no
        quarantine the mask is ``None`` (no extra pytree leaves)."""
        entries = self.serve_entries()
        if not entries:
            raise IngestError(f"no serveable shards under {self.directory}")
        trees, avail = [], []
        for e in entries:
            trees.append(self.shard_tree(e, verify=verify))
            avail.append(e.status == "committed")
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        n = sum(e.n_tokens for e in entries)
        mask = None if all(avail) else jnp.asarray(np.array(avail, bool))
        return stacked, n, mask, entries

    def seam_windows(self, entries: List[ShardEntry]) -> np.ndarray:
        """(S-1, 2·seam_overlap) boundary windows from the per-shard
        head/tail sidecars — identical to what
        ``index.sharded.seam_windows_from_tokens`` derives from the raw
        stream (the tail of every non-final shard is full, and slots past
        the true corpus length stay ``_SEAM_PAD``)."""
        from repro.index.sharded import _SEAM_PAD
        ov = self.seam_overlap
        ns = max(0, len(entries) - 1)
        win = np.full((ns, 2 * ov), _SEAM_PAD, np.int32)
        for i in range(1, len(entries)):
            tail = entries[i - 1].extra.get("tail", [])
            head = entries[i].extra.get("head", [])
            if tail:
                win[i - 1, ov - len(tail):ov] = tail
            if head:
                win[i - 1, ov:ov + len(head)] = head
        return win

    def engine(self, verify: bool = True):
        """Assemble the serving engine for this stream's current state:
        ``ShardedAnalytics`` (kind="analytics") or ``ShardedTextIndex``
        (kind="index"), quarantined generations masked unavailable."""
        stacked, n, mask, entries = self.load_stacked(verify=verify)
        if self.kind == "analytics":
            from repro.analytics.engine import ShardedAnalytics
            return ShardedAnalytics(shards=stacked, n=n, sigma=self.sigma,
                                    shard_bits=self.shard_bits,
                                    available=mask)
        if self.kind == "index":
            from repro.index.sharded import ShardedTextIndex
            return ShardedTextIndex(
                shards=stacked,
                seam_windows=jnp.asarray(self.seam_windows(entries)),
                n=n, sigma=self.sigma, shard_bits=self.shard_bits,
                seam_overlap=self.seam_overlap, available=mask)
        raise IngestError(f"unknown ingest kind {self.kind!r}")


# --------------------------------------------------------------------------
# kind-specific factories (mirror the from-scratch builders bit-for-bit)
# --------------------------------------------------------------------------

def analytics_ingester(directory: str | Path, sigma: int, *,
                       shard_bits: int = 16, tau: int = 8,
                       big_step: str = "compose", sample_rate: int = 512,
                       **kw) -> ShardIngester:
    """Ingester whose committed stream is bit-identical to
    ``build_sharded_analytics`` over the same tokens (same per-shard
    builder arguments, same jit-once dispatch, same 0-padding)."""
    from repro.core.wavelet_matrix import build_wavelet_matrix

    def build(s):
        return build_wavelet_matrix(s, sigma, tau=tau, big_step=big_step,
                                    sample_rate=sample_rate)

    return ShardIngester(directory, build, shard_bits, sigma=sigma,
                         kind="analytics", pad_value=0,
                         token_dtype=np.uint32, jit_build=True, **kw)


def index_ingester(directory: str | Path, sigma: int, *,
                   shard_bits: int = 14, sample_rate: int = 32,
                   tau: int = 8, big_step: str = "compose",
                   bv_sample_rate: int = 512, backend: str = "counting",
                   seam_overlap: int = 15, **kw) -> ShardIngester:
    """Ingester whose committed stream is bit-identical to
    ``build_sharded_index`` over the same tokens (σ-padding, widened
    σ+1 alphabet, seam windows recorded per shard)."""
    from repro.index.fm_index import build_fm_index

    def build(s):
        return build_fm_index(s.astype(jnp.int32), sigma + 1,
                              sample_rate=sample_rate, tau=tau,
                              big_step=big_step,
                              bv_sample_rate=bv_sample_rate,
                              backend=backend)

    return ShardIngester(directory, build, shard_bits, sigma=sigma,
                         kind="index", pad_value=sigma,
                         token_dtype=np.int64, seam_overlap=seam_overlap,
                         jit_build=False, **kw)
