"""Append-only journaled shard manifest (``manifest.jsonl``).

The write-path twin of the checkpoint layer's torn-write discipline: every
durable fact about the ingest stream is one JSON line stamped with a
crc32 over its canonical encoding, appended with an fsync, and never
rewritten. Replay reconstructs the manifest state from the record
sequence; a torn *tail* line (the single writer died mid-append) is
detected by the checksum and dropped, while a bad line anywhere *before*
the tail is real corruption and surfaces as :class:`JournalCorrupt` —
the append-only contract means only the last line can legitimately be
incomplete.

Record types (the commit protocol in ``ingest.ingester`` emits them):

* ``INTENT``     — a shard file is fully written, checksummed and fsynced
  under its ``.tmp`` name; carries the generation, target file name, true
  token count, per-leaf crc32 map and builder geometry. Published *before*
  the atomic rename so a crash between rename and COMMIT is recoverable.
* ``COMMIT``     — the rename happened; the shard at this generation is
  durable and serveable. COMMIT ⇒ the shard file exists and matches the
  INTENT checksums (``robust.verify.verify_manifest`` enforces it).
* ``QUARANTINE`` — the shard build failed permanently (retry budget or
  deadline exhausted) or a committed file was later found corrupt; the
  generation's positions are served as unavailable (coverage < 1).
* ``ABORT``      — written by recovery for an INTENT with no COMMIT: the
  crash window left the shard unpublished or unverifiable, its file was
  quarantined/deleted, and upstream must re-append from the last
  committed offset.

Generations are monotone: every INTENT/QUARANTINE introduces
``last_gen + 1``, so the journal itself is a total order of the stream.
"""
from __future__ import annotations

import json
import os
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, List, Optional, Tuple

MANIFEST_NAME = "manifest.jsonl"

#: record types the replay understands, in no particular order.
RECORD_TYPES = ("INTENT", "COMMIT", "QUARANTINE", "ABORT")


class JournalCorrupt(Exception):
    """A manifest line *before* the tail failed to parse or checksum —
    append-only journals can only be torn at the end, so this is real
    corruption, not a crash artifact."""

    def __init__(self, path, lineno: int, why: str):
        self.path, self.lineno, self.why = str(path), lineno, why
        super().__init__(f"{path}:{lineno}: {why}")


def _canonical(rec: dict) -> bytes:
    """Canonical encoding the crc covers: sorted keys, no whitespace,
    ``crc32`` field excluded."""
    body = {k: v for k, v in rec.items() if k != "crc32"}
    return json.dumps(body, sort_keys=True, separators=(",", ":")).encode()


def record_crc(rec: dict) -> str:
    return f"{zlib.crc32(_canonical(rec)):08x}"


def append_record(journal: str | Path, rec: dict, *,
                  fsync: bool = True) -> dict:
    """Append one checksummed record line (``\\n``-terminated) and fsync.

    Returns the record as written (with its ``crc32`` stamp). The append
    is a single ``write`` of one line, so a crash can only tear the tail.
    """
    if rec.get("type") not in RECORD_TYPES:
        raise ValueError(f"unknown record type {rec.get('type')!r} "
                         f"(expected one of {RECORD_TYPES})")
    rec = dict(rec)
    rec["crc32"] = record_crc(rec)
    line = json.dumps(rec, sort_keys=True, separators=(",", ":")) + "\n"
    journal = Path(journal)
    journal.parent.mkdir(parents=True, exist_ok=True)
    with open(journal, "a", encoding="utf-8") as f:
        f.write(line)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    return rec


def read_journal(journal: str | Path, *, strict: bool = True
                 ) -> Tuple[List[dict], bool]:
    """Replay-read the manifest → ``(records, torn_tail)``.

    A final line that is incomplete, unparseable, or checksum-failing is
    the torn tail of a crashed append: it is dropped and reported via
    ``torn_tail=True``. The same defect on any earlier line raises
    :class:`JournalCorrupt` (``strict=False`` instead stops replay at the
    bad line and reports it torn — the verify path uses this to keep
    scanning for other violations).
    """
    journal = Path(journal)
    if not journal.exists():
        return [], False
    raw = journal.read_text(encoding="utf-8", errors="replace")
    lines = raw.split("\n")
    # a well-formed journal ends with "\n" → last split element is ""
    if lines and lines[-1] == "":
        lines.pop()
    records: List[dict] = []
    for i, line in enumerate(lines):
        bad: Optional[str] = None
        rec = None
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            bad = "unparseable line"
        if bad is None and not isinstance(rec, dict):
            bad = "record is not an object"
        if bad is None and rec.get("crc32") != record_crc(rec):
            bad = "record crc32 mismatch"
        if bad is None and rec.get("type") not in RECORD_TYPES:
            bad = f"unknown record type {rec.get('type')!r}"
        if bad is not None:
            if i == len(lines) - 1:
                return records, True            # torn tail: drop + report
            if strict:
                raise JournalCorrupt(journal, i + 1, bad)
            return records, True                # verify mode: stop here
        records.append(rec)
    return records, False


# --------------------------------------------------------------------------
# replay → manifest state
# --------------------------------------------------------------------------

@dataclass
class ShardEntry:
    """One generation's durable fate after replay."""
    gen: int
    status: str                    # "committed" | "quarantined" | "aborted"
    #                                | "pending" (INTENT with no resolution)
    file: Optional[str] = None
    n_tokens: int = 0
    leaf_crc32: dict = field(default_factory=dict)
    dtypes: dict = field(default_factory=dict)
    extra: dict = field(default_factory=dict)
    reason: str = ""


@dataclass
class ManifestState:
    """The manifest a journal replay reconstructs.

    ``committed`` lists serveable shards in generation order;
    ``quarantined`` generations hold positions that are part of the
    stream but cannot be served (coverage < 1); ``pending`` generations
    are INTENTs the crash window left unresolved — recovery turns each
    into an ABORT. ``committed_tokens`` counts committed + quarantined
    positions: the stream offset ingest resumes from (quarantined data
    was consumed from upstream even though it cannot be served).
    """
    entries: dict = field(default_factory=dict)      # gen -> ShardEntry
    last_gen: int = -1
    torn_tail: bool = False

    @property
    def committed(self) -> List[ShardEntry]:
        return [e for _, e in sorted(self.entries.items())
                if e.status == "committed"]

    @property
    def quarantined(self) -> List[ShardEntry]:
        return [e for _, e in sorted(self.entries.items())
                if e.status == "quarantined"]

    @property
    def pending(self) -> List[ShardEntry]:
        return [e for _, e in sorted(self.entries.items())
                if e.status == "pending"]

    @property
    def committed_tokens(self) -> int:
        """Stream offset of the next un-ingested token: every committed
        or quarantined generation consumed its tokens from upstream."""
        return sum(e.n_tokens for e in self.entries.values()
                   if e.status in ("committed", "quarantined"))

    @property
    def next_gen(self) -> int:
        return self.last_gen + 1


def replay(records: Iterable[dict], *, torn_tail: bool = False
           ) -> ManifestState:
    """Fold the record sequence into a :class:`ManifestState`.

    Tolerant by design — out-of-protocol sequences (COMMIT for an unknown
    generation, double COMMIT) do not raise here; ``verify_manifest``
    classifies them. Replay keeps the *last-writer-wins* fate per
    generation so a recovery ABORT supersedes the dangling INTENT.
    """
    st = ManifestState(torn_tail=torn_tail)
    for rec in records:
        gen = int(rec.get("gen", -1))
        typ = rec.get("type")
        st.last_gen = max(st.last_gen, gen)
        if typ == "INTENT":
            st.entries[gen] = ShardEntry(
                gen=gen, status="pending", file=rec.get("file"),
                n_tokens=int(rec.get("n_tokens", 0)),
                leaf_crc32=rec.get("leaf_crc32", {}),
                dtypes=rec.get("dtypes", {}),
                extra=rec.get("extra", {}))
        elif typ == "COMMIT":
            e = st.entries.get(gen)
            if e is not None:
                e.status = "committed"
        elif typ == "QUARANTINE":
            e = st.entries.get(gen)
            if e is None:
                e = st.entries[gen] = ShardEntry(gen=gen, status="quarantined")
            e.status = "quarantined"
            e.n_tokens = int(rec.get("n_tokens", e.n_tokens))
            e.reason = rec.get("reason", "")
            if "extra" in rec:
                e.extra = rec["extra"]
        elif typ == "ABORT":
            e = st.entries.get(gen)
            if e is None:
                e = st.entries[gen] = ShardEntry(gen=gen, status="aborted")
            e.status = "aborted"
            e.reason = rec.get("reason", "")
    return st


def load_manifest(directory: str | Path, *, strict: bool = True
                  ) -> ManifestState:
    """Read + replay ``<directory>/manifest.jsonl``."""
    records, torn = read_journal(Path(directory) / MANIFEST_NAME,
                                 strict=strict)
    return replay(records, torn_tail=torn)
