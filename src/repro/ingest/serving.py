"""Epoch-fenced hot-swap serving: generation swaps that never tear a query.

``GenerationServer`` holds the current ``(generation, engine)`` pair and
hands out *pinned epochs*: a query batch enters through :meth:`session`,
reads one atomic pair, and runs every op of the batch against that one
engine object — so a batch can never observe a mixed-generation corpus,
no matter when :meth:`swap_generation` lands. Swaps are wait-free for
readers (they keep the old reference; Python object lifetime does the
rest) and the swapper can optionally *fence*: block until every session
pinned to an older generation drains, which is the point after which the
old engine is unreachable and its memory reclaimable. The fence duration
is the "hot-swap pause" — it stalls the *swapper*, never the queries —
and is recorded in the ``ingest.swap_pause_s`` histogram.

The server is engine-agnostic: anything with value semantics swaps
(``ShardedAnalytics``, ``ShardedTextIndex``, or a future mesh-resident
engine). ``ShardedAnalytics.add_shards`` / ``ShardedTextIndex.add_shards``
produce the next generation's engine from the previous one plus the newly
committed shard trees; ``swap_generation`` publishes it.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional, Tuple

from repro import obs


class GenerationServer:
    """Atomic (generation, engine) pair + epoch fencing for hot swaps."""

    def __init__(self, engine: Any, generation: int = 0):
        self._lock = threading.Condition()
        self._engine = engine
        self._gen = int(generation)
        self._inflight: dict[int, int] = {}      # generation -> open sessions
        obs.gauge("ingest.serving_generation").set(float(self._gen))

    # ---- reader side ----------------------------------------------------
    @property
    def generation(self) -> int:
        with self._lock:
            return self._gen

    @property
    def engine(self) -> Any:
        """The current engine (point-in-time read; batches that need
        epoch consistency across several ops must use :meth:`session`)."""
        with self._lock:
            return self._engine

    def pin(self) -> Tuple[int, Any]:
        """One atomic (generation, engine) read with no fencing — for
        single-op callers; the engine reference stays valid for as long
        as the caller holds it."""
        with self._lock:
            return self._gen, self._engine

    def session(self) -> "_Session":
        """Context manager yielding one pinned (generation, engine) pair;
        the session is fenced — a draining swap waits for its exit."""
        return _Session(self)

    def query(self, fn: Callable[[Any], Any]) -> Tuple[Any, int]:
        """Run ``fn(engine)`` inside a pinned session → (result, gen)."""
        with self.session() as (gen, eng):
            return fn(eng), gen

    # ---- swapper side ---------------------------------------------------
    def swap_generation(self, engine: Any, *, wait_drain: bool = True,
                        timeout_s: Optional[float] = None) -> int:
        """Publish ``engine`` as the next generation.

        New sessions see it immediately; in-flight sessions finish
        against the generation they pinned. ``wait_drain=True`` blocks
        the *swapper* until every older-generation session exits (the
        epoch fence); ``timeout_s`` bounds that wait (TimeoutError — the
        swap itself has already happened and is not rolled back).
        Returns the new generation number.
        """
        sw = obs.Stopwatch()
        with self._lock:
            self._gen += 1
            new_gen = self._gen
            self._engine = engine
            obs.counter("ingest.swap").inc()
            obs.gauge("ingest.serving_generation").set(float(new_gen))
            if wait_drain:
                def drained() -> bool:
                    return not any(g < new_gen and c > 0
                                   for g, c in self._inflight.items())
                if not self._lock.wait_for(drained, timeout=timeout_s):
                    obs.counter("ingest.swap_fence_timeout").inc()
                    raise TimeoutError(
                        f"generation {new_gen - 1} did not drain within "
                        f"{timeout_s}s")
        pause = sw.total()
        obs.histogram("ingest.swap_pause_s").observe(pause)
        obs.event("ingest.swap", generation=new_gen, pause_s=pause,
                  fenced=wait_drain)
        return new_gen

    # ---- session bookkeeping -------------------------------------------
    def _enter(self) -> Tuple[int, Any]:
        with self._lock:
            self._inflight[self._gen] = self._inflight.get(self._gen, 0) + 1
            return self._gen, self._engine

    def _exit(self, gen: int) -> None:
        with self._lock:
            left = self._inflight.get(gen, 0) - 1
            if left <= 0:
                self._inflight.pop(gen, None)
            else:
                self._inflight[gen] = left
            self._lock.notify_all()


class _Session:
    def __init__(self, server: GenerationServer):
        self._server = server
        self._gen: Optional[int] = None

    def __enter__(self) -> Tuple[int, Any]:
        gen, engine = self._server._enter()
        self._gen = gen
        return gen, engine

    def __exit__(self, *exc) -> None:
        if self._gen is not None:
            self._server._exit(self._gen)
            self._gen = None
