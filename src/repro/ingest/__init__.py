"""Crash-safe streaming ingest: journaled shard manifests, a two-phase
shard commit protocol, and epoch-fenced hot-swap serving.

The write-path counterpart of ``repro.robust``: PR 6 made *reading*
corrupted state safe (checksums, structural verify, repair, degraded
serving); this subsystem makes *creating* state safe. Shards reach the
serving set only through the journaled commit protocol in
:mod:`.ingester`, every durable fact lives in the append-only
checksummed ``manifest.jsonl`` of :mod:`.journal`, and serving swaps
between corpus generations through the epoch fencing of :mod:`.serving`
— a process dying at ANY protocol step recovers by journal replay to a
state bit-identical to a clean rebuild (the chaos sweep in
``launch.chaos`` proves it step by step).
"""
from .ingester import (COMMIT_STEPS, QUARANTINE_STEP, IngestError,
                       RecoveryReport, ShardIngester, analytics_ingester,
                       index_ingester)
from .journal import (MANIFEST_NAME, RECORD_TYPES, JournalCorrupt,
                      ManifestState, ShardEntry, append_record,
                      load_manifest, read_journal, record_crc, replay)
from .serving import GenerationServer

__all__ = [
    "COMMIT_STEPS", "QUARANTINE_STEP", "IngestError", "RecoveryReport",
    "ShardIngester", "analytics_ingester", "index_ingester",
    "MANIFEST_NAME", "RECORD_TYPES", "JournalCorrupt", "ManifestState",
    "ShardEntry", "append_record", "load_manifest", "read_journal",
    "record_crc", "replay",
    "GenerationServer",
]
