"""Training runtime: step function factory + fault-tolerant Trainer loop.

``make_train_step`` builds one jit-able update:
  microbatch gradient accumulation (lax.scan, remat'd model) →
  optional error-feedback gradient compression →
  AdamW with global-norm clip →
  NaN/Inf step rejection (the update is applied only if loss and grad norm
  are finite — a poisoned batch skips, it does not kill the run).

``Trainer`` owns the loop: deterministic batches by step index (any host
can serve any step — straggler/replacement tolerance), periodic atomic
checkpoints, resume-from-latest, metric history. Distribution comes from
the caller's jit shardings (see launch/train.py); the loop itself is
single-controller and mesh-agnostic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.grad_compress import ef_compress_tree, zero_residuals
from repro.optim.schedule import cosine_schedule


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TrainState:
    params: Any
    opt: AdamWState
    ef: Any            # error-feedback residuals ({} when compression off)


def init_train_state(model, seed: int = 0, compress_bits: int = 0
                     ) -> TrainState:
    params = model.init(seed)
    return TrainState(
        params=params, opt=adamw_init(params),
        ef=zero_residuals(params) if compress_bits else {})


def make_train_step(model, *, grad_accum: int = 1, base_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    compress_bits: int = 0, q_chunk: Optional[int] = 512,
                    nan_skip: bool = True) -> Callable:
    """Returns ``step(state, batch) -> (state, metrics)``.

    ``batch``: {"tokens": (B, S+1), **extras}. B must divide by grad_accum.
    """
    extras_keys = tuple(model.extras_shapes(1).keys())

    def loss_of(params, tokens, extras):
        return model.loss_fn(params, tokens, extras, q_chunk=q_chunk)

    def grads_of(params, batch):
        tokens = batch["tokens"]
        extras = {k: batch[k] for k in extras_keys} or None
        if grad_accum == 1:
            return jax.value_and_grad(loss_of)(params, tokens, extras)
        b = tokens.shape[0]
        assert b % grad_accum == 0
        mb = b // grad_accum
        mb_tokens = tokens.reshape(grad_accum, mb, *tokens.shape[1:])
        mb_extras = jax.tree.map(
            lambda x: x.reshape(grad_accum, mb, *x.shape[1:]),
            extras) if extras else None

        def body(carry, xs):
            acc_loss, acc_g = carry
            tok = xs["tokens"]
            ext = {k: xs[k] for k in extras_keys} or None
            loss, g = jax.value_and_grad(loss_of)(params, tok, ext)
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_g, g)), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        xs = {"tokens": mb_tokens, **(mb_extras or {})}
        (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.float32(0), zero_g),
                                            xs)
        inv = 1.0 / grad_accum
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def step(state: TrainState, batch) -> tuple[TrainState, Dict]:
        loss, grads = grads_of(state.params, batch)
        ef = state.ef
        if compress_bits:
            grads, ef = ef_compress_tree(grads, ef, compress_bits)
        lr = cosine_schedule(state.opt.step, base_lr, warmup, total_steps)
        new_params, new_opt, metrics = adamw_update(
            state.params, grads, state.opt, lr)
        if nan_skip:
            good = jnp.isfinite(loss) & jnp.isfinite(metrics["grad_norm"])
            sel = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(good, a, b), new, old)
            new_params = sel(new_params, state.params)
            new_opt = AdamWState(m=sel(new_opt.m, state.opt.m),
                                 v=sel(new_opt.v, state.opt.v),
                                 step=jnp.where(good, new_opt.step,
                                                state.opt.step))
            ef = sel(ef, state.ef) if compress_bits else ef
            metrics = {**metrics, "skipped": (~good).astype(jnp.int32)}
        new_state = TrainState(params=new_params, opt=new_opt, ef=ef)
        return new_state, {"loss": loss, "lr": lr, **metrics}

    return step


class Trainer:
    """Fault-tolerant training loop over a deterministic batcher."""

    def __init__(self, model, batcher, *, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 100, keep: int = 3, seed: int = 0,
                 log_every: int = 10, step_fn: Optional[Callable] = None,
                 compress_bits: int = 0, **step_kwargs):
        self.model = model
        self.batcher = batcher
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.log_every = log_every
        self.compress_bits = compress_bits
        self.step_fn = jax.jit(step_fn or make_train_step(
            model, compress_bits=compress_bits, **step_kwargs))
        self.state = init_train_state(model, seed, compress_bits)
        self.start_step = 0
        self.history: list[dict] = []

    def maybe_resume(self) -> int:
        """Resume from the newest checkpoint if one exists."""
        if not self.ckpt_dir:
            return 0
        step = latest_step(self.ckpt_dir)
        if step is None:
            return 0
        self.state, meta = restore_checkpoint(self.ckpt_dir, self.state)
        self.start_step = int(meta["step"])
        return self.start_step

    def run(self, num_steps: int) -> list[dict]:
        t0 = time.time()
        step = self.start_step
        end = self.start_step + num_steps
        while step < end:
            batch_np = self.batcher.batch_at(step)
            batch = {"tokens": jnp.asarray(batch_np)}
            for k, shp in self.model.extras_shapes(
                    batch_np.shape[0]).items():
                batch[k] = jnp.zeros(shp, jnp.bfloat16)
            self.state, metrics = self.step_fn(self.state, batch)
            step += 1
            if step % self.log_every == 0 or step == end:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "elapsed_s": round(time.time() - t0, 2)}
                self.history.append(rec)
                print(f"step {rec['step']:6d}  loss {rec['loss']:.4f}  "
                      f"gnorm {rec['grad_norm']:.3f}  "
                      f"{rec['elapsed_s']:.1f}s", flush=True)
            if self.ckpt_dir and (step % self.ckpt_every == 0
                                  or step == end):
                save_checkpoint(self.ckpt_dir, step, self.state,
                                keep=self.keep)
        self.start_step = step
        return self.history
