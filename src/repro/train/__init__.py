"""Training/serving runtime."""
from .trainer import TrainState, Trainer, init_train_state, make_train_step

__all__ = ["TrainState", "Trainer", "init_train_state", "make_train_step"]
