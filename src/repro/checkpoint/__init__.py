"""Fault-tolerance substrate: atomic checkpoints + elastic re-sharding."""
from .checkpoint import (checkpoint_steps, latest_step, prune_checkpoints,
                         restore_checkpoint, save_checkpoint, step_dir_valid)

__all__ = ["checkpoint_steps", "latest_step", "prune_checkpoints",
           "restore_checkpoint", "save_checkpoint", "step_dir_valid"]
