"""Atomic step checkpoints with elastic re-sharding.

Layout: ``<dir>/step_<N>/`` holding one ``arrays.npz`` (flattened pytree,
keys are '/'-joined tree paths) + ``meta.json``. Writes go to a ``.tmp``
sibling and are published with an atomic ``os.replace`` — a preempted
writer never leaves a half-checkpoint that ``latest_step`` could pick up.

Elastic re-sharding: arrays are stored unsharded (gathered); ``restore``
optionally takes shardings built against the *restoring* mesh and
``jax.device_put``s each leaf — so a job checkpointed on a 2×16×16 mesh
restarts unchanged on 16×16 (or a 1-chip debug host). On a real multi-host
cluster the same layout is produced per-host from
``fully_replicated_host_local_array``; the single-controller path here is
the degenerate case.

Integrity: every save records a per-leaf crc32 in ``meta.json``
(``leaf_crc32`` — see ``robust.integrity``); ``restore_checkpoint``
re-hashes what it read and raises ``IntegrityError`` naming the corrupted
leaves (``verify=False`` opts out, e.g. to load a corrupt state for
repair). ``latest_step`` only reports steps whose directory is structurally
sound (meta.json parses, arrays.npz present and zip-readable), so a
truncated or half-deleted step falls through to the newest valid one.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Flatten to {path: array}. Non-native dtypes (bfloat16, ...) are
    stored as raw byte views (npz can't round-trip ml_dtypes); the true
    dtype name travels in meta.json."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out, dtypes = {}, {}
    for path, leaf in flat:
        key = _SEP.join(_path_token(p) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind not in "biufc?":        # ml_dtypes extension type
            arr = arr.view(np.dtype(f"V{arr.dtype.itemsize}"))
        out[key] = arr
    return out, dtypes


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(ckpt_dir: str | Path, step: int, state: Any,
                    extra_meta: Optional[dict] = None,
                    keep: int = 3) -> Path:
    """Write an atomic checkpoint; prune to the newest ``keep`` steps."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays, dtypes = _flatten(state)
    np.savez(tmp / "arrays.npz", **arrays)
    from repro.robust.integrity import checksum_flat
    meta = {"step": int(step), "num_arrays": len(arrays),
            "dtypes": dtypes,
            "leaf_crc32": checksum_flat(arrays),
            "total_bytes": int(sum(a.nbytes for a in arrays.values()))}
    if extra_meta:
        meta.update(extra_meta)
    (tmp / "meta.json").write_text(json.dumps(meta, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    prune_checkpoints(ckpt_dir, keep)
    return final


def step_dir_valid(d: Path, deep: bool = True) -> bool:
    """Is a ``step_*`` directory a complete, readable checkpoint?

    Missing ``arrays.npz``/``meta.json``, unparseable meta, or a
    truncated/corrupt npz (broken zip central directory) all disqualify
    it. A meta.json that *parses* but whose ``leaf_crc32`` map lacks keys
    the npz actually holds also disqualifies: ``restore_checkpoint``
    could not verify those leaves, so the step is not a safe restore
    target (a half-rewritten meta is as dead as a torn npz).
    ``deep=False`` skips opening the npz (listing-only callers).
    """
    if not (d / "meta.json").exists() or not (d / "arrays.npz").exists():
        return False
    try:
        meta = json.loads((d / "meta.json").read_text())
    except (OSError, json.JSONDecodeError):
        return False
    if deep:
        try:
            with np.load(d / "arrays.npz") as z:
                files = set(z.files)
        except Exception:
            return False
        crcs = meta.get("leaf_crc32")
        if isinstance(crcs, dict) and not files <= set(crcs):
            return False
    return True


def checkpoint_steps(ckpt_dir: str | Path, validate: bool = True) -> list[int]:
    """Steps with a complete checkpoint directory, sorted ascending.

    ``validate=True`` (default) screens out corrupt or partially-written
    steps so ``latest_step`` — and therefore every ``step=None`` restore —
    falls back to the newest *valid* step instead of crashing on a
    truncated write.
    """
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = []
    for p in ckpt_dir.iterdir():
        if not p.name.startswith("step_"):
            continue
        try:
            step = int(p.name[5:])
        except ValueError:
            continue
        if validate and not step_dir_valid(p):
            continue
        if not validate and not (p / "meta.json").exists():
            continue
        steps.append(step)
    return sorted(steps)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = checkpoint_steps(ckpt_dir)
    return steps[-1] if steps else None


def prune_checkpoints(ckpt_dir: str | Path, keep: int) -> None:
    steps = checkpoint_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(Path(ckpt_dir) / f"step_{s:08d}", ignore_errors=True)


def restore_checkpoint(ckpt_dir: str | Path, target: Any,
                       step: Optional[int] = None,
                       shardings: Any = None,
                       verify: bool = True) -> tuple[Any, dict]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``, if given, is a matching pytree of
    ``jax.sharding.Sharding`` — each leaf is placed directly onto the new
    mesh (elastic re-sharding). Returns (state, meta).

    ``verify=True`` (default) re-hashes every stored leaf against the
    ``leaf_crc32`` table recorded at save time (when present) and raises
    ``robust.integrity.IntegrityError`` naming the corrupted leaves.
    Pass ``verify=False`` to load a known-corrupt state for repair."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    saved_dtypes = meta.get("dtypes", {})
    with np.load(d / "arrays.npz") as z:
        raw = {k: z[k] for k in z.files}
    if verify and meta.get("leaf_crc32"):
        from repro.robust.integrity import IntegrityError, verify_flat
        bad = verify_flat(raw, meta["leaf_crc32"])
        if bad:
            raise IntegrityError(bad, where=str(d))
    stored = {}
    for k, arr in raw.items():
        if arr.dtype.kind == "V" and k in saved_dtypes:
            arr = arr.view(np.dtype(saved_dtypes[k]))
        stored[k] = arr

    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: hasattr(x, "device_set"))[0]
        if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, tgt), shard in zip(paths, shard_leaves):
        key = _SEP.join(_path_token(p) for p in path)
        if key not in stored:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = stored[key]
        want_dtype = getattr(tgt, "dtype", arr.dtype)
        want_shape = tuple(getattr(tgt, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {key!r}: checkpoint {arr.shape} vs "
                f"target {want_shape}")
        arr = arr.astype(want_dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
