"""Parallel per-shard structure builds (compressed store + text index).

Shard builds are embarrassingly parallel: every shard runs the same
static-shape construction pipeline on its own slice. This helper turns the
historical host-side Python loop into a traced build:

* multi-device (``jax.local_device_count() > 1``): ``pmap`` over a device
  axis with an inner ``vmap`` over the shards each device owns — the mesh
  builds all shards at once and the result is already stacked leaf-wise;
* single device with ``parallel=True``: one ``vmap`` — a single XLA
  program builds every shard (no per-shard dispatch overhead);
* ``parallel=False`` or single device on "auto": the sequential loop —
  per-shard host dispatch, but each shard's build can early-exit on
  concrete values (e.g. the suffix-array doubling loop), which wins on one
  CPU device.

Any traced path requires ``build_one`` to be trace-safe (no host syncs on
data values) — the wavelet-matrix and FM-index builders both are when
their alphabet size is pinned.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def build_shards_stacked(build_one: Callable, shards: jax.Array, *,
                         parallel: str | bool = "auto",
                         jit_loop: bool = False,
                         retries: int = 0, backoff_s: float = 0.05):
    """Build one pytree per shard row and stack them leaf-wise.

    ``shards``: (num_shards, shard_size) array (any integer dtype).
    ``parallel``: "auto" | True | False as described in the module doc.
    pmap requires ``num_shards`` divisible by the device count; otherwise
    the traced path falls back to a single vmap.

    ``jit_loop=True`` jits ``build_one`` once on the sequential-loop path,
    so every shard reuses one compiled whole-builder executable instead of
    dispatching op-by-op (all shards share one static shape). Leave it off
    for builders that exploit concrete values in loop mode (e.g. the
    suffix-array doubling early exit).

    ``retries > 0`` wraps the whole build in bounded retry with
    exponential backoff (``robust.faults.with_retry``) — the
    rebuild-from-source escalation path uses this so a transiently failing
    device doesn't turn a repairable incident into an outage.
    """
    if retries > 0:
        from repro.robust.faults import with_retry
        return with_retry(
            lambda: build_shards_stacked(build_one, shards,
                                         parallel=parallel,
                                         jit_loop=jit_loop, retries=0),
            retries=retries, backoff_s=backoff_s)
    shards = jnp.asarray(shards)
    num_shards = shards.shape[0]
    ndev = jax.local_device_count()

    if parallel == "auto":
        mode = "pmap" if (ndev > 1 and num_shards > 1) else "loop"
    elif parallel is True:
        mode = "pmap" if (ndev > 1 and num_shards > 1) else "vmap"
    elif parallel is False:
        mode = "loop"
    else:
        raise ValueError(f"parallel must be 'auto'/True/False, "
                         f"got {parallel!r}")
    if mode == "pmap" and num_shards % ndev != 0:
        mode = "vmap"                  # ragged over devices → one program

    if mode == "loop" or num_shards == 1:
        fn = jax.jit(build_one) if jit_loop else build_one
        built = [fn(shards[s]) for s in range(num_shards)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *built)
    if mode == "vmap":
        return jax.vmap(build_one)(shards)
    per = num_shards // ndev
    out = jax.pmap(jax.vmap(build_one))(
        shards.reshape(ndev, per, shards.shape[1]))
    return jax.tree.map(
        lambda l: l.reshape((num_shards,) + l.shape[2:]), out)
