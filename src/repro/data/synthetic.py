"""Synthetic corpus generation (deterministic, Zipfian token statistics).

Real LM corpora are heavily skewed (Zipf exponent ~1), which is exactly the
regime where the paper's structures pay off: Huffman-shaped wavelet trees
compress to the empirical entropy, and rank/select corpus analytics touch
only packed words. The generator is seeded and stateless so any host can
regenerate any region of the corpus (fault-tolerance substrate: no pipeline
state to replay).
"""
from __future__ import annotations

import numpy as np


def zipf_probs(vocab_size: int, exponent: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    p = ranks ** (-exponent)
    return p / p.sum()


def make_corpus(n_tokens: int, vocab_size: int, seed: int = 0,
                exponent: float = 1.1, doc_len: int = 1024,
                eos_id: int = 0) -> np.ndarray:
    """Zipfian token stream with document boundaries every ``doc_len``.

    Token ids are assigned by shuffled rank so frequency is not correlated
    with id value (matches real tokenizers; also exercises the wavelet
    structures on non-monotone alphabets).
    """
    rng = np.random.default_rng(seed)
    p = zipf_probs(vocab_size, exponent)
    ids = rng.permutation(vocab_size)
    draws = rng.choice(vocab_size, size=n_tokens, p=p)
    toks = ids[draws].astype(np.uint32)
    toks[doc_len - 1::doc_len] = eos_id          # document separators
    return toks


def corpus_region(n_tokens: int, vocab_size: int, start: int, length: int,
                  seed: int = 0, exponent: float = 1.1,
                  doc_len: int = 1024, eos_id: int = 0) -> np.ndarray:
    """Regenerate ``[start, start+length)`` of the corpus without
    materializing the rest — the stateless-addressing primitive used when a
    data host is replaced mid-run.

    Implementation: per-block counter-mode RNG (Philox) keyed on the block
    index, so any aligned 64k block is independently reproducible.
    """
    block = 65536
    out = np.empty(length, np.uint32)
    p = zipf_probs(vocab_size, exponent)
    ids = np.random.default_rng(seed).permutation(vocab_size)
    b0, b1 = start // block, (start + length - 1) // block
    for b in range(b0, b1 + 1):
        rng = np.random.default_rng(np.random.Philox(key=seed + (b << 20)))
        blk = ids[rng.choice(vocab_size, size=block, p=p)].astype(np.uint32)
        gstart = b * block
        idx = np.arange(gstart, gstart + block)
        blk[(idx % doc_len) == doc_len - 1] = eos_id
        lo = max(start, gstart)
        hi = min(start + length, gstart + block)
        out[lo - start:hi - start] = blk[lo - gstart:hi - gstart]
    return out
