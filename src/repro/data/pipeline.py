"""Deterministic sharded batch pipeline.

Batch addressing is a pure function of (seed, step, example index): each
example's corpus offset comes from a counter-mode hash, so

* any host can (re)serve any batch of any step with no pipeline state —
  a restarted or replaced data host needs no replay (fault tolerance);
* stragglers can be re-assigned examples without coordination;
* resume-from-checkpoint restarts mid-stream exactly.

Two backing stores: a raw uint32 token array, or the wavelet-matrix
``CompressedCorpus`` (decoded on the fly via vectorized ``access``).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .compressed_store import CompressedCorpus


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — cheap counter-mode hash (vectorized)."""
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def batch_offsets(step: int, batch: int, n_tokens: int, seq_len: int,
                  seed: int = 0) -> np.ndarray:
    """Corpus start offsets for every example of a step (stateless)."""
    limit = n_tokens - seq_len - 1
    assert limit > 0, "corpus shorter than one example"
    ctr = (np.uint64(seed) << np.uint64(40)) \
        + (np.uint64(step) << np.uint64(16)) \
        + np.arange(batch, dtype=np.uint64)
    with np.errstate(over="ignore"):
        h = _mix64(ctr)
    return (h % np.uint64(limit)).astype(np.int64)


class TokenBatcher:
    """Serves (B, S+1) next-token-prediction batches by step index."""

    def __init__(self, tokens: Optional[np.ndarray] = None,
                 corpus: Optional[CompressedCorpus] = None,
                 batch: int = 8, seq_len: int = 256, seed: int = 0):
        assert (tokens is None) != (corpus is None), \
            "exactly one of tokens/corpus"
        self.tokens = tokens
        self.corpus = corpus
        self.n = len(tokens) if tokens is not None else corpus.n
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        if corpus is not None:
            self._decode = jax.jit(
                lambda starts: jax.vmap(
                    lambda s: corpus.decode_slice(s, seq_len + 1))(starts))

    def batch_at(self, step: int) -> np.ndarray:
        offs = batch_offsets(step, self.batch, self.n, self.seq_len,
                             self.seed)
        if self.tokens is not None:
            idx = offs[:, None] + np.arange(self.seq_len + 1)[None, :]
            return self.tokens[idx].astype(np.int32)
        out = self._decode(jnp.asarray(offs, jnp.int32))
        return np.asarray(out, np.int32)

    def iterate(self, start_step: int = 0,
                prefetch: int = 2) -> Iterator[np.ndarray]:
        """Host-prefetching iterator (a daemon thread keeps ``prefetch``
        batches ahead; the training loop never blocks on decode)."""
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
