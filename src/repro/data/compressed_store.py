"""Wavelet-matrix compressed token store — the paper's technique as the
framework's corpus substrate.

Tokenized corpora are stored as a stack of fixed-size wavelet-matrix shards
over the token alphabet: ``⌈logσ⌉`` bits/token (e.g. 18 for qwen2's
σ=151936 — 1.8× smaller than uint32) plus the o(n) rank/select directories.
Construction per shard runs the paper's τ-chunked parallel algorithm
(Theorem 4.5); queries give O(logσ) random ``access`` (batch decoding),
``rank`` (corpus-frequency analytics, dedup heuristics) and ``select``
(locate the k-th occurrence — span queries for retrieval-style sampling).

Shards are stacked leaf-wise into one pytree so a batch of positions across
shards is a single vmapped query (shard id → leaf gather). Shard size is a
power of two so position → (shard, offset) is shift/mask.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.engine import (sharded_range_count,
                                    sharded_range_distinct,
                                    sharded_range_histogram,
                                    sharded_range_quantile,
                                    sharded_range_topk)
from repro.core.wavelet_matrix import (WaveletMatrix, build_wavelet_matrix,
                                       num_levels, wm_access, wm_rank,
                                       wm_select)

from .shard_build import build_shards_stacked

_I32 = jnp.int32


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class CompressedCorpus:
    """Sharded wavelet-matrix corpus + per-shard symbol histograms."""
    shards: WaveletMatrix          # leaves carry a leading (num_shards,) axis
    shard_counts: jax.Array        # (num_shards + 1, sigma) exclusive cumsum
    n: int = field(metadata=dict(static=True))
    sigma: int = field(metadata=dict(static=True))
    shard_bits: int = field(metadata=dict(static=True))

    # ---- geometry ----
    @property
    def shard_size(self) -> int:
        return 1 << self.shard_bits

    @property
    def num_shards(self) -> int:
        return self.shard_counts.shape[0] - 1

    @property
    def nbits(self) -> int:
        return num_levels(self.sigma)

    def shard(self, s: jax.Array) -> WaveletMatrix:
        return jax.tree.map(lambda l: l[s], self.shards)

    # ---- size accounting ----
    def bits_per_token(self) -> float:
        total_bits = sum(l.size * l.dtype.itemsize * 8
                         for l in jax.tree.leaves(self.shards))
        return total_bits / self.n

    def raw_bits_per_token(self) -> int:
        return 32

    # ---- queries ----
    def access(self, pos: jax.Array) -> jax.Array:
        """Decode tokens at arbitrary positions. pos: (...,) int."""
        pos = jnp.asarray(pos, _I32)
        sid = pos >> self.shard_bits
        off = pos & (self.shard_size - 1)

        def one(s, o):
            return wm_access(self.shard(s), o)

        flat = jax.vmap(one)(sid.reshape(-1), off.reshape(-1))
        return flat.reshape(pos.shape)

    def decode_slice(self, start: jax.Array, length: int) -> jax.Array:
        """Decode a contiguous span (batch serving path). Static length."""
        return self.access(jnp.asarray(start, _I32) + jnp.arange(length, dtype=_I32))

    def count(self, token: jax.Array, upto: Optional[jax.Array] = None) -> jax.Array:
        """# occurrences of ``token`` in [0, upto) (whole corpus if None)."""
        token = jnp.asarray(token, _I32)
        if upto is None:
            return self.shard_counts[-1, token]
        upto = jnp.asarray(upto, _I32)
        sid = upto >> self.shard_bits
        off = upto & (self.shard_size - 1)

        def one(t, s, o):
            return self.shard_counts[s, t] + wm_rank(self.shard(s), t, o)

        flat = jax.vmap(one)(token.reshape(-1), sid.reshape(-1),
                             off.reshape(-1))
        return flat.reshape(token.shape)

    def locate(self, token: jax.Array, k: jax.Array) -> jax.Array:
        """Position of the k-th (0-based) occurrence of ``token``."""
        token = jnp.asarray(token, _I32)
        k = jnp.asarray(k, _I32)

        def one(t, kk):
            col = self.shard_counts[:, t]                  # (S+1,) cumulative
            s = jnp.clip(jnp.searchsorted(col, kk, side="right") - 1,
                         0, self.num_shards - 1)
            within = kk - col[s]
            return (s << self.shard_bits) + wm_select(self.shard(s), t, within)

        flat = jax.vmap(one)(token.reshape(-1), k.reshape(-1))
        return flat.reshape(token.shape)

    # ---- range analytics (repro.analytics engine over these shards) ----
    def range_quantile(self, lo, hi, k) -> jax.Array:
        """k-th smallest token in corpus positions [lo, hi). Batched."""
        return sharded_range_quantile(self.shards, self.shard_bits, self.n,
                                      lo, hi, k)

    def range_count(self, lo, hi, sym_lo, sym_hi) -> jax.Array:
        """# of positions in [lo, hi) holding a token in [sym_lo, sym_hi)."""
        return sharded_range_count(self.shards, self.shard_bits, self.n,
                                   lo, hi, sym_lo, sym_hi)

    def range_topk(self, lo, hi, k: int):
        """(tokens, counts) of the k most frequent tokens in [lo, hi)."""
        return sharded_range_topk(self.shards, self.shard_bits, self.n,
                                  lo, hi, k)

    def range_distinct(self, lo, hi) -> jax.Array:
        """# of distinct tokens in [lo, hi)."""
        return sharded_range_distinct(self.shards, self.shard_bits, self.n,
                                      lo, hi)

    def range_histogram(self, lo, hi) -> jax.Array:
        """Per-token counts over [lo, hi): (…, 2^nbits) int32."""
        return sharded_range_histogram(self.shards, self.shard_bits, self.n,
                                       lo, hi)


def build_compressed_corpus(tokens: np.ndarray, sigma: int,
                            shard_bits: int = 16, tau: int = 8,
                            big_step: str = "compose",
                            sample_rate: int = 512,
                            parallel: str | bool = "auto"
                            ) -> CompressedCorpus:
    """Ingest a token stream: pad to whole shards, run the paper's parallel
    construction per shard, stack the shard trees leaf-wise. Shard builds
    fan out over the device mesh (``data.shard_build``): pmap across
    devices when several are present, else a vmap or the sequential loop
    per ``parallel`` ("auto" | True | False).

    Padding tokens (id 0) exist only in the slack tail of the last shard
    and are never addressed (n records the true length; the shard
    histograms subtract them).
    """
    n = int(len(tokens))
    shard_size = 1 << shard_bits
    num_shards = max(1, (n + shard_size - 1) // shard_size)
    pad = num_shards * shard_size - n
    toks = np.asarray(tokens, np.uint32)
    if pad:
        toks = np.concatenate([toks, np.zeros(pad, np.uint32)])
    shards_np = toks.reshape(num_shards, shard_size)

    # The builder picks its own kernel route: Pallas on TPU, mechanically
    # falling back to the (fully batchable) XLA fast path when vmapped.
    # jit_loop compiles the whole builder once on the sequential path so
    # every shard reuses one executable.
    stacked = build_shards_stacked(
        lambda s: build_wavelet_matrix(s, sigma, tau=tau, big_step=big_step,
                                       sample_rate=sample_rate),
        shards_np, parallel=parallel, jit_loop=True)

    hist = np.zeros((num_shards, sigma), np.int64)
    for i, s in enumerate(shards_np):
        hist[i] = np.bincount(s, minlength=sigma)[:sigma]
    if pad:  # padding tokens are id 0: remove them from the last histogram
        hist[-1, 0] -= pad
    cum = np.concatenate([np.zeros((1, sigma), np.int64),
                          np.cumsum(hist, axis=0)]).astype(np.int32)

    return CompressedCorpus(shards=stacked,
                            shard_counts=jnp.asarray(cum),
                            n=n, sigma=sigma, shard_bits=shard_bits)


def token_histogram(corpus: CompressedCorpus) -> jax.Array:
    """Global symbol frequencies (drives Huffman codebooks, sampling)."""
    return corpus.shard_counts[-1]
