"""Data substrate: synthetic corpora, wavelet-matrix compressed store,
deterministic batch pipeline."""
from .compressed_store import (CompressedCorpus, build_compressed_corpus,
                               token_histogram)
from .pipeline import TokenBatcher, batch_offsets
from .synthetic import make_corpus, zipf_probs

__all__ = ["CompressedCorpus", "build_compressed_corpus", "token_histogram",
           "TokenBatcher", "batch_offsets", "make_corpus", "zipf_probs"]
