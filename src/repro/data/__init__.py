"""Data substrate: synthetic corpora, wavelet-matrix compressed store,
deterministic batch pipeline."""
from .compressed_store import (CompressedCorpus, build_compressed_corpus,
                               token_histogram)
from .pipeline import TokenBatcher, batch_offsets
from .shard_build import build_shards_stacked
from .synthetic import make_corpus, zipf_probs

__all__ = ["CompressedCorpus", "build_compressed_corpus", "token_histogram",
           "TokenBatcher", "batch_offsets", "build_shards_stacked",
           "make_corpus", "zipf_probs"]
