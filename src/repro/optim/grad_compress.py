"""Error-feedback bitplane gradient compression.

The DP/pod-axis all-reduce is the slowest link at multi-pod scale (DCN).
Each gradient tensor is quantized to ``bits`` levels (sign + magnitude) and
the bit-planes are packed into uint32 words with the same ``core.bitops``
machinery the rank/select structures use — wire volume drops to
``bits/32`` of f32 (e.g. 4 bits → 8×). Quantization error is carried in an
error-feedback residual (Seide et al. 2014; Karimireddy et al. 2019), so
the *accumulated* update is unbiased and convergence matches uncompressed
SGD/Adam to first order.

Planes are MSB-first: truncating trailing planes degrades precision
gracefully (an elastic-bandwidth knob: a congested pod link can drop
planes without renegotiation).

``compressed_allreduce_mean`` is the shard_map collective: quantize local →
all_gather packed planes (the compressed wire format) → dequantize → mean.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitops


def quantize_bitplanes(x: jax.Array, bits: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """x (any shape) → (planes (bits, ceil(n/32)) uint32, scale () f32).

    Plane 0 = sign; planes 1.. = magnitude bits, MSB first.
    """
    assert bits >= 2
    flat = x.reshape(-1).astype(jnp.float32)
    m = jnp.int32((1 << (bits - 1)) - 1)
    amax = jnp.max(jnp.abs(flat))
    scale = jnp.where(amax > 0, amax / m, 1.0)
    q = jnp.clip(jnp.round(flat / scale), -m, m).astype(jnp.int32)
    sign = (q < 0).astype(jnp.uint8)
    mag = jnp.abs(q).astype(jnp.uint32)
    planes = [sign]
    for i in range(bits - 1):
        planes.append(((mag >> jnp.uint32(bits - 2 - i)) & 1).astype(jnp.uint8))
    words = jnp.stack([bitops.pack_bits(bitops.pad_bits(p)) for p in planes])
    return words, scale


def dequantize_bitplanes(words: jax.Array, scale: jax.Array, bits: int,
                         shape: tuple, keep_planes: int | None = None
                         ) -> jax.Array:
    """Inverse of :func:`quantize_bitplanes`.

    ``keep_planes`` < bits emulates dropping trailing magnitude planes
    (coarser quantization at lower wire cost)."""
    n = 1
    for d in shape:
        n *= d
    kp = bits if keep_planes is None else keep_planes
    sign = bitops.unpack_bits(words[0], n).astype(jnp.bool_)
    mag = jnp.zeros((n,), jnp.uint32)
    for i in range(kp - 1):
        mag = mag | (bitops.unpack_bits(words[1 + i], n).astype(jnp.uint32)
                     << jnp.uint32(bits - 2 - i))
    val = jnp.where(sign, -(mag.astype(jnp.float32)), mag.astype(jnp.float32))
    return (val * scale).reshape(shape)


def ef_compress_tree(grads: Any, residuals: Any, bits: int
                     ) -> Tuple[Any, Any]:
    """Error-feedback round trip on a gradient pytree.

    Returns (decompressed grads as seen after the wire, new residuals).
    The caller feeds the output grads to the optimizer; residuals persist
    in the train state."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        words, scale = quantize_bitplanes(corrected, bits)
        dq = dequantize_bitplanes(words, scale, bits, g.shape)
        return dq.astype(g.dtype), corrected - dq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def zero_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_allreduce_mean(tree: Any, axis_name: str, bits: int) -> Any:
    """Mean-reduce a pytree across ``axis_name`` with compressed wire format
    (use under ``shard_map``). Each member ships packed planes + scale."""
    # jax.lax.axis_size is jax ≥ 0.6; psum of 1 is the portable spelling
    size = (jax.lax.axis_size(axis_name) if hasattr(jax.lax, "axis_size")
            else jax.lax.psum(1, axis_name))

    def one(g):
        words, scale = quantize_bitplanes(g, bits)
        all_words = jax.lax.all_gather(words, axis_name)     # (P, bits, W)
        all_scale = jax.lax.all_gather(scale, axis_name)     # (P,)
        dq = jax.vmap(
            lambda w, s: dequantize_bitplanes(w, s, bits, g.shape))(
                all_words, all_scale)
        return (jnp.sum(dq, axis=0) / size).astype(g.dtype)

    return jax.tree.map(one, tree)


def compression_ratio(bits: int) -> float:
    """Wire bytes vs f32 (ignoring the per-tensor scale scalar)."""
    return bits / 32.0
