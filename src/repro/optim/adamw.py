"""Sharded AdamW with global-norm clipping (ZeRO-style: states shard like
params — the dry-run in_shardings reuse the param PartitionSpecs for m/v)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class AdamWState:
    m: Any
    v: Any
    step: jax.Array      # () int32


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      step=jnp.zeros((), jnp.int32))


def abstract_opt_state(abstract_params) -> AdamWState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(m=jax.tree.map(f32, abstract_params),
                      v=jax.tree.map(f32, abstract_params),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: AdamWState, lr: jax.Array,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-12))
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (new_params,
            AdamWState(m=new_m, v=new_v, step=step),
            {"grad_norm": gnorm})
