from .adamw import AdamWState, adamw_init, adamw_update, abstract_opt_state  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
