"""Pallas TPU kernels: one fused wavelet-matrix level step.

A wavelet-matrix level does three things with the narrow (τ-bit) keys:
extract the level's bit, emit the packed bitmap, count zeros, and compute
the stable 0/1-partition destination of every element. The destination of
a one needs the *global* zero count, so the computation is two passes (the
classic two-phase scan). Two realizations:

* Two launches (historical):
  phase 1 (``wm_counts_pallas``)  — per-block zero counts;
  phase 2 (``wm_apply_pallas``)   — given the exclusive block offsets and
       the total, emit destinations and the packed bitmap in one pass.
       ``ones_before(block) = block_start − zeros_before(block)``, so only
       the zero offsets travel between phases.

* ONE launch (``wm_level_fused_pallas``, the construction fast path): the
  grid is (2, nblocks) and the TPU grid executes sequentially, so pass 0
  accumulates the per-block zero counts into a VMEM scratch that persists
  across the whole grid, and pass 1 reads the scratch (total + running
  carry in SMEM) to emit destinations, bitmap words, and the zero count —
  no XLA ops between phases, no HBM round-trip for the offsets. Because
  the scratch carries cross-step state, this kernel must NOT be wrapped in
  ``vmap`` (use the two-launch pair or the XLA fast path for batched
  builds).

Padding convention: the wrapper pads keys so that padded elements read as
ones; their destinations land past n and are trimmed, while bitmap bits at
padded positions are masked to 0 (rank directories require zero padding).

Block geometry: 1024 keys/grid step; VMEM ≈ 1024×4 B keys + 1024×4 B dest
+ 32×4 B bitmap words (+ nblocks×4 B count scratch for the fused form).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 1024
_WPB = BLOCK // 32      # bitmap words per block


def _counts_kernel(sub_ref, cnt_ref, *, shift):
    bit = (sub_ref[...] >> jnp.uint32(shift)) & jnp.uint32(1)
    cnt_ref[0, 0] = (jnp.int32(BLOCK)
                     - jnp.sum(bit, dtype=jnp.int32))


def wm_counts_pallas(sub: jax.Array, shift: int, *,
                     interpret: bool = False) -> jax.Array:
    """``sub``: (1, N) uint32 keys, N multiple of BLOCK → (1, N/BLOCK) zeros."""
    _, n = sub.shape
    assert n % BLOCK == 0
    nblocks = n // BLOCK
    return pl.pallas_call(
        functools.partial(_counts_kernel, shift=shift),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nblocks), jnp.int32),
        interpret=interpret,
    )(sub)


def _apply_kernel(sub_ref, zexcl_ref, total_ref, dest_ref, bm_ref,
                  *, shift, n_valid):
    i = pl.program_id(0)
    sub = sub_ref[...]                                      # (1, BLOCK)
    bit = ((sub >> jnp.uint32(shift)) & jnp.uint32(1)).astype(jnp.int32)
    idx_local = jax.lax.broadcasted_iota(jnp.int32, bit.shape, 1)
    zeros_local_excl = jnp.cumsum(1 - bit, axis=1) - (1 - bit)
    ones_local_excl = idx_local - zeros_local_excl
    zeros_before = zexcl_ref[0, 0]
    ones_before = i * BLOCK - zeros_before
    total_zeros = total_ref[0, 0]
    dest = jnp.where(bit == 0,
                     zeros_before + zeros_local_excl,
                     total_zeros + ones_before + ones_local_excl)
    dest_ref[...] = dest
    # packed bitmap with padding masked to zero
    gidx = i * BLOCK + idx_local
    bm_bit = jnp.where(gidx < n_valid, bit, 0).astype(jnp.uint32)
    b2 = bm_bit.reshape(_WPB, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, b2.shape, 1)
    bm_ref[...] = jnp.sum(b2 << shifts, axis=1, dtype=jnp.uint32
                          ).reshape(1, _WPB)


def _fused_kernel(sub_ref, dest_ref, bm_ref, z_ref, cnt_ref, carry_ref,
                  *, shift, n_valid):
    p = pl.program_id(0)                        # 0: count, 1: apply
    i = pl.program_id(1)
    sub = sub_ref[...]                                      # (1, BLOCK)
    bit = ((sub >> jnp.uint32(shift)) & jnp.uint32(1)).astype(jnp.int32)
    cnt = jnp.int32(BLOCK) - jnp.sum(bit, dtype=jnp.int32)

    @pl.when(p == 0)
    def _count():
        cnt_ref[0, i] = cnt

    @pl.when((p == 1) & (i == 0))
    def _init():
        carry_ref[0, 0] = jnp.int32(0)
        carry_ref[0, 1] = jnp.sum(cnt_ref[...], dtype=jnp.int32)

    zeros_before = carry_ref[0, 0]
    total_zeros = carry_ref[0, 1]
    idx_local = jax.lax.broadcasted_iota(jnp.int32, bit.shape, 1)
    zeros_local_excl = jnp.cumsum(1 - bit, axis=1) - (1 - bit)
    ones_local_excl = idx_local - zeros_local_excl
    ones_before = i * BLOCK - zeros_before
    dest = jnp.where(bit == 0,
                     zeros_before + zeros_local_excl,
                     total_zeros + ones_before + ones_local_excl)
    dest_ref[...] = dest
    gidx = i * BLOCK + idx_local
    bm_bit = jnp.where(gidx < n_valid, bit, 0).astype(jnp.uint32)
    b2 = bm_bit.reshape(_WPB, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, b2.shape, 1)
    bm_ref[...] = jnp.sum(b2 << shifts, axis=1, dtype=jnp.uint32
                          ).reshape(1, _WPB)
    z_ref[0, 0] = total_zeros

    @pl.when(p == 1)
    def _advance():
        carry_ref[0, 0] = zeros_before + cnt


def wm_level_fused_pallas(sub: jax.Array, shift: int, n_valid: int, *,
                          interpret: bool = False):
    """Single-launch fused level step (count pass + apply pass in one grid).

    ``sub``: (1, N) uint32 keys, N a multiple of BLOCK, padded with ones.
    Returns (dest (1, N) int32, bitmap (1, N/32) uint32,
    total_zeros (1, 1) int32). Pass 0 writes garbage to the dest/bitmap
    blocks; pass 1 revisits every block and overwrites it with the real
    values (the sequential TPU grid guarantees the ordering). Not
    vmap-safe — the scratch carries state across the whole grid.
    """
    _, n = sub.shape
    assert n % BLOCK == 0
    nblocks = n // BLOCK
    return pl.pallas_call(
        functools.partial(_fused_kernel, shift=shift, n_valid=n_valid),
        grid=(2, nblocks),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda p, i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda p, i: (0, i)),
            pl.BlockSpec((1, _WPB), lambda p, i: (0, i)),
            pl.BlockSpec((1, 1), lambda p, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n // 32), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, nblocks), jnp.int32),
                        pltpu.SMEM((1, 2), jnp.int32)],
        interpret=interpret,
    )(sub)


def wm_apply_pallas(sub: jax.Array, zeros_excl: jax.Array,
                    total_zeros: jax.Array, shift: int, n_valid: int, *,
                    interpret: bool = False):
    """Phase 2. Returns (dest (1, N) int32, bitmap (1, N/32) uint32)."""
    _, n = sub.shape
    assert n % BLOCK == 0
    nblocks = n // BLOCK
    return pl.pallas_call(
        functools.partial(_apply_kernel, shift=shift, n_valid=n_valid),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, _WPB), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n // 32), jnp.uint32),
        ],
        interpret=interpret,
    )(sub, zeros_excl, total_zeros)
