"""Pallas TPU kernel: pack a bit vector into uint32 words.

This is the innermost hot loop of every bitmap construction in the paper
(each wavelet level packs n bits). Layout: the wrapper (ops.py) presents the
bits as a (32, W) int32 array — bit k of output word w lives at [k, w] — so
the kernel reduces along the 32-sublane axis and keeps 128 words per lane
vector, matching the VPU's (8, 128) vreg tiling. One VMEM block is
(32, 128) int32 = 16 KiB in / (1, 128) uint32 out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _bitpack_kernel(bits_ref, words_ref):
    bits = bits_ref[...].astype(jnp.uint32)            # (32, 128)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 0)
    words_ref[...] = jnp.sum(bits << shifts, axis=0, keepdims=True,
                             dtype=jnp.uint32)


def bitpack_pallas(bits_t: jax.Array, *, interpret: bool = False) -> jax.Array:
    """``bits_t``: (32, W) with W a multiple of 128 → (1, W) uint32 words."""
    _, w = bits_t.shape
    assert w % LANES == 0
    return pl.pallas_call(
        _bitpack_kernel,
        grid=(w // LANES,),
        in_specs=[pl.BlockSpec((32, LANES), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, LANES), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, w), jnp.uint32),
        interpret=interpret,
    )(bits_t)
