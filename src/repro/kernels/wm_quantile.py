"""Pallas TPU kernel: fused wavelet-matrix level descent for quantile
batches.

A range-quantile query walks all ``nbits`` levels, probing ``rank0`` at
both interval endpoints per level. Issued from XLA, each probe is its own
gather chain over the rank directory with an HBM round-trip between
levels. This kernel fuses the *entire* descent: the per-level bitmaps,
rank directories and zero counts stay resident in VMEM while a block of
queries runs all levels to completion — one kernel launch per query block,
zero materialization of intermediate interval states.

Layout: every per-level array arrives stacked on a leading (nbits,) axis —
exactly how ``WaveletMatrix`` already stores them — so the kernel indexes
levels with static offsets inside an unrolled loop.

Geometry: QBLOCK queries per grid step; the structure arrays are broadcast
to every step (index_map → (0, 0)). VMEM ≈ nbits·(W + W/4 + W/32)·4 B for
the structure plus 4·QBLOCK·4 B of query state, which bounds the shard
sizes this kernel serves (a 2^16-position shard at σ=2^18 is ≈ 4.7 MB —
comfortably resident).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256
SUPERBLOCK_WORDS = 32     # must match repro.core.rank_select
BLOCK_WORDS = 4           # must match repro.core.rank_select
_BLK_PER_SB = SUPERBLOCK_WORDS // BLOCK_WORDS

_I32 = jnp.int32
_U32 = jnp.uint32


def _rank1_level(words_row, super_row, block_row, nblocks, i):
    """rank1 over one level's packed bits at positions ``i`` (QB,).

    Same two-level directory walk as ``core.rank_select.rank1``, expressed
    on the VMEM-resident rows: superblock + block-relative base, then ≤3
    whole-word popcounts and one masked popcount for the partial word.
    """
    w = i // 32
    b = w // BLOCK_WORDS
    bc = jnp.minimum(b, nblocks - 1)
    base = super_row[bc // _BLK_PER_SB].astype(_I32) + block_row[bc]
    j = jnp.arange(BLOCK_WORDS, dtype=_I32)
    widx = bc[:, None] * BLOCK_WORDS + j                       # (QB, 4)
    words4 = words_row[widx]                                   # gather
    wpos = widx
    off = (i - w * 32).astype(_U32)
    pc = jax.lax.population_count(words4).astype(_I32)
    mask = (_U32(1) << off[:, None]) - _U32(1)                 # off < 32
    partial = jax.lax.population_count(words4 & mask).astype(_I32)
    cnt = jnp.where(wpos < w[:, None], pc,
                    jnp.where(wpos == w[:, None], partial, 0))
    return base + jnp.sum(cnt, axis=1)


def _quantile_kernel(q_ref, words_ref, super_ref, block_ref, zeros_ref,
                     out_ref, *, nbits, n, nblocks):
    lo = jnp.clip(q_ref[0, :], 0, n)
    hi = jnp.clip(q_ref[1, :], lo, n)
    k = jnp.clip(q_ref[2, :], 0, jnp.maximum(hi - lo - 1, 0))
    empty = hi <= lo
    sym = jnp.zeros_like(lo)
    for l in range(nbits):                      # static unroll: fused descent
        words_row = words_ref[l, :]
        super_row = super_ref[l, :]
        block_row = block_ref[l, :]
        lo0 = lo - _rank1_level(words_row, super_row, block_row, nblocks, lo)
        hi0 = hi - _rank1_level(words_row, super_row, block_row, nblocks, hi)
        z = hi0 - lo0
        bit = (k >= z).astype(_I32)
        sym = (sym << 1) | bit
        k = jnp.where(bit == 1, k - z, k)
        zl = zeros_ref[0, l]
        lo = jnp.where(bit == 1, zl + (lo - lo0), lo0)
        hi = jnp.where(bit == 1, zl + (hi - hi0), hi0)
    out_ref[0, :] = jnp.where(empty, jnp.asarray(-1, _I32), sym)


def _sharded_quantile_kernel(q_ref, words_ref, super_ref, block_ref,
                             zeros_ref, out_ref, *, num_shards, nbits, n,
                             shard_bits, nblocks):
    """Count-then-refine descent over S stacked shards, fully fused.

    Per level: every shard probes rank0 at its local interval endpoints
    (rows ``s*nbits + l`` of the stacked structure arrays), the zero counts
    are summed across shards, the branch is taken on the *global* k, and
    every shard steps to the same child — the kernel realization of
    ``analytics.engine.sharded_range_quantile``.
    """
    size = 1 << shard_bits
    glo = jnp.clip(q_ref[0, :], 0, n)
    ghi = jnp.clip(q_ref[1, :], glo, n)
    los = [jnp.clip(glo - s * size, 0, size) for s in range(num_shards)]
    his = [jnp.clip(ghi - s * size, 0, size) for s in range(num_shards)]
    total = sum(h - l for l, h in zip(los, his))
    k = jnp.clip(q_ref[2, :], 0, jnp.maximum(total - 1, 0))
    empty = total <= 0
    sym = jnp.zeros_like(k)
    for l in range(nbits):                      # static unroll over levels
        lo0s, hi0s = [], []
        for s in range(num_shards):             # ... and over shards
            row = s * nbits + l
            words_row = words_ref[row, :]
            super_row = super_ref[row, :]
            block_row = block_ref[row, :]
            lo0s.append(los[s] - _rank1_level(words_row, super_row,
                                              block_row, nblocks, los[s]))
            hi0s.append(his[s] - _rank1_level(words_row, super_row,
                                              block_row, nblocks, his[s]))
        z = sum(h0 - l0 for l0, h0 in zip(lo0s, hi0s))
        bit = (k >= z).astype(_I32)
        sym = (sym << 1) | bit
        k = jnp.where(bit == 1, k - z, k)
        for s in range(num_shards):
            zl = zeros_ref[0, s * nbits + l]
            los[s] = jnp.where(bit == 1, zl + (los[s] - lo0s[s]), lo0s[s])
            his[s] = jnp.where(bit == 1, zl + (his[s] - hi0s[s]), hi0s[s])
    out_ref[0, :] = jnp.where(empty, jnp.asarray(-1, _I32), sym)


def wm_quantile_sharded_pallas(queries: jax.Array, words: jax.Array,
                               superblock: jax.Array, block: jax.Array,
                               zeros: jax.Array, *, num_shards: int,
                               nbits: int, n: int, shard_bits: int,
                               nblocks: int,
                               interpret: bool = False) -> jax.Array:
    """Fused sharded quantile descent: one launch per query block for the
    ENTIRE stacked (S,)-leaf layout.

    ``queries``: (3, Q) int32 rows (global lo, hi, k), Q a multiple of
    QBLOCK. ``words``/``superblock``/``block``/``zeros`` are the per-shard
    per-level arrays flattened to a leading (S·nbits,) row axis (row
    ``s*nbits + l``); see ``wm_quantile_pallas`` for the per-row layout
    contract. VMEM holds the whole stacked structure
    (≈ S·nbits·(W + W/4 + W/32)·4 B), which bounds the shard count × shard
    size this kernel serves. Returns (1, Q) int32 (-1 ⇔ empty)."""
    _, q = queries.shape
    assert q % QBLOCK == 0
    grid = (q // QBLOCK,)
    return pl.pallas_call(
        functools.partial(_sharded_quantile_kernel, num_shards=num_shards,
                          nbits=nbits, n=n, shard_bits=shard_bits,
                          nblocks=nblocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, QBLOCK), lambda i: (0, i)),
            pl.BlockSpec(words.shape, lambda i: (0, 0)),
            pl.BlockSpec(superblock.shape, lambda i: (0, 0)),
            pl.BlockSpec(block.shape, lambda i: (0, 0)),
            pl.BlockSpec(zeros.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, q), _I32),
        interpret=interpret,
    )(queries, words, superblock, block, zeros)


def wm_quantile_pallas(queries: jax.Array, words: jax.Array,
                       superblock: jax.Array, block: jax.Array,
                       zeros: jax.Array, *, n: int, nblocks: int,
                       interpret: bool = False) -> jax.Array:
    """Fused quantile descent over a query batch.

    ``queries``: (3, Q) int32 rows (lo, hi, k), Q a multiple of QBLOCK.
    ``words``: (nbits, W) uint32; ``superblock``: (nbits, SB) uint32;
    ``block``: (nbits, B) int32 (block-relative ranks, widened from the
    directory's uint16); ``zeros``: (1, nbits) int32. Gather safety:
    ``W ≥ nblocks·BLOCK_WORDS`` (zero-padded), ``nblocks`` counts the
    *real* directory blocks. Returns (1, Q) int32 symbols (-1 ⇔ empty).
    """
    nbits, w = words.shape
    _, q = queries.shape
    assert q % QBLOCK == 0
    grid = (q // QBLOCK,)
    return pl.pallas_call(
        functools.partial(_quantile_kernel, nbits=nbits, n=n,
                          nblocks=nblocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, QBLOCK), lambda i: (0, i)),
            pl.BlockSpec(words.shape, lambda i: (0, 0)),
            pl.BlockSpec(superblock.shape, lambda i: (0, 0)),
            pl.BlockSpec(block.shape, lambda i: (0, 0)),
            pl.BlockSpec(zeros.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, QBLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, q), _I32),
        interpret=interpret,
    )(queries, words, superblock, block, zeros)
