"""Pallas TPU kernel: one fused *segmented* wavelet-tree level step.

A wavelet-tree level partitions the narrow (τ-bit) short list *per node*:
each element's stable destination is ``bucket_base[(nid<<1)|bit] +
rank-within-bucket-before-it``. The bucket count is 2^(l+1), so unlike the
wavelet-matrix step (2 buckets) the cross-block state is a histogram, not
a pair of counters. Same single-launch two-pass structure as
``wm_level.wm_level_fused_pallas``: the grid is (2, nblocks) and the TPU
grid executes sequentially, so pass 0 accumulates per-block (node, bit)
histograms into a VMEM scratch persisting across the whole grid, and pass
1 derives the global bucket bases (exclusive sum over the total
histogram) plus a running per-bucket carry to emit stable destinations
and the packed bitmap — no XLA ops between phases, no HBM round-trip for
the offsets. Because the scratch carries cross-step state, this kernel
must NOT be wrapped in ``vmap``; deep levels whose bucket count exceeds
``MAX_KEYS`` use the XLA segmented select-gather instead
(``rank_select.segmented_partition_gather``).

Padding convention: the wrapper pads keys into a sentinel bucket ordered
after every real bucket, so padded destinations land past n and are
trimmed; bitmap bits at padded positions are masked to 0.

Block geometry: 1024 keys/grid step; VMEM ≈ BLOCK×NB one-hot (≤ 2.6 MB at
MAX_KEYS) + nblocks×NB count scratch + 2×NB carry rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 1024
_WPB = BLOCK // 32      # bitmap words per block
MAX_KEYS = 512          # max real (node, bit) buckets = 2^(l+1)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _fused_kernel(sub_ref, nid_ref, dest_ref, bm_ref, cnt_ref, carry_ref,
                  *, shift, nb, n_valid):
    p = pl.program_id(0)                        # 0: count, 1: apply
    i = pl.program_id(1)
    sub = sub_ref[...]                                      # (1, BLOCK)
    nid = nid_ref[...]                                      # (1, BLOCK)
    bit = ((sub >> jnp.uint32(shift)) & jnp.uint32(1)).astype(jnp.int32)
    key = (nid << 1) | bit                                  # (1, BLOCK)
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (BLOCK, nb), 1)
    onehot = (key.reshape(BLOCK, 1) == iota_b).astype(jnp.int32)
    hist = jnp.sum(onehot, axis=0)                          # (nb,)

    @pl.when(p == 0)
    def _count():
        cnt_ref[i, :] = hist

    @pl.when((p == 1) & (i == 0))
    def _init():
        totals = jnp.sum(cnt_ref[...], axis=0)
        carry_ref[0, :] = jnp.cumsum(totals) - totals       # bucket bases
        carry_ref[1, :] = jnp.zeros((nb,), jnp.int32)

    off = carry_ref[0, :] + carry_ref[1, :]                 # (nb,)
    within = jnp.cumsum(onehot, axis=0) - onehot            # (BLOCK, nb)
    dest = jnp.sum(onehot * (off[None, :] + within), axis=1)
    dest_ref[...] = dest.reshape(1, BLOCK)
    idx_local = jax.lax.broadcasted_iota(jnp.int32, (1, BLOCK), 1)
    gidx = i * BLOCK + idx_local
    bm_bit = jnp.where(gidx < n_valid, bit, 0).astype(jnp.uint32)
    b2 = bm_bit.reshape(_WPB, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, b2.shape, 1)
    bm_ref[...] = jnp.sum(b2 << shifts, axis=1, dtype=jnp.uint32
                          ).reshape(1, _WPB)

    @pl.when(p == 1)
    def _advance():
        carry_ref[1, :] = carry_ref[1, :] + hist


def wt_level_fused_pallas(sub: jax.Array, nid: jax.Array, shift: int,
                          nbkt: int, n_valid: int, *,
                          interpret: bool = False):
    """Single-launch fused segmented level step.

    ``sub``: (1, N) uint32 keys, ``nid``: (1, N) int32 node ids, N a
    multiple of BLOCK; padded elements must carry key ``(nid<<1)|bit ==
    nbkt`` (the sentinel bucket). Returns (dest (1, N) int32,
    bitmap (1, N/32) uint32). Pass 0 writes garbage dest/bitmap blocks;
    pass 1 revisits and overwrites them (the sequential TPU grid
    guarantees the ordering). Not vmap-safe.
    """
    _, n = sub.shape
    assert n % BLOCK == 0
    assert nbkt <= MAX_KEYS
    nblocks = n // BLOCK
    nb = _round_up(nbkt + 1, 128)
    return pl.pallas_call(
        functools.partial(_fused_kernel, shift=shift, nb=nb,
                          n_valid=n_valid),
        grid=(2, nblocks),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda p, i: (0, i)),
                  pl.BlockSpec((1, BLOCK), lambda p, i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda p, i: (0, i)),
            pl.BlockSpec((1, _WPB), lambda p, i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n // 32), jnp.uint32),
        ],
        scratch_shapes=[pltpu.VMEM((nblocks, nb), jnp.int32),
                        pltpu.VMEM((2, nb), jnp.int32)],
        interpret=interpret,
    )(sub, nid)
