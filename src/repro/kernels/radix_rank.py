"""Pallas TPU kernel: blocked stable counting rank (the paper's big-node
stable-integer-sort primitive, Section 2 / Theorem 4.5's one-sort-per-τ).

``counting_rank`` needs, for every element, ``bucket_base[d] +
rank_within_bucket`` — the classic stable-counting-sort destination. The
XLA realizations either materialize an O(n·B) one-hot matrix in HBM or
serialize blocks under ``lax.map``. This kernel keeps the one-hot strictly
in VMEM and runs two sequential-grid passes:

  phase 1 (``radix_hist_pallas``)  — per-block bucket histograms
       (BLOCK×(B+1) one-hot reduced in VMEM → (B+1,) counts per block);
  phase 2 (``radix_apply_pallas``) — given the exclusive cross-block
       offsets and the global bucket bases (two tiny XLA scans over the
       (nblocks, B+1) histogram matrix), emit each element's destination:
       ``base[d] + across[block, d] + within_block_rank``. The within-block
       rank and the per-element gathers from the offset rows are expressed
       as masked one-hot sums, so the kernel is pure VPU arithmetic — no
       gathers, no HBM one-hot.

Padding convention: the wrapper pads the digit array with a sentinel
bucket B (placed after every real bucket), so padded elements rank past
every real element and are trimmed.

Geometry: 1024 digits per grid step; VMEM ≈ 1024×(B+1)×4 B for the
one-hot (B ≤ 512 → ≤ 2.1 MB) plus the (B+1,) offset rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024
MAX_BUCKETS = 512      # one-hot VMEM bound: BLOCK×(MAX_BUCKETS+1)×4 B

_I32 = jnp.int32


def _onehot(d, nb1):
    """(1, BLOCK) int32 digits → (BLOCK, nb1) int32 one-hot, in VMEM."""
    cols = jax.lax.broadcasted_iota(_I32, (BLOCK, nb1), 1)
    return (d.reshape(BLOCK, 1) == cols).astype(_I32)


def _hist_kernel(d_ref, hist_ref, *, nb1):
    oh = _onehot(d_ref[...], nb1)
    hist_ref[...] = jnp.sum(oh, axis=0, dtype=_I32).reshape(1, nb1)


def radix_hist_pallas(digits: jax.Array, num_buckets: int, *,
                      interpret: bool = False) -> jax.Array:
    """``digits``: (1, N) int32 in [0, num_buckets] (== num_buckets is the
    padding sentinel), N a multiple of BLOCK → (N/BLOCK, B+1) histograms."""
    _, n = digits.shape
    assert n % BLOCK == 0
    nblocks = n // BLOCK
    nb1 = num_buckets + 1
    return pl.pallas_call(
        functools.partial(_hist_kernel, nb1=nb1),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, nb1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, nb1), _I32),
        interpret=interpret,
    )(digits)


def _apply_kernel(d_ref, base_ref, across_ref, dest_ref, *, nb1):
    oh = _onehot(d_ref[...], nb1)                            # (BLOCK, nb1)
    excl = jnp.cumsum(oh, axis=0, dtype=_I32) - oh
    within = jnp.sum(excl * oh, axis=1, dtype=_I32)          # (BLOCK,)
    offs = base_ref[...] + across_ref[...]                   # (1, nb1)
    picked = jnp.sum(oh * offs, axis=1, dtype=_I32)          # offs[d_i]
    dest_ref[...] = (within + picked).reshape(1, BLOCK)


def radix_apply_pallas(digits: jax.Array, base: jax.Array,
                       across: jax.Array, num_buckets: int, *,
                       interpret: bool = False) -> jax.Array:
    """Phase 2: ``base``: (1, B+1) global bucket bases; ``across``:
    (N/BLOCK, B+1) exclusive cross-block bucket offsets. Returns
    (1, N) int32 stable destinations."""
    _, n = digits.shape
    assert n % BLOCK == 0
    nblocks = n // BLOCK
    nb1 = num_buckets + 1
    return pl.pallas_call(
        functools.partial(_apply_kernel, nb1=nb1),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, nb1), lambda i: (0, 0)),
            pl.BlockSpec((1, nb1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), _I32),
        interpret=interpret,
    )(digits, base, across)
