"""Pure-jnp oracles for every Pallas kernel (exact integer semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops
from repro.core.rank_select import BLOCK_WORDS, SUPERBLOCK_WORDS
from repro.core.scan import stable_partition_indices


def bitpack_ref(bits: jax.Array) -> jax.Array:
    """(n,) 0/1 → ceil(n/32) uint32 words, LSB-first."""
    return bitops.pack_bits(bitops.pad_bits(bits.astype(jnp.uint8)))


def rank_build_ref(words: jax.Array, n: int):
    """(superblock uint32, block_rel uint16) for a packed bit sequence.

    Same two-level geometry as ``core.rank_select.build_binary_rank``."""
    w = (n + 31) // 32
    words = words[:w]
    prefix = bitops.word_prefix_popcount(words)
    superblock = prefix[::SUPERBLOCK_WORDS]
    blk_prefix = prefix[::BLOCK_WORDS]
    nblk = blk_prefix.shape[0]
    sb_of_blk = jnp.arange(nblk, dtype=jnp.int32) // (SUPERBLOCK_WORDS
                                                      // BLOCK_WORDS)
    block = (blk_prefix - superblock[sb_of_blk]).astype(jnp.uint16)
    return superblock, block


def wm_quantile_ref(level_words: jax.Array, zeros: jax.Array, n: int,
                    lo: jax.Array, hi: jax.Array, k: jax.Array) -> jax.Array:
    """Range-quantile oracle from raw level bitmaps (exact integers).

    ``level_words``: (nbits, W) packed level bitmaps; ``zeros``: (nbits,)
    zero counts. rank0 is a dense prefix sum over the unpacked bits — no
    directories involved, so this cross-checks the kernel's directory walk.
    Vectorized over query arrays; empty ranges return -1, k clamps.
    """
    nbits = level_words.shape[0]
    # cum0[l, i] = # of zero bits among the first i bits of level l
    bits = jnp.stack([bitops.unpack_bits(level_words[l], n)
                      for l in range(nbits)]).astype(jnp.int32)
    cum0 = jnp.concatenate(
        [jnp.zeros((nbits, 1), jnp.int32),
         jnp.cumsum(1 - bits, axis=1, dtype=jnp.int32)], axis=1)
    lo = jnp.clip(jnp.asarray(lo, jnp.int32), 0, n)
    hi = jnp.clip(jnp.asarray(hi, jnp.int32), lo, n)
    k = jnp.clip(jnp.asarray(k, jnp.int32), 0, jnp.maximum(hi - lo - 1, 0))
    empty = hi <= lo
    sym = jnp.zeros_like(lo)
    for l in range(nbits):
        lo0, hi0 = cum0[l][lo], cum0[l][hi]
        z = hi0 - lo0
        bit = (k >= z).astype(jnp.int32)
        sym = (sym << 1) | bit
        k = jnp.where(bit == 1, k - z, k)
        lo = jnp.where(bit == 1, zeros[l] + (lo - lo0), lo0)
        hi = jnp.where(bit == 1, zeros[l] + (hi - hi0), hi0)
    return jnp.where(empty, jnp.asarray(-1, jnp.int32), sym)


def radix_rank_ref(digits: jax.Array, num_buckets: int) -> jax.Array:
    """Stable counting-sort destinations (exact integer semantics).

    dest[i] = # elements with smaller digit + # j<i with equal digit —
    the inverse of a stable argsort by digit."""
    del num_buckets
    n = digits.shape[0]
    order = jnp.argsort(digits.astype(jnp.int32), stable=True)
    return jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32), unique_indices=True)


def rank_build_levels_ref(words: jax.Array, n: int):
    """Row-wise ``rank_build_ref`` over stacked (L, W) level bitmaps."""
    outs = [rank_build_ref(words[l], n) for l in range(words.shape[0])]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))


def wm_quantile_sharded_ref(level_words: jax.Array, zeros: jax.Array,
                            shard_bits: int, n: int,
                            lo: jax.Array, hi: jax.Array,
                            k: jax.Array) -> jax.Array:
    """Global sharded range-quantile oracle from raw per-shard bitmaps.

    ``level_words``: (S, nbits, W) packed per-shard level bitmaps (shards
    cover ``2**shard_bits`` positions each); ``zeros``: (S, nbits).
    Count-then-refine descent with dense per-shard prefix sums — the
    cross-shard analogue of ``wm_quantile_ref``.
    """
    S, nbits, _ = level_words.shape
    size = 1 << shard_bits
    cum0 = []
    for s in range(S):
        bits = jnp.stack([bitops.unpack_bits(level_words[s, l], size)
                          for l in range(nbits)]).astype(jnp.int32)
        cum0.append(jnp.concatenate(
            [jnp.zeros((nbits, 1), jnp.int32),
             jnp.cumsum(1 - bits, axis=1, dtype=jnp.int32)], axis=1))
    lo = jnp.clip(jnp.asarray(lo, jnp.int32), 0, n)
    hi = jnp.clip(jnp.asarray(hi, jnp.int32), lo, n)
    los = [jnp.clip(lo - s * size, 0, size) for s in range(S)]
    his = [jnp.clip(hi - s * size, 0, size) for s in range(S)]
    total = sum(h - l for l, h in zip(los, his))
    k = jnp.clip(jnp.asarray(k, jnp.int32), 0, jnp.maximum(total - 1, 0))
    empty = total <= 0
    sym = jnp.zeros_like(k)
    for l in range(nbits):
        lo0s = [cum0[s][l][los[s]] for s in range(S)]
        hi0s = [cum0[s][l][his[s]] for s in range(S)]
        z = sum(h0 - l0 for l0, h0 in zip(lo0s, hi0s))
        bit = (k >= z).astype(jnp.int32)
        sym = (sym << 1) | bit
        k = jnp.where(bit == 1, k - z, k)
        for s in range(S):
            zl = zeros[s, l]
            los[s] = jnp.where(bit == 1, zl + (los[s] - lo0s[s]), lo0s[s])
            his[s] = jnp.where(bit == 1, zl + (his[s] - hi0s[s]), hi0s[s])
    return jnp.where(empty, jnp.asarray(-1, jnp.int32), sym)


def wm_level_step_ref(sub: jax.Array, shift: int, n: int):
    """(dest, bitmap, total_zeros) for one wavelet-matrix level."""
    sub = sub[:n].astype(jnp.uint32)
    bit = (sub >> jnp.uint32(shift)) & jnp.uint32(1)
    dest = stable_partition_indices(bit)
    bitmap = bitops.pack_bits(bitops.pad_bits(bit.astype(jnp.uint8)))
    total_zeros = jnp.int32(n) - jnp.sum(bit, dtype=jnp.int32)
    return dest, bitmap, total_zeros


def wt_level_step_ref(sub: jax.Array, nid: jax.Array, shift: int, n: int):
    """(dest, bitmap) for one *segmented* wavelet-tree level: stable
    destinations under a sort by (node id, level bit) — exact integer
    semantics via a stable argsort."""
    sub = sub[:n].astype(jnp.uint32)
    nid = nid[:n].astype(jnp.int32)
    bit = ((sub >> jnp.uint32(shift)) & jnp.uint32(1)).astype(jnp.int32)
    key = (nid << 1) | bit
    order = jnp.argsort(key, stable=True)
    dest = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32), unique_indices=True)
    bitmap = bitops.pack_bits(bitops.pad_bits(bit.astype(jnp.uint8)))
    return dest, bitmap
