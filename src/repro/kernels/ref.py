"""Pure-jnp oracles for every Pallas kernel (exact integer semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops
from repro.core.rank_select import BLOCK_WORDS, SUPERBLOCK_WORDS
from repro.core.scan import stable_partition_indices


def bitpack_ref(bits: jax.Array) -> jax.Array:
    """(n,) 0/1 → ceil(n/32) uint32 words, LSB-first."""
    return bitops.pack_bits(bitops.pad_bits(bits.astype(jnp.uint8)))


def rank_build_ref(words: jax.Array, n: int):
    """(superblock uint32, block_rel uint16) for a packed bit sequence.

    Same two-level geometry as ``core.rank_select.build_binary_rank``."""
    w = (n + 31) // 32
    words = words[:w]
    prefix = bitops.word_prefix_popcount(words)
    superblock = prefix[::SUPERBLOCK_WORDS]
    blk_prefix = prefix[::BLOCK_WORDS]
    nblk = blk_prefix.shape[0]
    sb_of_blk = jnp.arange(nblk, dtype=jnp.int32) // (SUPERBLOCK_WORDS
                                                      // BLOCK_WORDS)
    block = (blk_prefix - superblock[sb_of_blk]).astype(jnp.uint16)
    return superblock, block


def wm_level_step_ref(sub: jax.Array, shift: int, n: int):
    """(dest, bitmap, total_zeros) for one wavelet-matrix level."""
    sub = sub[:n].astype(jnp.uint32)
    bit = (sub >> jnp.uint32(shift)) & jnp.uint32(1)
    dest = stable_partition_indices(bit)
    bitmap = bitops.pack_bits(bitops.pad_bits(bit.astype(jnp.uint8)))
    total_zeros = jnp.int32(n) - jnp.sum(bit, dtype=jnp.int32)
    return dest, bitmap, total_zeros
