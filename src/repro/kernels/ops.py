"""Jit'd public wrappers around the Pallas kernels.

Each op handles padding/layout, dispatches to the kernel, and trims the
result. ``interpret`` defaults to True off-TPU so the same call sites work
on CPU (validation) and TPU (deployment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bitpack as _bitpack
from . import rank_build as _rank_build
from . import wm_level as _wm_level
from . import wm_quantile as _wm_quantile


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitpack(bits: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Pack a (n,) 0/1 vector into ceil(n/32) uint32 words (LSB-first)."""
    if interpret is None:
        interpret = _default_interpret()
    n = bits.shape[0]
    w = (n + 31) // 32
    wpad = ((w + _bitpack.LANES - 1) // _bitpack.LANES) * _bitpack.LANES
    flat = jnp.zeros((wpad * 32,), jnp.int32).at[:n].set(bits.astype(jnp.int32))
    bits_t = flat.reshape(wpad, 32).T                     # (32, wpad)
    words = _bitpack.bitpack_pallas(bits_t, interpret=interpret)
    return words[0, :w]


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def rank_build(words: jax.Array, n: int,
               interpret: bool | None = None):
    """Jacobson directory for a packed bit sequence of n bits.

    Returns (superblock uint32 (ceil(W/32),), block_rel uint16 (ceil(W/4),)),
    W = ceil(n/32) — identical contract to
    ``repro.core.rank_select.build_binary_rank``.
    """
    if interpret is None:
        interpret = _default_interpret()
    w = (n + 31) // 32
    sw = _rank_build.STEP_WORDS
    wpad = ((w + sw - 1) // sw) * sw
    wp = jnp.zeros((1, wpad), jnp.uint32).at[0, :words.shape[0]].set(words)
    block_rel, superblock = _rank_build.rank_build_pallas(
        wp, interpret=interpret)
    nsb = (w + _rank_build.SUPERBLOCK_WORDS - 1) // _rank_build.SUPERBLOCK_WORDS
    nblk = (w + _rank_build.BLOCK_WORDS - 1) // _rank_build.BLOCK_WORDS
    return superblock[0, :nsb], block_rel[0, :nblk]


@functools.partial(jax.jit, static_argnames=("shift", "n", "interpret"))
def wm_level_step(sub: jax.Array, shift: int, n: int,
                  interpret: bool | None = None):
    """One fused wavelet-matrix level on narrow keys ``sub`` (n,).

    ``shift``: bit position of this level's bit inside the key.
    Returns (dest (n,) int32 stable-partition destinations,
             bitmap ceil(n/32) uint32, total_zeros scalar int32).
    """
    if interpret is None:
        interpret = _default_interpret()
    blk = _wm_level.BLOCK
    npad = ((n + blk - 1) // blk) * blk
    # pad with all-ones keys: they partition past n and are trimmed
    pad_val = jnp.uint32(1) << jnp.uint32(shift)
    sp = jnp.full((1, npad), pad_val, jnp.uint32).at[0, :n].set(
        sub.astype(jnp.uint32))
    zeros_per_block = _wm_level.wm_counts_pallas(sp, shift,
                                                 interpret=interpret)
    zexcl = (jnp.cumsum(zeros_per_block, axis=1) - zeros_per_block)
    total = jnp.sum(zeros_per_block, dtype=jnp.int32).reshape(1, 1)
    dest, bitmap = _wm_level.wm_apply_pallas(sp, zexcl, total, shift, n,
                                             interpret=interpret)
    wreal = (n + 31) // 32
    return dest[0, :n], bitmap[0, :wreal], total[0, 0]


def _pad_axis1(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[1]) % mult
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((x.shape[0], pad), x.dtype)], axis=1)
    return x


@functools.partial(jax.jit, static_argnames=("interpret",))
def wm_quantile_batch(wm, lo: jax.Array, hi: jax.Array, k: jax.Array,
                      interpret: bool | None = None) -> jax.Array:
    """Batched range-quantile over one ``WaveletMatrix`` via the fused
    Pallas level-descent kernel (all nbits levels in one launch).

    ``lo``/``hi``/``k``: (Q,) int32. Returns (Q,) int32 symbols, -1 for
    empty ranges (same contract as ``repro.analytics.range_quantile``).
    """
    if interpret is None:
        interpret = _default_interpret()
    lo = jnp.atleast_1d(jnp.asarray(lo, jnp.int32))
    hi = jnp.atleast_1d(jnp.asarray(hi, jnp.int32))
    k = jnp.atleast_1d(jnp.asarray(k, jnp.int32))
    q = lo.shape[0]
    qpad = ((q + _wm_quantile.QBLOCK - 1)
            // _wm_quantile.QBLOCK) * _wm_quantile.QBLOCK
    queries = jnp.zeros((3, qpad), jnp.int32)
    queries = queries.at[0, :q].set(lo).at[1, :q].set(hi).at[2, :q].set(k)

    rank = wm.bitvectors.rank                 # leaves carry (nbits,) axis
    nblocks = rank.block.shape[1]
    # pad the word rows so every directory block can gather all 4 words
    words = _pad_axis1(rank.words, 128)
    if words.shape[1] < nblocks * _wm_quantile.BLOCK_WORDS:
        words = _pad_axis1(
            jnp.concatenate(
                [words, jnp.zeros((words.shape[0],
                                   nblocks * _wm_quantile.BLOCK_WORDS
                                   - words.shape[1]), words.dtype)],
                axis=1), 128)
    superblock = _pad_axis1(rank.superblock, 128)
    block = _pad_axis1(rank.block.astype(jnp.int32), 128)
    zeros = wm.zeros.reshape(1, -1)
    out = _wm_quantile.wm_quantile_pallas(
        queries, words, superblock, block, zeros,
        n=wm.n, nblocks=nblocks, interpret=interpret)
    return out[0, :q]
