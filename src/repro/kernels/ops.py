"""Jit'd public wrappers around the Pallas kernels.

Each op handles padding/layout, dispatches to the kernel, and trims the
result. ``interpret`` defaults to True off-TPU so the same call sites work
on CPU (validation) and TPU (deployment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs as _obs

from . import bitpack as _bitpack
from . import radix_rank as _radix_rank
from . import rank_build as _rank_build
from . import wm_level as _wm_level
from . import wm_quantile as _wm_quantile
from . import wt_level as _wt_level


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _work_gauges(op: str, elements: int, bits: int | None = None) -> None:
    """Trace-time work counters: problem size per kernel launch.

    Shapes are static under jit, so these record the size of the *traced*
    launch (the same semantics as the ``kernels.trace`` counters). The
    profiling layer divides them by measured steady-state time to derive
    Melem/s (``prof.melem_per_s``)."""
    _obs.gauge("kernels.work.elements", op=op).set(float(elements))
    if bits is not None:
        _obs.gauge("kernels.work.bits", op=op).set(float(bits))


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitpack(bits: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Pack a (n,) 0/1 vector into ceil(n/32) uint32 words (LSB-first)."""
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="bitpack",
                 interpret=str(bool(interpret)).lower()).inc()
    n = bits.shape[0]
    _work_gauges("bitpack", n, bits=n)
    w = (n + 31) // 32
    wpad = ((w + _bitpack.LANES - 1) // _bitpack.LANES) * _bitpack.LANES
    flat = jnp.zeros((wpad * 32,), jnp.int32).at[:n].set(bits.astype(jnp.int32))
    bits_t = flat.reshape(wpad, 32).T                     # (32, wpad)
    words = _bitpack.bitpack_pallas(bits_t, interpret=interpret)
    return words[0, :w]


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def rank_build(words: jax.Array, n: int,
               interpret: bool | None = None):
    """Jacobson directory for a packed bit sequence of n bits.

    Returns (superblock uint32 (ceil(W/32),), block_rel uint16 (ceil(W/4),)),
    W = ceil(n/32) — identical contract to
    ``repro.core.rank_select.build_binary_rank``.
    """
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="rank_build",
                 interpret=str(bool(interpret)).lower()).inc()
    _work_gauges("rank_build", n, bits=n)
    w = (n + 31) // 32
    sw = _rank_build.STEP_WORDS
    wpad = ((w + sw - 1) // sw) * sw
    wp = jnp.zeros((1, wpad), jnp.uint32).at[0, :words.shape[0]].set(words)
    block_rel, superblock = _rank_build.rank_build_pallas(
        wp, interpret=interpret)
    nsb = (w + _rank_build.SUPERBLOCK_WORDS - 1) // _rank_build.SUPERBLOCK_WORDS
    nblk = (w + _rank_build.BLOCK_WORDS - 1) // _rank_build.BLOCK_WORDS
    return superblock[0, :nsb], block_rel[0, :nblk]


@functools.partial(jax.jit, static_argnames=("shift", "n", "interpret"))
def wm_level_step(sub: jax.Array, shift: int, n: int,
                  interpret: bool | None = None):
    """One fused wavelet-matrix level on narrow keys ``sub`` (n,).

    ``shift``: bit position of this level's bit inside the key.
    Returns (dest (n,) int32 stable-partition destinations,
             bitmap ceil(n/32) uint32, total_zeros scalar int32).
    """
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="wm_level_step",
                 interpret=str(bool(interpret)).lower()).inc()
    _work_gauges("wm_level_step", n, bits=n)
    blk = _wm_level.BLOCK
    npad = ((n + blk - 1) // blk) * blk
    # pad with all-ones keys: they partition past n and are trimmed
    pad_val = jnp.uint32(1) << jnp.uint32(shift)
    sp = jnp.full((1, npad), pad_val, jnp.uint32).at[0, :n].set(
        sub.astype(jnp.uint32))
    zeros_per_block = _wm_level.wm_counts_pallas(sp, shift,
                                                 interpret=interpret)
    zexcl = (jnp.cumsum(zeros_per_block, axis=1) - zeros_per_block)
    total = jnp.sum(zeros_per_block, dtype=jnp.int32).reshape(1, 1)
    dest, bitmap = _wm_level.wm_apply_pallas(sp, zexcl, total, shift, n,
                                             interpret=interpret)
    wreal = (n + 31) // 32
    return dest[0, :n], bitmap[0, :wreal], total[0, 0]


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def rank_build_levels(words: jax.Array, n: int,
                      interpret: bool | None = None):
    """Batched Jacobson directories for L stacked level bitmaps, one
    launch. ``words``: (L, W) uint32 packed bits (n bits per row).

    Returns (superblock uint32 (L, ceil(W/32)), block_rel uint16
    (L, ceil(W/4))) — row-wise identical to ``rank_build``.
    """
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="rank_build_levels",
                 interpret=str(bool(interpret)).lower()).inc()
    nlev = words.shape[0]
    _work_gauges("rank_build_levels", nlev * n, bits=nlev * n)
    w = (n + 31) // 32
    sw = _rank_build.STEP_WORDS
    wpad = ((w + sw - 1) // sw) * sw
    wp = jnp.zeros((nlev, wpad), jnp.uint32).at[:, :words.shape[1]].set(words)
    block_rel, superblock = _rank_build.rank_build_levels_pallas(
        wp, interpret=interpret)
    nsb = (w + _rank_build.SUPERBLOCK_WORDS - 1) // _rank_build.SUPERBLOCK_WORDS
    nblk = (w + _rank_build.BLOCK_WORDS - 1) // _rank_build.BLOCK_WORDS
    return superblock[:, :nsb], block_rel[:, :nblk]


@functools.partial(jax.jit, static_argnames=("shift", "n", "interpret"))
def wm_level_step_fused(sub: jax.Array, shift: int, n: int,
                        interpret: bool | None = None):
    """Single-launch fused wavelet-matrix level (tentpole form of
    ``wm_level_step``): bit extract, bitmap pack, zero count and stable
    partition destinations in ONE kernel launch over the narrow short
    list. Same contract as ``wm_level_step``. Not vmap-safe (cross-grid
    scratch) — batched builders use the XLA fast path instead.
    """
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="wm_level_step_fused",
                 interpret=str(bool(interpret)).lower()).inc()
    _work_gauges("wm_level_step_fused", n, bits=n)
    blk = _wm_level.BLOCK
    npad = ((n + blk - 1) // blk) * blk
    pad_val = jnp.uint32(1) << jnp.uint32(shift)
    sp = jnp.full((1, npad), pad_val, jnp.uint32).at[0, :n].set(
        sub.astype(jnp.uint32))
    dest, bitmap, total = _wm_level.wm_level_fused_pallas(
        sp, shift, n, interpret=interpret)
    wreal = (n + 31) // 32
    return dest[0, :n], bitmap[0, :wreal], total[0, 0]


@functools.partial(jax.jit, static_argnames=("shift", "nbkt", "n",
                                             "interpret"))
def wt_level_step_fused(sub: jax.Array, nid: jax.Array, shift: int,
                        nbkt: int, n: int, interpret: bool | None = None):
    """One fused *segmented* wavelet-tree level on narrow keys (n,).

    ``nid``: (n,) int32 node id per element (non-decreasing), ``shift``:
    bit position of this level's bit inside the key, ``nbkt`` = 2^(l+1)
    the (node, bit) bucket count (≤ ``wt_level.MAX_KEYS``). Returns
    (dest (n,) int32 stable per-node partition destinations,
    bitmap ceil(n/32) uint32). Not vmap-safe (cross-grid scratch).
    """
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="wt_level_step_fused",
                 interpret=str(bool(interpret)).lower()).inc()
    _work_gauges("wt_level_step_fused", n, bits=n)
    blk = _wt_level.BLOCK
    npad = ((n + blk - 1) // blk) * blk
    # padding: bit 0 + nid nbkt//2 -> key == nbkt, the sentinel bucket
    # ordered after every real bucket (destinations land past n, trimmed)
    sp = jnp.zeros((1, npad), jnp.uint32).at[0, :n].set(
        sub.astype(jnp.uint32))
    nidp = jnp.full((1, npad), nbkt // 2, jnp.int32).at[0, :n].set(
        nid.astype(jnp.int32))
    dest, bitmap = _wt_level.wt_level_fused_pallas(
        sp, nidp, shift, nbkt, n, interpret=interpret)
    wreal = (n + 31) // 32
    return dest[0, :n], bitmap[0, :wreal]


@functools.partial(jax.jit, static_argnames=("num_buckets", "interpret"))
def radix_rank(digits: jax.Array, num_buckets: int,
               interpret: bool | None = None) -> jax.Array:
    """Blocked stable counting rank (Pallas): destination of every element
    under a stable sort by ``digits`` (each in [0, num_buckets),
    num_buckets ≤ ``radix_rank.MAX_BUCKETS``). Same contract as
    ``core.sort.counting_rank``; the per-block one-hot lives only in VMEM.
    """
    assert num_buckets <= _radix_rank.MAX_BUCKETS
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="radix_rank",
                 interpret=str(bool(interpret)).lower()).inc()
    n = digits.shape[0]
    _work_gauges("radix_rank", n)
    blk = _radix_rank.BLOCK
    npad = ((n + blk - 1) // blk) * blk
    d = jnp.full((1, npad), num_buckets, jnp.int32).at[0, :n].set(
        digits.astype(jnp.int32))
    hist = _radix_rank.radix_hist_pallas(d, num_buckets,
                                         interpret=interpret)
    across = jnp.cumsum(hist, axis=0, dtype=jnp.int32) - hist
    totals = jnp.sum(hist, axis=0, dtype=jnp.int32)
    base = (jnp.cumsum(totals) - totals).reshape(1, -1)
    dest = _radix_rank.radix_apply_pallas(d, base, across, num_buckets,
                                          interpret=interpret)
    return dest[0, :n]


def _pad_axis1(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[1]) % mult
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((x.shape[0], pad), x.dtype)], axis=1)
    return x


def _pad_rank_rows(words: jax.Array, superblock: jax.Array,
                   block: jax.Array, nblocks: int):
    """Lane-pad the row-stacked rank-directory arrays for the quantile
    kernels: word rows grow to ≥ nblocks·BLOCK_WORDS (so every directory
    block can gather all its words) and everything pads to 128 lanes."""
    words = _pad_axis1(words, 128)
    if words.shape[1] < nblocks * _wm_quantile.BLOCK_WORDS:
        words = _pad_axis1(
            jnp.concatenate(
                [words, jnp.zeros((words.shape[0],
                                   nblocks * _wm_quantile.BLOCK_WORDS
                                   - words.shape[1]), words.dtype)],
                axis=1), 128)
    return (words, _pad_axis1(superblock, 128),
            _pad_axis1(block.astype(jnp.int32), 128))


@functools.partial(jax.jit, static_argnames=("interpret",))
def wm_quantile_batch(wm, lo: jax.Array, hi: jax.Array, k: jax.Array,
                      interpret: bool | None = None) -> jax.Array:
    """Batched range-quantile over one ``WaveletMatrix`` via the fused
    Pallas level-descent kernel (all nbits levels in one launch).

    ``lo``/``hi``/``k``: (Q,) int32. Returns (Q,) int32 symbols, -1 for
    empty ranges (same contract as ``repro.analytics.range_quantile``).
    """
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="wm_quantile_batch",
                 interpret=str(bool(interpret)).lower()).inc()
    lo = jnp.atleast_1d(jnp.asarray(lo, jnp.int32))
    hi = jnp.atleast_1d(jnp.asarray(hi, jnp.int32))
    k = jnp.atleast_1d(jnp.asarray(k, jnp.int32))
    q = lo.shape[0]
    _work_gauges("wm_quantile_batch", q)
    qpad = ((q + _wm_quantile.QBLOCK - 1)
            // _wm_quantile.QBLOCK) * _wm_quantile.QBLOCK
    queries = jnp.zeros((3, qpad), jnp.int32)
    queries = queries.at[0, :q].set(lo).at[1, :q].set(hi).at[2, :q].set(k)

    rank = wm.bitvectors.rank                 # leaves carry (nbits,) axis
    nblocks = rank.block.shape[1]
    words, superblock, block = _pad_rank_rows(rank.words, rank.superblock,
                                              rank.block, nblocks)
    zeros = wm.zeros.reshape(1, -1)
    out = _wm_quantile.wm_quantile_pallas(
        queries, words, superblock, block, zeros,
        n=wm.n, nblocks=nblocks, interpret=interpret)
    return out[0, :q]


@functools.partial(jax.jit, static_argnames=("shard_bits", "n", "interpret"))
def wm_quantile_sharded_batch(shards, shard_bits: int, n: int,
                              lo: jax.Array, hi: jax.Array, k: jax.Array,
                              interpret: bool | None = None) -> jax.Array:
    """Batched global range-quantile over a stacked (S,)-leaf shard layout
    via the fused sharded Pallas descent (all shards × all levels in one
    launch per query block).

    ``shards``: a ``WaveletMatrix`` whose leaves carry a leading
    (num_shards,) axis (the ``ShardedAnalytics``/``CompressedCorpus``
    layout); ``lo``/``hi``/``k``: (Q,) int32 *global* positions / rank.
    Exact same contract as ``analytics.engine.sharded_range_quantile``.
    """
    if interpret is None:
        interpret = _default_interpret()
    _obs.counter("kernels.trace", op="wm_quantile_sharded_batch",
                 interpret=str(bool(interpret)).lower()).inc()
    lo = jnp.atleast_1d(jnp.asarray(lo, jnp.int32))
    hi = jnp.atleast_1d(jnp.asarray(hi, jnp.int32))
    k = jnp.atleast_1d(jnp.asarray(k, jnp.int32))
    q = lo.shape[0]
    _work_gauges("wm_quantile_sharded_batch", q)
    qpad = ((q + _wm_quantile.QBLOCK - 1)
            // _wm_quantile.QBLOCK) * _wm_quantile.QBLOCK
    queries = jnp.zeros((3, qpad), jnp.int32)
    queries = queries.at[0, :q].set(lo).at[1, :q].set(hi).at[2, :q].set(k)

    rank = shards.bitvectors.rank
    num_shards, nbits = rank.words.shape[0], shards.nbits
    nblocks = rank.block.shape[2]
    words, superblock, block = _pad_rank_rows(
        rank.words.reshape(num_shards * nbits, -1),
        rank.superblock.reshape(num_shards * nbits, -1),
        rank.block.reshape(num_shards * nbits, -1), nblocks)
    zeros = shards.zeros.reshape(1, num_shards * nbits)
    out = _wm_quantile.wm_quantile_sharded_pallas(
        queries, words, superblock, block, zeros,
        num_shards=num_shards, nbits=nbits, n=n, shard_bits=shard_bits,
        nblocks=nblocks, interpret=interpret)
    return out[0, :q]
