"""Pallas TPU kernel: fused Jacobson rank-directory construction.

Builds both levels of the paper's Section 5.1 rank structure in a single
pass over the packed words: per-word popcounts, the block-relative ranks
(uint16, one per BLOCK_WORDS=4 words) and the absolute superblock ranks
(uint32, one per SUPERBLOCK_WORDS=32 words). The running total is carried
across the sequential TPU grid in SMEM — the kernel-level analogue of the
paper's prefix sum, exploiting that the TPU grid executes in order.

Block geometry: 512 words (= 16 superblocks) per grid step; VMEM footprint
512×4 B in + 128×2 B + 16×4 B out.

``rank_build_levels_pallas`` is the batched form used by the construction
fast path: a (L, steps) grid builds the directories of every wavelet-matrix
level in ONE launch, resetting the popcount carry at the start of each
level row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUPERBLOCK_WORDS = 32     # must match repro.core.rank_select
BLOCK_WORDS = 4           # must match repro.core.rank_select
STEP_WORDS = 512
_SB_PER_STEP = STEP_WORDS // SUPERBLOCK_WORDS      # 16
_BLK_PER_STEP = STEP_WORDS // BLOCK_WORDS          # 128
_BLK_PER_SB = SUPERBLOCK_WORDS // BLOCK_WORDS      # 8


def _rank_build_kernel(words_ref, block_ref, super_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0, 0] = jnp.uint32(0)

    carry = carry_ref[0, 0]
    words = words_ref[...]                                   # (1, 512)
    counts = jax.lax.population_count(words).astype(jnp.uint32)
    local_excl = jnp.cumsum(counts, axis=1, dtype=jnp.uint32) - counts
    prefix = local_excl + carry                              # absolute ranks
    sb = prefix[:, ::SUPERBLOCK_WORDS]                       # (1, 16)
    super_ref[...] = sb
    blk = prefix[:, ::BLOCK_WORDS]                           # (1, 128)
    sb_broadcast = jnp.repeat(sb, _BLK_PER_SB, axis=1)       # (1, 128)
    block_ref[...] = (blk - sb_broadcast).astype(jnp.uint16)
    carry_ref[0, 0] = carry + jnp.sum(counts, dtype=jnp.uint32)


def rank_build_pallas(words: jax.Array, *, interpret: bool = False):
    """``words``: (1, W) uint32, W a multiple of STEP_WORDS.

    Returns (block_rel (1, W/4) uint16, superblock (1, W/32) uint32).
    """
    _, w = words.shape
    assert w % STEP_WORDS == 0
    grid = (w // STEP_WORDS,)
    return pl.pallas_call(
        _rank_build_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, STEP_WORDS), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, _BLK_PER_STEP), lambda i: (0, i)),
            pl.BlockSpec((1, _SB_PER_STEP), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, w // BLOCK_WORDS), jnp.uint16),
            jax.ShapeDtypeStruct((1, w // SUPERBLOCK_WORDS), jnp.uint32),
        ],
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.uint32)],
        interpret=interpret,
    )(words)


def _rank_build_levels_kernel(words_ref, block_ref, super_ref, carry_ref):
    j = pl.program_id(1)                    # step within the level's row

    @pl.when(j == 0)
    def _reset():                           # new level row → fresh prefix
        carry_ref[0, 0] = jnp.uint32(0)

    carry = carry_ref[0, 0]
    words = words_ref[...]                                   # (1, 512)
    counts = jax.lax.population_count(words).astype(jnp.uint32)
    local_excl = jnp.cumsum(counts, axis=1, dtype=jnp.uint32) - counts
    prefix = local_excl + carry
    sb = prefix[:, ::SUPERBLOCK_WORDS]                       # (1, 16)
    super_ref[...] = sb
    blk = prefix[:, ::BLOCK_WORDS]                           # (1, 128)
    sb_broadcast = jnp.repeat(sb, _BLK_PER_SB, axis=1)       # (1, 128)
    block_ref[...] = (blk - sb_broadcast).astype(jnp.uint16)
    carry_ref[0, 0] = carry + jnp.sum(counts, dtype=jnp.uint32)


def rank_build_levels_pallas(words: jax.Array, *, interpret: bool = False):
    """Batched Jacobson build: one launch for every level of a wavelet
    matrix. ``words``: (L, W) uint32, W a multiple of STEP_WORDS; the grid
    is (L, W/STEP_WORDS) with the running popcount carry reset at the
    start of each level row (the sequential TPU grid iterates the inner
    step axis fastest). Returns (block_rel (L, W/4) uint16,
    superblock (L, W/32) uint32). Not vmap-safe (cross-step scratch).
    """
    nlev, w = words.shape
    assert w % STEP_WORDS == 0
    grid = (nlev, w // STEP_WORDS)
    return pl.pallas_call(
        _rank_build_levels_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, STEP_WORDS), lambda l, j: (l, j))],
        out_specs=[
            pl.BlockSpec((1, _BLK_PER_STEP), lambda l, j: (l, j)),
            pl.BlockSpec((1, _SB_PER_STEP), lambda l, j: (l, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nlev, w // BLOCK_WORDS), jnp.uint16),
            jax.ShapeDtypeStruct((nlev, w // SUPERBLOCK_WORDS), jnp.uint32),
        ],
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.uint32)],
        interpret=interpret,
    )(words)
