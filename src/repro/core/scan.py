"""Prefix-sum primitives (the paper's workhorse parallel primitive).

The paper uses prefix sum with custom associative operators throughout
(Section 2: O(n) work, O(log n) depth). ``jax.lax.associative_scan`` is the
direct TPU realization (a Blelloch-style log-depth scan tree).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def exclusive_sum(x: jax.Array, axis: int = 0, dtype=None) -> jax.Array:
    """Exclusive prefix sum: out[i] = sum(x[:i]). Matches the paper's defn."""
    dtype = dtype or x.dtype
    incl = jnp.cumsum(x, axis=axis, dtype=dtype)
    zero_shape = list(x.shape)
    zero_shape[axis] = 1
    zeros = jnp.zeros(zero_shape, dtype)
    return jax.lax.concatenate([zeros, jax.lax.slice_in_dim(incl, 0, x.shape[axis] - 1, axis=axis)], axis)


def inclusive_sum(x: jax.Array, axis: int = 0, dtype=None) -> jax.Array:
    return jnp.cumsum(x, axis=axis, dtype=dtype or x.dtype)


def prefix_scan(op: Callable, x, reverse: bool = False, axis: int = 0):
    """Inclusive scan with a custom associative operator (paper Section 2)."""
    return jax.lax.associative_scan(op, x, reverse=reverse, axis=axis)


@functools.partial(jax.jit, static_argnames=("num_segments",))
def segment_offsets(segment_sizes: jax.Array, num_segments: int) -> jax.Array:
    """Exclusive offsets for variable-length segments (packed-list appends)."""
    del num_segments
    return exclusive_sum(segment_sizes.astype(jnp.int32))


def segmented_exclusive_sum(x: jax.Array, segment_starts: jax.Array) -> jax.Array:
    """Segmented exclusive prefix sum.

    ``segment_starts`` is a 0/1 vector marking the first element of each
    segment. Implemented with the classic (value, flag) associative operator —
    the same style of custom-⊕ scan the paper uses for its rank/select merge
    steps.
    """
    flags = segment_starts.astype(jnp.int32)

    def op(a, b):
        va, fa = a
        vb, fb = b
        return jnp.where(fb, vb, va + vb), fa | fb

    incl, _ = jax.lax.associative_scan(op, (x.astype(jnp.int32), flags))
    # convert inclusive → exclusive within segments
    return incl - x.astype(jnp.int32)


def segment_ids_from_starts(starts: jax.Array, n: int) -> jax.Array:
    """Segment id of every position given sorted segment start offsets.

    ``starts`` (S,) int32, non-decreasing, ``starts[0] == 0``; position p
    belongs to the largest segment s with ``starts[s] <= p``. Realized as a
    run-start mark scatter (S indices, O(S) work) + a running max — the
    gather-friendly inverse of ``jnp.searchsorted`` that every segmented
    fast path here uses (empty segments share a start and are superseded
    by the mark max, so they correctly own no positions).
    """
    sid = jnp.arange(starts.shape[0], dtype=jnp.int32)
    marks = jnp.zeros((n,), jnp.int32).at[starts].max(sid, mode="drop")
    return jax.lax.cummax(marks)


def stable_partition_indices(flags: jax.Array) -> jax.Array:
    """Destination index of each element under a stable 0/1 partition.

    Zeros keep order and go first; ones keep order and follow. This is the
    per-level wavelet-tree/matrix shuffle, built from two prefix sums exactly
    as in the paper's short-list splitting.
    Returns int32 destinations (a permutation of [0, n)).
    """
    flags = flags.astype(jnp.int32)
    ones_before = exclusive_sum(flags)
    zeros_before = jnp.arange(flags.shape[0], dtype=jnp.int32) - ones_before
    total_zeros = flags.shape[0] - jnp.sum(flags)
    return jnp.where(flags == 0, zeros_before, total_zeros + ones_before)


def apply_permutation_dest(values: jax.Array, dest: jax.Array) -> jax.Array:
    """Scatter ``values[i]`` to position ``dest[i]`` (dest is a permutation)."""
    out = jnp.zeros_like(values)
    return out.at[dest].set(values, mode="drop", unique_indices=True)
