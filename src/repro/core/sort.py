"""Stable parallel integer sorting — the paper's big-node primitive.

The paper's τ-chunked wavelet construction performs one *stable* integer sort
per big-node level, with keys of τ bits. It discusses two PRAM sorts:
an O(n loglog n)-work polylog-depth sort [BDH+91, RR89] and a work-efficient
O(n/ε)-work O(n^ε/ε)-depth sort [Vishkin]. Neither has a TPU analogue, so we
provide the two TPU-native realizations (both stable):

* ``backend="counting"`` — LSD counting sort built from histograms + prefix
  sums: O(n + 2^pass_bits) work per pass and O(log n) scan depth. This is the
  paper-faithful backend — "stable integer sort via prefix sums" — and
  vectorizes over the whole array. For wide digits the stable rank runs
  blocked (per-block histogram → cross-block scan → within-block rank, the
  same block-local-count-then-scan structure as the paper's domain-
  decomposition merge): on TPU through the Pallas ``kernels.radix_rank``
  kernel (the one-hot never leaves VMEM), elsewhere through an XLA
  realization that vectorizes groups of blocks under a bounded one-hot
  working set.
* ``backend="xla"`` — ``jax.lax.sort`` (stable), the vendor-shipped sort.

Both are benchmarked against each other in ``benchmarks/run.py``. The
counting backend is the paper's Theorem 4.5 big-node sort and also drives
every suffix-array doubling round (``repro.index.suffix_array``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .scan import exclusive_sum

# One-hot rank computation is fully vectorized when the bucket count is at
# most this; beyond it, the blocked path bounds the one-hot working set.
_VECTORIZED_BUCKET_LIMIT = 32
_BLOCK = 512
# The blocked path vectorizes groups of blocks as long as the group's
# one-hot stays under this many int32 elements; larger problems fall back
# to lax.map over the groups.
_ONEHOT_BUDGET = 1 << 25


@functools.partial(jax.jit, static_argnames=("num_buckets",))
def _counting_rank_vectorized(digits: jax.Array, num_buckets: int) -> jax.Array:
    """Stable destination of each element when sorting by ``digits``.

    dest[i] = (# elements with smaller digit) + (# j<i with digit==digits[i]).
    The first term is an exclusive sum over the histogram; the second an
    exclusive column-wise sum over the one-hot matrix. O(n·B) space — used
    for small bucket counts only.
    """
    digits = digits.astype(jnp.int32)
    hist = jnp.zeros((num_buckets,), jnp.int32).at[digits].add(1, mode="drop")
    bucket_base = exclusive_sum(hist)
    onehot = jax.nn.one_hot(digits, num_buckets, dtype=jnp.int32)
    within = exclusive_sum(onehot, axis=0)
    rank_within = jnp.take_along_axis(within, digits[:, None], axis=1)[:, 0]
    return bucket_base[digits] + rank_within


@functools.partial(jax.jit, static_argnames=("num_buckets", "block"))
def _blocked_rank_parts(digits: jax.Array, num_buckets: int,
                        block: int = _BLOCK):
    """Memory-lean within-bucket stable rank + bucket totals.

    Returns ``(within, totals)``: ``within[i]`` = # of j < i with
    digits[j] == digits[i]; ``totals`` the (num_buckets + 1,) histogram
    (sentinel bucket last) — so callers that also need bucket bases don't
    histogram the array a second time.

    Per-block histogram → cross-block exclusive scan (each block's
    per-bucket offset) → within-block equal-before counts. The within-block
    one-hots are vectorized over groups of blocks sized to the
    ``_ONEHOT_BUDGET`` working set, with ``lax.map`` over the groups only
    when the problem exceeds one group — so moderate inputs (e.g. every
    suffix-array doubling round) run as a single fused XLA op. Padding
    elements go to a sentinel bucket after all real buckets.
    """
    n = digits.shape[0]
    B1 = num_buckets + 1
    nb = -(-n // block)
    # blocks per group, clamped so small inputs never pad past their own
    # block count (a group larger than nb would inflate the one-hot)
    group = max(1, min(_ONEHOT_BUDGET // (block * B1), nb))
    ng = -(-nb // group)
    pad = ng * group * block - n
    sentinel = num_buckets
    d = jnp.concatenate([digits.astype(jnp.int32),
                         jnp.full((pad,), sentinel, jnp.int32)])
    nb = d.shape[0] // block
    db = d.reshape(nb, block)

    blk_ids = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), block)
    flat = blk_ids * B1 + d
    block_hist = jnp.zeros((nb * B1,), jnp.int32).at[flat].add(1).reshape(nb, B1)
    across = exclusive_sum(block_hist, axis=0)                   # (nb, B1)

    def group_rank(dg):                                          # (g, block)
        onehot = jax.nn.one_hot(dg, B1, dtype=jnp.int32)
        within = exclusive_sum(onehot, axis=1)
        return jnp.take_along_axis(within, dg[..., None], axis=2)[..., 0]

    dgrp = db.reshape(ng, group, block)
    if ng == 1:
        rank_within = group_rank(dgrp[0])                        # (nb, block)
    else:
        rank_within = jax.lax.map(group_rank, dgrp).reshape(nb, block)
    out = jnp.take_along_axis(across, db, axis=1) + rank_within
    return out.reshape(-1)[:n], jnp.sum(block_hist, axis=0)


def counting_rank(digits: jax.Array, num_buckets: int,
                  use_kernel: bool | None = None) -> jax.Array:
    """Stable sort destinations (a permutation when there is no padding).

    dest[i] = (# elements with smaller digit) + (# j<i with equal digit) —
    the paper's "stable integer sort via prefix sums" (Section 2), used as
    the big-node sort of Theorem 4.5 and by every suffix-array doubling
    round. Routing: small bucket counts use the fully vectorized one-hot;
    large ones the blocked histogram→scan→within-block path — through the
    Pallas ``kernels.radix_rank`` kernel when ``use_kernel`` (default: on
    TPU) and the bucket count fits its VMEM bound, else the XLA blocked
    realization.
    """
    n = digits.shape[0]
    if num_buckets <= _VECTORIZED_BUCKET_LIMIT or n <= 4 * _BLOCK:
        return _counting_rank_vectorized(digits, num_buckets)
    if use_kernel is None:
        # the radix_rank kernels are stateless (no cross-grid scratch), so
        # the route is safe under jit/vmap and gates on the backend alone
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels import ops as _kops
        from repro.kernels import radix_rank as _rr
        if num_buckets <= _rr.MAX_BUCKETS:
            return _kops.radix_rank(digits, num_buckets)
    digits = digits.astype(jnp.int32)
    within, totals = _blocked_rank_parts(digits, num_buckets)
    bucket_base = exclusive_sum(totals)
    return bucket_base[digits] + within


def bucket_ranks(digits: jax.Array, num_buckets: int) -> jax.Array:
    """rank_within[i] = # of j < i with digits[j] == digits[i].

    The arrival-order rank inside each bucket — the same prefix-sum
    machinery as the stable counting sort, exposed for consumers like MoE
    token dispatch (DESIGN.md §3.2) where the bucket offset is implicit
    (capacity slots) rather than a sort destination. Small bucket counts
    use the fully vectorized one-hot; large ones route through the blocked
    path instead of materializing the O(n·B) matrix.
    """
    digits = digits.astype(jnp.int32)
    if num_buckets <= _VECTORIZED_BUCKET_LIMIT or digits.shape[0] <= 4 * _BLOCK:
        onehot = jax.nn.one_hot(digits, num_buckets, dtype=jnp.int32)
        within = exclusive_sum(onehot, axis=0)
        return jnp.take_along_axis(within, digits[:, None], axis=1)[:, 0]
    return _blocked_rank_parts(digits, num_buckets)[0]


def _invert_permutation(dest: jax.Array) -> jax.Array:
    """perm[k] = i such that dest[i] == k (dest must be a permutation)."""
    n = dest.shape[0]
    return jnp.zeros((n,), jnp.int32).at[dest].set(
        jnp.arange(n, dtype=jnp.int32), unique_indices=True)


def sort_pass(keys: jax.Array,
              digits: jax.Array,
              num_buckets: int,
              values: Optional[Tuple[jax.Array, ...]] = None,
              backend: str = "counting"):
    """One stable sort pass by ``digits`` (each in [0, num_buckets)).

    Reorders ``keys`` (and optional tuple of ``values``) stably by digit.
    """
    if backend == "xla":
        operands = (digits.astype(jnp.int32), keys) + tuple(values or ())
        out = jax.lax.sort(operands, num_keys=1, is_stable=True)
        new_keys = out[1]
        new_values = tuple(out[2:]) if values is not None else None
        return new_keys, new_values
    if backend == "counting":
        dest = counting_rank(digits, num_buckets)
        perm = _invert_permutation(dest)
        new_keys = keys[perm]
        new_values = tuple(v[perm] for v in values) if values is not None else None
        return new_keys, new_values
    raise ValueError(f"unknown sort backend {backend!r}")


def sort_permutation(digits: jax.Array, num_buckets: int,
                     backend: str = "counting") -> jax.Array:
    """Gather permutation realizing the stable sort by ``digits``."""
    if backend == "xla":
        _, perm = jax.lax.sort(
            (digits.astype(jnp.int32),
             jnp.arange(digits.shape[0], dtype=jnp.int32)),
            num_keys=1, is_stable=True)
        return perm
    return _invert_permutation(counting_rank(digits, num_buckets))


def radix_sort_stable(keys: jax.Array,
                      key_bits: int,
                      values: Optional[Tuple[jax.Array, ...]] = None,
                      bits_per_pass: int = 8,
                      backend: str = "counting"):
    """LSD stable radix sort of integer ``keys`` with ``key_bits`` bits.

    ``bits_per_pass`` plays the role of the paper's τ: fewer, wider passes do
    less total data movement but need larger histograms — the same work/depth
    trade the paper optimizes with τ = √log n. Returns (keys, values).
    """
    kb = int(key_bits)
    shift = 0
    while shift < kb:
        width = min(bits_per_pass, kb - shift)
        digits = (keys.astype(jnp.uint32) >> jnp.uint32(shift)) & jnp.uint32((1 << width) - 1)
        keys, values = sort_pass(keys, digits, 1 << width, values, backend=backend)
        shift += width
    return keys, values
