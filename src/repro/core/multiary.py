"""Multiary (degree d = 2^b) wavelet trees (paper Theorem 4.4).

Each level stores a sequence of b-bit digits (not bits), with the elements
stably sorted by their top l·b symbol bits; each level carries a generalized
rank/select structure (Section 5.2) on its digit sequence. The paper's
restriction d = o(log^{1/3} n) corresponds to the small field widths
(b ∈ {1, 2, 4}) we expose.

Construction follows the same pattern as the binary levelwise tree, with the
0/1 partition generalized to a d-way node-segmented stable split: one
histogram over (node, digit) pairs + d segmented prefix sums.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp

from .rank_select import (GeneralizedRankSelect, build_generalized,
                          build_generalized_from_counts, field_node_counts,
                          generalized_access, generalized_rank,
                          generalized_select, packed_field_counts,
                          segmented_partition_gather_fields)
from .scan import (exclusive_sum, segment_ids_from_starts,
                   segmented_exclusive_sum)
from .sort import _invert_permutation

_I32 = jnp.int32
_U32 = jnp.uint32


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class MultiaryWaveletTree:
    """Levelwise multiary tree: per-level digit sequences + rank/select.

    ``node_starts`` has shape (nlevels+1, d**nlevels): row l holds the start
    offset of each depth-l node (first d**l entries meaningful); the last
    row is the leaf/symbol offset table.
    """
    levels: GeneralizedRankSelect   # stacked with leading (nlevels,) axis
    node_starts: jax.Array          # (nlevels+1, d**nlevels) int32
    n: int = field(metadata=dict(static=True))
    width: int = field(metadata=dict(static=True))     # b: bits per digit
    nlevels: int = field(metadata=dict(static=True))

    @property
    def degree(self) -> int:
        return 1 << self.width

    def level(self, l: int) -> GeneralizedRankSelect:
        return jax.tree.map(lambda x: x[l], self.levels)


def _node_starts_multiary(seq: jax.Array, width: int,
                          nlevels: int) -> jax.Array:
    total_bits = width * nlevels
    size = 1 << total_bits
    hist = jnp.zeros((size,), _I32).at[seq.astype(_I32)].add(1, mode="drop")
    leaf_starts = exclusive_sum(hist)
    rows = [leaf_starts]
    for l in range(nlevels - 1, -1, -1):
        stride = 1 << (total_bits - l * width)
        starts_l = leaf_starts[::stride]
        rows.append(jnp.concatenate(
            [starts_l, jnp.zeros((size - starts_l.shape[0],), _I32)]))
    rows.reverse()
    return jnp.stack(rows)


def build_multiary_wavelet_tree(seq: jax.Array, sigma: int, width: int = 2,
                                chunk_syms: int = 128,
                                fused: bool = True) -> MultiaryWaveletTree:
    """Theorem 4.4 construction for degree d = 2^width.

    Symbols are treated as (nlevels·width)-bit numbers (zero-extended at the
    top, as in the paper's full-binary-tree embedding where only every
    (β·log d)-th binary level keeps a sequence).

    ``fused=True`` (default) collapses the d-way node-segmented split —
    one (node, digit) histogram scatter + d segmented prefix sums + an
    n-element inverse-permutation scatter — into one histogram-offset
    select-gather (``rank_select.segmented_partition_gather_fields``).
    The shared per-(word, digit) directory additionally replaces the two
    remaining n-element histogram scatters of the build: the generalized
    rank/select chunk tables are reshape-sums over it
    (``build_generalized_from_counts``), and the ``node_starts`` rows
    chain level to level through the gather's own per-node digit counts
    (a (node, digit) pair at level l IS a node at level l+1) instead of a
    full-symbol histogram. ``fused=False`` keeps the scatter baseline;
    outputs are bit-identical.
    """
    from repro import obs
    n = int(seq.shape[0])
    nbits = max(1, math.ceil(math.log2(max(2, sigma))))
    nlevels = (nbits + width - 1) // width
    total_bits = width * nlevels
    obs.counter("core.build", builder="multiary",
                path="fused" if fused else "scatter").inc()
    if fused:
        return _build_multiary_fused(seq, width, nlevels, n, chunk_syms)
    node_starts = _node_starts_multiary(seq, width, nlevels)
    order = seq.astype(_U32)
    level_seqs: List[jax.Array] = []

    for l in range(nlevels):
        digit = ((order >> _U32(total_bits - (l + 1) * width))
                 & _U32((1 << width) - 1)).astype(_I32)
        level_seqs.append(digit)
        if l == nlevels - 1:
            break
        d = 1 << width
        # d-way node-segmented stable split (scatter baseline)
        nid = (order >> _U32(total_bits - l * width)).astype(_I32) if l else \
            jnp.zeros((n,), _I32)
        key = nid * d + digit
        hist = jnp.zeros(((1 << (l + 1) * width),), _I32).at[key].add(
            1, mode="drop")
        key_start = exclusive_sum(hist)
        seg_start = jnp.concatenate([
            jnp.ones((1,), _I32), (nid[1:] != nid[:-1]).astype(_I32)])
        rank_within = jnp.zeros((n,), _I32)
        for v in range(d):
            rv = segmented_exclusive_sum((digit == v).astype(_I32), seg_start)
            rank_within = jnp.where(digit == v, rv, rank_within)
        dest = key_start[key] + rank_within
        order = order[_invert_permutation(dest)]

    grs = [build_generalized(s, width, n, chunk_syms) for s in level_seqs]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grs)
    return MultiaryWaveletTree(levels=stacked, node_starts=node_starts,
                               n=n, width=width, nlevels=nlevels)


def _build_multiary_fused(seq: jax.Array, width: int, nlevels: int, n: int,
                          chunk_syms: int) -> MultiaryWaveletTree:
    """Scatter-free realization of the Theorem 4.4 build (see
    :func:`build_multiary_wavelet_tree`)."""
    total_bits = width * nlevels
    size = 1 << total_bits
    order = seq.astype(_U32)
    starts = jnp.zeros((1,), _I32)               # level-0 node offsets
    start_rows: List[jax.Array] = []
    grs: List[GeneralizedRankSelect] = []

    for l in range(nlevels):
        digit = ((order >> _U32(total_bits - (l + 1) * width))
                 & _U32((1 << width) - 1)).astype(_I32)
        plan = packed_field_counts(digit, width, n)
        grs.append(build_generalized_from_counts(*plan, width=width, n=n,
                                                 chunk_syms=chunk_syms))
        _, cnt_node = field_node_counts(*plan, width=width,
                                        node_start=starts, n=n)
        start_rows.append(starts)
        if l < nlevels - 1:
            nid = segment_ids_from_starts(starts, n) if l else \
                jnp.zeros((n,), _I32)
            g = segmented_partition_gather_fields(digit, width, nid,
                                                  starts, n, plan=plan)
            order = order[g]
        starts = exclusive_sum(cnt_node.reshape(-1))
    start_rows.append(starts)                    # leaf/symbol offsets

    rows = [jnp.concatenate([r, jnp.zeros((size - r.shape[0],), _I32)])
            if r.shape[0] < size else r for r in start_rows]
    node_starts = jnp.stack(rows)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grs)
    return MultiaryWaveletTree(levels=stacked, node_starts=node_starts,
                               n=n, width=width, nlevels=nlevels)


# --------------------------------------------------------------------------
# Queries
# --------------------------------------------------------------------------

def mwt_access(mwt: MultiaryWaveletTree, i: jax.Array) -> jax.Array:
    i = jnp.asarray(i, _I32)
    p = i
    v = jnp.zeros_like(i)
    c = jnp.zeros_like(i)
    for l in range(mwt.nlevels):
        g = mwt.level(l)
        s = mwt.node_starts[l][v]
        digit = generalized_access(g, p)
        rb = generalized_rank(g, digit, p) - generalized_rank(g, digit, s)
        v = v * mwt.degree + digit
        c = (c << mwt.width) | digit
        p = mwt.node_starts[l + 1][v] + rb
    return c


def mwt_rank(mwt: MultiaryWaveletTree, c: jax.Array, i: jax.Array) -> jax.Array:
    """# of occurrences of symbol c in [0, i)."""
    c = jnp.asarray(c, _I32)
    i = jnp.asarray(i, _I32)
    total_bits = mwt.width * mwt.nlevels
    p = i
    v = jnp.zeros_like(i)
    for l in range(mwt.nlevels):
        g = mwt.level(l)
        s = mwt.node_starts[l][v]
        end = _node_end(mwt, l, v)
        p = jnp.minimum(p, end)
        digit = (c >> (total_bits - (l + 1) * mwt.width)) & (mwt.degree - 1)
        rb = generalized_rank(g, digit, p) - generalized_rank(g, digit, s)
        v = v * mwt.degree + digit
        p = mwt.node_starts[l + 1][v] + rb
    return p - mwt.node_starts[mwt.nlevels][c]


def _node_end(mwt: MultiaryWaveletTree, l: int, v: jax.Array) -> jax.Array:
    nodes_l = mwt.degree ** l
    nxt = v + 1
    return jnp.where(nxt >= nodes_l, mwt.n,
                     mwt.node_starts[l][jnp.minimum(nxt, nodes_l - 1)])


def mwt_select(mwt: MultiaryWaveletTree, c: jax.Array,
               k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) occurrence of c."""
    c = jnp.asarray(c, _I32)
    k = jnp.asarray(k, _I32)
    total_bits = mwt.width * mwt.nlevels
    pos = k
    for l in range(mwt.nlevels - 1, -1, -1):
        g = mwt.level(l)
        v = c >> (total_bits - l * mwt.width) if l else jnp.zeros_like(c)
        s = mwt.node_starts[l][v]
        digit = (c >> (total_bits - (l + 1) * mwt.width)) & (mwt.degree - 1)
        abs_rank = generalized_rank(g, digit, s) + pos
        p_abs = generalized_select(g, digit, abs_rank)
        pos = p_abs - s
    return pos
