"""Packed-word bit operations — the word-RAM substrate of the paper, on TPU.

The paper stores bitmaps and short lists packed Θ(log n) bits to a word and
manipulates them with table lookups. On TPU we fix the word to ``uint32`` and
replace every lookup table with vector bit-arithmetic (shifts, masks,
``lax.population_count``): TPUs have no cheap gather for small LUTs, while
bit ops run at full VPU rate (see DESIGN.md §2).

All functions are shape-static and jittable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

WORD_BITS = 32
_U32 = jnp.uint32


def num_words(n_bits: int) -> int:
    """Number of uint32 words needed to hold ``n_bits`` bits."""
    return (n_bits + WORD_BITS - 1) // WORD_BITS


@functools.partial(jax.jit, static_argnames=())
def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a vector of 0/1 values into uint32 words, LSB-first.

    Bit ``i`` of the sequence lands in word ``i // 32`` at position ``i % 32``.
    Input length must be padded to a multiple of 32 by the caller via
    :func:`pad_bits` (padding bits must be 0).
    """
    n = bits.shape[0]
    assert n % WORD_BITS == 0, "pad_bits first"
    b = bits.astype(_U32).reshape(-1, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=_U32)
    return jnp.bitwise_or.reduce(b << shifts, axis=1)


def pad_bits(bits: jax.Array) -> jax.Array:
    """Zero-pad a bit vector to a multiple of the word size."""
    n = bits.shape[0]
    pad = (-n) % WORD_BITS
    if pad:
        bits = jnp.concatenate([bits, jnp.zeros((pad,), bits.dtype)])
    return bits


@functools.partial(jax.jit, static_argnames=("n",))
def unpack_bits(words: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns the first ``n`` bits as uint8."""
    shifts = jnp.arange(WORD_BITS, dtype=_U32)
    bits = (words[:, None] >> shifts) & _U32(1)
    return bits.reshape(-1)[:n].astype(jnp.uint8)


def popcount(x: jax.Array) -> jax.Array:
    """Per-element population count (the paper's rank-in-word LUT)."""
    return jax.lax.population_count(x)


@functools.partial(jax.jit, static_argnames=())
def word_prefix_popcount(words: jax.Array) -> jax.Array:
    """Exclusive prefix sum of per-word popcounts — ranks at word boundaries.

    This is the parallel version of Jacobson's first-level counting: count 1s
    per word (LUT → popcount instruction), then prefix-sum. O(n/log n) work,
    O(log n) depth in the PRAM accounting.
    """
    counts = popcount(words).astype(jnp.uint32)
    incl = jnp.cumsum(counts, dtype=jnp.uint32)
    return jnp.concatenate([jnp.zeros((1,), jnp.uint32), incl[:-1]])


def mask_below(bit_index: jax.Array) -> jax.Array:
    """uint32 mask with bits [0, bit_index) set; bit_index in [0, 32]."""
    bit_index = bit_index.astype(_U32)
    # (1 << 32) overflows; handle bit_index == 32 via the all-ones special case.
    full = jnp.uint32(0xFFFFFFFF)
    return jnp.where(bit_index >= 32, full, (_U32(1) << bit_index) - _U32(1))


def rank1_word(word: jax.Array, bit_index: jax.Array) -> jax.Array:
    """Number of 1 bits strictly below ``bit_index`` within a word."""
    return popcount(word & mask_below(bit_index))


def select_in_word(word: jax.Array, k: jax.Array) -> jax.Array:
    """Position of the k'th (0-based) set bit of ``word``.

    The paper answers this with a half-word lookup table; on TPU we use a
    branchless binary search over popcounts of masked prefixes — 5 popcounts
    per query, all vectorized. Returns 32 if the word has fewer than k+1 bits.
    """
    word = word.astype(_U32)
    k = k.astype(jnp.int32)
    pos = jnp.zeros_like(k)
    remaining = k
    for width in (16, 8, 4, 2, 1):
        half = (word >> pos.astype(_U32)) & mask_below(jnp.full_like(pos, width).astype(_U32))
        cnt = popcount(half).astype(jnp.int32)
        go_right = cnt <= remaining
        remaining = jnp.where(go_right, remaining - cnt, remaining)
        pos = jnp.where(go_right, pos + width, pos)
    return pos


@functools.partial(jax.jit, static_argnames=("width", "out_dtype_name"))
def pack_fields(values: jax.Array, width: int, out_dtype_name: str = "uint32") -> jax.Array:
    """Pack fixed-width integer fields into words (the paper's packed lists).

    ``values`` is a vector of integers each fitting in ``width`` bits; the
    result packs ``32 // width`` of them per uint32 word (LSB-first). width
    must divide 32. This is the TPU analogue of the packed list storing
    ``N·b/ log n`` words for N b-bit integers.
    """
    assert 32 % width == 0
    per = 32 // width
    n = values.shape[0]
    pad = (-n) % per
    if pad:
        values = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
    v = values.astype(_U32).reshape(-1, per)
    shifts = (jnp.arange(per, dtype=_U32) * _U32(width))
    words = jnp.bitwise_or.reduce(v << shifts, axis=1)
    return words.astype(jnp.dtype(out_dtype_name))


@functools.partial(jax.jit, static_argnames=("width", "n"))
def unpack_fields(words: jax.Array, width: int, n: int) -> jax.Array:
    """Inverse of :func:`pack_fields`: extract n fields of ``width`` bits."""
    assert 32 % width == 0
    per = 32 // width
    shifts = jnp.arange(per, dtype=_U32) * _U32(width)
    mask = _U32((1 << width) - 1)
    fields = (words.astype(_U32)[:, None] >> shifts) & mask
    return fields.reshape(-1)[:n]


def extract_bit(values: jax.Array, bit: jax.Array) -> jax.Array:
    """Extract bit ``bit`` (0 = LSB) of each value, as uint32 in {0,1}."""
    return (values.astype(_U32) >> bit.astype(_U32)) & _U32(1)


def extract_field(values: jax.Array, lo_bit: jax.Array, width: int) -> jax.Array:
    """Extract ``width`` bits starting at ``lo_bit`` from each value."""
    mask = _U32((1 << width) - 1)
    return (values.astype(_U32) >> lo_bit.astype(_U32)) & mask
