"""Arbitrary-shaped (Huffman) binary wavelet trees (paper Theorem 4.3).

Codeword generation runs host-side (the paper likewise treats codewords as
given input; it cites [Edwards & Vishkin] for an O(n)-work parallel Huffman).
Construction is levelwise: an element with codeword length L contributes one
bit at levels 0..L-1 and then leaves the sequence. The array invariant is

    [ active elements, stably sorted by their top-l code bits | retired ]

Each level performs a node-segmented stable partition of the active prefix
(two segmented prefix sums + a compact segment histogram); elements whose
code ends sink stably to the retired tail. Segments are identified
*positionally* (boundary flags → cumsum), so no 2^depth histograms are
needed even for very skewed trees.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitops
from .rank_select import BinaryRank, build_binary_rank
from .scan import exclusive_sum, segmented_exclusive_sum
from .sort import _invert_permutation

_I32 = jnp.int32
_U32 = jnp.uint32


# --------------------------------------------------------------------------
# Host-side codebook generation
# --------------------------------------------------------------------------

def huffman_code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Classic heap Huffman over symbol frequencies (host-side)."""
    sigma = len(freqs)
    if sigma == 1:
        return np.ones(1, np.int32)
    heap = [(int(f), i) for i, f in enumerate(freqs)]
    heapq.heapify(heap)
    parent = {}
    next_id = sigma
    while len(heap) > 1:
        fa, ia = heapq.heappop(heap)
        fb, ib = heapq.heappop(heap)
        parent[ia] = next_id
        parent[ib] = next_id
        heapq.heappush(heap, (fa + fb, next_id))
        next_id += 1
    lengths = np.zeros(sigma, np.int32)
    for s in range(sigma):
        d, node = 0, s
        while node in parent:
            node = parent[node]
            d += 1
        lengths[s] = max(d, 1)
    return lengths


def canonical_codes(lengths: np.ndarray) -> Tuple[np.ndarray, int]:
    """Canonical (prefix-free, MSB-first) codes from code lengths."""
    sigma = len(lengths)
    max_len = int(lengths.max())
    order = np.lexsort((np.arange(sigma), lengths))
    codes = np.zeros(sigma, np.uint64)
    code = 0
    prev_len = int(lengths[order[0]])
    for s in order:
        L = int(lengths[s])
        code <<= (L - prev_len)
        codes[s] = code
        code += 1
        prev_len = L
    return codes.astype(np.uint32), max_len


def huffman_codebook(freqs: np.ndarray) -> Tuple[np.ndarray, np.ndarray, int]:
    """(codes, lengths, max_len) for a frequency table."""
    lengths = huffman_code_lengths(np.asarray(freqs))
    codes, max_len = canonical_codes(lengths)
    return codes, lengths, max_len


# --------------------------------------------------------------------------
# Construction
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class HuffmanWaveletTree:
    """Levelwise arbitrary-shape wavelet tree.

    ``ranks`` stacks per-level rank directories; the level-l bitmap's
    meaningful length is ``active[l]`` bits (deeper positions are padding).
    """
    ranks: BinaryRank        # stacked: leaves have a leading (max_len,) axis
    active: jax.Array        # (max_len,) int32 — bitmap length per level
    n: int = field(metadata=dict(static=True))
    max_len: int = field(metadata=dict(static=True))

    def level(self, l: int) -> BinaryRank:
        return jax.tree.map(lambda x: x[l], self.ranks)

    @property
    def total_bits(self) -> jax.Array:
        """Compressed size in bits = Σ code lengths."""
        return jnp.sum(self.active)


def build_huffman_wavelet_tree(seq: jax.Array, codes: jax.Array,
                               lengths: jax.Array,
                               max_len: int) -> HuffmanWaveletTree:
    """Theorem 4.3 construction, codewords given.

    Per level: survivors (code longer than l+1 bits) are stably reordered by
    (segment, bit) via a compact-segment histogram + segmented prefix sums;
    everyone else retires to the tail. Total data movement is
    O(Σ_l active_l) = O(n · avg code length) on narrow arrays.
    """
    n = int(seq.shape[0])
    sidx = seq.astype(_I32)
    elen = lengths.astype(_I32)[sidx]                       # (n,)
    cw = (codes.astype(_U32)[sidx]
          << (jnp.uint32(max_len) - elen.astype(_U32)))     # left-justified
    level_words: List[jax.Array] = []
    active_counts: List[jax.Array] = []

    for l in range(max_len):
        act = elen > l
        bit = jnp.where(act, (cw >> _U32(max_len - 1 - l)) & _U32(1),
                        _U32(0)).astype(_I32)
        level_words.append(bitops.pack_bits(bitops.pad_bits(
            bit.astype(jnp.uint8))))
        active_counts.append(jnp.sum(act, dtype=_I32))
        if l == max_len - 1:
            break

        # ---- reorder for level l+1 -----------------------------------
        surv = elen > l + 1
        # positional segments over the active prefix (node = top-l bits)
        nid = (cw >> _U32(max_len - l)).astype(_I32) if l else \
            jnp.zeros((n,), _I32)
        seg_start = jnp.concatenate([
            jnp.ones((1,), _I32),
            ((nid[1:] != nid[:-1]) | (act[1:] != act[:-1])).astype(_I32)])
        seg_idx = jnp.cumsum(seg_start) - 1                  # compact ids
        # survivors: stable order by (segment, bit)
        key = jnp.where(surv, seg_idx * 2 + bit, 2 * n)      # sentinel last
        hist = jnp.zeros((2 * n + 1,), _I32).at[key].add(1)
        key_start = exclusive_sum(hist)
        s0 = segmented_exclusive_sum((surv & (bit == 0)).astype(_I32),
                                     seg_start)
        s1 = segmented_exclusive_sum((surv & (bit == 1)).astype(_I32),
                                     seg_start)
        dest = key_start[key] + jnp.where(bit == 0, s0, s1)
        # non-survivors: stable tail
        n_surv = jnp.sum(surv, dtype=_I32)
        tail_rank = exclusive_sum((~surv).astype(_I32))
        dest = jnp.where(surv, dest, n_surv + tail_rank)
        g = _invert_permutation(dest)
        cw, elen = cw[g], elen[g]

    ranks = [build_binary_rank(w, n) for w in level_words]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ranks)
    return HuffmanWaveletTree(ranks=stacked, active=jnp.stack(active_counts),
                              n=n, max_len=max_len)


# --------------------------------------------------------------------------
# Oracle (numpy) for tests/benchmarks
# --------------------------------------------------------------------------

def reference_huffman_levels(seq: np.ndarray, codes: np.ndarray,
                             lengths: np.ndarray,
                             max_len: int) -> List[np.ndarray]:
    """Pure-numpy oracle: the level bitmaps of the arbitrary-shape tree."""
    n = len(seq)
    elen = lengths[seq]
    cw_lj = codes[seq].astype(np.uint64) << (max_len - elen).astype(np.uint64)
    cur = np.arange(n)                       # active elements, level order
    out = []
    for l in range(max_len):
        bits = ((cw_lj[cur] >> np.uint64(max_len - 1 - l)) & 1).astype(np.int32)
        out.append(bits)
        if l == max_len - 1:
            break
        key = cw_lj[cur] >> np.uint64(max_len - 1 - l)   # top l+1 bits
        cur = cur[np.argsort(key, kind="stable")]
        cur = cur[elen[cur] > l + 1]
    return out
