"""Arbitrary-shaped (Huffman) binary wavelet trees (paper Theorem 4.3).

Codeword generation runs host-side (the paper likewise treats codewords as
given input; it cites [Edwards & Vishkin] for an O(n)-work parallel Huffman).
Construction is levelwise: an element with codeword length L contributes one
bit at levels 0..L-1 and then leaves the sequence. The array invariant is

    [ active elements, stably sorted by their top-l code bits | retired ]

Each level performs a node-segmented stable partition of the active prefix
(two segmented prefix sums + a compact segment histogram); elements whose
code ends sink stably to the retired tail. Segments are identified
*positionally* (boundary flags → cumsum), so no 2^depth histograms are
needed even for very skewed trees.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitops
from .rank_select import (BinaryRank, _rank1_at, _word_zero_one_prefixes,
                          build_binary_rank, partition_select,
                          partition_select_directory)
from .scan import exclusive_sum, segmented_exclusive_sum
from .sort import _invert_permutation

_I32 = jnp.int32
_U32 = jnp.uint32


# --------------------------------------------------------------------------
# Host-side codebook generation
# --------------------------------------------------------------------------

def huffman_code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Classic heap Huffman over symbol frequencies (host-side)."""
    sigma = len(freqs)
    if sigma == 1:
        return np.ones(1, np.int32)
    heap = [(int(f), i) for i, f in enumerate(freqs)]
    heapq.heapify(heap)
    parent = {}
    next_id = sigma
    while len(heap) > 1:
        fa, ia = heapq.heappop(heap)
        fb, ib = heapq.heappop(heap)
        parent[ia] = next_id
        parent[ib] = next_id
        heapq.heappush(heap, (fa + fb, next_id))
        next_id += 1
    lengths = np.zeros(sigma, np.int32)
    for s in range(sigma):
        d, node = 0, s
        while node in parent:
            node = parent[node]
            d += 1
        lengths[s] = max(d, 1)
    return lengths


def canonical_codes(lengths: np.ndarray) -> Tuple[np.ndarray, int]:
    """Canonical (prefix-free, MSB-first) codes from code lengths."""
    sigma = len(lengths)
    max_len = int(lengths.max())
    order = np.lexsort((np.arange(sigma), lengths))
    codes = np.zeros(sigma, np.uint64)
    code = 0
    prev_len = int(lengths[order[0]])
    for s in order:
        L = int(lengths[s])
        code <<= (L - prev_len)
        codes[s] = code
        code += 1
        prev_len = L
    return codes.astype(np.uint32), max_len


def huffman_codebook(freqs: np.ndarray) -> Tuple[np.ndarray, np.ndarray, int]:
    """(codes, lengths, max_len) for a frequency table."""
    lengths = huffman_code_lengths(np.asarray(freqs))
    codes, max_len = canonical_codes(lengths)
    return codes, lengths, max_len


# --------------------------------------------------------------------------
# Construction
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class HuffmanWaveletTree:
    """Levelwise arbitrary-shape wavelet tree.

    ``ranks`` stacks per-level rank directories; the level-l bitmap's
    meaningful length is ``active[l]`` bits (deeper positions are padding).
    """
    ranks: BinaryRank        # stacked: leaves have a leading (max_len,) axis
    active: jax.Array        # (max_len,) int32 — bitmap length per level
    n: int = field(metadata=dict(static=True))
    max_len: int = field(metadata=dict(static=True))

    def level(self, l: int) -> BinaryRank:
        return jax.tree.map(lambda x: x[l], self.ranks)

    @property
    def total_bits(self) -> jax.Array:
        """Compressed size in bits = Σ code lengths."""
        return jnp.sum(self.active)


def _huffman_level_plans(codes: np.ndarray, lengths: np.ndarray,
                         max_len: int):
    """Static per-level run tables for the fused (select-gather) build.

    A level-l reorder moves each (l+1)-bit code prefix as one *run*:
    prefix-freedom means a child prefix is either a complete codeword
    (every element retires) or a proper prefix (every element survives),
    so survivorship is a static property of the run. Runs are contiguous
    symbol ranges in code order; their element counts come from the symbol
    histogram at build time. Returns ``(sym_order, plans)`` with one dict
    per level: symbol-range bounds ``a``/``b`` per run (dst order:
    survivors ascending, then retirees ascending), the run's partition
    ``bit``, the first symbol index ``pa`` of its parent's level-l
    segment, and the survivor run count ``n_internal``.
    """
    codes = np.asarray(codes, np.uint64)
    lengths = np.asarray(lengths, np.int64)
    sigma = len(codes)
    code_lj = codes << (np.uint64(max_len) - lengths.astype(np.uint64))
    sym_order = np.argsort(code_lj, kind="stable")
    lj_s = code_lj[sym_order]
    len_s = lengths[sym_order]
    plans = []
    for l in range(max_len - 1):
        act = len_s > l
        pfx = lj_s >> np.uint64(max_len - l - 1)
        runs = []                                   # (a, b, pfx, is_leaf)
        i = 0
        while i < sigma:
            if not act[i]:
                i += 1
                continue
            j = i
            while j < sigma and act[j] and pfx[j] == pfx[i]:
                j += 1
            runs.append((i, j, int(pfx[i]), bool(len_s[i] == l + 1)))
            i = j
        first_of_parent = {}
        for a, _, q, _ in runs:
            first_of_parent.setdefault(q >> 1, a)   # runs are ascending
        dst = [r for r in runs if not r[3]] + [r for r in runs if r[3]]
        plans.append(dict(
            a=np.array([r[0] for r in dst], np.int32),
            b=np.array([r[1] for r in dst], np.int32),
            bit=np.array([r[2] & 1 for r in dst], np.int32),
            pa=np.array([first_of_parent[r[2] >> 1] for r in dst],
                        np.int32),
            n_internal=sum(1 for r in runs if not r[3]),
            retired=(len_s <= l).astype(np.int32),
        ))
    return sym_order, plans


def build_huffman_wavelet_tree(seq: jax.Array, codes: jax.Array,
                               lengths: jax.Array,
                               max_len: int,
                               fused: bool = True) -> HuffmanWaveletTree:
    """Theorem 4.3 construction, codewords given.

    Per level: survivors (code longer than l+1 bits) are stably reordered by
    (segment, bit); everyone else retires to the tail. Total data movement
    is O(Σ_l active_l) = O(n · avg code length) on narrow arrays.

    ``fused=True`` (default) is the segmented select-gather fast path:
    every (l+1)-prefix is one output run (survivors first, retirees behind
    them — run membership and survivorship are *static* codebook facts, so
    the per-level histogram over 2n+1 keys and the n-element
    inverse-permutation scatter both disappear). The element landing at
    run offset q is ``select_bit(rank_bit(parent segment start) + q)`` on
    the level bitmap — the same word-granularity select directory as
    ``rank_select.segmented_partition_gather``, with run offsets coming
    from one symbol histogram. Requires concrete (non-traced) codewords;
    traced codebooks fall back to the scatter path. Level bitmaps, rank
    directories and active counts are bit-identical on both paths (only
    the internal order of the retired tail — which never contributes
    another bit — differs).
    """
    from repro import obs
    concrete = not (isinstance(codes, jax.core.Tracer)
                    or isinstance(lengths, jax.core.Tracer))
    if fused and not concrete:
        obs.counter("core.huffman_traced_codebook_fallback").inc()
    take_fused = fused and concrete and max_len > 1
    obs.counter("core.build", builder="huffman",
                path="fused" if take_fused else "scatter").inc()
    if take_fused:
        return _build_huffman_fused(seq, codes, lengths, max_len)
    n = int(seq.shape[0])
    sidx = seq.astype(_I32)
    elen = lengths.astype(_I32)[sidx]                       # (n,)
    cw = (codes.astype(_U32)[sidx]
          << (jnp.uint32(max_len) - elen.astype(_U32)))     # left-justified
    level_words: List[jax.Array] = []
    active_counts: List[jax.Array] = []

    for l in range(max_len):
        act = elen > l
        bit = jnp.where(act, (cw >> _U32(max_len - 1 - l)) & _U32(1),
                        _U32(0)).astype(_I32)
        level_words.append(bitops.pack_bits(bitops.pad_bits(
            bit.astype(jnp.uint8))))
        active_counts.append(jnp.sum(act, dtype=_I32))
        if l == max_len - 1:
            break

        # ---- reorder for level l+1 -----------------------------------
        surv = elen > l + 1
        # positional segments over the active prefix (node = top-l bits)
        nid = (cw >> _U32(max_len - l)).astype(_I32) if l else \
            jnp.zeros((n,), _I32)
        seg_start = jnp.concatenate([
            jnp.ones((1,), _I32),
            ((nid[1:] != nid[:-1]) | (act[1:] != act[:-1])).astype(_I32)])
        seg_idx = jnp.cumsum(seg_start) - 1                  # compact ids
        # survivors: stable order by (segment, bit)
        key = jnp.where(surv, seg_idx * 2 + bit, 2 * n)      # sentinel last
        hist = jnp.zeros((2 * n + 1,), _I32).at[key].add(1)
        key_start = exclusive_sum(hist)
        s0 = segmented_exclusive_sum((surv & (bit == 0)).astype(_I32),
                                     seg_start)
        s1 = segmented_exclusive_sum((surv & (bit == 1)).astype(_I32),
                                     seg_start)
        dest = key_start[key] + jnp.where(bit == 0, s0, s1)
        # non-survivors: stable tail
        n_surv = jnp.sum(surv, dtype=_I32)
        tail_rank = exclusive_sum((~surv).astype(_I32))
        dest = jnp.where(surv, dest, n_surv + tail_rank)
        g = _invert_permutation(dest)
        cw, elen = cw[g], elen[g]

    ranks = [build_binary_rank(w, n) for w in level_words]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ranks)
    return HuffmanWaveletTree(ranks=stacked, active=jnp.stack(active_counts),
                              n=n, max_len=max_len)


def _build_huffman_fused(seq: jax.Array, codes: jax.Array,
                         lengths: jax.Array,
                         max_len: int) -> HuffmanWaveletTree:
    """Select-gather realization of the Theorem 4.3 build (see
    :func:`build_huffman_wavelet_tree`)."""
    n = int(seq.shape[0])
    sigma = int(np.asarray(lengths).shape[0])
    sym_order, plans = _huffman_level_plans(np.asarray(codes),
                                            np.asarray(lengths), max_len)
    sidx = seq.astype(_I32)
    elen = lengths.astype(_I32)[sidx]                       # (n,)
    cw = (codes.astype(_U32)[sidx]
          << (jnp.uint32(max_len) - elen.astype(_U32)))     # left-justified
    # one symbol histogram (code order) feeds every level's run offsets
    hist = jnp.zeros((sigma,), _I32).at[sidx].add(1, mode="drop")
    hist_s = hist[jnp.asarray(sym_order)]
    H = jnp.concatenate([jnp.zeros((1,), _I32), jnp.cumsum(hist_s)])
    p_out = jnp.arange(n, dtype=_I32)
    level_words: List[jax.Array] = []
    active_counts: List[jax.Array] = []

    for l in range(max_len):
        act = elen > l
        bit = jnp.where(act, (cw >> _U32(max_len - 1 - l)) & _U32(1),
                        _U32(0)).astype(_I32)
        words = bitops.pack_bits(bitops.pad_bits(bit.astype(jnp.uint8)))
        level_words.append(words)
        active_counts.append(jnp.sum(act, dtype=_I32))
        if l == max_len - 1:
            break

        # ---- reorder for level l+1 (all gathers) ---------------------
        pl = plans[l]
        ret = jnp.concatenate([jnp.zeros((1,), _I32),
                               jnp.cumsum(hist_s * jnp.asarray(pl["retired"]))])
        a_l = H[sigma] - ret[sigma]                  # active element count
        cnt = H[jnp.asarray(pl["b"])] - H[jnp.asarray(pl["a"])]
        dst_start = jnp.cumsum(cnt) - cnt
        pa = jnp.asarray(pl["pa"])
        ps = H[pa] - ret[pa]                         # parent segment start
        directory = partition_select_directory(words, n)
        _, ocum, _, _ = directory
        total_ones = jnp.asarray(n, _I32) - directory[2]
        ones_at = _rank1_at(words, ocum, total_ones, ps, n)
        run_bit = jnp.asarray(pl["bit"])
        base = jnp.where(run_bit == 1, ones_at, ps - ones_at)
        # run of every output position (run starts ascending in dst order)
        nr = pl["a"].shape[0]
        rmarks = jnp.zeros((n,), _I32).at[dst_start].max(
            jnp.arange(nr, dtype=_I32), mode="drop")
        r = jax.lax.cummax(rmarks)
        t = base[r] + (p_out - dst_start[r])
        src = partition_select(words, directory, run_bit[r], t)
        g = jnp.where(p_out < a_l, src, p_out)       # old tail stays put
        cw, elen = cw[g], elen[g]

    ranks = jax.vmap(lambda w: build_binary_rank(w, n))(
        jnp.stack(level_words))
    return HuffmanWaveletTree(ranks=ranks, active=jnp.stack(active_counts),
                              n=n, max_len=max_len)


# --------------------------------------------------------------------------
# Oracle (numpy) for tests/benchmarks
# --------------------------------------------------------------------------

def reference_huffman_levels(seq: np.ndarray, codes: np.ndarray,
                             lengths: np.ndarray,
                             max_len: int) -> List[np.ndarray]:
    """Pure-numpy oracle: the level bitmaps of the arbitrary-shape tree."""
    n = len(seq)
    elen = lengths[seq]
    cw_lj = codes[seq].astype(np.uint64) << (max_len - elen).astype(np.uint64)
    cur = np.arange(n)                       # active elements, level order
    out = []
    for l in range(max_len):
        bits = ((cw_lj[cur] >> np.uint64(max_len - 1 - l)) & 1).astype(np.int32)
        out.append(bits)
        if l == max_len - 1:
            break
        key = cw_lj[cur] >> np.uint64(max_len - 1 - l)   # top l+1 bits
        cur = cur[np.argsort(key, kind="stable")]
        cur = cur[elen[cur] > l + 1]
    return out
