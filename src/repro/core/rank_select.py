"""Parallel construction of succinct rank/select structures (paper Section 5).

Binary rank follows Jacobson's two-level scheme: absolute ranks every
``SUPERBLOCK_WORDS`` words (uint32, 3.1% of the bitmap) plus superblock-
relative ranks every ``BLOCK_WORDS`` words (uint16, 12.5%), built with
popcounts + prefix sums in O(n/log n) work and O(log n) depth (Theorem 5.1).
Binary select follows Clark's sampling scheme: the *block* containing every
``sample_rate``-th 1 (resp. 0) is stored, and a query binary-searches only
between two consecutive samples — probing ranks *derived from the rank
directory in O(1)* rather than a stored prefix array, so select adds just
the sample hints (≈ 32/sample_rate bits per bit). Total directory overhead
is ~18% of the bitmap; the structures are succinct as in the paper.

The generalized (σ-ary) structures follow Section 5.2: per-chunk per-
character cumulative counts via a prefix sum whose operator adds σ-vectors
of counts.

TPU adaptation (DESIGN.md §2): every lookup table in the paper (rank-in-word,
select-in-word, count-symbol-in-word) is replaced with vector bit arithmetic —
``lax.population_count``, masked popcounts, and field-compare cascades. The
word-RAM O(1) query cost becomes O(1) vector ops per query; construction work
remains proportional to words, not bits.

All structures are frozen-dataclass pytrees: arrays are pytree leaves, sizes
are static metadata, so they can cross ``jax.jit`` boundaries freely.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import bitops
from .scan import exclusive_sum

_U32 = jnp.uint32
_I32 = jnp.int32

# Two-level rank geometry: a superblock covers 32 words = 1024 bits ≈ log²n
# (the paper's range size); a block covers 4 words = 128 bits (sub-range).
SUPERBLOCK_WORDS = 32
BLOCK_WORDS = 4
_BLOCKS_PER_SB = SUPERBLOCK_WORDS // BLOCK_WORDS
BLOCK_BITS = BLOCK_WORDS * bitops.WORD_BITS          # 128


# --------------------------------------------------------------------------
# Binary rank (Jacobson)
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BinaryRank:
    """Two-level rank directory over a packed bit sequence.

    ``superblock[k]`` = # of 1s strictly before word ``k*SUPERBLOCK_WORDS``;
    ``block[b]``      = # of 1s in b's superblock strictly before word
                        ``b*BLOCK_WORDS`` (≤ 28·32 < 2^16 → uint16).
    """
    words: jax.Array       # (num_words,) uint32 packed bits
    superblock: jax.Array  # (ceil(W/32),) uint32
    block: jax.Array       # (ceil(W/4),) uint16
    n: int = field(metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.block.shape[0]

    @property
    def total_ones(self) -> jax.Array:
        return rank1(self, jnp.int32(self.n))


def build_binary_rank(words: jax.Array, n: int) -> BinaryRank:
    """O(n/log n)-work, O(log n)-depth construction (paper Theorem 5.1).

    One popcount per word, one prefix sum, one subtraction — the parallel
    version of Jacobson's counting. ``words`` must be zero-padded past bit n.
    """
    prefix = bitops.word_prefix_popcount(words)                  # (W,) excl.
    superblock = prefix[::SUPERBLOCK_WORDS]
    blk_prefix = prefix[::BLOCK_WORDS]                           # (B,)
    nblk = blk_prefix.shape[0]
    sb_of_blk = jnp.arange(nblk, dtype=_I32) // _BLOCKS_PER_SB
    block = (blk_prefix - superblock[sb_of_blk]).astype(jnp.uint16)
    return BinaryRank(words=words, superblock=superblock, block=block, n=n)


def _rank_at_block_fast(rs: BinaryRank, b: jax.Array) -> jax.Array:
    """rank1 at a block boundary, b < num_blocks — two gathers, no popcount."""
    return (rs.superblock[b // _BLOCKS_PER_SB].astype(_I32)
            + rs.block[b].astype(_I32))


def rank_at_block(rs: BinaryRank, b: jax.Array) -> jax.Array:
    """# of 1 bits strictly before block b — O(1) from the directory."""
    b = jnp.asarray(b, _I32)
    bc = jnp.minimum(b, rs.num_blocks - 1)
    base = _rank_at_block_fast(rs, bc)
    # b may equal num_blocks (one-past-the-end): clamp to total by adding
    # the popcount of the final block.
    over = jnp.sum(bitops.popcount(_block_words(rs, bc)), axis=-1).astype(_I32)
    return jnp.where(b > bc, base + over, base)


def _block_words(rs: BinaryRank, b: jax.Array) -> jax.Array:
    """Gather the BLOCK_WORDS words of block b (clipped). b: (...,)."""
    w0 = jnp.asarray(b, _I32) * BLOCK_WORDS
    idx = w0[..., None] + jnp.arange(BLOCK_WORDS, dtype=_I32)
    idx = jnp.minimum(idx, rs.words.shape[0] - 1)
    valid = (w0[..., None] + jnp.arange(BLOCK_WORDS, dtype=_I32)
             < rs.words.shape[0])
    return jnp.where(valid, rs.words[idx], _U32(0))


def rank1(rs: BinaryRank, i: jax.Array) -> jax.Array:
    """# of 1 bits in positions [0, i). Vectorized over ``i``.

    superblock + block + ≤3 whole-word popcounts + 1 masked popcount —
    the paper's two lookups realized as vector bit ops.
    """
    i = jnp.asarray(i, _I32)
    w = i // bitops.WORD_BITS
    b = w // BLOCK_WORDS
    bc = jnp.minimum(b, rs.num_blocks - 1)
    base = (rs.superblock[bc // _BLOCKS_PER_SB].astype(_I32)
            + rs.block[bc].astype(_I32))
    words4 = _block_words(rs, bc)                       # (..., 4)
    j = jnp.arange(BLOCK_WORDS, dtype=_I32)
    wpos = bc[..., None] * BLOCK_WORDS + j
    off_in_word = (i - w * bitops.WORD_BITS).astype(_U32)
    full = (wpos < w[..., None])
    part = (wpos == w[..., None])
    cnt = jnp.where(
        full, bitops.popcount(words4).astype(_I32),
        jnp.where(part,
                  bitops.rank1_word(words4,
                                    off_in_word[..., None]).astype(_I32),
                  0))
    return base + jnp.sum(cnt, axis=-1)


def rank0(rs: BinaryRank, i: jax.Array) -> jax.Array:
    i = jnp.asarray(i, _I32)
    return i - rank1(rs, i)


def access_bit(rs: BinaryRank, i: jax.Array) -> jax.Array:
    i = jnp.asarray(i, _I32)
    w = i // bitops.WORD_BITS
    off = (i % bitops.WORD_BITS).astype(_U32)
    return ((rs.words[w] >> off) & _U32(1)).astype(_I32)


# --------------------------------------------------------------------------
# Binary select (Clark-style sampling over the rank directory)
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BinarySelect:
    """Sampled select hints: ``sample[j]`` = block index containing the
    (j·sample_rate)-th target bit. Queries search only between consecutive
    samples, probing block-boundary ranks derived from the rank directory."""
    sample: jax.Array       # (num_samples,) int32 block hints
    n: int = field(metadata=dict(static=True))
    sample_rate: int = field(metadata=dict(static=True))
    zeros: bool = field(metadata=dict(static=True))  # select0 directory?


def build_binary_select(words: jax.Array, n: int,
                        sample_rate: int = 512,
                        zeros: bool = False) -> BinarySelect:
    """O(n/log n)-work construction (Theorem 5.1): block popcounts + one
    prefix sum + a vectorized searchsorted per sample (the paper's "identify
    the half-words containing every k-th 1 bit")."""
    W = words.shape[0]
    nblk = (W + BLOCK_WORDS - 1) // BLOCK_WORDS
    pad = nblk * BLOCK_WORDS - W
    wp = jnp.concatenate([words, jnp.zeros((pad,), _U32)]) if pad else words
    ones = jnp.sum(bitops.popcount(wp.reshape(nblk, BLOCK_WORDS)),
                   axis=1).astype(_I32)
    if zeros:
        valid = jnp.clip(n - jnp.arange(nblk, dtype=_I32) * BLOCK_BITS,
                         0, BLOCK_BITS)
        counts = valid - ones
    else:
        counts = ones
    cum = jnp.concatenate([jnp.zeros((1,), _I32), jnp.cumsum(counts)])
    # +2: any valid k has both bracketing samples (targets past the last
    # occurrence clip to the final block → hi = nblk is a safe upper bound)
    num_samples = n // sample_rate + 2
    targets = jnp.arange(num_samples, dtype=_I32) * _I32(sample_rate)
    sample = jnp.clip(jnp.searchsorted(cum, targets, side="right") - 1,
                      0, nblk - 1).astype(_I32)
    return BinarySelect(sample=sample, n=n, sample_rate=sample_rate,
                        zeros=zeros)


def _zero_rank_at_block(rs: BinaryRank, b: jax.Array) -> jax.Array:
    b = jnp.asarray(b, _I32)
    pos = jnp.minimum(b * BLOCK_BITS, rs.n)
    return pos - rank_at_block(rs, b)


def _zero_rank_at_block_fast(rs: BinaryRank, b: jax.Array) -> jax.Array:
    pos = jnp.minimum(b * BLOCK_BITS, rs.n)
    return pos - _rank_at_block_fast(rs, b)


def _select_search(rs: BinaryRank, sel: BinarySelect,
                   k: jax.Array) -> jax.Array:
    """Largest block b in [sample[j], sample[j+1]] with rank(b) <= k.

    The search invariant keeps mid < num_blocks, so every probe uses the
    two-gather fast boundary rank (no per-probe popcounts)."""
    k = jnp.asarray(k, _I32)
    j = k // sel.sample_rate
    lo = sel.sample[j]
    hi = sel.sample[jnp.minimum(j + 1, sel.sample.shape[0] - 1)] + 1
    hi = jnp.maximum(hi, lo + 1)
    steps = max(1, math.ceil(math.log2(rs.num_blocks + 1)))
    probe = _zero_rank_at_block_fast if sel.zeros else _rank_at_block_fast
    for _ in range(steps):
        mid = (lo + hi) // 2
        go_right = probe(rs, mid) <= k
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        hi = jnp.maximum(hi, lo)
    return lo


def _select_in_block(rs: BinaryRank, b: jax.Array, cnt: jax.Array,
                     zeros: bool) -> jax.Array:
    """Position of the cnt-th target bit inside block b (cnt block-local)."""
    words4 = _block_words(rs, b)                         # (..., 4)
    if zeros:
        words4 = ~words4                                 # padding→1s is fine:
        # a valid query's target lies before the padding region
    pc = bitops.popcount(words4).astype(_I32)
    excl = jnp.cumsum(pc, axis=-1) - pc                  # (..., 4) exclusive
    in_this = (excl <= cnt[..., None]) & \
              (cnt[..., None] < excl + pc)
    wsel = jnp.argmax(in_this, axis=-1)                  # word within block
    word = jnp.take_along_axis(words4, wsel[..., None], axis=-1)[..., 0]
    base = jnp.take_along_axis(excl, wsel[..., None], axis=-1)[..., 0]
    within = bitops.select_in_word(word, cnt - base)
    return (b * BLOCK_WORDS + wsel) * bitops.WORD_BITS + within


def select1(rs: BinaryRank, sel: BinarySelect, k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) 1 bit. Vectorized over ``k``."""
    k = jnp.asarray(k, _I32)
    b = _select_search(rs, sel, k)
    return _select_in_block(rs, b, k - _rank_at_block_fast(rs, b),
                            zeros=False)


def select0(rs: BinaryRank, sel0: BinarySelect, k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) 0 bit."""
    k = jnp.asarray(k, _I32)
    b = _select_search(rs, sel0, k)
    return _select_in_block(rs, b, k - _zero_rank_at_block_fast(rs, b),
                            zeros=True)


def invert_words(words: jax.Array, n: int) -> jax.Array:
    """~words with the padding tail (bits ≥ n) forced back to 0."""
    inv = ~words
    w = words.shape[0]
    last = bitops.num_words(n) - 1
    tail = n - last * bitops.WORD_BITS
    idx = jnp.arange(w)
    tail_mask = bitops.mask_below(jnp.uint32(tail))
    inv = jnp.where(idx == last, inv & tail_mask, inv)
    inv = jnp.where(idx > last, jnp.uint32(0), inv)
    return inv


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BitVector:
    """Packed bits + rank + select1/select0 — what a wavelet node stores."""
    rank: BinaryRank
    sel1: BinarySelect
    sel0: BinarySelect


def build_bitvector(words: jax.Array, n: int,
                    sample_rate: int = 512) -> BitVector:
    rank = build_binary_rank(words, n)
    sel1 = build_binary_select(words, n, sample_rate, zeros=False)
    sel0 = build_binary_select(words, n, sample_rate, zeros=True)
    return BitVector(rank=rank, sel1=sel1, sel0=sel0)


def build_bitvector_levels(words: jax.Array, n: int,
                           sample_rate: int = 512,
                           use_kernels: bool = False,
                           interpret: bool | None = None) -> BitVector:
    """Batched directory build over stacked level bitmaps (fast-path form).

    ``words``: (L, W) — one packed n-bit bitmap per row. Builds the rank
    superblock/block tables and both select sample directories for every
    level in one vmapped/fused launch group instead of L separate
    ``build_bitvector`` calls, and returns a ``BitVector`` whose leaves all
    carry the leading (L,) axis — the exact stacked layout ``WaveletMatrix``
    stores. Bit-identical to stacking per-level ``build_bitvector`` results.

    ``use_kernels`` routes the rank tables through the Pallas
    ``rank_build_levels`` kernel (one launch for all levels, paper Theorem
    5.1); the select samples stay XLA (they are O(W) per level).
    """
    from repro import obs
    obs.counter("core.rank_build",
                impl="kernel" if use_kernels else "xla").inc()
    if use_kernels:
        from repro.kernels import ops as _kops
        superblock, block = _kops.rank_build_levels(words, n,
                                                    interpret=interpret)
        rank = BinaryRank(words=words, superblock=superblock, block=block,
                          n=n)
    else:
        rank = jax.vmap(lambda w: build_binary_rank(w, n))(words)
    sel1 = jax.vmap(
        lambda w: build_binary_select(w, n, sample_rate, zeros=False))(words)
    sel0 = jax.vmap(
        lambda w: build_binary_select(w, n, sample_rate, zeros=True))(words)
    return BitVector(rank=rank, sel1=sel1, sel0=sel0)


def partition_select_directory(words: jax.Array, n: int):
    """Word-granularity select directory over a packed n-bit flag bitmap.

    Returns ``(zcum, ocum, Z, cm)``: per-word exclusive zero/one counts,
    the total zero count, and the run-start cummax ``cm`` over the
    combined [zero targets | one targets] space — word w's zero run starts
    at ``zcum[w]`` with mark w, its one run at ``Z + ocum[w]`` with mark
    ``W + w``; a running max assigns every target the word that feeds it
    (empty runs are superseded by the next run sharing their start). This
    is the Theorem 5.1 structure every partition-by-select gather here is
    built from: per-word popcounts + two prefix sums + an O(n/log n)-index
    scatter + one cummax.
    """
    W = words.shape[0]
    zcum, ocum, total_ones = _word_zero_one_prefixes(words, n)
    Z = jnp.asarray(n, _I32) - total_ones
    wid = jnp.arange(W, dtype=_I32)
    marks = jnp.zeros((n,), _I32)
    marks = marks.at[zcum].max(wid, mode="drop")
    marks = marks.at[Z + ocum].max(W + wid, mode="drop")
    return zcum, ocum, Z, jax.lax.cummax(marks)


def partition_select(words: jax.Array, directory, bit: jax.Array,
                     t: jax.Array) -> jax.Array:
    """Source index of the t-th ``bit``-valued flag, via the directory.

    ``bit``/``t`` are (n,) arrays (one select per output position); the
    zero half selects in the complemented word — padding bits sit past
    every valid zero, so the in-word rank always lands on a real bit.
    """
    zcum, ocum, Z, cm = directory
    W = words.shape[0]
    m = cm[jnp.where(bit == 1, Z + t, t)]
    w = jnp.where(bit == 1, m - W, m)
    r = t - jnp.where(bit == 1, ocum[w], zcum[w])             # rank in word
    word = words[w]
    wsel = jnp.where(bit == 1, word, ~word)
    return w * bitops.WORD_BITS + bitops.select_in_word(wsel, r)


def stable_partition_gather(words: jax.Array, total_zeros: jax.Array,
                            n: int) -> jax.Array:
    """Gather permutation of the stable 0/1 partition, via select (no sort,
    no scatter of n elements).

    ``words``: the packed n-bit partition-flag bitmap (padding bits past n
    must be 0); ``total_zeros``: number of 0 flags. Returns ``g`` (n,) int32
    with ``g[p]`` = source index of the element that lands at position p —
    i.e. ``out = x[g]`` realizes the partition (zeros first, ones after,
    both in original order).

    This is the construction-side payoff of the paper's Section 5 select
    structures: position p takes element ``select0(p)`` (or
    ``select1(p - Z)``), so the whole permutation is one
    :func:`partition_select_directory` — everything past its tiny
    run-start scatter is vectorized gathers/arithmetic, which is why this
    formulation beats the scatter-based inverse permutation on CPU/TPU
    backends where n-element scatters serialize.
    """
    del total_zeros                      # derivable; kept for API stability
    directory = partition_select_directory(words, n)
    Z = directory[2]
    p = jnp.arange(n, dtype=_I32)
    is_one = (p >= Z).astype(_I32)
    t = jnp.where(is_one == 1, p - Z, p)
    return partition_select(words, directory, is_one, t)


def _word_zero_one_prefixes(words: jax.Array, n: int):
    """Per-word exclusive zero/one counts of an n-bit packed bitmap.

    Returns ``(zcum, ocum, total_ones)`` — the word-granularity select
    directory every partition-by-select gather is built from. Padding bits
    past n must be 0 (they are excluded from the zero counts).
    """
    W = words.shape[0]
    pc = bitops.popcount(words).astype(_I32)
    valid = jnp.clip(n - jnp.arange(W, dtype=_I32) * bitops.WORD_BITS,
                     0, bitops.WORD_BITS)
    zc = valid - pc
    zcum = jnp.cumsum(zc) - zc
    ocum = jnp.cumsum(pc) - pc
    return zcum, ocum, ocum[-1] + pc[-1]


def _rank1_at(words: jax.Array, ocum: jax.Array, total_ones: jax.Array,
              pos: jax.Array, n: int) -> jax.Array:
    """rank1 at positions ``pos`` (each in [0, n]) from the word directory.

    One word gather + one masked popcount per query — used for the
    O(#nodes) boundary ranks of the segmented partition gathers.
    """
    W = words.shape[0]
    w = pos // bitops.WORD_BITS
    off = (pos % bitops.WORD_BITS).astype(_U32)
    wc = jnp.minimum(w, W - 1)
    part = (ocum[wc]
            + bitops.popcount(words[wc] & bitops.mask_below(off)).astype(_I32))
    # pos == n with n a word multiple walks past the last word: total ones
    return jnp.where(w >= W, total_ones, part)


def segmented_partition_gather(words: jax.Array, nid: jax.Array,
                               node_start: jax.Array, n: int) -> jax.Array:
    """Gather permutation of the stable *per-node* 0/1 partition.

    ``words``: packed n-bit partition-flag bitmap (padding past n must be
    0); ``nid``: (n,) int32 node id of each element (elements already
    grouped by node, ids non-decreasing); ``node_start``: (V,) int32 start
    offset of every node (= count of elements in smaller nodes; empty
    nodes repeat the next start). Returns ``g`` (n,) int32 with ``g[p]`` =
    source index of the element landing at p — ``x[g]`` reorders every
    node's segment to [zeros | ones], both stably.

    The segmented generalization of :func:`stable_partition_gather`
    (paper Theorem 5.1 select machinery driving the Theorem 4.1/4.2
    node-segmented splits): a per-node partition never crosses node
    boundaries, so position p still belongs to node ``nid[p]``, and the
    element landing there is ``select0(rank0(node_start) + local offset)``
    (resp. select1) on the *global* bitmap. One word-granularity select
    directory — per-word popcounts, two prefix sums, run-start marks at
    word granularity (O(n/log n) scatter indices), a running max, and a
    branchless in-word select — therefore serves all ``V·2`` runs at once;
    only the O(V) boundary ranks are segmented state. Replaces the
    histogram + segmented-scan + n-element-scatter inverse permutation
    that serializes on CPU/XLA backends.
    """
    # global select directory: word run starts in the [zeros | ones] target
    # space (exactly the unsegmented structure — targets are global ranks)
    directory = partition_select_directory(words, n)
    zcum, ocum, Z, _ = directory
    total_ones = jnp.asarray(n, _I32) - Z
    # per-node boundary ranks (O(V) gathers)
    ns = node_start.astype(_I32)
    ones_at = _rank1_at(words, ocum, total_ones, ns, n)
    zeros_at = ns - ones_at                                # rank0(node start)
    znode = jnp.concatenate([zeros_at[1:], Z[None]]) - zeros_at
    # per-position: local offset -> global select target
    p = jnp.arange(n, dtype=_I32)
    v = nid.astype(_I32)
    offp = p - ns[v]
    is_one = (offp >= znode[v]).astype(_I32)
    t = jnp.where(is_one == 1, (ns[v] - zeros_at[v]) + offp - znode[v],
                  zeros_at[v] + offp)
    return partition_select(words, directory, is_one, t)


_FIELDS_SUPERWORD = 16      # words per run-start mark in the d-way select


def _field_start_mult(width: int) -> jnp.ndarray:
    """uint32 with a 1 at the start bit of every ``width``-bit field."""
    return _U32(sum(1 << (j * width) for j in range(32 // width)))


def _field_eq_mask(words: jax.Array, dv: jax.Array, width: int) -> jax.Array:
    """SWAR equality mask: bit ``j*width`` set iff field j == dv.

    The packed-list analogue of the paper's count-symbol-in-word LUT:
    XOR with the broadcast symbol, OR-fold each field onto its start bit,
    invert — O(width) vector ops, no per-field loop.
    """
    mult = _field_start_mult(width)
    x = words ^ (jnp.asarray(dv).astype(_U32) * mult)
    y = x
    for s in range(1, width):
        y = y | (x >> _U32(s))
    return ~y & mult


def packed_field_counts(digits: jax.Array, width: int, n: int):
    """(packed words, per-(word, digit) counts) for a digit sequence.

    ``cntwd[w, v]`` counts fields equal to v in word w, padding excluded —
    the word-granularity directory the d-way select gather, the
    generalized rank/select build, and the multiary node-offset chain all
    share (one packing + d popcount passes serves all three).
    """
    d = 1 << width
    per = 32 // width
    packed = bitops.pack_fields(digits, width)
    Wf = packed.shape[0]
    vf = jnp.clip(n - jnp.arange(Wf, dtype=_I32) * per, 0, per)
    vmask = bitops.mask_below((vf * width).astype(_U32))
    cntwd = jnp.stack(
        [bitops.popcount(_field_eq_mask(packed, jnp.asarray(dv), width)
                         & vmask).astype(_I32) for dv in range(d)],
        axis=1)                                            # (Wf, d)
    return packed, cntwd


def field_node_counts(packed: jax.Array, cntwd: jax.Array, width: int,
                      node_start: jax.Array, n: int):
    """Per-node digit boundary ranks: ``rank_at[v, dv]`` = # of dv-digits
    before node v's start; ``cnt_node[v, dv]`` = # inside node v.

    O(V·d) work from the shared word directory. ``cnt_node`` doubles as
    the next level's node-size table (a (node, digit) pair at level l IS
    a node at level l+1), which is how the fused multiary build chains
    its ``node_starts`` rows without any n-element histogram.
    """
    d = 1 << width
    per = 32 // width
    Wf = packed.shape[0]
    vcum = jnp.cumsum(cntwd, axis=0) - cntwd
    totals = vcum[-1] + cntwd[-1]
    ns = node_start.astype(_I32)
    w0 = jnp.minimum(ns // per, Wf - 1)
    off0 = (ns % per).astype(_U32) * _U32(width)
    words0 = packed[w0]
    before = jnp.stack(
        [bitops.popcount(_field_eq_mask(words0, jnp.asarray(dv), width)
                         & bitops.mask_below(off0)).astype(_I32)
         for dv in range(d)], axis=1)                      # (V, d)
    rank_at = jnp.where((ns // per >= Wf)[:, None], totals[None, :],
                        vcum[w0] + before)
    cnt_node = jnp.concatenate([rank_at[1:], totals[None, :]]) - rank_at
    return rank_at, cnt_node


def segmented_partition_gather_fields(digits: jax.Array, width: int,
                                      nid: jax.Array, node_start: jax.Array,
                                      n: int,
                                      plan=None) -> jax.Array:
    """Gather permutation of the stable per-node *d-way* partition
    (d = 2^width): every node's segment reorders to [digit-0 run | … |
    digit-(d−1) run], each run stable.

    The d-ary generalization of :func:`segmented_partition_gather` for
    the multiary trees (paper Theorem 4.4): d per-word SWAR field
    histograms replace the popcount pair and d prefix-sum columns replace
    zcum/ocum. Run-start marks live in a single length-n digit-major
    target space at *superword* granularity (full word granularity would
    scatter d·Wf = n·d/per indices — more marks than elements for d >
    per — while superwords keep the scatter at d·Wf/16 sorted indices);
    a ≤4-step branchless binary refine inside the superword finds the
    exact word, then a SWAR equality mask + in-word select finds the
    field. The d segmented prefix sums + (node, digit) histogram +
    n-element scatter of the baseline collapse into this one
    histogram-offset gather. ``plan`` optionally reuses
    ``packed_field_counts`` output shared with the directory builds.
    """
    d = 1 << width
    per = 32 // width
    packed, cntwd = plan if plan is not None else \
        packed_field_counts(digits, width, n)
    Wf = packed.shape[0]
    vcum = jnp.cumsum(cntwd, axis=0) - cntwd               # (Wf, d) excl.
    vflat = vcum.reshape(-1)
    totals = vcum[-1] + cntwd[-1]                          # (d,)
    dbase = jnp.cumsum(totals) - totals                    # (d,) excl.
    rank_at, cnt_node = field_node_counts(packed, cntwd, width,
                                          node_start, n)
    ndp = jnp.cumsum(cnt_node, axis=1) - cnt_node          # (V, d) excl.
    # per-position: node-local offset -> digit run -> global select target
    ns = node_start.astype(_I32)
    p = jnp.arange(n, dtype=_I32)
    v = nid.astype(_I32)
    offp = p - ns[v]
    dv = jnp.sum((offp[:, None] >= ndp[v]).astype(_I32), axis=1) - 1
    t = rank_at[v, dv] + offp - ndp[v, dv]
    # superword run-start marks in the digit-major target space
    S = _FIELDS_SUPERWORD
    wsup = (Wf + S - 1) // S
    vsup = vcum[::S]                                       # (wsup, d)
    sidx = jnp.arange(wsup, dtype=_I32)
    dvals = jnp.arange(d, dtype=_I32)
    marks = jnp.zeros((n,), _I32).at[
        (dbase[:, None] + vsup.T).reshape(-1)].max(
        (dvals[:, None] * wsup + sidx[None, :]).reshape(-1), mode="drop")
    cm = jax.lax.cummax(marks)
    ws = cm[dbase[dv] + t] - dv * wsup
    # refine: rightmost word in the superword with vcum[w, dv] <= t (ties
    # left of it are empty words)
    lo = ws * S
    hi = jnp.minimum(lo + (S - 1), Wf - 1)
    for _ in range(max(1, math.ceil(math.log2(S)))):
        mid = (lo + hi + 1) // 2
        go = vflat[mid * d + dv] <= t
        lo = jnp.where(go, mid, lo)
        hi = jnp.where(go, hi, mid - 1)
    w = lo
    r = t - vflat[w * d + dv]
    # r-th field equal to dv inside word w: SWAR mask + in-word select
    eqb = _field_eq_mask(packed[w], dv, width)
    return w * per + bitops.select_in_word(eqb, r) // width


def build_generalized_from_counts(packed: jax.Array, cntwd: jax.Array,
                                  width: int, n: int,
                                  chunk_syms: int = 128
                                  ) -> GeneralizedRankSelect:
    """``build_generalized`` from the shared word directory — the chunk
    histogram is a reshape-sum over ``cntwd`` instead of an n-element
    scatter. Bit-identical to :func:`build_generalized` on the same
    sequence.
    """
    per = 32 // width
    sigma = 1 << width
    assert chunk_syms % per == 0
    wpc = chunk_syms // per
    num_chunks = (n + chunk_syms - 1) // chunk_syms
    want_words = num_chunks * wpc
    if packed.shape[0] < want_words:
        packed = jnp.concatenate(
            [packed, jnp.zeros((want_words - packed.shape[0],), _U32)])
        cntwd = jnp.concatenate(
            [cntwd, jnp.zeros((want_words - cntwd.shape[0], sigma), _I32)])
    hist = jnp.sum(cntwd[:want_words].reshape(num_chunks, wpc, sigma),
                   axis=1)
    cum = jnp.concatenate([jnp.zeros((1, sigma), _I32),
                           jnp.cumsum(hist, axis=0)], axis=0)
    return GeneralizedRankSelect(packed=packed[:want_words], chunk_cum=cum,
                                 n=n, width=width, chunk_syms=chunk_syms)


def bitvector_bits(bv: BitVector) -> int:
    """Total storage in bits (bitmap + directories)."""
    return sum(l.size * l.dtype.itemsize * 8 for l in jax.tree.leaves(bv))


# --------------------------------------------------------------------------
# Generalized rank/select for small alphabets (paper Section 5.2)
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class GeneralizedRankSelect:
    """Rank/select over a sequence of ``width``-bit symbols, σ = 2^width.

    ``chunk_cum[k, c]`` = # of occurrences of symbol c strictly before chunk
    k (chunks of ``chunk_syms`` symbols). Queries finish inside one chunk by
    counting symbol hits with vectorized field compares on the packed words —
    the replacement for the paper's per-(block, character) lookup tables.
    """
    packed: jax.Array     # (num_words,) uint32, fields of `width` bits
    chunk_cum: jax.Array  # (num_chunks + 1, sigma) int32
    n: int = field(metadata=dict(static=True))
    width: int = field(metadata=dict(static=True))
    chunk_syms: int = field(metadata=dict(static=True))

    @property
    def sigma(self) -> int:
        return 1 << self.width


def build_generalized(seq: jax.Array, width: int, n: int,
                      chunk_syms: int = 128) -> GeneralizedRankSelect:
    """O(n·width/log n + n·σ/chunk)-work construction (paper Theorem 5.2).

    The paper's prefix sum with the "add two σ-count vectors" operator is a
    cumsum over the (chunks × σ) histogram matrix.
    """
    assert chunk_syms % (32 // width) == 0
    sigma = 1 << width
    packed = bitops.pack_fields(seq, width)
    num_chunks = (n + chunk_syms - 1) // chunk_syms
    # pad the packed words out to whole chunks so in-chunk slices are static
    want_words = num_chunks * (chunk_syms // (32 // width))
    if packed.shape[0] < want_words:
        packed = jnp.concatenate(
            [packed, jnp.zeros((want_words - packed.shape[0],), jnp.uint32)])
    pad = num_chunks * chunk_syms - n
    seq_p = jnp.concatenate([seq.astype(jnp.int32),
                             jnp.full((pad,), sigma, jnp.int32)])
    chunk_ids = jnp.arange(seq_p.shape[0], dtype=jnp.int32) // chunk_syms
    flat = chunk_ids * (sigma + 1) + seq_p
    hist = (jnp.zeros((num_chunks * (sigma + 1),), jnp.int32)
            .at[flat].add(1).reshape(num_chunks, sigma + 1)[:, :sigma])
    cum = jnp.concatenate([jnp.zeros((1, sigma), jnp.int32),
                           jnp.cumsum(hist, axis=0)], axis=0)
    return GeneralizedRankSelect(packed=packed, chunk_cum=cum, n=n,
                                 width=width, chunk_syms=chunk_syms)


def _count_symbol_in_words(words: jax.Array, c: jax.Array, width: int,
                           upto_fields: jax.Array) -> jax.Array:
    """# of fields equal to c among the first ``upto_fields`` fields.

    ``words``: (..., W) uint32; counts across the trailing word axis.
    Field-compare trick: XOR with the broadcast symbol and test each field
    for zero — O(1) vector ops per word in place of the paper's LUT.
    """
    per = 32 // width
    W = words.shape[-1]
    shifts = jnp.arange(per, dtype=_U32) * _U32(width)
    mask = _U32((1 << width) - 1)
    fields = (words[..., :, None] >> shifts) & mask            # (..., W, per)
    eq = (fields == c[..., None, None].astype(_U32))
    pos = (jnp.arange(W, dtype=jnp.int32)[:, None] * per
           + jnp.arange(per, dtype=jnp.int32)[None, :])        # (W, per)
    valid = pos < upto_fields[..., None, None]
    return jnp.sum(eq & valid, axis=(-1, -2)).astype(jnp.int32)


def generalized_rank(g: GeneralizedRankSelect, c: jax.Array,
                     i: jax.Array) -> jax.Array:
    """# of occurrences of symbol c in positions [0, i). Vectorized."""
    c = jnp.asarray(c, jnp.int32)
    i = jnp.asarray(i, jnp.int32)
    per = 32 // g.width
    wpc = g.chunk_syms // per                                   # words/chunk
    chunk = i // g.chunk_syms
    base = g.chunk_cum[chunk, c]
    w0 = chunk * wpc
    win = jax.vmap(lambda s: jax.lax.dynamic_slice(g.packed, (s,), (wpc,)))(
        jnp.atleast_1d(w0))
    win = win.reshape(i.shape + (wpc,)) if i.ndim else win[0]
    rem = i - chunk * g.chunk_syms
    return base + _count_symbol_in_words(win, c, g.width,
                                         jnp.asarray(rem, jnp.int32))


def generalized_access(g: GeneralizedRankSelect, i: jax.Array) -> jax.Array:
    i = jnp.asarray(i, jnp.int32)
    per = 32 // g.width
    w = i // per
    off = (i % per).astype(_U32) * _U32(g.width)
    mask = _U32((1 << g.width) - 1)
    return ((g.packed[w] >> off) & mask).astype(jnp.int32)


def generalized_select(g: GeneralizedRankSelect, c: jax.Array,
                       k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) occurrence of c. Vectorized.

    Binary search over chunk_cum[:, c], then a per-symbol scan within the
    chunk realized as a field-compare + prefix count. Out-of-range ``k``
    (≥ count of c, or c absent) returns a clamped position in [0, n);
    compare k against ``generalized_rank(g, c, n)`` to detect overflow.
    """
    c = jnp.asarray(c, jnp.int32)
    k = jnp.asarray(k, jnp.int32)
    col = g.chunk_cum[:, c] if c.ndim == 0 else jnp.take_along_axis(
        g.chunk_cum, c[None, :], axis=1).T  # (batch, chunks+1)
    if c.ndim == 0:
        chunk = jnp.searchsorted(col, k, side="right") - 1
    else:
        chunk = jax.vmap(lambda cc, kk: jnp.searchsorted(cc, kk, side="right") - 1)(col, k)
    chunk = jnp.clip(chunk, 0, g.chunk_cum.shape[0] - 2)
    per = 32 // g.width
    wpc = g.chunk_syms // per
    w0 = chunk * wpc
    win = jax.vmap(lambda s: jax.lax.dynamic_slice(g.packed, (s,), (wpc,)))(
        jnp.atleast_1d(w0))
    win = win.reshape(k.shape + (wpc,)) if k.ndim else win[0]
    # position within chunk of the (k - cum)-th occurrence of c
    residual = k - g.chunk_cum[chunk, c] if c.ndim == 0 else \
        k - jnp.take_along_axis(g.chunk_cum[chunk], c[:, None], axis=1)[:, 0]
    shifts = jnp.arange(per, dtype=_U32) * _U32(g.width)
    mask = _U32((1 << g.width) - 1)
    fields = (win[..., :, None] >> shifts) & mask
    eq = (fields == (c[..., None, None] if c.ndim else c).astype(_U32))
    eqf = eq.reshape(eq.shape[:-2] + (wpc * per,)).astype(jnp.int32)
    cum = jnp.cumsum(eqf, axis=-1)
    # first position with cum == residual+1
    hit = cum == (residual[..., None] if k.ndim else residual) + 1
    pos_in_chunk = jnp.argmax(hit, axis=-1)
    return jnp.clip(chunk * g.chunk_syms + pos_in_chunk, 0, g.n - 1)
