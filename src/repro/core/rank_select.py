"""Parallel construction of succinct rank/select structures (paper Section 5).

Binary rank follows Jacobson's two-level scheme: absolute ranks every
``SUPERBLOCK_WORDS`` words (uint32, 3.1% of the bitmap) plus superblock-
relative ranks every ``BLOCK_WORDS`` words (uint16, 12.5%), built with
popcounts + prefix sums in O(n/log n) work and O(log n) depth (Theorem 5.1).
Binary select follows Clark's sampling scheme: the *block* containing every
``sample_rate``-th 1 (resp. 0) is stored, and a query binary-searches only
between two consecutive samples — probing ranks *derived from the rank
directory in O(1)* rather than a stored prefix array, so select adds just
the sample hints (≈ 32/sample_rate bits per bit). Total directory overhead
is ~18% of the bitmap; the structures are succinct as in the paper.

The generalized (σ-ary) structures follow Section 5.2: per-chunk per-
character cumulative counts via a prefix sum whose operator adds σ-vectors
of counts.

TPU adaptation (DESIGN.md §2): every lookup table in the paper (rank-in-word,
select-in-word, count-symbol-in-word) is replaced with vector bit arithmetic —
``lax.population_count``, masked popcounts, and field-compare cascades. The
word-RAM O(1) query cost becomes O(1) vector ops per query; construction work
remains proportional to words, not bits.

All structures are frozen-dataclass pytrees: arrays are pytree leaves, sizes
are static metadata, so they can cross ``jax.jit`` boundaries freely.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import bitops
from .scan import exclusive_sum

_U32 = jnp.uint32
_I32 = jnp.int32

# Two-level rank geometry: a superblock covers 32 words = 1024 bits ≈ log²n
# (the paper's range size); a block covers 4 words = 128 bits (sub-range).
SUPERBLOCK_WORDS = 32
BLOCK_WORDS = 4
_BLOCKS_PER_SB = SUPERBLOCK_WORDS // BLOCK_WORDS
BLOCK_BITS = BLOCK_WORDS * bitops.WORD_BITS          # 128


# --------------------------------------------------------------------------
# Binary rank (Jacobson)
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BinaryRank:
    """Two-level rank directory over a packed bit sequence.

    ``superblock[k]`` = # of 1s strictly before word ``k*SUPERBLOCK_WORDS``;
    ``block[b]``      = # of 1s in b's superblock strictly before word
                        ``b*BLOCK_WORDS`` (≤ 28·32 < 2^16 → uint16).
    """
    words: jax.Array       # (num_words,) uint32 packed bits
    superblock: jax.Array  # (ceil(W/32),) uint32
    block: jax.Array       # (ceil(W/4),) uint16
    n: int = field(metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.block.shape[0]

    @property
    def total_ones(self) -> jax.Array:
        return rank1(self, jnp.int32(self.n))


def build_binary_rank(words: jax.Array, n: int) -> BinaryRank:
    """O(n/log n)-work, O(log n)-depth construction (paper Theorem 5.1).

    One popcount per word, one prefix sum, one subtraction — the parallel
    version of Jacobson's counting. ``words`` must be zero-padded past bit n.
    """
    prefix = bitops.word_prefix_popcount(words)                  # (W,) excl.
    superblock = prefix[::SUPERBLOCK_WORDS]
    blk_prefix = prefix[::BLOCK_WORDS]                           # (B,)
    nblk = blk_prefix.shape[0]
    sb_of_blk = jnp.arange(nblk, dtype=_I32) // _BLOCKS_PER_SB
    block = (blk_prefix - superblock[sb_of_blk]).astype(jnp.uint16)
    return BinaryRank(words=words, superblock=superblock, block=block, n=n)


def _rank_at_block_fast(rs: BinaryRank, b: jax.Array) -> jax.Array:
    """rank1 at a block boundary, b < num_blocks — two gathers, no popcount."""
    return (rs.superblock[b // _BLOCKS_PER_SB].astype(_I32)
            + rs.block[b].astype(_I32))


def rank_at_block(rs: BinaryRank, b: jax.Array) -> jax.Array:
    """# of 1 bits strictly before block b — O(1) from the directory."""
    b = jnp.asarray(b, _I32)
    bc = jnp.minimum(b, rs.num_blocks - 1)
    base = _rank_at_block_fast(rs, bc)
    # b may equal num_blocks (one-past-the-end): clamp to total by adding
    # the popcount of the final block.
    over = jnp.sum(bitops.popcount(_block_words(rs, bc)), axis=-1).astype(_I32)
    return jnp.where(b > bc, base + over, base)


def _block_words(rs: BinaryRank, b: jax.Array) -> jax.Array:
    """Gather the BLOCK_WORDS words of block b (clipped). b: (...,)."""
    w0 = jnp.asarray(b, _I32) * BLOCK_WORDS
    idx = w0[..., None] + jnp.arange(BLOCK_WORDS, dtype=_I32)
    idx = jnp.minimum(idx, rs.words.shape[0] - 1)
    valid = (w0[..., None] + jnp.arange(BLOCK_WORDS, dtype=_I32)
             < rs.words.shape[0])
    return jnp.where(valid, rs.words[idx], _U32(0))


def rank1(rs: BinaryRank, i: jax.Array) -> jax.Array:
    """# of 1 bits in positions [0, i). Vectorized over ``i``.

    superblock + block + ≤3 whole-word popcounts + 1 masked popcount —
    the paper's two lookups realized as vector bit ops.
    """
    i = jnp.asarray(i, _I32)
    w = i // bitops.WORD_BITS
    b = w // BLOCK_WORDS
    bc = jnp.minimum(b, rs.num_blocks - 1)
    base = (rs.superblock[bc // _BLOCKS_PER_SB].astype(_I32)
            + rs.block[bc].astype(_I32))
    words4 = _block_words(rs, bc)                       # (..., 4)
    j = jnp.arange(BLOCK_WORDS, dtype=_I32)
    wpos = bc[..., None] * BLOCK_WORDS + j
    off_in_word = (i - w * bitops.WORD_BITS).astype(_U32)
    full = (wpos < w[..., None])
    part = (wpos == w[..., None])
    cnt = jnp.where(
        full, bitops.popcount(words4).astype(_I32),
        jnp.where(part,
                  bitops.rank1_word(words4,
                                    off_in_word[..., None]).astype(_I32),
                  0))
    return base + jnp.sum(cnt, axis=-1)


def rank0(rs: BinaryRank, i: jax.Array) -> jax.Array:
    i = jnp.asarray(i, _I32)
    return i - rank1(rs, i)


def access_bit(rs: BinaryRank, i: jax.Array) -> jax.Array:
    i = jnp.asarray(i, _I32)
    w = i // bitops.WORD_BITS
    off = (i % bitops.WORD_BITS).astype(_U32)
    return ((rs.words[w] >> off) & _U32(1)).astype(_I32)


# --------------------------------------------------------------------------
# Binary select (Clark-style sampling over the rank directory)
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BinarySelect:
    """Sampled select hints: ``sample[j]`` = block index containing the
    (j·sample_rate)-th target bit. Queries search only between consecutive
    samples, probing block-boundary ranks derived from the rank directory."""
    sample: jax.Array       # (num_samples,) int32 block hints
    n: int = field(metadata=dict(static=True))
    sample_rate: int = field(metadata=dict(static=True))
    zeros: bool = field(metadata=dict(static=True))  # select0 directory?


def build_binary_select(words: jax.Array, n: int,
                        sample_rate: int = 512,
                        zeros: bool = False) -> BinarySelect:
    """O(n/log n)-work construction (Theorem 5.1): block popcounts + one
    prefix sum + a vectorized searchsorted per sample (the paper's "identify
    the half-words containing every k-th 1 bit")."""
    W = words.shape[0]
    nblk = (W + BLOCK_WORDS - 1) // BLOCK_WORDS
    pad = nblk * BLOCK_WORDS - W
    wp = jnp.concatenate([words, jnp.zeros((pad,), _U32)]) if pad else words
    ones = jnp.sum(bitops.popcount(wp.reshape(nblk, BLOCK_WORDS)),
                   axis=1).astype(_I32)
    if zeros:
        valid = jnp.clip(n - jnp.arange(nblk, dtype=_I32) * BLOCK_BITS,
                         0, BLOCK_BITS)
        counts = valid - ones
    else:
        counts = ones
    cum = jnp.concatenate([jnp.zeros((1,), _I32), jnp.cumsum(counts)])
    # +2: any valid k has both bracketing samples (targets past the last
    # occurrence clip to the final block → hi = nblk is a safe upper bound)
    num_samples = n // sample_rate + 2
    targets = jnp.arange(num_samples, dtype=_I32) * _I32(sample_rate)
    sample = jnp.clip(jnp.searchsorted(cum, targets, side="right") - 1,
                      0, nblk - 1).astype(_I32)
    return BinarySelect(sample=sample, n=n, sample_rate=sample_rate,
                        zeros=zeros)


def _zero_rank_at_block(rs: BinaryRank, b: jax.Array) -> jax.Array:
    b = jnp.asarray(b, _I32)
    pos = jnp.minimum(b * BLOCK_BITS, rs.n)
    return pos - rank_at_block(rs, b)


def _zero_rank_at_block_fast(rs: BinaryRank, b: jax.Array) -> jax.Array:
    pos = jnp.minimum(b * BLOCK_BITS, rs.n)
    return pos - _rank_at_block_fast(rs, b)


def _select_search(rs: BinaryRank, sel: BinarySelect,
                   k: jax.Array) -> jax.Array:
    """Largest block b in [sample[j], sample[j+1]] with rank(b) <= k.

    The search invariant keeps mid < num_blocks, so every probe uses the
    two-gather fast boundary rank (no per-probe popcounts)."""
    k = jnp.asarray(k, _I32)
    j = k // sel.sample_rate
    lo = sel.sample[j]
    hi = sel.sample[jnp.minimum(j + 1, sel.sample.shape[0] - 1)] + 1
    hi = jnp.maximum(hi, lo + 1)
    steps = max(1, math.ceil(math.log2(rs.num_blocks + 1)))
    probe = _zero_rank_at_block_fast if sel.zeros else _rank_at_block_fast
    for _ in range(steps):
        mid = (lo + hi) // 2
        go_right = probe(rs, mid) <= k
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        hi = jnp.maximum(hi, lo)
    return lo


def _select_in_block(rs: BinaryRank, b: jax.Array, cnt: jax.Array,
                     zeros: bool) -> jax.Array:
    """Position of the cnt-th target bit inside block b (cnt block-local)."""
    words4 = _block_words(rs, b)                         # (..., 4)
    if zeros:
        words4 = ~words4                                 # padding→1s is fine:
        # a valid query's target lies before the padding region
    pc = bitops.popcount(words4).astype(_I32)
    excl = jnp.cumsum(pc, axis=-1) - pc                  # (..., 4) exclusive
    in_this = (excl <= cnt[..., None]) & \
              (cnt[..., None] < excl + pc)
    wsel = jnp.argmax(in_this, axis=-1)                  # word within block
    word = jnp.take_along_axis(words4, wsel[..., None], axis=-1)[..., 0]
    base = jnp.take_along_axis(excl, wsel[..., None], axis=-1)[..., 0]
    within = bitops.select_in_word(word, cnt - base)
    return (b * BLOCK_WORDS + wsel) * bitops.WORD_BITS + within


def select1(rs: BinaryRank, sel: BinarySelect, k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) 1 bit. Vectorized over ``k``."""
    k = jnp.asarray(k, _I32)
    b = _select_search(rs, sel, k)
    return _select_in_block(rs, b, k - _rank_at_block_fast(rs, b),
                            zeros=False)


def select0(rs: BinaryRank, sel0: BinarySelect, k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) 0 bit."""
    k = jnp.asarray(k, _I32)
    b = _select_search(rs, sel0, k)
    return _select_in_block(rs, b, k - _zero_rank_at_block_fast(rs, b),
                            zeros=True)


def invert_words(words: jax.Array, n: int) -> jax.Array:
    """~words with the padding tail (bits ≥ n) forced back to 0."""
    inv = ~words
    w = words.shape[0]
    last = bitops.num_words(n) - 1
    tail = n - last * bitops.WORD_BITS
    idx = jnp.arange(w)
    tail_mask = bitops.mask_below(jnp.uint32(tail))
    inv = jnp.where(idx == last, inv & tail_mask, inv)
    inv = jnp.where(idx > last, jnp.uint32(0), inv)
    return inv


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BitVector:
    """Packed bits + rank + select1/select0 — what a wavelet node stores."""
    rank: BinaryRank
    sel1: BinarySelect
    sel0: BinarySelect


def build_bitvector(words: jax.Array, n: int,
                    sample_rate: int = 512) -> BitVector:
    rank = build_binary_rank(words, n)
    sel1 = build_binary_select(words, n, sample_rate, zeros=False)
    sel0 = build_binary_select(words, n, sample_rate, zeros=True)
    return BitVector(rank=rank, sel1=sel1, sel0=sel0)


def build_bitvector_levels(words: jax.Array, n: int,
                           sample_rate: int = 512,
                           use_kernels: bool = False,
                           interpret: bool | None = None) -> BitVector:
    """Batched directory build over stacked level bitmaps (fast-path form).

    ``words``: (L, W) — one packed n-bit bitmap per row. Builds the rank
    superblock/block tables and both select sample directories for every
    level in one vmapped/fused launch group instead of L separate
    ``build_bitvector`` calls, and returns a ``BitVector`` whose leaves all
    carry the leading (L,) axis — the exact stacked layout ``WaveletMatrix``
    stores. Bit-identical to stacking per-level ``build_bitvector`` results.

    ``use_kernels`` routes the rank tables through the Pallas
    ``rank_build_levels`` kernel (one launch for all levels, paper Theorem
    5.1); the select samples stay XLA (they are O(W) per level).
    """
    if use_kernels:
        from repro.kernels import ops as _kops
        superblock, block = _kops.rank_build_levels(words, n,
                                                    interpret=interpret)
        rank = BinaryRank(words=words, superblock=superblock, block=block,
                          n=n)
    else:
        rank = jax.vmap(lambda w: build_binary_rank(w, n))(words)
    sel1 = jax.vmap(
        lambda w: build_binary_select(w, n, sample_rate, zeros=False))(words)
    sel0 = jax.vmap(
        lambda w: build_binary_select(w, n, sample_rate, zeros=True))(words)
    return BitVector(rank=rank, sel1=sel1, sel0=sel0)


def stable_partition_gather(words: jax.Array, total_zeros: jax.Array,
                            n: int) -> jax.Array:
    """Gather permutation of the stable 0/1 partition, via select (no sort,
    no scatter of n elements).

    ``words``: the packed n-bit partition-flag bitmap (padding bits past n
    must be 0); ``total_zeros``: number of 0 flags. Returns ``g`` (n,) int32
    with ``g[p]`` = source index of the element that lands at position p —
    i.e. ``out = x[g]`` realizes the partition (zeros first, ones after,
    both in original order).

    This is the construction-side payoff of the paper's Section 5 select
    structures: position p takes element ``select0(p)`` (or
    ``select1(p - Z)``), so the whole permutation is one word-granularity
    select directory — per-word popcounts + two prefix sums (O(n/log n)
    work, Theorem 5.1), run starts scattered at *word* granularity
    (O(n/log n) indices), a running max to assign each position its word,
    and a branchless in-word select. Everything past the tiny run-start
    scatter is vectorized gathers/arithmetic, which is why this formulation
    beats the scatter-based inverse permutation on CPU/TPU backends where
    n-element scatters serialize.
    """
    W = words.shape[0]
    pc = bitops.popcount(words).astype(_I32)                  # ones per word
    valid = jnp.clip(n - jnp.arange(W, dtype=_I32) * bitops.WORD_BITS,
                     0, bitops.WORD_BITS)
    zc = valid - pc                                           # zeros (no pad)
    zcum = jnp.cumsum(zc) - zc                                # exclusive
    ocum = jnp.cumsum(pc) - pc
    Z = jnp.asarray(total_zeros, _I32)
    # Mark the output start of every word's zero-run and one-run, then a
    # running max assigns each output position the word that feeds it
    # (empty runs are superseded by the next run sharing their start).
    wid = jnp.arange(W, dtype=_I32)
    marks = jnp.zeros((n,), _I32)
    marks = marks.at[zcum].max(wid, mode="drop")
    marks = marks.at[Z + ocum].max(W + wid, mode="drop")
    cm = jax.lax.cummax(marks)
    p = jnp.arange(n, dtype=_I32)
    is_one = p >= Z
    w = jnp.where(is_one, cm - W, cm)
    r = jnp.where(is_one, p - Z - ocum[w], p - zcum[w])       # rank in word
    word = words[w]
    # zeros half selects in the complemented word; padding bits sit past
    # every valid zero, so r always lands on a real bit
    wsel = jnp.where(is_one, word, ~word)
    return w * bitops.WORD_BITS + bitops.select_in_word(wsel, r)


def bitvector_bits(bv: BitVector) -> int:
    """Total storage in bits (bitmap + directories)."""
    return sum(l.size * l.dtype.itemsize * 8 for l in jax.tree.leaves(bv))


# --------------------------------------------------------------------------
# Generalized rank/select for small alphabets (paper Section 5.2)
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class GeneralizedRankSelect:
    """Rank/select over a sequence of ``width``-bit symbols, σ = 2^width.

    ``chunk_cum[k, c]`` = # of occurrences of symbol c strictly before chunk
    k (chunks of ``chunk_syms`` symbols). Queries finish inside one chunk by
    counting symbol hits with vectorized field compares on the packed words —
    the replacement for the paper's per-(block, character) lookup tables.
    """
    packed: jax.Array     # (num_words,) uint32, fields of `width` bits
    chunk_cum: jax.Array  # (num_chunks + 1, sigma) int32
    n: int = field(metadata=dict(static=True))
    width: int = field(metadata=dict(static=True))
    chunk_syms: int = field(metadata=dict(static=True))

    @property
    def sigma(self) -> int:
        return 1 << self.width


def build_generalized(seq: jax.Array, width: int, n: int,
                      chunk_syms: int = 128) -> GeneralizedRankSelect:
    """O(n·width/log n + n·σ/chunk)-work construction (paper Theorem 5.2).

    The paper's prefix sum with the "add two σ-count vectors" operator is a
    cumsum over the (chunks × σ) histogram matrix.
    """
    assert chunk_syms % (32 // width) == 0
    sigma = 1 << width
    packed = bitops.pack_fields(seq, width)
    num_chunks = (n + chunk_syms - 1) // chunk_syms
    # pad the packed words out to whole chunks so in-chunk slices are static
    want_words = num_chunks * (chunk_syms // (32 // width))
    if packed.shape[0] < want_words:
        packed = jnp.concatenate(
            [packed, jnp.zeros((want_words - packed.shape[0],), jnp.uint32)])
    pad = num_chunks * chunk_syms - n
    seq_p = jnp.concatenate([seq.astype(jnp.int32),
                             jnp.full((pad,), sigma, jnp.int32)])
    chunk_ids = jnp.arange(seq_p.shape[0], dtype=jnp.int32) // chunk_syms
    flat = chunk_ids * (sigma + 1) + seq_p
    hist = (jnp.zeros((num_chunks * (sigma + 1),), jnp.int32)
            .at[flat].add(1).reshape(num_chunks, sigma + 1)[:, :sigma])
    cum = jnp.concatenate([jnp.zeros((1, sigma), jnp.int32),
                           jnp.cumsum(hist, axis=0)], axis=0)
    return GeneralizedRankSelect(packed=packed, chunk_cum=cum, n=n,
                                 width=width, chunk_syms=chunk_syms)


def _count_symbol_in_words(words: jax.Array, c: jax.Array, width: int,
                           upto_fields: jax.Array) -> jax.Array:
    """# of fields equal to c among the first ``upto_fields`` fields.

    ``words``: (..., W) uint32; counts across the trailing word axis.
    Field-compare trick: XOR with the broadcast symbol and test each field
    for zero — O(1) vector ops per word in place of the paper's LUT.
    """
    per = 32 // width
    W = words.shape[-1]
    shifts = jnp.arange(per, dtype=_U32) * _U32(width)
    mask = _U32((1 << width) - 1)
    fields = (words[..., :, None] >> shifts) & mask            # (..., W, per)
    eq = (fields == c[..., None, None].astype(_U32))
    pos = (jnp.arange(W, dtype=jnp.int32)[:, None] * per
           + jnp.arange(per, dtype=jnp.int32)[None, :])        # (W, per)
    valid = pos < upto_fields[..., None, None]
    return jnp.sum(eq & valid, axis=(-1, -2)).astype(jnp.int32)


def generalized_rank(g: GeneralizedRankSelect, c: jax.Array,
                     i: jax.Array) -> jax.Array:
    """# of occurrences of symbol c in positions [0, i). Vectorized."""
    c = jnp.asarray(c, jnp.int32)
    i = jnp.asarray(i, jnp.int32)
    per = 32 // g.width
    wpc = g.chunk_syms // per                                   # words/chunk
    chunk = i // g.chunk_syms
    base = g.chunk_cum[chunk, c]
    w0 = chunk * wpc
    win = jax.vmap(lambda s: jax.lax.dynamic_slice(g.packed, (s,), (wpc,)))(
        jnp.atleast_1d(w0))
    win = win.reshape(i.shape + (wpc,)) if i.ndim else win[0]
    rem = i - chunk * g.chunk_syms
    return base + _count_symbol_in_words(win, c, g.width,
                                         jnp.asarray(rem, jnp.int32))


def generalized_access(g: GeneralizedRankSelect, i: jax.Array) -> jax.Array:
    i = jnp.asarray(i, jnp.int32)
    per = 32 // g.width
    w = i // per
    off = (i % per).astype(_U32) * _U32(g.width)
    mask = _U32((1 << g.width) - 1)
    return ((g.packed[w] >> off) & mask).astype(jnp.int32)


def generalized_select(g: GeneralizedRankSelect, c: jax.Array,
                       k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) occurrence of c. Vectorized.

    Binary search over chunk_cum[:, c], then a per-symbol scan within the
    chunk realized as a field-compare + prefix count. Out-of-range ``k``
    (≥ count of c, or c absent) returns a clamped position in [0, n);
    compare k against ``generalized_rank(g, c, n)`` to detect overflow.
    """
    c = jnp.asarray(c, jnp.int32)
    k = jnp.asarray(k, jnp.int32)
    col = g.chunk_cum[:, c] if c.ndim == 0 else jnp.take_along_axis(
        g.chunk_cum, c[None, :], axis=1).T  # (batch, chunks+1)
    if c.ndim == 0:
        chunk = jnp.searchsorted(col, k, side="right") - 1
    else:
        chunk = jax.vmap(lambda cc, kk: jnp.searchsorted(cc, kk, side="right") - 1)(col, k)
    chunk = jnp.clip(chunk, 0, g.chunk_cum.shape[0] - 2)
    per = 32 // g.width
    wpc = g.chunk_syms // per
    w0 = chunk * wpc
    win = jax.vmap(lambda s: jax.lax.dynamic_slice(g.packed, (s,), (wpc,)))(
        jnp.atleast_1d(w0))
    win = win.reshape(k.shape + (wpc,)) if k.ndim else win[0]
    # position within chunk of the (k - cum)-th occurrence of c
    residual = k - g.chunk_cum[chunk, c] if c.ndim == 0 else \
        k - jnp.take_along_axis(g.chunk_cum[chunk], c[:, None], axis=1)[:, 0]
    shifts = jnp.arange(per, dtype=_U32) * _U32(g.width)
    mask = _U32((1 << g.width) - 1)
    fields = (win[..., :, None] >> shifts) & mask
    eq = (fields == (c[..., None, None] if c.ndim else c).astype(_U32))
    eqf = eq.reshape(eq.shape[:-2] + (wpc * per,)).astype(jnp.int32)
    cum = jnp.cumsum(eqf, axis=-1)
    # first position with cum == residual+1
    hit = cum == (residual[..., None] if k.ndim else residual) + 1
    pos_in_chunk = jnp.argmax(hit, axis=-1)
    return jnp.clip(chunk * g.chunk_syms + pos_in_chunk, 0, g.n - 1)
