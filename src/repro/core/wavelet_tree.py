"""Parallel wavelet tree construction (paper Section 4, Theorems 4.1–4.2).

Levelwise layout: level l stores one n-bit bitmap — the concatenation of all
node bitmaps at depth l, with the sequence stably sorted by the top-l bits of
each symbol (so node bitmaps are contiguous). ``node_starts[l][v]`` gives the
offset of node v (= a top-l-bit prefix) in that bitmap.

Three constructions, mirroring the paper's Table 1 rows:

* ``build_wavelet_tree``            — the τ-chunked sort-based algorithm
  (Theorem 4.1). Big-node levels every τ are produced by a stable integer
  sort of the full-width symbols; in-between levels operate on narrow
  ("short list") τ-bit keys with *node-segmented* stable partitions built
  from prefix sums. ``big_step`` chooses compose/radix/xla as in the
  wavelet matrix (see wavelet_matrix.py docstring).
* ``build_wavelet_tree_levelwise``  — prior-work baseline [Shun'15]:
  O(n logσ) work, full symbols reshuffled every level.
* ``build_wavelet_tree_dd``         — the domain-decomposition algorithm
  (Theorem 4.2): split into P chunks, build P trees in parallel (vmap), and
  merge per-node bitmaps with cross-chunk prefix-sum offsets.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp

from . import bitops
from .rank_select import (BitVector, access_bit, build_bitvector,
                          build_bitvector_levels, rank0, rank1,
                          segmented_partition_gather, select0, select1)
from .scan import (apply_permutation_dest, exclusive_sum,
                   segment_ids_from_starts, segmented_exclusive_sum)
from .sort import _invert_permutation, counting_rank, sort_pass
from .wavelet_matrix import default_use_kernels, num_levels

_U32 = jnp.uint32
_I32 = jnp.int32


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class WaveletTree:
    """Levelwise wavelet tree with per-level bitvectors + node offsets.

    ``node_starts`` has shape (nbits+1, 2**nbits): row l holds the starting
    offset of every depth-l node (only the first 2**l entries are
    meaningful); row nbits is the leaf (symbol) offset table — the C array.
    """
    bitvectors: BitVector    # every leaf carries a leading (nbits,) axis
    node_starts: jax.Array   # (nbits+1, 2**nbits) int32
    n: int = field(metadata=dict(static=True))
    nbits: int = field(metadata=dict(static=True))

    def level(self, l: int) -> BitVector:
        return jax.tree.map(lambda x: x[l], self.bitvectors)


def _node_starts_from_symbols(seq: jax.Array, nbits: int) -> jax.Array:
    """Offsets of every node at every level, from symbol counts alone.

    Node v at level l covers symbols [v<<(nbits-l), (v+1)<<(nbits-l)); its
    start is the count of smaller symbols — one histogram + one prefix sum
    (O(n + σ·logσ) work, O(log n) depth).
    """
    size = 1 << nbits
    hist = jnp.zeros((size,), _I32).at[seq.astype(_I32)].add(1, mode="drop")
    leaf_starts = exclusive_sum(hist)
    rows = [leaf_starts]
    for l in range(nbits - 1, -1, -1):
        width = 1 << (nbits - l)
        starts_l = leaf_starts[::width]                  # (2**l,)
        pad = jnp.zeros((size - starts_l.shape[0],), _I32)
        rows.append(jnp.concatenate([starts_l, pad]))
    rows.reverse()
    return jnp.stack(rows)                               # (nbits+1, size)


def _finalize(level_words: List[jax.Array], node_starts: jax.Array,
              n: int, nbits: int, sample_rate: int) -> WaveletTree:
    bvs = [build_bitvector(w, n, sample_rate) for w in level_words]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *bvs)
    return WaveletTree(bitvectors=stacked, node_starts=node_starts,
                       n=n, nbits=nbits)


def _finalize_fused(level_words: List[jax.Array], node_starts: jax.Array,
                    n: int, nbits: int, sample_rate: int,
                    use_kernels: bool = False) -> WaveletTree:
    """All nbits rank/select directories in one batched launch group —
    bit-identical to :func:`_finalize` (see build_bitvector_levels)."""
    stacked = build_bitvector_levels(jnp.stack(level_words), n, sample_rate,
                                     use_kernels=use_kernels)
    return WaveletTree(bitvectors=stacked, node_starts=node_starts,
                       n=n, nbits=nbits)


def _level_nid(node_starts: jax.Array, l: int, n: int) -> jax.Array:
    """Node id of every position at level l, from the offset table alone.

    After the level-(l−1) split the sequence is sorted by its top l bits,
    so membership is determined by the precomputed ``node_starts`` row —
    no per-element state needs to ride along the partitions.
    """
    if l == 0:
        return jnp.zeros((n,), _I32)
    return segment_ids_from_starts(node_starts[l][:1 << l], n)


def _pack_level(bit: jax.Array) -> jax.Array:
    return bitops.pack_bits(bitops.pad_bits(bit.astype(jnp.uint8)))


def _segmented_partition_dest(nid: jax.Array, bit: jax.Array,
                              level_plus1_bits: int) -> jax.Array:
    """Destination of each element under a stable per-node 0/1 partition.

    ``nid`` is the node id of each element (elements already grouped by
    node), ``bit`` the partition bit. Built from two segmented prefix sums
    plus a (node,bit) histogram — the paper's short-list split, with the
    packed-list table lookups replaced by scans (DESIGN.md §2).
    """
    n = nid.shape[0]
    key = (nid.astype(_I32) << 1) | bit.astype(_I32)
    nbuckets = 1 << level_plus1_bits
    hist = jnp.zeros((nbuckets,), _I32).at[key].add(1, mode="drop")
    key_start = exclusive_sum(hist)
    seg_start = jnp.concatenate([jnp.ones((1,), _I32),
                                 (nid[1:] != nid[:-1]).astype(_I32)])
    zeros_before = segmented_exclusive_sum(1 - bit.astype(_I32), seg_start)
    ones_before = segmented_exclusive_sum(bit.astype(_I32), seg_start)
    rank_within = jnp.where(bit == 0, zeros_before, ones_before)
    return key_start[key] + rank_within


def build_wavelet_tree(seq: jax.Array, sigma: int, tau: int = 8,
                       big_step: str = "compose",
                       sample_rate: int = 512,
                       fused: bool = True,
                       use_kernels: bool | None = None) -> WaveletTree:
    """τ-chunked sort-based construction (paper Theorem 4.1).

    ``fused=True`` (default) is the segmented select-gather fast path:
    each node-segmented stable partition is applied as a *gather* whose
    permutation comes from one word-granularity select directory
    (``rank_select.segmented_partition_gather``), node membership is
    re-derived per level from the precomputed ``node_starts`` table
    (a run-start mark + running max instead of a carried nid array), the
    composed permutation only materializes when a compose big step will
    consume it, and all nbits rank/select directories build in one
    batched launch group. ``fused=False`` keeps the historical scatter
    path (histogram + segmented scans + n-element inverse-permutation
    scatters) as the benchmark baseline. Outputs are bit-identical.

    ``use_kernels`` routes shallow levels (2^(l+1) key buckets within the
    ``kernels.wt_level`` VMEM bound) through the fused Pallas segmented
    level step and the directory builds through ``kernels.rank_build``;
    ``None`` auto-enables on TPU with the same BatchTracer guard as
    ``build_wavelet_matrix``.
    """
    from repro import obs
    if use_kernels is None:
        use_kernels = default_use_kernels(seq)
    obs.counter("core.build", builder="wt",
                path="fused" if fused else "scatter").inc()
    if not fused:
        return _build_wavelet_tree_steps(seq, sigma, tau, big_step,
                                         sample_rate)

    n = int(seq.shape[0])
    nbits = num_levels(sigma)
    node_starts = _node_starts_from_symbols(seq, nbits)
    order = seq.astype(_U32)
    level_words: List[jax.Array] = []

    for alpha0 in range(0, nbits, tau):
        width = min(tau, nbits - alpha0)
        fld = bitops.extract_field(order, jnp.uint32(nbits - alpha0 - width),
                                   width)
        sub = fld
        last_chunk = alpha0 + width >= nbits
        need_idx = (not last_chunk) and big_step == "compose"
        idx = jnp.arange(n, dtype=_I32) if need_idx else None
        for t in range(width):
            l = alpha0 + t
            shift = width - 1 - t
            last_level = l == nbits - 1
            # Movement only arranges the *next* level; at the chunk's last
            # level only a compose big step still consumes the permutation
            # (radix/xla re-sort from the chunk-start order).
            move = (not last_level) and (t < width - 1 or need_idx)
            words = None
            if move:
                nid = _level_nid(node_starts, l, n)
                kernel_ok = _wt_kernel_fits(l)
                if use_kernels and not kernel_ok:
                    # deep level: 2^(l+1) buckets exceed the wt_level VMEM
                    # bound — the gap ROADMAP item 4's deep-level kernel
                    # will close; count it so profiles show the fallback
                    obs.counter("core.wt_deep_fallback", level=l).inc()
                obs.counter("core.level_step", builder="wt",
                            impl="kernel" if use_kernels and kernel_ok
                            else "xla").inc()
                if use_kernels and kernel_ok:
                    from repro.kernels import ops as _kops
                    dest, words = _kops.wt_level_step_fused(
                        sub, nid, shift, 1 << (l + 1), n)
                    if t < width - 1:
                        sub = apply_permutation_dest(sub, dest)
                    if need_idx:
                        idx = apply_permutation_dest(idx, dest)
                else:
                    bit = ((sub >> _U32(shift)) & _U32(1)).astype(_I32)
                    words = _pack_level(bit)
                    g = segmented_partition_gather(
                        words, nid, node_starts[l][:1 << l], n)
                    if t < width - 1:
                        sub = sub[g]
                    if need_idx:
                        idx = idx[g]
            if words is None:
                bit = ((sub >> _U32(shift)) & _U32(1)).astype(_I32)
                words = _pack_level(bit)
            level_words.append(words)
        if not last_chunk:
            if big_step == "compose":
                order = order[idx]
            else:
                order = _tree_big_step(order, nbits, alpha0 + width,
                                       big_step)

    return _finalize_fused(level_words, node_starts, n, nbits, sample_rate,
                           use_kernels=use_kernels)


def _wt_kernel_fits(l: int) -> bool:
    from repro.kernels import wt_level as _wtk
    return (1 << (l + 1)) <= _wtk.MAX_KEYS


def _tree_big_step(order: jax.Array, nbits: int, consumed: int,
                   big_step: str) -> jax.Array:
    """One stable counting/XLA sort keyed on the top ``consumed`` bits —
    globally a sort by (node, next τ bits)."""
    key = (order >> _U32(nbits - consumed)).astype(_I32)
    if big_step == "radix":
        order, _ = sort_pass(order, key, 1 << consumed, backend="counting")
        return order
    if big_step == "xla":
        _, order = jax.lax.sort((key, order), num_keys=1, is_stable=True)
        return order
    raise ValueError(f"unknown big_step {big_step!r}")


def _build_wavelet_tree_steps(seq: jax.Array, sigma: int, tau: int = 8,
                              big_step: str = "compose",
                              sample_rate: int = 512) -> WaveletTree:
    """Historical step-by-step scatter realization of Theorem 4.1
    (benchmark baseline for the fused fast path)."""
    n = int(seq.shape[0])
    nbits = num_levels(sigma)
    node_starts = _node_starts_from_symbols(seq, nbits)
    order = seq.astype(_U32)
    level_words: List[jax.Array] = []

    for alpha0 in range(0, nbits, tau):
        width = min(tau, nbits - alpha0)
        fld = bitops.extract_field(order, jnp.uint32(nbits - alpha0 - width),
                                   width)
        nid = (order >> _U32(nbits - alpha0)).astype(_I32) if alpha0 else \
            jnp.zeros((n,), _I32)
        sub = fld
        perm = None
        for t in range(width):
            bit = ((sub >> _U32(width - 1 - t)) & _U32(1)).astype(_I32)
            level_words.append(_pack_level(bit))
            last_level = (alpha0 + t == nbits - 1)
            if not last_level:
                dest = _segmented_partition_dest(nid, bit, alpha0 + t + 1)
                g = _invert_permutation(dest)
                sub = sub[g]
                nid = ((nid << 1) | bit)[g]
                perm = g if perm is None else perm[g]
        if alpha0 + width < nbits:
            if big_step == "compose":
                order = order[perm]
            elif big_step in ("radix", "xla"):
                # one stable counting sort keyed on (node, next τ bits) —
                # globally this is a sort by the top (α+1)τ bits.
                key = (order >> _U32(nbits - alpha0 - width)).astype(_I32)
                if big_step == "radix":
                    dest = counting_rank(key, 1 << (alpha0 + width))
                    order = order[_invert_permutation(dest)]
                else:
                    _, order = jax.lax.sort((key, order), num_keys=1,
                                            is_stable=True)
            else:
                raise ValueError(f"unknown big_step {big_step!r}")

    return _finalize(level_words, node_starts, n, nbits, sample_rate)


def build_wavelet_tree_levelwise(seq: jax.Array, sigma: int,
                                 sample_rate: int = 512,
                                 fused: bool = True) -> WaveletTree:
    """Prior-work baseline [Shun'15]: O(n·logσ) work.

    ``fused=True`` applies each level's node-segmented partition as a
    select-gather (full-width symbols still move every level — the
    baseline's work bound is unchanged, only the scatter is gone).
    """
    from repro import obs
    obs.counter("core.build", builder="wt_levelwise",
                path="fused" if fused else "scatter").inc()
    n = int(seq.shape[0])
    nbits = num_levels(sigma)
    node_starts = _node_starts_from_symbols(seq, nbits)
    order = seq.astype(_U32)
    level_words = []
    for l in range(nbits):
        bit = ((order >> _U32(nbits - 1 - l)) & _U32(1)).astype(_I32)
        words = _pack_level(bit)
        level_words.append(words)
        if l < nbits - 1:
            if fused:
                nid = _level_nid(node_starts, l, n)
                g = segmented_partition_gather(
                    words, nid, node_starts[l][:1 << l], n)
                order = order[g]
            else:
                nid = (order >> _U32(nbits - l)).astype(_I32) if l else \
                    jnp.zeros((n,), _I32)
                dest = _segmented_partition_dest(nid, bit, l + 1)
                order = order[_invert_permutation(dest)]
    if fused:
        return _finalize_fused(level_words, node_starts, n, nbits,
                               sample_rate)
    return _finalize(level_words, node_starts, n, nbits, sample_rate)


# --------------------------------------------------------------------------
# Domain decomposition (paper Theorem 4.2)
# --------------------------------------------------------------------------

def build_wavelet_tree_dd(seq: jax.Array, sigma: int, num_chunks: int,
                          sample_rate: int = 512,
                          fused: bool = True) -> WaveletTree:
    """Domain-decomposition construction.

    The P per-chunk builds run under ``vmap`` (the paper's "P processors");
    the merge computes, for every (level, chunk, node), the destination
    offset ``global_node_start + Σ_{c'<c} len(c', node) + within`` with one
    cross-chunk prefix sum per level. ``fused=True`` (default) realizes
    both phases scatter-free: the per-chunk splits are segmented
    select-gathers (per-chunk node offsets sliced from one chunk
    histogram), and the merge becomes a *gather* — every (node, chunk)
    pair is one output run whose start is ``global_node_start[v] +
    across[c, v]``, so a run-start mark + running max assigns each output
    position its source chunk/offset directly (the paper's word-granular
    copy, with the boundary-word bookkeeping replaced by the mark trick).
    ``fused=False`` keeps the historical element-granular scatter merge.
    """
    from repro import obs
    obs.counter("core.build", builder="wt_dd",
                path="fused" if fused else "scatter").inc()
    n = int(seq.shape[0])
    assert n % num_chunks == 0, "pad the sequence to a multiple of num_chunks"
    m = n // num_chunks
    nbits = num_levels(sigma)
    size = 1 << nbits
    node_starts = _node_starts_from_symbols(seq, nbits)
    chunks = seq.reshape(num_chunks, m).astype(_U32)

    if fused:
        def chunk_build(chunk):
            """Per-chunk fused levelwise build: (nbits, m) bits + the
            chunk's symbol histogram (feeds the merge offsets)."""
            histc = jnp.zeros((size,), _I32).at[chunk.astype(_I32)].add(
                1, mode="drop")
            leafc = exclusive_sum(histc)
            order = chunk
            bits_out = []
            for l in range(nbits):
                bit = ((order >> _U32(nbits - 1 - l)) & _U32(1)).astype(_I32)
                bits_out.append(bit)
                if l < nbits - 1:
                    starts_l = leafc[:: 1 << (nbits - l)]       # (2**l,)
                    nid = segment_ids_from_starts(starts_l, m) if l else \
                        jnp.zeros((m,), _I32)
                    words = _pack_level(bit)
                    g = segmented_partition_gather(words, nid, starts_l, m)
                    order = order[g]
            return jnp.stack(bits_out), histc

        bits_all, hist_all = jax.vmap(chunk_build)(chunks)   # (P,nbits,m)
        csum = exclusive_sum(hist_all, axis=1)               # (P, size)
        p_out = jnp.arange(n, dtype=_I32)
        level_words = []
        for l in range(nbits):
            nodes_l = 1 << l
            sc = csum[:, :: 1 << (nbits - l)]                # (P, nodes_l)
            cnt = jnp.concatenate(
                [sc[:, 1:], jnp.full((num_chunks, 1), m, _I32)],
                axis=1) - sc                                 # per-chunk len
            across = exclusive_sum(cnt, axis=0)              # (P, nodes_l)
            gs = node_starts[l][:nodes_l]
            # output runs in (node-major, chunk-minor) order; run (v, c)
            # starts at gs[v] + across[c, v] — globally non-decreasing
            run_start = (gs[:, None] + across.T).reshape(-1)
            rid = segment_ids_from_starts(run_start, n)
            src_base = sc.T.reshape(-1)                      # rid -> sc[c,v]
            src = ((rid % num_chunks) * m + src_base[rid]
                   + (p_out - run_start[rid]))
            merged = bits_all[:, l, :].reshape(-1)[src]
            level_words.append(_pack_level(merged))
        return _finalize_fused(level_words, node_starts, n, nbits,
                               sample_rate)

    def chunk_levels(chunk):
        """Per-chunk levelwise build; returns (nbits, m) bits and node ids."""
        order = chunk
        bits_out, nids_out = [], []
        for l in range(nbits):
            bit = ((order >> _U32(nbits - 1 - l)) & _U32(1)).astype(_I32)
            nid = (order >> _U32(nbits - l)).astype(_I32) if l else \
                jnp.zeros((m,), _I32)
            bits_out.append(bit)
            nids_out.append(nid)
            if l < nbits - 1:
                dest = _segmented_partition_dest(nid, bit, l + 1)
                order = order[_invert_permutation(dest)]
        return jnp.stack(bits_out), jnp.stack(nids_out)

    bits_all, nids_all = jax.vmap(chunk_levels)(chunks)
    # bits_all, nids_all: (P, nbits, m) → per level merge
    level_words = []
    for l in range(nbits):
        bits_l = bits_all[:, l, :]                        # (P, m)
        nid_l = nids_all[:, l, :]                         # (P, m)
        nodes_l = 1 << l
        flat = (jnp.arange(num_chunks, dtype=_I32)[:, None] * nodes_l
                + nid_l)                                  # (P, m)
        cnt = (jnp.zeros((num_chunks * nodes_l,), _I32)
               .at[flat.reshape(-1)].add(1).reshape(num_chunks, nodes_l))
        across = exclusive_sum(cnt, axis=0)               # (P, nodes_l)
        chunk_node_start = exclusive_sum(cnt, axis=1)     # within-chunk
        global_start = node_starts[l, ::1][: nodes_l] if nodes_l == size \
            else node_starts[l, :nodes_l]
        pos_in_chunk = jnp.arange(m, dtype=_I32)[None, :]
        q = pos_in_chunk - jnp.take_along_axis(chunk_node_start, nid_l, axis=1)
        dest = (global_start[nid_l]
                + jnp.take_along_axis(across, nid_l, axis=1) + q)
        merged = (jnp.zeros((n,), _I32)
                  .at[dest.reshape(-1)].set(bits_l.reshape(-1),
                                            unique_indices=True))
        level_words.append(_pack_level(merged))
    return _finalize(level_words, node_starts, n, nbits, sample_rate)


# --------------------------------------------------------------------------
# Queries (levelwise layout)
# --------------------------------------------------------------------------

def wt_access(wt: WaveletTree, i: jax.Array) -> jax.Array:
    i = jnp.asarray(i, _I32)
    c = jnp.zeros_like(i)
    p = i
    v = jnp.zeros_like(i)
    for l in range(wt.nbits):
        bv = wt.level(l)
        s = wt.node_starts[l][v]
        bit = access_bit(bv.rank, p)
        rb = jnp.where(bit == 0,
                       rank0(bv.rank, p) - rank0(bv.rank, s),
                       rank1(bv.rank, p) - rank1(bv.rank, s))
        v = (v << 1) | bit
        c = (c << 1) | bit
        if l < wt.nbits - 1:
            p = wt.node_starts[l + 1][v] + rb
        else:
            p = wt.node_starts[wt.nbits][v] + rb
    return c


def wt_rank(wt: WaveletTree, c: jax.Array, i: jax.Array) -> jax.Array:
    """# of occurrences of c in [0, i)."""
    c = jnp.asarray(c, _I32)
    i = jnp.asarray(i, _I32)
    p = i
    v = jnp.zeros_like(i)
    for l in range(wt.nbits):
        bv = wt.level(l)
        s = wt.node_starts[l][v]
        p = jnp.minimum(p, _next_start(wt, l, v))
        bit = (c >> (wt.nbits - 1 - l)) & 1
        rb = jnp.where(bit == 0,
                       rank0(bv.rank, p) - rank0(bv.rank, s),
                       rank1(bv.rank, p) - rank1(bv.rank, s))
        v = (v << 1) | bit
        p = (wt.node_starts[l + 1][v] if l < wt.nbits - 1
             else wt.node_starts[wt.nbits][v]) + rb
    return p - wt.node_starts[wt.nbits][c]


def _next_start(wt: WaveletTree, l: int, v: jax.Array) -> jax.Array:
    """End offset of node v at level l (start of the next node, or n)."""
    nodes_l = 1 << l
    nxt = v + 1
    return jnp.where(nxt >= nodes_l, wt.n, wt.node_starts[l][jnp.minimum(nxt, nodes_l - 1)])


def wt_select(wt: WaveletTree, c: jax.Array, k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) occurrence of c."""
    c = jnp.asarray(c, _I32)
    k = jnp.asarray(k, _I32)
    pos = k
    for l in range(wt.nbits - 1, -1, -1):
        bv = wt.level(l)
        v = c >> (wt.nbits - l)
        s = wt.node_starts[l][v]
        bit = (c >> (wt.nbits - 1 - l)) & 1
        abs_rank = jnp.where(bit == 0,
                             rank0(bv.rank, s) + pos,
                             rank1(bv.rank, s) + pos)
        p_abs = jnp.where(bit == 0,
                          select0(bv.rank, bv.sel0, abs_rank),
                          select1(bv.rank, bv.sel1, abs_rank))
        pos = p_abs - s
    return pos
