"""Core library: the paper's parallel construction algorithms in JAX.

Public API for wavelet trees/matrices and rank/select structures
(Shun 2016, "Improved Parallel Construction of Wavelet Trees and
Rank/Select Structures").
"""
from . import bitops, scan, sort
from .huffman import (HuffmanWaveletTree, build_huffman_wavelet_tree,
                      canonical_codes, huffman_code_lengths, huffman_codebook,
                      reference_huffman_levels)
from .multiary import (MultiaryWaveletTree, build_multiary_wavelet_tree,
                       mwt_access, mwt_rank, mwt_select)
from .rank_select import (BinaryRank, BinarySelect, BitVector,
                          GeneralizedRankSelect, access_bit,
                          build_binary_rank, build_binary_select,
                          build_bitvector, build_generalized,
                          generalized_access, generalized_rank,
                          generalized_select, rank0, rank1, select0, select1)
from .sort import (bucket_ranks, counting_rank, radix_sort_stable,
                   sort_pass, sort_permutation)
from .wavelet_matrix import (WaveletMatrix, build_wavelet_matrix,
                             build_wavelet_matrix_levelwise, num_levels,
                             reverse_bits, wm_access, wm_child_interval,
                             wm_interval_zeros, wm_position_step, wm_rank,
                             wm_select)
from .wavelet_tree import (WaveletTree, build_wavelet_tree,
                           build_wavelet_tree_dd,
                           build_wavelet_tree_levelwise, wt_access, wt_rank,
                           wt_select)

__all__ = [
    "bitops", "scan", "sort",
    "BinaryRank", "BinarySelect", "BitVector", "GeneralizedRankSelect",
    "access_bit", "build_binary_rank", "build_binary_select",
    "build_bitvector", "build_generalized", "generalized_access",
    "generalized_rank", "generalized_select", "rank0", "rank1",
    "select0", "select1",
    "bucket_ranks", "counting_rank", "radix_sort_stable", "sort_pass",
    "sort_permutation",
    "WaveletMatrix", "build_wavelet_matrix", "build_wavelet_matrix_levelwise",
    "num_levels", "reverse_bits", "wm_access", "wm_child_interval",
    "wm_interval_zeros", "wm_position_step", "wm_rank", "wm_select",
    "WaveletTree", "build_wavelet_tree", "build_wavelet_tree_dd",
    "build_wavelet_tree_levelwise", "wt_access", "wt_rank", "wt_select",
    "HuffmanWaveletTree", "build_huffman_wavelet_tree", "canonical_codes",
    "huffman_code_lengths", "huffman_codebook", "reference_huffman_levels",
    "MultiaryWaveletTree", "build_multiary_wavelet_tree", "mwt_access",
    "mwt_rank", "mwt_select",
]
