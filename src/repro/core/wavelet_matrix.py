"""Wavelet matrix construction (paper Section 4, Theorem 4.5) and queries.

The wavelet matrix [Claude & Navarro] stores one n-bit bitmap per level; at
level l all symbols whose l-th highest bit is 0 move (stably) to the left and
the rest to the right. The paper constructs it in τ-bit chunks: every τ-th
level is produced by ONE stable integer sort keyed on the *reverse* of the
next τ bits, and the τ−1 levels in between are derived from packed τ-bit
"short lists".

TPU realization (DESIGN.md §2): the short lists become narrow (uint8) key
arrays; each in-between level is a stable 0/1 partition of the narrow array
(two prefix sums); the big-level sort is either (a) the *composition* of the
τ partition permutations applied once to the full-width symbols
(``big_step="compose"``, paper-faithful prefix-sum-only data flow), (b) a
direct stable counting sort on the reversed τ-bit key (``"radix"``), or
(c) XLA's stable sort (``"xla"``). Full-width symbols move only once per τ
levels — the τ-fold traffic saving that the paper's work bound expresses.

Construction fast path (default, ``fused=True``): each in-chunk level is
applied as a *gather* whose permutation comes from the select formulation
of the stable partition (``rank_select.stable_partition_gather`` — the
Theorem 5.1 word-rank/select directory, built per level in O(n/log n)
work, answers "which element lands at position p"), the composed
permutation advances only when a compose big step will consume it, and all
``nbits`` rank/select directories are built as one batched launch group
(``rank_select.build_bitvector_levels``). On TPU the per-level step and
the batched rank tables can further route through the Pallas kernels
``kernels.wm_level`` (bit extract + bitmap pack + zero count + stable
destinations in a single launch over the narrow short list) and
``kernels.rank_build`` (all levels' Jacobson tables in one launch); the
big-step counting sort routes through ``kernels.radix_rank`` via
``core.sort.counting_rank``. Outputs are bit-identical on every path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp

from . import bitops
from .rank_select import (BitVector, access_bit, build_bitvector,
                          build_bitvector_levels, rank0, rank1, select0,
                          select1, stable_partition_gather)
from .scan import apply_permutation_dest, stable_partition_indices
from .sort import _invert_permutation, sort_pass

_U32 = jnp.uint32


def num_levels(sigma: int) -> int:
    return max(1, math.ceil(math.log2(max(2, sigma))))


def reverse_bits(x: jax.Array, width: int) -> jax.Array:
    """Reverse the low ``width`` bits of each element."""
    x = x.astype(_U32)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out | (((x >> _U32(i)) & _U32(1)) << _U32(width - 1 - i))
    return out


def default_use_kernels(seq: jax.Array) -> bool:
    """Default Pallas-kernel routing for the fused builders: auto on TPU,
    mechanically off when the builder sees a batching tracer (the fused
    level kernels carry cross-grid scratch, so they must not be vmapped).
    The guard cannot see through ``vmap``-of-``jit`` composition — callers
    wrapping a *jitted* builder in ``vmap`` on TPU must pass
    ``use_kernels=False`` themselves. Guard trips are counted
    (``core.kernel_guard_trip``) so profile runs show when shard builds
    silently lose the kernels."""
    from jax.interpreters import batching
    from repro import obs
    if isinstance(seq, batching.BatchTracer):
        if jax.default_backend() == "tpu":
            obs.counter("core.kernel_guard_trip", reason="batch_tracer").inc()
        return False
    return jax.default_backend() == "tpu"


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class WaveletMatrix:
    """Per-level bitvectors stacked on a leading (nbits,) axis."""
    bitvectors: BitVector   # every leaf carries a leading (nbits,) axis
    zeros: jax.Array        # (nbits,) int32 — zeros per level
    n: int = field(metadata=dict(static=True))
    nbits: int = field(metadata=dict(static=True))

    def level(self, l: int) -> BitVector:
        return jax.tree.map(lambda x: x[l], self.bitvectors)


def _pack_level(bit: jax.Array) -> jax.Array:
    return bitops.pack_bits(bitops.pad_bits(bit.astype(jnp.uint8)))


def build_wavelet_matrix(seq: jax.Array, sigma: int, tau: int = 8,
                         big_step: str = "compose",
                         sample_rate: int = 512,
                         fused: bool = True,
                         use_kernels: bool | None = None) -> WaveletMatrix:
    """τ-chunked parallel construction (paper Theorem 4.5).

    ``tau`` plays the paper's τ = √(log n) role; 8 (byte-aligned) is the TPU
    sweet spot (DESIGN.md §2 assumption 4). ``big_step`` selects how the
    every-τ-levels reshuffle of the full-width symbols is realized.

    ``fused=True`` (default) takes the construction fast path: the
    per-level stable partition is applied as a *gather* whose permutation
    comes from the paper's own select machinery
    (``rank_select.stable_partition_gather`` — Theorem 5.1 structures
    driving the Theorem 4.5 build), the composed permutation is carried
    only when a compose big step actually needs it, and all ``nbits``
    rank/select directories are built in one batched launch group
    (``build_bitvector_levels``). ``fused=False`` is the historical XLA
    step-by-step path (scatter-based inverse permutations, per-level
    directory builds) kept as the benchmark baseline.

    ``use_kernels`` routes the per-level step and the batched rank tables
    through the Pallas kernels (``kernels.wm_level`` /
    ``kernels.rank_build``); ``None`` auto-enables them on TPU. Those two
    kernels carry cross-grid scratch state, so they must not be batched:
    the ``None`` default disables them when the builder sees a batching
    tracer as input (direct ``vmap``, as in the shard builds). The guard
    cannot see through ``vmap``-of-``jit`` composition — callers wrapping
    a *jitted* builder in ``vmap`` on TPU must pass ``use_kernels=False``
    themselves. Passing ``use_kernels=True`` overrides the guard.

    Output is bit-identical across ``fused``/``use_kernels``/``big_step``
    settings (and to ``build_wavelet_matrix_levelwise``).
    """
    from repro import obs
    if use_kernels is None:
        use_kernels = default_use_kernels(seq)
    obs.counter("core.build", builder="wm",
                path="fused" if fused else "scatter").inc()
    if not fused:
        return _build_wavelet_matrix_steps(seq, sigma, tau, big_step,
                                           sample_rate)

    n = int(seq.shape[0])
    nbits = num_levels(sigma)
    order = seq.astype(_U32)
    level_words: List[jax.Array] = []
    zeros: List[jax.Array] = []

    for alpha0 in range(0, nbits, tau):
        width = min(tau, nbits - alpha0)
        # τ-bit field starting at bit-offset alpha0 from the top.
        fld = bitops.extract_field(order, jnp.uint32(nbits - alpha0 - width),
                                   width)
        sub = fld                       # narrow working array ("short list")
        last_chunk = alpha0 + width >= nbits
        # The composed permutation is materialized only when the compose
        # big step will consume it (the historical path carried it always).
        need_idx = (not last_chunk) and big_step == "compose"
        idx = jnp.arange(n, dtype=jnp.int32) if need_idx else None
        for t in range(width):
            shift = width - 1 - t
            last_level = (alpha0 + t == nbits - 1)
            # Movement is needed to arrange the *next* level's bitmap; at
            # the chunk's final level only the composed permutation (if
            # any) still advances — radix/xla big steps re-sort from the
            # chunk-start order and subsume it.
            move = (not last_level) and (t < width - 1 or need_idx)
            obs.counter("core.level_step", builder="wm",
                        impl="kernel" if use_kernels else "xla").inc()
            if use_kernels:
                from repro.kernels import ops as _kops
                dest, words, z = _kops.wm_level_step_fused(sub, shift, n)
                level_words.append(words)
                zeros.append(z)
                if move:
                    if t < width - 1:
                        sub = apply_permutation_dest(sub, dest)
                    if need_idx:
                        idx = apply_permutation_dest(idx, dest)
            else:
                bit = (sub >> _U32(shift)) & _U32(1)
                words = _pack_level(bit)
                z = jnp.int32(n) - jnp.sum(bit, dtype=jnp.int32)
                level_words.append(words)
                zeros.append(z)
                if move:
                    g = stable_partition_gather(words, z, n)
                    if t < width - 1:
                        sub = sub[g]
                    if need_idx:
                        idx = idx[g]
        if not last_chunk:
            if big_step == "compose":
                order = order[idx]
            elif big_step in ("radix", "xla"):
                rev = reverse_bits(fld, width)
                backend = "counting" if big_step == "radix" else "xla"
                order, _ = sort_pass(order, rev, 1 << width, backend=backend)
            else:
                raise ValueError(f"unknown big_step {big_step!r}")

    stacked = build_bitvector_levels(jnp.stack(level_words), n, sample_rate,
                                     use_kernels=use_kernels)
    return WaveletMatrix(bitvectors=stacked, zeros=jnp.stack(zeros),
                         n=n, nbits=nbits)


def _build_wavelet_matrix_steps(seq: jax.Array, sigma: int, tau: int = 8,
                                big_step: str = "compose",
                                sample_rate: int = 512) -> WaveletMatrix:
    """Historical step-by-step XLA realization of Theorem 4.5 (benchmark
    baseline for the fused fast path): per-level scatter-based inverse
    permutations, unconditionally composed permutation, per-level
    directory builds."""
    n = int(seq.shape[0])
    nbits = num_levels(sigma)
    order = seq.astype(_U32)
    level_words: List[jax.Array] = []
    zeros: List[jax.Array] = []

    for alpha0 in range(0, nbits, tau):
        width = min(tau, nbits - alpha0)
        fld = bitops.extract_field(order, jnp.uint32(nbits - alpha0 - width),
                                   width)
        sub = fld
        perm = None                     # composed gather permutation
        for t in range(width):
            bit = (sub >> _U32(width - 1 - t)) & _U32(1)
            level_words.append(_pack_level(bit))
            zeros.append(jnp.int32(n) - jnp.sum(bit, dtype=jnp.int32))
            last_level = (alpha0 + t == nbits - 1)
            if not last_level:
                dest = stable_partition_indices(bit)
                g = _invert_permutation(dest)
                sub = sub[g]
                perm = g if perm is None else perm[g]
        if alpha0 + width < nbits:
            if big_step == "compose":
                order = order[perm]
            elif big_step in ("radix", "xla"):
                rev = reverse_bits(fld, width)
                backend = "counting" if big_step == "radix" else "xla"
                order, _ = sort_pass(order, rev, 1 << width, backend=backend)
            else:
                raise ValueError(f"unknown big_step {big_step!r}")

    bvs = [build_bitvector(w, n, sample_rate) for w in level_words]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *bvs)
    return WaveletMatrix(bitvectors=stacked, zeros=jnp.stack(zeros),
                         n=n, nbits=nbits)


def build_wavelet_matrix_levelwise(seq: jax.Array, sigma: int,
                                   sample_rate: int = 512) -> WaveletMatrix:
    """Prior-work baseline [Shun'15]: O(n·logσ) work, full-width symbols
    permuted at every level. Kept for the benchmarks' before/after rows."""
    from repro import obs
    obs.counter("core.build", builder="wm_levelwise", path="scatter").inc()
    n = int(seq.shape[0])
    nbits = num_levels(sigma)
    order = seq.astype(_U32)
    level_words, zeros = [], []
    for l in range(nbits):
        bit = (order >> _U32(nbits - 1 - l)) & _U32(1)
        level_words.append(_pack_level(bit))
        zeros.append(jnp.int32(n) - jnp.sum(bit, dtype=jnp.int32))
        if l < nbits - 1:
            dest = stable_partition_indices(bit)
            order = order[_invert_permutation(dest)]
    bvs = [build_bitvector(w, n, sample_rate) for w in level_words]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *bvs)
    return WaveletMatrix(bitvectors=stacked, zeros=jnp.stack(zeros),
                         n=n, nbits=nbits)


# --------------------------------------------------------------------------
# Level-descent primitives (shared by queries here and repro.analytics)
# --------------------------------------------------------------------------

def wm_interval_zeros(wm: WaveletMatrix, l: int, lo: jax.Array,
                      hi: jax.Array):
    """rank0 at both ends of [lo, hi) on level ``l``: (zeros before lo,
    zeros before hi). The zero count *inside* the interval is the
    difference — the quantity every range query branches on."""
    bv = wm.level(l)
    return rank0(bv.rank, lo), rank0(bv.rank, hi)


def wm_child_interval(wm: WaveletMatrix, l: int, lo: jax.Array,
                      hi: jax.Array, bit: jax.Array,
                      lo0: jax.Array = None, hi0: jax.Array = None):
    """Map interval [lo, hi) at level ``l`` to its child interval under
    ``bit`` (0 → left/zero block, 1 → right/one block). Pass ``lo0``/``hi0``
    (rank0 at the endpoints) when already computed to avoid re-ranking."""
    if lo0 is None or hi0 is None:
        lo0, hi0 = wm_interval_zeros(wm, l, lo, hi)
    lo1 = wm.zeros[l] + (lo - lo0)
    hi1 = wm.zeros[l] + (hi - hi0)
    return (jnp.where(bit == 0, lo0, lo1),
            jnp.where(bit == 0, hi0, hi1))


def wm_position_step(wm: WaveletMatrix, l: int, p: jax.Array):
    """Follow one position down a level: (bit at p, position in child)."""
    bv = wm.level(l)
    bit = access_bit(bv.rank, p)
    child = jnp.where(bit == 0, rank0(bv.rank, p),
                      wm.zeros[l] + rank1(bv.rank, p))
    return bit, child


# --------------------------------------------------------------------------
# Queries
# --------------------------------------------------------------------------

def wm_access(wm: WaveletMatrix, i: jax.Array) -> jax.Array:
    """Symbol at position i. Vectorized over i; O(logσ) rank calls."""
    i = jnp.asarray(i, jnp.int32)
    c = jnp.zeros_like(i)
    p = i
    for l in range(wm.nbits):
        bit, p = wm_position_step(wm, l, p)
        c = (c << 1) | bit
    return c


def wm_rank(wm: WaveletMatrix, c: jax.Array, i: jax.Array) -> jax.Array:
    """# of occurrences of symbol c in [0, i). Vectorized."""
    c = jnp.asarray(c, jnp.int32)
    i = jnp.asarray(i, jnp.int32)
    lo = jnp.zeros_like(i)
    hi = i
    for l in range(wm.nbits):
        bit = (c >> (wm.nbits - 1 - l)) & 1
        lo, hi = wm_child_interval(wm, l, lo, hi, bit)
    return hi - lo


def wm_select(wm: WaveletMatrix, c: jax.Array, k: jax.Array) -> jax.Array:
    """Position of the k-th (0-based) occurrence of c. Vectorized.

    Descend to find the start offset of c's block at the deepest level, then
    ascend converting block-relative ranks back to positions via select.
    Out-of-range ``k`` (≥ count of c, or c absent) returns a clamped
    position in [0, n) rather than garbage — callers that need to detect
    overflow should compare k against ``wm_rank(wm, c, n)`` first.
    """
    c = jnp.asarray(c, jnp.int32)
    k = jnp.asarray(k, jnp.int32)
    lo = jnp.zeros_like(k)
    for l in range(wm.nbits):
        bit = (c >> (wm.nbits - 1 - l)) & 1
        lo, _ = wm_child_interval(wm, l, lo, lo, bit)
    pos = lo + k
    for l in range(wm.nbits - 1, -1, -1):
        bv = wm.level(l)
        bit = (c >> (wm.nbits - 1 - l)) & 1
        pos = jnp.where(bit == 0,
                        select0(bv.rank, bv.sel0, pos),
                        select1(bv.rank, bv.sel1, pos - wm.zeros[l]))
        pos = jnp.clip(pos, 0, wm.n - 1)
    return pos
