"""Overload-hardened asynchronous query front-end.

The bridge from a ragged, bursty request stream to the fixed-shape
batches the sharded kernels want — built to stay up, bounded, and honest
when offered load exceeds capacity:

* ``admission`` — bounded queue, per-request deadlines on the shared
  ``robust.Clock``, reject-early (CoDel-style) shedding with explicit
  rejections.
* ``ladder``    — queue-pressure-driven graceful degradation with
  hysteresis: exact ops step down to cheaper honest variants (bounds,
  brackets, greedy frontiers), never silently.
* ``batching``  — pad-and-bucket coalescing into a small set of
  pre-compiled shapes with donated double-buffered device staging.
* ``breakers``  — per-shard circuit breakers over hedged liveness
  probes; a slow/stuck shard costs coverage, not queue time.
* ``frontend``  — the pump loop tying it together over an epoch-pinned
  ``ingest.serving.GenerationServer``.

(The model-serving CLI lives in ``repro.launch.serve``; this query
front-end's CLI is ``repro.launch.frontend``.)
"""
from .admission import AdmissionQueue, Answer, Request, ShedError, Ticket
from .batching import BatchRunner
from .breakers import BreakerConfig, ShardBreakers
from .frontend import FrontendConfig, QueryFrontend
from .ladder import DegradeLadder, LadderConfig

__all__ = [
    "AdmissionQueue", "Answer", "Request", "ShedError", "Ticket",
    "BatchRunner", "BreakerConfig", "ShardBreakers",
    "FrontendConfig", "QueryFrontend",
    "DegradeLadder", "LadderConfig",
]
