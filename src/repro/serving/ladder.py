"""Queue-pressure-driven graceful-degradation ladder with hysteresis.

Three rungs, applied per op by the front-end (the ladder itself only
tracks the *level*; the op → variant mapping lives in ``frontend``):

====== ==================== ======================== =======================
level  ``range_count``      ``range_quantile``       ``range_topk``
====== ==================== ======================== =======================
0      exact                exact (full refinement)  exact (full histogram)
1      ``count_bounds``     bracket, nbits−2 levels  greedy frontier, wide
                                                     budget
2      ``count_bounds``     bracket, ⌈nbits/2⌉       greedy frontier, tight
                            levels                   budget
====== ==================== ======================== =======================

Every downgraded answer is honest — bounds/brackets provably contain the
exact answer and greedy counts are true per-symbol counts — and tagged
with its mode, so the ladder trades *precision*, never correctness.

Transitions are asymmetric (hysteresis), which is what makes the ladder
monotone within a burst:

* pressure ≥ ``up_pressure``  → step **up** immediately (one rung per
  observation — overload response is prompt but not a cliff);
* pressure ≤ ``down_pressure`` *sustained for* ``cooldown_s`` → step
  down one rung. Any pressure excursion above ``down_pressure`` resets
  the cooldown, so mid-burst the ladder can only hold or climb — answer
  quality never flaps upward between two overloaded batches.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro import obs
from repro.robust.clock import SYSTEM_CLOCK, Clock


@dataclass(frozen=True)
class LadderConfig:
    up_pressure: float = 0.75     # step up at/above this queue fullness
    down_pressure: float = 0.25   # eligible to step down at/below this
    cooldown_s: float = 0.5       # sustained-calm time per downward step
    max_level: int = 2


class DegradeLadder:
    """Current degradation level, driven by ``observe(pressure)``."""

    def __init__(self, config: LadderConfig = LadderConfig(), *,
                 clock: Clock = SYSTEM_CLOCK):
        self.config = config
        self.clock = clock
        self._level = 0
        # last instant pressure was NOT low — the cooldown anchor.
        self._calm_since = clock.now()

    @property
    def level(self) -> int:
        return self._level

    def observe(self, pressure: float) -> int:
        """Fold one pressure sample into the level; returns the level the
        *next* batch must serve at."""
        cfg = self.config
        now = self.clock.now()
        if pressure > cfg.down_pressure:
            self._calm_since = now
        if pressure >= cfg.up_pressure and self._level < cfg.max_level:
            self._level += 1
            obs.counter("serve.frontend.degrade", direction="up").inc()
            obs.event("frontend.degrade", level=self._level,
                      pressure=pressure)
        elif (pressure <= cfg.down_pressure and self._level > 0
              and now - self._calm_since >= cfg.cooldown_s):
            self._level -= 1
            self._calm_since = now          # one rung per cooldown window
            obs.counter("serve.frontend.degrade", direction="down").inc()
            obs.event("frontend.degrade", level=self._level,
                      pressure=pressure)
        obs.gauge("serve.frontend.degrade_level").set(float(self._level))
        return self._level
