"""The overload-hardened query front-end: queue → ladder → batch → answer.

``QueryFrontend`` is the serving loop that turns a ragged, bursty stream
of analytics requests into the fixed-shape batches the sharded kernels
want, while staying up — and honest — when offered load exceeds
capacity. One pump iteration:

1. read queue pressure, fold it into the :class:`~.ladder.DegradeLadder`
   (the level the batch will serve at);
2. refresh the per-shard :class:`~.breakers.ShardBreakers` (hedged
   probes; a chaos-stalled shard opens its breaker);
3. take one homogeneous batch from the :class:`~.admission.AdmissionQueue`
   (expired requests shed *before* dispatch, with explicit rejections);
4. pin an epoch via ``GenerationServer.session()`` — the batch runs
   entirely against one ``(generation, engine)`` pair, so a concurrent
   ``swap_generation`` (even one stuck on its drain fence) never tears
   or stalls it;
5. fold the breaker mask into the engine's availability mask and run the
   ladder-selected op variant through the :class:`~.batching.BatchRunner`
   (bucket-padded, jit-cached, donated device buffers);
6. resolve every ticket with an :class:`~.admission.Answer` tagged with
   mode / coverage / level / generation / deadline outcome.

Observability rides the existing ``repro.obs`` substrate:
``serve.frontend.{qps,shed_rate,queue_depth,deadline_miss,degrade_level}``
gauges/counters, per-op ``serve.frontend.<op>.latency_s`` histograms
(which the ``repro.launch.obs --slo`` gate picks up as ``frontend.<op>``
rows), and ``frontend.pump`` spans.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analytics import engine as eng_mod
from repro.ingest.serving import GenerationServer
from repro.robust.clock import SYSTEM_CLOCK, Clock

from .admission import AdmissionQueue, Answer, Request, ShedError, Ticket
from .batching import BatchRunner
from .breakers import BreakerConfig, ShardBreakers
from .ladder import DegradeLadder, LadderConfig

_I32 = jnp.int32

#: mode tag per (op, ladder level) — level indexes clamp to the last entry.
_MODES = {
    "count": ("exact", "count_bounds", "count_bounds"),
    "quantile": ("exact", "quantile_bracket", "quantile_bracket"),
    "topk": ("exact", "topk_greedy", "topk_greedy"),
}


@dataclass(frozen=True)
class FrontendConfig:
    buckets: Tuple[int, ...] = (8, 32, 128)
    capacity: int = 256
    default_deadline_s: float = 0.25
    topk_k: int = 8                   # static k every top-k request shares
    #: greedy frontier budget per ladder level, × k (level 0 unused).
    greedy_budget_factors: Tuple[int, ...] = (0, 6, 3)
    #: bit levels *shaved* off the quantile descent per ladder level.
    quantile_shave: Tuple[int, ...] = (0, 2, 4)
    idle_sleep_s: float = 1e-3
    probe_shards: bool = True
    ladder: LadderConfig = field(default_factory=LadderConfig)
    breaker: BreakerConfig = field(default_factory=BreakerConfig)


class QueryFrontend:
    """Deadline-aware admission + degradation ladder over a
    ``GenerationServer`` holding a ``ShardedAnalytics`` engine."""

    def __init__(self, server: GenerationServer, *,
                 config: FrontendConfig = FrontendConfig(),
                 clock: Clock = SYSTEM_CLOCK):
        self.server = server
        self.config = config
        self.clock = clock
        self.queue = AdmissionQueue(config.capacity, clock=clock)
        self.ladder = DegradeLadder(config.ladder, clock=clock)
        self.runner = BatchRunner(config.buckets)
        engine = server.engine
        self.breakers = ShardBreakers(
            engine.num_shards,
            lambda s: self.server.engine.probe_shard(s, self.clock),
            config=config.breaker, clock=clock)
        self.served = 0
        self.deadline_misses = 0
        self.degraded_served = 0
        self._thread: Optional[threading.Thread] = None
        self._running = False

    # ---- submission -----------------------------------------------------
    def submit(self, op: str, lo: int, hi: int, *,
               sym_lo: int = 0, sym_hi: Optional[int] = None,
               k: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Ticket:
        """Admit one request; returns its ticket (already rejected with
        :class:`ShedError` if admission shed it).

        * ``count``    — symbols in ``[sym_lo, sym_hi)`` within positions
          ``[lo, hi)`` (``sym_hi`` defaults to σ);
        * ``quantile`` — ``k``-th smallest symbol in ``[lo, hi)``;
        * ``topk``     — the config-static ``topk_k`` heaviest symbols
          (a per-request ``k`` must match — k is a compiled shape).
        """
        if op not in _MODES:
            raise ValueError(f"unknown op {op!r} "
                             f"(expected one of {sorted(_MODES)})")
        if op == "count":
            b = int(self.server.engine.sigma if sym_hi is None else sym_hi)
            args = (int(lo), int(hi), int(sym_lo), b)
        elif op == "quantile":
            if k is None:
                raise ValueError("quantile requires k")
            args = (int(lo), int(hi), int(k), 0)
        else:                                     # topk
            if k is not None and int(k) != self.config.topk_k:
                raise ValueError(
                    f"topk k={k} != configured static k="
                    f"{self.config.topk_k}")
            args = (int(lo), int(hi), 0, 0)
        now = self.clock.now()
        budget = (self.config.default_deadline_s if deadline_s is None
                  else float(deadline_s))
        obs.counter("serve.frontend.submitted", op=op).inc()
        req = Request(op=op, args=args, deadline_t=now + budget,
                      submitted_t=now, ticket=Ticket())
        return self.queue.submit(req)

    # ---- op variants (ladder level → jitted callable) -------------------
    def _op_fn(self, op: str, level: int):
        """(mode, fn) where ``fn(engine, q)`` maps a (4, B) query block to
        ``(a, b, coverage)`` arrays. All degraded variants return honest
        brackets; coverage comes from the same masked ranges the answer
        used."""
        cfg = self.config
        mode = _MODES[op][min(level, len(_MODES[op]) - 1)]

        def cov(eng, q):
            return eng_mod.sharded_coverage(
                eng.shard_bits, eng.num_shards, eng.n, q[0], q[1],
                eng.available)

        if op == "count":
            if mode == "exact":
                def fn(eng, q):
                    c = eng_mod.sharded_range_count(
                        eng.shards, eng.shard_bits, eng.n,
                        q[0], q[1], q[2], q[3], eng.available)
                    return c, c, cov(eng, q)
            else:
                def fn(eng, q):
                    return eng_mod.sharded_range_count_bounds(
                        eng.shards, eng.shard_bits, eng.n,
                        q[0], q[1], q[2], q[3], eng.available)
        elif op == "quantile":
            if mode == "exact":
                def fn(eng, q):
                    s = eng_mod.sharded_range_quantile(
                        eng.shards, eng.shard_bits, eng.n,
                        q[0], q[1], q[2], eng.available)
                    hi = jnp.where(s < 0, s, s + 1)
                    return s, hi, cov(eng, q)
            else:
                shave = cfg.quantile_shave[
                    min(level, len(cfg.quantile_shave) - 1)]

                def fn(eng, q):
                    lvl = max(1, eng.shards.nbits - shave)
                    a, b = eng_mod.sharded_range_quantile_bracket(
                        eng.shards, eng.shard_bits, eng.n,
                        q[0], q[1], q[2], lvl, eng.available)
                    return a, b, cov(eng, q)
        else:                                     # topk
            if mode == "exact":
                def fn(eng, q):
                    syms, counts = eng_mod.sharded_range_topk(
                        eng.shards, eng.shard_bits, eng.n,
                        q[0], q[1], cfg.topk_k, eng.available)
                    return syms, counts, cov(eng, q)
            else:
                factor = cfg.greedy_budget_factors[
                    min(level, len(cfg.greedy_budget_factors) - 1)]
                budget = max(cfg.topk_k, factor * cfg.topk_k)

                def fn(eng, q):
                    syms, counts = eng_mod.sharded_range_topk_greedy(
                        eng.shards, eng.shard_bits, eng.n,
                        q[0], q[1], cfg.topk_k, budget=budget,
                        prune=True, available=eng.available)
                    return syms, counts, cov(eng, q)
        return mode, fn

    # ---- serving loop ---------------------------------------------------
    def _effective_engine(self, engine, bmask):
        """Engine availability ∧ breaker mask — tripped breakers degrade
        coverage through the exact same masking path as lost shards."""
        if bmask is None or bool(bmask.all()):
            return engine
        base = (np.ones(engine.num_shards, bool)
                if engine.available is None
                else np.asarray(engine.available))
        return engine.with_availability(base & bmask[:engine.num_shards])

    def pump(self) -> int:
        """Serve one batch; returns the number of requests resolved.

        Safe to call from tests (synchronous, fake-clock friendly) or
        from the :meth:`start` worker thread.
        """
        pressure = self.queue.pressure
        level = self.ladder.observe(pressure)
        batch = self.queue.take(self.runner.max_batch)
        obs.gauge("serve.frontend.queue_depth").set(float(self.queue.depth))
        if not batch:
            self._publish_rates()
            return 0
        op = batch[0].op
        t0 = self.clock.now()
        with obs.span("frontend.pump", op=op, n=len(batch),
                      level=level) as sp:
            with self.server.session() as (gen, engine):
                if engine.num_shards != self.breakers.num_shards:
                    self.breakers.resize(engine.num_shards)
                bmask = (self.breakers.refresh()
                         if self.config.probe_shards else None)
                eng = self._effective_engine(engine, bmask)
                mode, fn = self._op_fn(op, level)
                qargs = np.asarray([r.args for r in batch],
                                   np.int32).T          # (4, n)
                try:
                    a, b, cov = self.runner.run((op, level), fn, eng,
                                                qargs, len(batch))
                except Exception as e:                    # noqa: BLE001
                    for r in batch:
                        r.ticket.reject(e)
                    raise
            batch_s = self.clock.now() - t0
            self.queue.observe_service(batch_s, len(batch))
            self._resolve(batch, op, mode, level, gen, a, b, cov)
            sp.set("gen", gen)
            sp.set("mode", mode)
        self._publish_rates(batch_s=batch_s, batch_n=len(batch))
        return len(batch)

    def _resolve(self, batch, op, mode, level, gen, a, b, cov) -> None:
        finish = self.clock.now()
        for i, r in enumerate(batch):
            coverage = float(cov[i])
            if op == "topk":
                value = (a[i], b[i])
            elif mode == "exact":
                value = int(a[i])
            else:
                value = (int(a[i]), int(b[i]))
            degraded = mode != "exact" or coverage < 1.0
            met = finish <= r.deadline_t
            lat = finish - r.submitted_t
            if not met:
                self.deadline_misses += 1
                obs.counter("serve.frontend.deadline_miss", op=op).inc()
            if degraded:
                self.degraded_served += 1
            self.served += 1
            obs.counter("serve.frontend.served", op=op, mode=mode).inc()
            obs.histogram(f"serve.frontend.{op}.latency_s").observe(lat)
            r.ticket.resolve(Answer(
                value=value, mode=mode, degraded=degraded,
                coverage=coverage, level=level, generation=gen,
                latency_s=lat, deadline_met=met))

    def _publish_rates(self, batch_s: float = 0.0, batch_n: int = 0
                       ) -> None:
        if batch_n and batch_s > 0:
            obs.gauge("serve.frontend.qps").set(batch_n / batch_s)
        sub = max(1, self.queue.submitted)
        obs.gauge("serve.frontend.shed_rate").set(
            self.queue.total_shed / sub)

    # ---- background worker ---------------------------------------------
    def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._thread = threading.Thread(target=self._loop,
                                        name="frontend-pump", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while self._running:
            try:
                if self.pump() == 0:
                    self.clock.sleep(self.config.idle_sleep_s)
            except Exception:                             # noqa: BLE001
                # the failing batch's tickets were already rejected;
                # keep the loop alive for the rest of the stream.
                obs.counter("serve.frontend.pump_error").inc()

    def stop(self, drain: bool = True) -> None:
        self._running = False
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        if drain:
            while self.pump():
                pass
        self.breakers.close_pool()

    # ---- reporting ------------------------------------------------------
    def stats(self) -> dict:
        """Point-in-time accounting — ``submitted == served + shed +
        queued`` always holds (every request is resolved exactly once)."""
        return {
            "submitted": self.queue.submitted,
            "served": self.served,
            "degraded_served": self.degraded_served,
            "shed": dict(self.queue.shed_counts),
            "total_shed": self.queue.total_shed,
            "queued": self.queue.depth,
            "deadline_misses": self.deadline_misses,
            "degrade_level": self.ladder.level,
            "open_breakers": self.breakers.open_shards,
            "compiled": self.runner.compiled,
            "service_ewma_s": self.queue.service_s,
        }
