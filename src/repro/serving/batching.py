"""Pad-and-bucket batch execution: ragged streams → pre-compiled shapes.

The kernels underneath (vmapped sharded descents, the fused Pallas
quantile) want fixed-shape batches; a ragged request stream wants to be
served *now*. The runner reconciles the two:

* **buckets** — every batch is padded up to the smallest of a few fixed
  sizes (default 8/32/128), so the jit cache holds at most
  ``len(buckets)`` entries per (op, ladder-level) instead of one per
  ragged batch size. Padding queries are the neutral ``lo == hi == 0``
  empty range, which every op answers harmlessly (count 0, quantile −1,
  empty top-k) and which costs one lane of an already-launched kernel.
* **double-buffered staging** — per bucket, two pinned host arrays are
  alternated so the next batch can be packed while the previous one's
  device transfer is still in flight; the device copy is **donated** to
  the jitted call (non-CPU backends), letting XLA reuse the query
  buffer's memory for outputs instead of allocating fresh.
* **jit cache** — compiled executables are keyed ``(op-key, bucket)``.
  The engine rides along as a pytree *argument*, so a generation hot-swap
  with unchanged geometry hits the existing executable; only a geometry
  change (new ``n``/shard count — static fields) or an availability-mask
  appearance (pytree structure change) retraces.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

_Key = Tuple[Any, int]


class BatchRunner:
    """Jit-cached, bucket-padded executor for (4, B) int32 query blocks."""

    def __init__(self, buckets: Tuple[int, ...] = (8, 32, 128)):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"invalid buckets {buckets!r}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self._jit: Dict[_Key, Callable] = {}
        self._staging: Dict[int, list] = {}   # bucket -> [buf0, buf1, flip]
        self._donate = jax.default_backend() != "cpu"

    def bucket_for(self, n: int) -> int:
        """Smallest bucket ≥ n (the largest bucket caps batch size —
        callers split bigger batches)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    @property
    def max_batch(self) -> int:
        return self.buckets[-1]

    def _stage(self, bucket: int, qargs: np.ndarray, n: int) -> np.ndarray:
        if bucket not in self._staging:
            self._staging[bucket] = [np.zeros((4, bucket), np.int32),
                                     np.zeros((4, bucket), np.int32), 0]
        slot = self._staging[bucket]
        buf = slot[slot[2]]
        slot[2] ^= 1
        buf[:, :n] = qargs[:, :n]
        buf[:, n:] = 0                      # neutral lo == hi == 0 pads
        return buf

    def run(self, key: Any, fn: Callable, engine: Any,
            qargs: np.ndarray, n: int):
        """Execute ``fn(engine, q)`` on the bucket-padded device block.

        ``qargs`` is (4, n) int32 (op-specific lanes); returns ``fn``'s
        output pytree with leading batch dim = bucket (callers slice
        ``[:n]``).
        """
        if n <= 0:
            raise ValueError("empty batch")
        if n > self.max_batch:
            raise ValueError(f"batch {n} exceeds max bucket "
                             f"{self.max_batch}")
        bucket = self.bucket_for(n)
        buf = self._stage(bucket, qargs, n)
        jkey = (key, bucket)
        if jkey not in self._jit:
            obs.counter("serve.frontend.compile").inc()
            donate = (1,) if self._donate else ()
            self._jit[jkey] = jax.jit(fn, donate_argnums=donate)
        out = self._jit[jkey](engine, jnp.asarray(buf))
        return jax.tree.map(np.asarray, jax.block_until_ready(out))

    @property
    def compiled(self) -> int:
        return len(self._jit)
