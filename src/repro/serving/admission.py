"""Bounded admission with per-request deadlines and reject-early shedding.

The queue is the *only* place a request can wait, so it is also the only
place overload shows up — and the contract is that overload turns into
explicit, early rejections rather than unbounded latency:

* **bounded**: at most ``capacity`` requests queue; a submit beyond that
  is shed immediately (``queue_full``) — memory and tail latency stay
  bounded no matter the offered load.
* **deadline-aware, reject-early (CoDel-style)**: every request carries
  an absolute deadline on the shared ``robust.Clock``. At submit time the
  queue estimates the sojourn ahead of the request (queue depth × an EWMA
  of observed per-request service time) and sheds ``over_budget`` work
  whose deadline cannot survive the wait — the request is rejected in
  microseconds instead of timing out after burning queue space (the
  tail-drop failure CoDel exists to prevent). At dispatch time anything
  whose deadline has already passed is shed as ``expired`` *before* it
  reaches a batch.
* **explicit rejection**: every shed resolves the caller's ticket with a
  :class:`ShedError` naming the reason — callers are never left hanging
  and never silently dropped.

``Ticket`` is the caller's handle: ``result()`` blocks (real time) until
the worker resolves it with an :class:`Answer` or a shed/failure.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.robust.clock import SYSTEM_CLOCK, Clock


class ShedError(Exception):
    """Explicit admission rejection — the request was never dispatched.

    ``reason`` is one of ``queue_full`` / ``over_budget`` / ``expired``;
    ``est_wait_s`` reports the sojourn estimate that condemned an
    over-budget request.
    """

    def __init__(self, reason: str, *, queue_depth: int = 0,
                 est_wait_s: Optional[float] = None):
        self.reason = reason
        self.queue_depth = queue_depth
        self.est_wait_s = est_wait_s
        extra = (f", est_wait={est_wait_s:.4f}s"
                 if est_wait_s is not None else "")
        super().__init__(f"request shed: {reason} "
                         f"(queue_depth={queue_depth}{extra})")


@dataclass
class Answer:
    """One resolved request — always tagged with *how* it was answered.

    ``mode`` names the op variant that produced ``value`` (``exact``,
    ``count_bounds``, ``quantile_bracket``, ``topk_greedy``);
    ``degraded`` is True whenever the ladder downgraded the op or
    coverage < 1, so callers are never silently lied to. ``coverage`` is
    the fraction of the queried range on available shards,
    ``generation`` the epoch pin the batch ran under.
    """
    value: Any
    mode: str
    degraded: bool
    coverage: float
    level: int
    generation: int
    latency_s: float
    deadline_met: bool


@dataclass
class Request:
    """One admitted query: op name + normalized int32 args + deadline."""
    op: str
    args: Tuple[int, int, int, int]      # (lo, hi, a, b) — op-specific
    deadline_t: float                    # absolute, on the shared clock
    submitted_t: float
    ticket: "Ticket" = field(repr=False, default=None)


class Ticket:
    """Caller-side future for one request (thread-safe, wait via Event)."""

    def __init__(self) -> None:
        self._event = threading.Event()
        self._answer: Optional[Answer] = None
        self._error: Optional[BaseException] = None

    # -- worker side --
    def resolve(self, answer: Answer) -> None:
        self._answer = answer
        self._event.set()

    def reject(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    # -- caller side --
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def shed(self) -> bool:
        return self._event.is_set() and isinstance(self._error, ShedError)

    def result(self, timeout: Optional[float] = None) -> Answer:
        if not self._event.wait(timeout):
            raise TimeoutError("ticket not resolved in time")
        if self._error is not None:
            raise self._error
        return self._answer


class AdmissionQueue:
    """Bounded FIFO with submit-time and dispatch-time shedding.

    ``observe_service(batch_s, batch_n)`` feeds the per-request service
    EWMA the sojourn estimator uses; until the first observation the
    estimate is ``init_service_s`` (optimistic — a cold queue admits).
    """

    def __init__(self, capacity: int = 256, *,
                 clock: Clock = SYSTEM_CLOCK,
                 init_service_s: float = 1e-4,
                 ewma_alpha: float = 0.2):
        self.capacity = int(capacity)
        self.clock = clock
        self._dq: deque[Request] = deque()
        self._lock = threading.Lock()
        self._service_s = float(init_service_s)
        self._alpha = float(ewma_alpha)
        self.submitted = 0
        self.shed_counts = {"queue_full": 0, "over_budget": 0, "expired": 0}

    # ---- sizing / pressure ---------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._dq)

    @property
    def depth(self) -> int:
        return len(self)

    @property
    def pressure(self) -> float:
        """Queue fullness in [0, 1] — the degradation ladder's input."""
        return min(1.0, len(self) / max(1, self.capacity))

    @property
    def service_s(self) -> float:
        return self._service_s

    def observe_service(self, batch_s: float, batch_n: int) -> None:
        if batch_n <= 0:
            return
        per = max(0.0, float(batch_s) / batch_n)
        self._service_s += self._alpha * (per - self._service_s)

    # ---- submit-time admission -----------------------------------------
    def submit(self, req: Request) -> Ticket:
        """Admit or shed ``req``; always returns its (possibly already
        rejected) ticket."""
        ticket = req.ticket = req.ticket or Ticket()
        with self._lock:
            self.submitted += 1
            depth = len(self._dq)
            if depth >= self.capacity:
                self._shed_locked(req, "queue_full", depth)
                return ticket
            est_wait = depth * self._service_s
            budget = req.deadline_t - self.clock.now()
            if est_wait > budget:
                self._shed_locked(req, "over_budget", depth,
                                  est_wait_s=est_wait)
                return ticket
            self._dq.append(req)
        return ticket

    def _shed_locked(self, req: Request, reason: str, depth: int,
                     est_wait_s: Optional[float] = None) -> None:
        self.shed_counts[reason] += 1
        obs.counter("serve.frontend.shed", reason=reason).inc()
        req.ticket.reject(ShedError(reason, queue_depth=depth,
                                    est_wait_s=est_wait_s))

    # ---- dispatch-time take --------------------------------------------
    def take(self, max_n: int) -> List[Request]:
        """Pop up to ``max_n`` same-op requests, shedding expired ones.

        Scans FIFO order: requests whose deadline has already passed are
        shed (``expired``) *before* dispatch; the first live request
        fixes the batch's op, later live requests of other ops stay
        queued (order preserved) so each pump serves one homogeneous,
        bucketable batch.
        """
        now = self.clock.now()
        batch: List[Request] = []
        keep: List[Request] = []
        op: Optional[str] = None
        with self._lock:
            while self._dq:
                req = self._dq.popleft()
                if req.deadline_t <= now:
                    self._shed_locked(req, "expired", len(self._dq))
                    continue
                if op is None:
                    op = req.op
                if req.op == op and len(batch) < max_n:
                    batch.append(req)
                else:
                    keep.append(req)
            self._dq.extendleft(reversed(keep))
        return batch

    @property
    def total_shed(self) -> int:
        return sum(self.shed_counts.values())
