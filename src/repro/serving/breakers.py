"""Per-shard circuit breakers with hedged liveness probes.

One slow or stuck shard must cost *coverage*, never queue time. Each
shard gets a three-state breaker:

* **closed** — serveable; probed at most every ``probe_interval_s``.
* **open** — recently failed; the shard is masked out of serving (the
  front-end folds the breaker mask into the engine's availability mask,
  so PR 6's bounds/coverage machinery reports the loss honestly) and no
  probes run until ``reset_after_s`` elapses.
* **half-open** — the reset window passed; exactly one trial probe runs.
  Success closes the breaker (full coverage restored), failure re-opens
  it for another window.

Probes are **hedged**: each runs on a worker thread with a generous wall
timeout (so a probe stuck inside a real device call cannot stall the
pump), and the *decision* timeout is measured on the shared injectable
``robust.Clock`` — chaos-armed ``inject_shard_latency`` stalls the probe
on that clock, so a ``FakeClock`` test sees the exact same "slow shard →
probe timeout → breaker opens" path with zero real sleeping.
"""
from __future__ import annotations

import concurrent.futures
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro import obs
from repro.robust.clock import SYSTEM_CLOCK, Clock

_CLOSED, _OPEN = 0, 1


@dataclass(frozen=True)
class BreakerConfig:
    fail_threshold: int = 2       # consecutive probe failures to open
    reset_after_s: float = 1.0    # open → half-open trial window
    probe_timeout_s: float = 0.05  # logical (clock) probe deadline
    probe_interval_s: float = 0.25  # min spacing of closed-state probes
    wall_timeout_s: float = 5.0   # hard wall cap per hedged probe


class ShardBreakers:
    """Breaker state for ``num_shards`` shards + the serveable mask.

    ``probe(shard) -> bool`` is the injected liveness check (the engines'
    ``probe_shard``, which honours chaos latency on the shared clock).
    ``refresh()`` advances due probes/state transitions and returns the
    mask; ``mask()`` returns the last result (``None`` when everything is
    closed — no pytree-structure churn for the jit cache).
    """

    def __init__(self, num_shards: int, probe: Callable[[int], bool], *,
                 config: BreakerConfig = BreakerConfig(),
                 clock: Clock = SYSTEM_CLOCK):
        self.config = config
        self.clock = clock
        self._probe = probe
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=2, thread_name_prefix="breaker-probe")
        self.resize(num_shards)

    def resize(self, num_shards: int) -> None:
        """(Re)initialize for a generation with ``num_shards`` shards —
        all breakers start closed and immediately probe-eligible."""
        self.num_shards = int(num_shards)
        self._state = np.full(self.num_shards, _CLOSED, np.int8)
        self._fails = np.zeros(self.num_shards, np.int32)
        self._opened_t = np.zeros(self.num_shards, np.float64)
        self._next_probe_t = np.full(self.num_shards, -np.inf)
        self._mask = np.ones(self.num_shards, bool)

    # ---- hedged probe ---------------------------------------------------
    def _hedged_probe(self, s: int) -> bool:
        cfg = self.config
        t0 = self.clock.now()
        fut = self._pool.submit(self._probe, s)
        try:
            ok = bool(fut.result(timeout=cfg.wall_timeout_s))
        except concurrent.futures.TimeoutError:
            fut.cancel()
            ok = False
        except Exception:                                  # noqa: BLE001
            ok = False
        # the decision deadline lives on the injectable clock: a chaos
        # latency slept on a FakeClock is invisible to the wall timeout
        # but lands here, and a real stall lands in both.
        if self.clock.now() - t0 > cfg.probe_timeout_s:
            ok = False
        return ok

    # ---- state machine --------------------------------------------------
    def refresh(self) -> np.ndarray:
        """Run due probes, advance breaker states, return the mask."""
        cfg = self.config
        for s in range(self.num_shards):
            now = self.clock.now()
            if self._state[s] == _OPEN:
                if now - self._opened_t[s] < cfg.reset_after_s:
                    continue                       # still cooling off
                # half-open: one trial probe decides
                if self._hedged_probe(s):
                    self._close(s)
                else:
                    self._open(s, half_open_retrial=True)
                continue
            if now < self._next_probe_t[s]:
                continue
            self._next_probe_t[s] = now + cfg.probe_interval_s
            if self._hedged_probe(s):
                self._fails[s] = 0
            else:
                self._fails[s] += 1
                if self._fails[s] >= cfg.fail_threshold:
                    self._open(s)
        self._mask = self._state == _CLOSED
        obs.gauge("serve.frontend.breakers_open").set(
            float(np.sum(~self._mask)))
        return self._mask

    def _open(self, s: int, half_open_retrial: bool = False) -> None:
        self._state[s] = _OPEN
        self._opened_t[s] = self.clock.now()
        self._fails[s] = 0
        obs.counter("serve.frontend.breaker_open").inc()
        obs.event("frontend.breaker_open", shard=int(s),
                  retrial=half_open_retrial)

    def _close(self, s: int) -> None:
        self._state[s] = _CLOSED
        self._fails[s] = 0
        self._next_probe_t[s] = (self.clock.now()
                                 + self.config.probe_interval_s)
        obs.counter("serve.frontend.breaker_close").inc()
        obs.event("frontend.breaker_close", shard=int(s))

    # ---- serving-side view ---------------------------------------------
    def mask(self) -> Optional[np.ndarray]:
        """(S,) bool serveable mask from the last refresh, or ``None``
        when every breaker is closed."""
        return None if bool(self._mask.all()) else self._mask.copy()

    @property
    def open_shards(self) -> list:
        return [int(s) for s in np.flatnonzero(self._state == _OPEN)]

    def close_pool(self) -> None:
        self._pool.shutdown(wait=False)
