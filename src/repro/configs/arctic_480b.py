"""arctic-480b [moe]: 35L d7168 56H (GQA kv=8) ff4864 V32000,
MoE 128e top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base; hf]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="arctic_480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        num_experts=128, experts_per_token=2, d_ff_moe=4864,
        moe_dense_residual=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="arctic_480b_smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        num_experts=8, experts_per_token=2, d_ff_moe=96,
        moe_dense_residual=True)
