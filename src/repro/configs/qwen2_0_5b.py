"""qwen2-0.5b [dense]: 24L d896 14H (GQA kv=2) ff4864 V151936, QKV bias.
[arXiv:2407.10671; hf]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_0_5b", family="dense",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        d_ff=4864, vocab_size=151936, qkv_bias=True, rope_theta=1e6)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_0_5b_smoke", family="dense",
        num_layers=2, d_model=56, num_heads=2, num_kv_heads=1,
        d_ff=128, vocab_size=256, qkv_bias=True)
