"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) ff12800 V49155.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite_3_8b", family="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=12800, vocab_size=49155)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite_3_8b_smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256)
