"""deepseek-7b [dense]: 30L d4096 32H (GQA kv=32 ⇒ MHA) ff11008 V102400.
[arXiv:2401.02954; hf]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_7b", family="dense",
        num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=11008, vocab_size=102400)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_7b_smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256)
