"""mamba2-370m [ssm]: 48L d1024 attn-free V50280, ssm_state=128 (SSD).
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_370m", family="ssm",
        num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, head_dim=1)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_370m_smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=256,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, head_dim=1)
