"""Config dataclasses + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 → d_model // num_heads
    qkv_bias: bool = False
    norm_type: str = "rms"      # rms | layer
    activation: str = "swiglu"  # swiglu | gelu
    pos_embed: str = "rope"     # rope | learned
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_moe: int = 0           # 0 → d_ff
    moe_dense_residual: bool = False
    moe_every: int = 1          # MoE FF on every k-th layer (jamba: 2)
    # --- SSM ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0         # hybrid: one attn layer per k (jamba: 8)
    # --- enc-dec ---
    encoder_layers: int = 0
    encoder_frames: int = 0     # stubbed audio frontend length
    # --- VLM ---
    cross_attn_every: int = 0
    num_image_tokens: int = 0
    # --- misc ---
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    max_position: int = 1 << 20

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.num_heads))
        if self.num_experts and not self.d_ff_moe:
            object.__setattr__(self, "d_ff_moe", self.d_ff)

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so embed/lm_head shard
        over any mesh axis (Megatron-style vocab padding). Pad logits are
        masked to -inf in the loss/decode (§Perf iteration 2)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    @property
    def period(self) -> int:
        """Layers per scanned block (the smallest repeating pattern)."""
        if self.family == "hybrid":
            return self.attn_every
        if self.family == "vlm":
            return self.cross_attn_every
        return 1

    @property
    def num_blocks(self) -> int:
        assert self.num_layers % self.period == 0
        return self.num_layers // self.period

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCHITECTURES = (
    "granite_3_8b",
    "deepseek_7b",
    "internlm2_20b",
    "qwen2_0_5b",
    "arctic_480b",
    "dbrx_132b",
    "whisper_medium",
    "mamba2_370m",
    "jamba_v0_1_52b",
    "llama_3_2_vision_90b",
)

# long_500k needs sub-quadratic token mixing; only SSM/hybrid families
# qualify (DESIGN.md §4).
LONG_CONTEXT_ARCHS = ("mamba2_370m", "jamba_v0_1_52b")


def supports_shape(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.smoke_config() if smoke else mod.full_config()


def smoke_shape(kind: str = "train") -> ShapeConfig:
    if kind == "train":
        return ShapeConfig("smoke_train", 64, 2, "train")
    if kind == "prefill":
        return ShapeConfig("smoke_prefill", 64, 2, "prefill")
    return ShapeConfig("smoke_decode", 64, 2, "decode")
