"""llama-3.2-vision-90b [vlm]: 100L d8192 64H (GQA kv=8) ff28672 V128256,
cross-attn image layers every 5th layer; patch embeddings stubbed.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="llama_3_2_vision_90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        cross_attn_every=5, num_image_tokens=1601, rope_theta=5e5)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama_3_2_vision_90b_smoke", family="vlm",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        cross_attn_every=5, num_image_tokens=8)
