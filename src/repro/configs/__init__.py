"""Architecture configs: one module per assigned architecture.

``get_config(name)`` returns the full published config;
``get_config(name, smoke=True)`` returns the reduced same-family config used
by CPU smoke tests. ``ARCHITECTURES`` lists all assigned ids.
"""
from .base import ModelConfig, ShapeConfig, SHAPES, get_config, ARCHITECTURES  # noqa: F401
