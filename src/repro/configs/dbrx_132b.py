"""dbrx-132b [moe]: 40L d6144 48H (GQA kv=8) ff10752 V100352,
MoE 16e top-4 fine-grained. [hf:databricks/dbrx-base; unverified]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx_132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10752, vocab_size=100352,
        num_experts=16, experts_per_token=4, d_ff_moe=10752)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx_132b_smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        num_experts=4, experts_per_token=2, d_ff_moe=96)
