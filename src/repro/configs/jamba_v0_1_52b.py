"""jamba-v0.1-52b [hybrid]: 32L d4096 32H (GQA kv=8) ff14336 V65536,
MoE 16e top-2, Mamba+attn 1:7 interleave. [arXiv:2403.19887; hf]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="jamba_v0_1_52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=65536,
        num_experts=16, experts_per_token=2, d_ff_moe=14336, moe_every=2,
        attn_every=8, ssm_state=16, ssm_headdim=64, ssm_expand=2)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba_v0_1_52b_smoke", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        num_experts=4, experts_per_token=2, d_ff_moe=96, moe_every=2,
        attn_every=8, ssm_state=16, ssm_headdim=16, ssm_expand=2)
