"""internlm2-20b [dense]: 48L d6144 48H (GQA kv=8) ff16384 V92544.
[arXiv:2403.17297; hf]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2_20b", family="dense",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=92544)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2_20b_smoke", family="dense",
        num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
        d_ff=192, vocab_size=256)
