"""whisper-medium [audio enc-dec]: 24L d1024 16H (MHA) ff4096 V51865.
Conv frontend stubbed: input_specs feeds 1500 precomputed frame embeddings.
Deviations (DESIGN.md §4): decoder uses RoPE instead of Whisper's learned
448-position table (the assigned 32k decoder lengths exceed it); encoder
keeps learned positions. [arXiv:2212.04356; unverified]"""
from .base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="whisper_medium", family="encdec",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=51865,
        encoder_layers=24, encoder_frames=1500,
        norm_type="layer", activation="gelu")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper_medium_smoke", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        encoder_layers=2, encoder_frames=16,
        norm_type="layer", activation="gelu")
