"""Ambient activation-sharding context.

The launcher declares the mesh's data-parallel axes before tracing
(``set_dp_axes``), and model code marks activation layouts with
``constrain(x, "dp", None, "model")``-style hints. Hints are no-ops when no
mesh context is active (CPU smoke tests) or when a dimension isn't evenly
divisible (shape-aware, like param fitting). This is what keeps GSPMD from
replicating activations under FSDP-sharded weights (see EXPERIMENTS §Perf
iteration 0).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_DP_AXES: Optional[Tuple[str, ...]] = None
_AXIS_SIZES: Optional[dict] = None


def set_mesh_context(dp_axes, axis_sizes) -> None:
    global _DP_AXES, _AXIS_SIZES
    _DP_AXES = tuple(dp_axes) if dp_axes else None
    _AXIS_SIZES = dict(axis_sizes) if axis_sizes else None


def clear_mesh_context() -> None:
    set_mesh_context(None, None)


def constrain(x: jax.Array, *dims):
    """dims: one entry per axis of x — "dp", a mesh axis name, or None."""
    if _DP_AXES is None or _AXIS_SIZES is None:
        return x
    spec = []
    for size, d in zip(x.shape, dims):
        if d is None:
            spec.append(None)
            continue
        axes = _DP_AXES if d == "dp" else (d,)
        total = math.prod(_AXIS_SIZES.get(a, 1) for a in axes)
        spec.append((axes if d == "dp" else d)
                    if (total and size % total == 0) else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
