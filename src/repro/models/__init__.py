"""Model zoo: dense GQA / MoE / SSD / hybrid / enc-dec / VLM backbones."""
from .model import Model, build_model  # noqa: F401
