"""Mixture-of-Experts layer with paper-technique token dispatch.

Routing-to-slots is exactly the paper's stable-counting machinery: each
token's rank among same-expert tokens (``core.sort.bucket_ranks`` — prefix
sums over a one-hot expert matrix) is its capacity slot; overflowing tokens
are dropped (standard capacity-factor semantics). Dispatch/combine are
scatter/gather, experts run as one grouped einsum sharded over the ``model``
axis (expert parallelism).

Supports top-k routing, optional dense residual branch (arctic) and
fine-grained expert counts (dbrx, arctic, jamba).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sort import bucket_ranks

from .layers import swiglu_mlp
from .shard_ctx import constrain


def moe_layer(x: jax.Array, p: dict, cfg, capacity_factor: float = 1.25
              ) -> jax.Array:
    """x: (B, S, D) → (B, S, D).

    Params: router (D, E); w1, w3 (E, D, F); w2 (E, F, D);
    optional dense residual branch under p["dense"].
    """
    b, s, d = x.shape
    e = cfg.num_experts
    k = cfg.experts_per_token

    # bf16 matmul + f32 cast after (not preferred=f32): keeps the router's
    # dx cotangent bf16 (see layers.full_attention and §Perf iteration 1)
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    gate_vals, gate_idx = jax.lax.top_k(logits, k)           # (B, S, k)
    gate = jax.nn.softmax(gate_vals, axis=-1).astype(x.dtype)

    # Global (GShard-style) dispatch for every shape: all B·S·k routings
    # share one (E, cap, d) buffer. Two wins over per-example vmap
    # dispatch: (i) expert weights never need contraction-dim (data)
    # sharding, so FSDP weight gathers vanish — decisive once microbatch
    # grad-accum would otherwise re-gather weights per microbatch
    # (§Perf iterations 3–5); (ii) the capacity is pooled across the
    # batch (standard GShard semantics).
    out = _moe_apply_global(x.reshape(b * s, d),
                            gate_idx.reshape(b * s * k),
                            gate.reshape(b * s, k), p, cfg, e, k,
                            capacity_factor).reshape(b, s, d)
    if cfg.moe_dense_residual:
        out = out + swiglu_mlp(x, p["dense"])
    return out


def _moe_apply_global(xt: jax.Array, flat_e: jax.Array, gate: jax.Array,
                      p: dict, cfg, e: int, k: int,
                      capacity_factor: float) -> jax.Array:
    """Global-batch MoE for decode. xt: (T, D) tokens; flat_e: (T*k,).

    One (E, cap, D) buffer for the whole step; slot assignment is the
    paper's stable-counting primitive over all T·k routings.
    """
    t, d = xt.shape
    cap = max(8, int(t * k * capacity_factor / e))
    slot = bucket_ranks(flat_e, e)
    keep = slot < cap
    src = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[flat_e, jnp.minimum(slot, cap - 1)].add(
        jnp.where(keep[:, None], xt[src], 0))
    buf = constrain(buf, "model", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    # pin h to the f-sharding w1 produced (f over data): the w2 matmul can
    # then local-slice w2's unsharded f and reduce-scatter its d output —
    # without the pin SPMD re-gathers the (large) h across data per layer
    # per microbatch
    h = constrain(h, "model", None, "data")
    eout = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    # keep eout's d sharded like w2's output dim: demanding unsharded d
    # here makes SPMD gather the full w2 instead of resharding the (tiny)
    # expert outputs after the matmul
    eout = constrain(eout, "model", None, "data")
    tok_out = eout[flat_e, jnp.minimum(slot, cap - 1)]        # (T*k, D)
    tok_out = jnp.where(keep[:, None], tok_out, 0)
    w = gate.reshape(t * k)[:, None].astype(tok_out.dtype)
    return jnp.zeros((t, d), tok_out.dtype).at[src].add(tok_out * w)


def moe_param_shapes(cfg, d_ff_moe: int | None = None) -> dict:
    d = cfg.d_model
    e = cfg.num_experts
    f = d_ff_moe if d_ff_moe is not None else cfg.d_ff
    shapes = {
        "router": (d, e),
        "w1": (e, d, f),
        "w3": (e, d, f),
        "w2": (e, f, d),
    }
    if cfg.moe_dense_residual:
        shapes["dense"] = {"w1": (d, cfg.d_ff), "w3": (d, cfg.d_ff),
                           "w2": (cfg.d_ff, d)}
    return shapes
