"""Model assembly: params, shardings, and train/prefill/decode step functions.

All families share one structure: token embedding → ``lax.scan`` over a
stack of identical *blocks* (the smallest repeating layer pattern, so HLO
size is independent of depth) → final norm → LM head. Per-block params are
stacked on a leading (num_blocks,) axis; blocks are rematerialized
(``jax.checkpoint``) during training.

Families:
  dense   — [GQA attn, MLP]                        (granite/deepseek/internlm2/qwen2)
  moe     — [GQA attn, MoE(+dense residual)]       (arctic/dbrx)
  ssm     — [Mamba-2 SSD]                          (mamba2)
  hybrid  — period-8 block: attn at slot 3, Mamba elsewhere; MoE FF on odd
            slots, dense FF on even                 (jamba)
  encdec  — encoder [attn, MLP] + decoder [self, cross, MLP]   (whisper)
  vlm     — period-5 block: 4 self layers + 1 image-cross layer (llama-vision)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from .shard_ctx import constrain
from .moe import moe_layer, moe_param_shapes
from .ssm import CONV_K, mamba2_block, mamba2_decode, mamba2_param_shapes

Params = Dict[str, Any]

ACT_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.bfloat16


def _norm(x, scale, cfg):
    if cfg.norm_type == "layer":
        mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
        var = jnp.var(x.astype(jnp.float32), axis=-1, keepdims=True)
        return ((x.astype(jnp.float32) - mu)
                * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype) * scale
    return L.rms_norm(x, scale, cfg.norm_eps)


def _mlp(x, p, cfg):
    if cfg.activation == "gelu":
        return L.gelu_mlp(x, p)
    return L.swiglu_mlp(x, p)


# ==========================================================================
# Parameter shapes
# ==========================================================================

def _attn_shapes(cfg) -> Dict[str, tuple]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {"wq": (d, h, hd), "wk": (d, kv, hd), "wv": (d, kv, hd),
         "wo": (h, hd, d)}
    if cfg.qkv_bias:
        s.update({"bq": (h, hd), "bk": (kv, hd), "bv": (kv, hd)})
    return s


def _mlp_shapes(cfg, d_ff=None) -> Dict[str, tuple]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.activation == "gelu":
        return {"w1": (d, f), "w2": (f, d)}
    return {"w1": (d, f), "w3": (d, f), "w2": (f, d)}


def _block_shapes(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    if cfg.family in ("dense",):
        return {"ln1": (d,), "attn": _attn_shapes(cfg),
                "ln2": (d,), "mlp": _mlp_shapes(cfg)}
    if cfg.family == "moe":
        return {"ln1": (d,), "attn": _attn_shapes(cfg),
                "ln2": (d,), "moe": moe_param_shapes(cfg, cfg.d_ff_moe)}
    if cfg.family == "ssm":
        return {"ln1": (d,), "mamba": mamba2_param_shapes(cfg)}
    if cfg.family == "hybrid":
        per = cfg.period
        n_mamba = per - 1
        n_moe = per // cfg.moe_every
        n_dense = per - n_moe
        return {
            "ln_mix": (per, d),
            "ln_ff": (per, d),
            "attn": _attn_shapes(cfg),
            "mamba": _stack_shapes(mamba2_param_shapes(cfg), n_mamba),
            "moe": _stack_shapes(moe_param_shapes(cfg, cfg.d_ff_moe), n_moe),
            "mlp": _stack_shapes(_mlp_shapes(cfg), n_dense),
        }
    if cfg.family == "encdec":
        return {"ln1": (d,), "self_attn": _attn_shapes(cfg),
                "ln2": (d,), "cross_attn": _attn_shapes(cfg),
                "ln3": (d,), "mlp": _mlp_shapes(cfg)}
    if cfg.family == "vlm":
        n_self = cfg.period - 1
        return {
            "self": _stack_shapes({"ln1": (d,), "attn": _attn_shapes(cfg),
                                   "ln2": (d,), "mlp": _mlp_shapes(cfg)},
                                  n_self),
            "cross": {"ln1": (d,), "attn": _attn_shapes(cfg),
                      "ln2": (d,), "mlp": _mlp_shapes(cfg),
                      "gate_attn": (), "gate_mlp": ()},
        }
    raise ValueError(cfg.family)


def _stack_shapes(tree, n: int):
    return jax.tree.map(lambda s: (n,) + tuple(s), tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def _enc_block_shapes(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln1": (d,), "attn": _attn_shapes(cfg),
            "ln2": (d,), "mlp": _mlp_shapes(cfg)}


def param_shapes(cfg) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    shapes: Dict[str, Any] = {
        "embed": (v, d),
        "final_norm": (d,),
        "lm_head": (d, v),
        "blocks": _stack_shapes(_block_shapes(cfg), cfg.num_blocks),
    }
    if cfg.family == "encdec":
        shapes["enc_blocks"] = _stack_shapes(_enc_block_shapes(cfg),
                                             cfg.encoder_layers)
        shapes["enc_pos"] = (cfg.encoder_frames, d)
        shapes["enc_final_norm"] = (d,)
    return shapes


def abstract_params(cfg) -> Params:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(tuple(s), PARAM_DTYPE),
        param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple))


def count_params(cfg, active_only: bool = False) -> int:
    total = 0
    for path, shp in jax.tree_util.tree_flatten_with_path(
            param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple))[0]:
        size = math.prod(shp) if shp else 1
        if active_only and cfg.num_experts:
            keys = [getattr(k, "key", "") for k in path]
            if "moe" in keys and any(k in ("w1", "w2", "w3") for k in keys):
                size = size * cfg.experts_per_token // cfg.num_experts
        total += size
    return total


def count_expert_params(cfg) -> int:
    """Parameters in MoE expert banks (2D-shardable at decode)."""
    total = 0
    for path, shp in jax.tree_util.tree_flatten_with_path(
            param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple))[0]:
        keys = [getattr(k, "key", "") for k in path]
        if "moe" in keys and keys[-1] in ("w1", "w2", "w3"):
            total += math.prod(shp)
    return total


def init_params(cfg, seed: int = 0) -> Params:
    """Materialized init (smoke tests / examples — small configs only)."""
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    flat_paths = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))[0]

    def init_one(key, path_shape):
        path, shp = path_shape
        name = getattr(path[-1], "key", "")
        shp = tuple(shp)
        if name.startswith(("ln", "out_norm")) or "norm" in name or \
                name in ("D_skip",):
            return jnp.ones(shp, PARAM_DTYPE)
        if name in ("dt_bias",):
            return jnp.full(shp, -4.6, PARAM_DTYPE)
        if name in ("A_log",):
            return jnp.log(jnp.linspace(1.0, 8.0, shp[-1], dtype=jnp.float32)
                           ).astype(PARAM_DTYPE) * jnp.ones(shp, PARAM_DTYPE)
        if name.startswith(("b", "gate")):
            return jnp.zeros(shp, PARAM_DTYPE)
        # fan-in per leaf: attention weights are 3D — (d, h, hd) projects
        # from d (NOT shp[-2], which would be the head count), and
        # wo (h, hd, d) projects from h·hd.
        if name in ("wq", "wk", "wv"):
            fan_in = shp[-3]
        elif name == "wo":
            fan_in = shp[-3] * shp[-2]
        elif len(shp) >= 2:
            fan_in = shp[-2]
        else:
            fan_in = max(1, shp[-1] if shp else 1)
        # unit-scale embeddings: keeps the layer-1 pre-norm Jacobian O(1)
        # (a 0.02-scale embedding puts 1/rms ≈ 50× into the first RMSNorm
        # backward, which explodes the embed gradient with depth and stalls
        # Adam after global clipping).
        scale = 1.0 if name in ("embed",) else 1.0 / math.sqrt(fan_in)
        # GPT-2-style depth scaling on residual-out projections keeps the
        # stream variance ~constant with depth.
        if name in ("wo", "w2", "out_proj"):
            scale /= math.sqrt(2.0 * max(1, cfg.num_layers))
        return (jax.random.normal(key, shp, jnp.float32) * scale
                ).astype(PARAM_DTYPE)

    inits = [init_one(k, ps) for k, ps in zip(keys, flat_paths)]
    return jax.tree_util.tree_unflatten(treedef, inits)


# ==========================================================================
# Sharding rules
# ==========================================================================

# spec for the TRAILING dims of each named leaf; leading stack axes get None
_PARAM_RULES = {
    "embed": P("model", "data"),
    "lm_head": P("data", "model"),
    "enc_pos": P(None, None),
    "wq": P("data", "model", None),
    "wk": P("data", "model", None),
    "wv": P("data", "model", None),
    "wo": P("model", None, "data"),
    "bq": P("model", None),
    "bk": P("model", None),
    "bv": P("model", None),
    "w1": P("data", "model"),
    "w3": P("data", "model"),
    "w2": P("model", "data"),
    "router": P("data", None),
    "in_proj": P("data", "model"),
    "out_proj": P("model", "data"),
    "conv_w": P(None, "model"),
    "dt_bias": P("model"),
    "A_log": P("model"),
    "D_skip": P("model"),
    "out_norm": P("model"),
}

# Expert banks are 2D-sharded on (experts × ff) — never on the contraction
# dim. Contraction-dim (FSDP) sharding forces a full weight all-gather per
# layer per microbatch under grad accumulation (measured 8.9 GB/layer on
# arctic; §Perf iteration 5); ff-dim sharding costs only small activation
# reshards around the grouped einsums.
_MOE_RULES = {
    "w1": P("model", None, "data"),
    "w3": P("model", None, "data"),
    "w2": P("model", None, "data"),
}

# Decode-mode rules (§Perf iteration 3): weights sharded on NON-contracting
# dims only (Megatron TP), so a token step never all-gathers weight shards —
# FSDP's contraction-dim sharding amortizes over 10^6 train tokens but costs
# a full weight gather per decode step. MoE experts keep 2D (model × data)
# sharding via the f dimension so giant expert banks still fit.
_PARAM_RULES_DECODE = {
    "embed": P("model", None),
    "lm_head": P(None, "model"),
    "enc_pos": P(None, None),
    "wq": P(None, "model", None),
    "wk": P(None, "model", None),
    "wv": P(None, "model", None),
    "wo": P("model", None, None),
    "bq": P("model", None),
    "bk": P("model", None),
    "bv": P("model", None),
    "w1": P(None, "model"),
    "w3": P(None, "model"),
    "w2": P("model", None),
    "router": P(None, None),
    "in_proj": P(None, "model"),
    "out_proj": P("model", None),
    "conv_w": P(None, "model"),
    "dt_bias": P("model"),
    "A_log": P("model"),
    "D_skip": P("model"),
    "out_norm": P("model"),
}

_MOE_RULES_DECODE = {
    "w1": P("model", None, "data"),
    "w3": P("model", None, "data"),
    # w2 sharded on its OUTPUT dim (d over data), contraction f unsharded:
    # the reshard XLA must insert is then a ~1 MB h-gather, not a 1 GB
    # w2-gather (SPMD picks gather over partial-sum on mismatched f).
    "w2": P("model", None, "data"),
}

# TP-only dense shards above this per-device size keep the train-mode FSDP
# rules at decode (capacity over collective cost): llama-3.2-vision's 90B
# dense params would be 11.25 GB/device on a 16-way model axis, and
# arctic's 56 attention heads (indivisible by 16) would replicate 8.2 GB
# of attention weights. With the global-dispatch MoE (§Perf iter 5), FSDP
# decode sharding costs arctic only 0.32 GB/step of collectives anyway.
_DECODE_TP_BUDGET_BYTES = 4e9


def fit_spec(spec: P, shape, axis_sizes: Optional[Dict[str, int]]) -> P:
    """Drop sharded axes that do not divide the dimension evenly.

    GSPMD in/out shardings require exact divisibility (e.g. qwen2's kv=2
    cannot shard over model=16; granite's odd vocab cannot shard at all);
    the undivisible dims fall back to replication.
    """
    if axis_sizes is None:
        return spec
    new = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            new.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = math.prod(axis_sizes.get(a, 1) for a in axes)
        new.append(ax if (size > 0 and dim % size == 0) else None)
    return P(*new)


def param_specs(cfg, axis_sizes: Optional[Dict[str, int]] = None,
                mode: str = "train") -> Params:
    """PartitionSpec pytree matching param_shapes(cfg).

    ``axis_sizes`` (e.g. {"data": 16, "model": 16}) enables shape-aware
    fitting; without it the raw logical rules are returned. ``mode``:
    "train" = FSDP×TP (contraction dims sharded over data — weight gathers
    amortize over the batch); "decode" = TP-only (no per-step weight
    gathers; falls back to train rules when the TP shard would not fit).
    """
    shapes = param_shapes(cfg)
    decode = mode == "decode"
    if decode and axis_sizes:
        tp = axis_sizes.get("model", 1)
        dp = axis_sizes.get("data", 1)
        # expert banks stay 2D-sharded (model × data) in decode mode;
        # only the dense remainder is TP-only. Gate on the actual
        # per-device footprint the decode rules would produce.
        n_moe = count_expert_params(cfg)
        n_dense = count_params(cfg) - n_moe
        per_dev = 2.0 * (n_dense / tp + n_moe / (tp * dp))
        if per_dev > _DECODE_TP_BUDGET_BYTES:
            decode = False      # capacity-forced FSDP (e.g. vlm-90b)
    rules_main = _PARAM_RULES_DECODE if decode else _PARAM_RULES
    rules_moe = _MOE_RULES_DECODE if decode else _MOE_RULES

    def spec_for(path, shp):
        keys = [getattr(k, "key", "") for k in path]
        name = keys[-1]
        rules = rules_moe if ("moe" in keys and name in rules_moe) \
            else rules_main
        base = rules.get(name)
        if base is None:
            return P()          # norms, gates, scalars: replicated
        pad = len(shp) - len(base)
        if pad < 0:             # leaf smaller than rule (e.g. degenerate)
            return P()
        spec = P(*((None,) * pad + tuple(base)))
        return fit_spec(spec, shp, axis_sizes)

    return jax.tree_util.tree_map_with_path(
        spec_for, shapes, is_leaf=lambda x: isinstance(x, tuple))


def batch_spec(dp_axes) -> P:
    return P(dp_axes, None)


def cache_specs(cfg, dp_axes, batch: int, seq: int,
                axis_sizes: Optional[Dict[str, int]] = None,
                shard_seq: bool = True) -> Any:
    """PartitionSpec tree matching cache_shapes(cfg, batch, seq)."""
    def spec_for(path, shp):
        name = getattr(path[-1], "key", "")
        if name in ("k", "v"):
            base = (dp_axes, "model" if shard_seq else None, None, None)
        elif name in ("xk", "xv"):
            base = (dp_axes, None, None, None)
        elif name == "ssm":
            base = (dp_axes, "model", None, None)
        elif name == "conv":
            base = (dp_axes, None, "model")
        else:
            return P()
        pad = len(shp) - len(base)
        spec = P(*((None,) * pad + tuple(base)))
        return fit_spec(spec, shp, axis_sizes)

    shapes = cache_shapes(cfg, batch, seq)
    return jax.tree_util.tree_map_with_path(
        spec_for, shapes, is_leaf=lambda x: isinstance(x, tuple))


# ==========================================================================
# Cache shapes
# ==========================================================================

def cache_shapes(cfg, batch: int, seq: int) -> Dict[str, Any]:
    """Pytree of decode-cache shapes (tuples) for one model."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    nb = cfg.num_blocks
    h, n, pdim = (cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim) \
        if cfg.ssm_state else (0, 0, 0)
    conv_c = cfg.ssm_inner + 2 * cfg.ssm_state if cfg.ssm_state else 0
    if cfg.family in ("dense", "moe"):
        return {"k": (nb, batch, seq, kv, hd), "v": (nb, batch, seq, kv, hd)}
    if cfg.family == "ssm":
        return {"ssm": (nb, batch, h, n, pdim),
                "conv": (nb, batch, CONV_K - 1, conv_c)}
    if cfg.family == "hybrid":
        nm = cfg.period - 1
        return {"k": (nb, batch, seq, kv, hd),
                "v": (nb, batch, seq, kv, hd),
                "ssm": (nb, nm, batch, h, n, pdim),
                "conv": (nb, nm, batch, CONV_K - 1, conv_c)}
    if cfg.family == "encdec":
        return {"k": (nb, batch, seq, kv, hd),
                "v": (nb, batch, seq, kv, hd),
                "xk": (nb, batch, cfg.encoder_frames, kv, hd),
                "xv": (nb, batch, cfg.encoder_frames, kv, hd)}
    if cfg.family == "vlm":
        ns = cfg.period - 1
        return {"k": (nb, ns, batch, seq, kv, hd),
                "v": (nb, ns, batch, seq, kv, hd),
                "xk": (nb, batch, cfg.num_image_tokens, kv, hd),
                "xv": (nb, batch, cfg.num_image_tokens, kv, hd)}
    raise ValueError(cfg.family)


def abstract_cache(cfg, batch: int, seq: int):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(tuple(s), ACT_DTYPE),
                        cache_shapes(cfg, batch, seq),
                        is_leaf=lambda x: isinstance(x, tuple))


def zero_cache(cfg, batch: int, seq: int):
    return jax.tree.map(lambda s: jnp.zeros(tuple(s), ACT_DTYPE),
                        cache_shapes(cfg, batch, seq),
                        is_leaf=lambda x: isinstance(x, tuple))


# ==========================================================================
# Block forward functions (training / prefill)
# ==========================================================================

def _attn_sub(x, ln, attn_p, cfg, positions, q_chunk):
    return x + L.gqa_attention_train(_norm(x, ln, cfg), attn_p, cfg,
                                     positions, q_chunk=q_chunk)


def _block_train(x, bp, cfg, positions, memory, q_chunk):
    if cfg.family in ("dense",):
        x = _attn_sub(x, bp["ln1"], bp["attn"], cfg, positions, q_chunk)
        return x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
    if cfg.family == "moe":
        x = _attn_sub(x, bp["ln1"], bp["attn"], cfg, positions, q_chunk)
        return x + moe_layer(_norm(x, bp["ln2"], cfg), bp["moe"], cfg)
    if cfg.family == "ssm":
        return x + mamba2_block(_norm(x, bp["ln1"], cfg), bp["mamba"], cfg)
    if cfg.family == "hybrid":
        mi = di = 0
        for i in range(cfg.period):
            h = _norm(x, bp["ln_mix"][i], cfg)
            if i == cfg.period // 2 - 1:      # attn slot (1:7 interleave)
                x = x + L.gqa_attention_train(h, bp["attn"], cfg, positions,
                                              q_chunk=q_chunk)
            else:
                x = x + mamba2_block(
                    h, jax.tree.map(lambda a: a[mi], bp["mamba"]), cfg)
                mi += 1
            hf = _norm(x, bp["ln_ff"][i], cfg)
            if i % cfg.moe_every == 1:
                x = x + moe_layer(
                    hf, jax.tree.map(lambda a: a[i // cfg.moe_every],
                                     bp["moe"]), cfg)
            else:
                x = x + _mlp(hf, jax.tree.map(lambda a: a[di], bp["mlp"]),
                             cfg)
                di += 1
        return x
    if cfg.family == "encdec":
        x = _attn_sub(x, bp["ln1"], bp["self_attn"], cfg, positions, q_chunk)
        x = x + L.cross_attention(_norm(x, bp["ln2"], cfg), memory,
                                  bp["cross_attn"], cfg)
        return x + _mlp(_norm(x, bp["ln3"], cfg), bp["mlp"], cfg)
    if cfg.family == "vlm":
        for i in range(cfg.period - 1):
            sp = jax.tree.map(lambda a: a[i], bp["self"])
            x = _attn_sub(x, sp["ln1"], sp["attn"], cfg, positions, q_chunk)
            x = x + _mlp(_norm(x, sp["ln2"], cfg), sp["mlp"], cfg)
        cp = bp["cross"]
        x = x + jnp.tanh(cp["gate_attn"]) * L.cross_attention(
            _norm(x, cp["ln1"], cfg), memory, cp["attn"], cfg)
        return x + jnp.tanh(cp["gate_mlp"]) * _mlp(
            _norm(x, cp["ln2"], cfg), cp["mlp"], cfg)
    raise ValueError(cfg.family)


def _encoder(params, cfg, frames):
    """Whisper encoder over stubbed frame embeddings (B, F, D)."""
    x = frames + params["enc_pos"][None].astype(frames.dtype)
    positions = jnp.arange(cfg.encoder_frames)[None, :]

    def body(h, bp):
        h = h + L.gqa_attention_train(
            _norm(h, bp["ln1"], cfg), bp["attn"], cfg, positions,
            q_chunk=None)
        # encoder self-attention is bidirectional
        return h + _mlp(_norm(h, bp["ln2"], cfg), bp["mlp"], cfg), None

    # NOTE: encoder attention must be non-causal; handled via flag below.
    def body_nc(h, bp):
        hn = _norm(h, bp["ln1"], cfg)
        q = jnp.einsum("bsd,dhk->bshk", hn, bp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", hn, bp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", hn, bp["attn"]["wv"])
        groups = cfg.num_heads // cfg.num_kv_heads
        k = L._repeat_kv(k, groups)
        v = L._repeat_kv(v, groups)
        o = L.full_attention(q, k, v, causal=False)
        h = h + jnp.einsum("bshk,hkd->bsd", o, bp["attn"]["wo"])
        return h + _mlp(_norm(h, bp["ln2"], cfg), bp["mlp"], cfg), None

    x, _ = jax.lax.scan(jax.checkpoint(body_nc), x, params["enc_blocks"])
    return _norm(x, params["enc_final_norm"], cfg)


def forward_train(params: Params, cfg, tokens: jax.Array,
                  extras: Optional[Dict[str, jax.Array]] = None,
                  q_chunk: Optional[int] = 512,
                  logits_mode: str = "all") -> jax.Array:
    """tokens: (B, S) → logits (B, S, V) (or (B, V) for logits_mode="last")."""
    b, s = tokens.shape
    x = params["embed"].astype(ACT_DTYPE)[tokens]
    x = constrain(x, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    memory = None
    if cfg.family == "encdec":
        memory = _encoder(params, cfg, extras["frames"].astype(ACT_DTYPE))
    elif cfg.family == "vlm":
        memory = extras["image_embeds"].astype(ACT_DTYPE)

    def body(h, bp):
        h = _block_train(h, bp, cfg, positions, memory, q_chunk)
        return constrain(h, "dp", None, None), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["blocks"])
    x = _norm(x, params["final_norm"], cfg)
    if logits_mode == "last":
        x = x[:, -1:]
    # bf16 matmul with an f32 cast AFTER: the cast boundary keeps the
    # residual-stream cotangent bf16 through the whole backward scan —
    # with preferred_element_type=f32 the f32 cotangent propagates into
    # every layer and doubles all backward collective/memory traffic
    # (EXPERIMENTS §Perf iteration 1).
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(ACT_DTYPE))
    logits = constrain(logits, "dp", None, "model")
    logits = logits.astype(jnp.float32)
    return logits[:, 0] if logits_mode == "last" else logits


# ==========================================================================
# Decode (serve_step)
# ==========================================================================

def _attn_decode_sub(x, ln, attn_p, cfg, k, v, pos):
    h = _norm(x, ln, cfg)
    o, k, v = L.gqa_attention_decode(h, attn_p, cfg, k, v, pos)
    return x + o, k, v


def _block_decode(x, bp, cfg, cache_b, pos, memory_kv):
    """One block, one token. cache_b: this block's cache slice."""
    if cfg.family in ("dense", "moe"):
        x, k, v = _attn_decode_sub(x, bp["ln1"], bp["attn"], cfg,
                                   cache_b["k"], cache_b["v"], pos)
        if cfg.family == "dense":
            x = x + _mlp(_norm(x, bp["ln2"], cfg), bp["mlp"], cfg)
        else:
            x = x + moe_layer(_norm(x, bp["ln2"], cfg), bp["moe"], cfg)
        return x, {"k": k, "v": v}
    if cfg.family == "ssm":
        h = _norm(x, bp["ln1"], cfg)
        o, st, cv = mamba2_decode(h, bp["mamba"], cfg,
                                  cache_b["ssm"], cache_b["conv"])
        return x + o, {"ssm": st, "conv": cv}
    if cfg.family == "hybrid":
        new_ssm, new_conv = [], []
        k = v = None
        mi = di = 0
        for i in range(cfg.period):
            h = _norm(x, bp["ln_mix"][i], cfg)
            if i == cfg.period // 2 - 1:
                o, k, v = L.gqa_attention_decode(h, bp["attn"], cfg,
                                                 cache_b["k"], cache_b["v"],
                                                 pos)
                x = x + o
            else:
                o, st, cv = mamba2_decode(
                    h, jax.tree.map(lambda a: a[mi], bp["mamba"]), cfg,
                    cache_b["ssm"][mi], cache_b["conv"][mi])
                new_ssm.append(st)
                new_conv.append(cv)
                x = x + o
                mi += 1
            hf = _norm(x, bp["ln_ff"][i], cfg)
            if i % cfg.moe_every == 1:
                x = x + moe_layer(
                    hf, jax.tree.map(lambda a: a[i // cfg.moe_every],
                                     bp["moe"]), cfg)
            else:
                x = x + _mlp(hf, jax.tree.map(lambda a: a[di], bp["mlp"]),
                             cfg)
                di += 1
        return x, {"k": k, "v": v, "ssm": jnp.stack(new_ssm),
                   "conv": jnp.stack(new_conv)}
    if cfg.family == "encdec":
        x, k, v = _attn_decode_sub(x, bp["ln1"], bp["self_attn"], cfg,
                                   cache_b["k"], cache_b["v"], pos)
        h = _norm(x, bp["ln2"], cfg)
        x = x + _cross_decode(h, bp["cross_attn"], cfg,
                              cache_b["xk"], cache_b["xv"])
        x = x + _mlp(_norm(x, bp["ln3"], cfg), bp["mlp"], cfg)
        return x, {"k": k, "v": v, "xk": cache_b["xk"], "xv": cache_b["xv"]}
    if cfg.family == "vlm":
        ks, vs = [], []
        for i in range(cfg.period - 1):
            sp = jax.tree.map(lambda a: a[i], bp["self"])
            x, k, v = _attn_decode_sub(x, sp["ln1"], sp["attn"], cfg,
                                       cache_b["k"][i], cache_b["v"][i], pos)
            x = x + _mlp(_norm(x, sp["ln2"], cfg), sp["mlp"], cfg)
            ks.append(k)
            vs.append(v)
        cp = bp["cross"]
        h = _norm(x, cp["ln1"], cfg)
        x = x + jnp.tanh(cp["gate_attn"]) * _cross_decode(
            h, cp["attn"], cfg, cache_b["xk"], cache_b["xv"])
        x = x + jnp.tanh(cp["gate_mlp"]) * _mlp(
            _norm(x, cp["ln2"], cfg), cp["mlp"], cfg)
        return x, {"k": jnp.stack(ks), "v": jnp.stack(vs),
                   "xk": cache_b["xk"], "xv": cache_b["xv"]}
    raise ValueError(cfg.family)


def _cross_decode(x, p, cfg, xk, xv):
    """Cross-attention against precomputed memory K/V. x: (B, 1, D)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    groups = cfg.num_heads // cfg.num_kv_heads
    kk = L._repeat_kv(xk, groups)
    vv = L._repeat_kv(xv, groups)
    mask = jnp.ones((x.shape[0], xk.shape[1]), bool)
    o = L.decode_attention(q, kk, vv, mask)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def forward_decode(params: Params, cfg, tokens: jax.Array, cache,
                   pos: jax.Array):
    """tokens: (B, 1); pos: (B,) current positions (aligned batches).

    Returns (logits (B, V), new_cache).
    """
    x = params["embed"].astype(ACT_DTYPE)[tokens]
    x = constrain(x, "dp", None, None)

    def body(h, inp):
        bp, cb = inp
        h, new_cb = _block_decode(h, bp, cfg, cb, pos, None)
        return constrain(h, "dp", None, None), new_cb

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = _norm(x, params["final_norm"], cfg)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(ACT_DTYPE)
                        ).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    return logits[:, 0], new_cache


# ==========================================================================
# Model facade
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any

    # ----- shapes / specs -----
    def abstract_params(self):
        return abstract_params(self.cfg)

    def init(self, seed: int = 0):
        return init_params(self.cfg, seed)

    def param_specs(self):
        return param_specs(self.cfg)

    def extras_shapes(self, batch: int) -> Dict[str, tuple]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return {"frames": (batch, cfg.encoder_frames, cfg.d_model)}
        if cfg.family == "vlm":
            return {"image_embeds": (batch, cfg.num_image_tokens,
                                     cfg.d_model)}
        return {}

    # ----- step functions -----
    def loss_fn(self, params, tokens, extras=None, q_chunk=512):
        """tokens: (B, S+1). Mean next-token cross-entropy."""
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        logits = forward_train(params, self.cfg, inp, extras,
                               q_chunk=q_chunk)
        # vocab-padding slots never receive probability mass
        if self.cfg.padded_vocab != self.cfg.vocab_size:
            valid = jnp.arange(self.cfg.padded_vocab) < self.cfg.vocab_size
            logits = jnp.where(valid, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    def prefill(self, params, tokens, extras=None, q_chunk=512):
        """Forward pass returning last-position logits only."""
        logits = forward_train(params, self.cfg, tokens, extras,
                               q_chunk=q_chunk, logits_mode="last")
        if self.cfg.padded_vocab != self.cfg.vocab_size:
            valid = jnp.arange(self.cfg.padded_vocab) < self.cfg.vocab_size
            logits = jnp.where(valid, logits, -1e30)
        return logits

    def decode_step(self, params, tokens, cache, pos):
        return forward_decode(params, self.cfg, tokens, cache, pos)


def build_model(cfg) -> Model:
    return Model(cfg=cfg)
