"""Shared transformer layers: norms, RoPE, GQA attention, MLPs.

Everything is a pure function over explicit parameter pytrees (no module
framework): params are dicts of arrays, shapes documented per function.
Attention supports three modes: full (training), query-chunked online-softmax
(long prefill, bounded memory), and single-token decode against a KV cache.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

DP_AXES = ("pod", "data")      # batch axes (pod absent on single-pod meshes)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            ).astype(x.dtype) * scale


def rope_frequencies(head_dim: int, theta: float, positions: jax.Array):
    """(..., head_dim/2) cos/sin tables for the given positions."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                      dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KV, hd) → (B, S, KV*groups, hd) for GQA."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, kv, groups, hd)).reshape(b, s, kv * groups, hd)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   causal: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, H, hd) — plain softmax attention."""
    hd = q.shape[-1]
    # bf16 matmul + f32 cast AFTER (not preferred_element_type): keeps the
    # backward cotangents of q/k bf16 — a preferred=f32 einsum transposes
    # to f32 gradients that infect the whole backward stream (§Perf iter 1).
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        # additive 2D bias (not a 5D select mask): stays a loop-invariant
        # (Sq, Sk) f32 instead of a hoisted (chunks, B, H, Sq, Sk) pred
        bias = jnp.where(jnp.arange(sk)[None, :]
                         <= (jnp.arange(sq)[:, None] + (sk - sq)),
                         0.0, -1e30).astype(jnp.float32)
        scores = scores + bias[None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_chunk: int = 1024, causal: bool = True) -> jax.Array:
    """Online-softmax attention, scanned over query chunks.

    Bounds activation memory to O(q_chunk · Sk) per head instead of
    O(Sq · Sk) — required for the 32k-prefill shapes. Matches
    full_attention bit-for-bit up to fp accumulation order.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    assert sq % q_chunk == 0
    nchunks = sq // q_chunk
    qs = q.reshape(b, nchunks, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(sk)

    def chunk_out(qc, ci):
        qpos = ci * q_chunk + jnp.arange(q_chunk) + (sk - sq)
        scores = (jnp.einsum("bqhd,bkhd->bhqk", qc, k).astype(jnp.float32)
                  / math.sqrt(hd))
        if causal:
            bias = jnp.where(kpos[None, :] <= qpos[:, None],
                             0.0, -1e30).astype(jnp.float32)
            scores = scores + bias[None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    # remat each chunk: the (q_chunk × Sk) score matrix is recomputed in the
    # backward pass instead of being stacked across the chunk scan — this is
    # what bounds attention memory to one chunk (EXPERIMENTS §Perf iter 1).
    chunk_out = jax.checkpoint(chunk_out)

    def body(carry, inp):
        qc, ci = inp
        return carry, chunk_out(qc, ci)

    _, outs = jax.lax.scan(body, None,
                           (qs, jnp.arange(nchunks)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length_mask: jax.Array) -> jax.Array:
    """Single-position attention: q (B, 1, H, hd) vs cache (B, S, H, hd).

    ``length_mask``: (B, S) bool — True for valid cache slots. The score
    reduction runs over the (possibly sequence-sharded) cache axis, so
    GSPMD lowers it to partial reductions + a small all-reduce instead of
    gathering the cache (see EXPERIMENTS §Perf).
    """
    hd = q.shape[-1]
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q, k_cache)
              .astype(jnp.float32) / math.sqrt(hd))
    scores = jnp.where(length_mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v_cache)


def gqa_attention_train(x: jax.Array, p: dict, cfg, positions: jax.Array,
                        q_chunk: Optional[int] = None) -> jax.Array:
    """Full-sequence GQA attention. x: (B, S, D)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    cos, sin = rope_frequencies(hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    groups = cfg.num_heads // cfg.num_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    if q_chunk is not None and s > q_chunk:
        o = chunked_attention(q, k, v, q_chunk=q_chunk)
    else:
        o = full_attention(q, k, v)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def gqa_attention_decode(x: jax.Array, p: dict, cfg, cache_k, cache_v,
                         pos: jax.Array):
    """One-token decode. x: (B, 1, D); cache: (B, S_max, KV, hd).

    Returns (out (B, 1, D), new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    posb = jnp.broadcast_to(pos.reshape(-1, 1), (b, 1))
    cos, sin = rope_frequencies(hd, cfg.rope_theta, posb)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos[0], axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos[0], axis=1)
    groups = cfg.num_heads // cfg.num_kv_heads
    kk = _repeat_kv(cache_k, groups)
    vv = _repeat_kv(cache_v, groups)
    smax = cache_k.shape[1]
    length_mask = jnp.arange(smax)[None, :] <= pos.reshape(-1, 1)
    o = decode_attention(q, kk, vv, length_mask)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), cache_k, cache_v


def cross_attention(x: jax.Array, memory: jax.Array, p: dict,
                    cfg) -> jax.Array:
    """Cross-attention over a fixed memory (encoder states / image tokens)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    groups = cfg.num_heads // cfg.num_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    o = full_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def swiglu_mlp(x: jax.Array, p: dict) -> jax.Array:
    from .shard_ctx import constrain
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w3"])
    # pin the hidden f-sharding so the w2 matmul partial-sums (one small
    # activation all-reduce) instead of gathering the w2 shard
    h = constrain(h, "dp", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


def gelu_mlp(x: jax.Array, p: dict) -> jax.Array:
    from .shard_ctx import constrain
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    h = constrain(h, "dp", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])
