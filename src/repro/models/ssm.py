"""Mamba-2 SSD (state-space duality) blocks — training scan + O(1) decode.

Chunked SSD algorithm (Dao & Gu 2024): within chunks of length Q the output
is an attention-like quadratic form masked by cumulative decays; across
chunks a (H, P, N) state is carried by a linear recurrence. Both the
intra-chunk form and the recurrence are exact — this is the standard
sub-quadratic formulation that makes ``long_500k`` decodable in O(1)/token.

Shapes (per layer): x (B, S, H, P) heads×headdim, B/C (B, S, N) shared
across heads (G=1), dt (B, S, H), A (H,) negative decay rates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rms_norm

CONV_K = 4   # causal depthwise conv width (Mamba standard)


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Exact chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); A: (H,); Bm/Cm: (B, S, N).
    Returns y: (B, S, H, P).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    loga = dtc * A                                  # (b, nc, Q, h) ≤ 0
    L = jnp.cumsum(loga, axis=2)                    # within-chunk cumulative

    # --- intra-chunk quadratic term ------------------------------------
    # M[t, s] = (C_t · B_s) · exp(L_t − L_s) · dt_s   for s ≤ t
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc
                    ).astype(jnp.float32)                     # (b,nc,Q,Q)
    decay = L[:, :, :, None, :] - L[:, :, None, :, :]         # (b,nc,Q,Q,h)
    tmask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    gate = jnp.where(tmask[None, None, :, :, None],
                     jnp.exp(decay), 0.0)
    m = cb[..., None] * gate * dtc[:, :, None, :, :]          # (b,nc,Q,Q,h)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", m.astype(x.dtype), xc)

    # --- chunk summaries and inter-chunk recurrence ---------------------
    # S_c = Σ_s exp(L_end − L_s) dt_s · B_s ⊗ x_s      (b, nc, h, n, p)
    end_decay = jnp.exp(L[:, :, -1:, :] - L)                  # (b,nc,Q,h)
    wgt = (end_decay * dtc).astype(x.dtype)
    s_chunk = jnp.einsum("bcsh,bcsn,bcshp->bchnp", wgt, Bc.astype(x.dtype), xc)
    chunk_decay = jnp.exp(L[:, :, -1, :])                     # (b,nc,h)

    def body(hstate, inp):
        s_c, g_c = inp                    # (b,h,n,p), (b,h)
        out = hstate                      # state BEFORE this chunk
        new = hstate * g_c[..., None, None].astype(x.dtype) + s_c
        return new, out

    h0 = jnp.zeros((b, h, n, p), x.dtype)
    _, h_prev = jax.lax.scan(
        body, h0, (s_chunk.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                  # (b,nc,h,n,p)

    # --- inter-chunk contribution ---------------------------------------
    instate_decay = jnp.exp(L).astype(x.dtype)                # (b,nc,Q,h)
    y_inter = jnp.einsum("bcth,bctn,bchnp->bcthp",
                         instate_decay, Cc.astype(x.dtype), h_prev)
    return (y_intra + y_inter).reshape(b, s, h, p)


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. u: (B, S, C); w: (K, C)."""
    pads = jnp.pad(u, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(CONV_K):
        out = out + pads[:, i:i + u.shape[1]] * w[i]
    return out


def mamba2_block(x: jax.Array, p: dict, cfg, chunk: int = 256) -> jax.Array:
    """Full Mamba-2 mixer. x: (B, S, D) → (B, S, D)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    din = h * pdim
    zxbc = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xin, Bm, Cm, dt = jnp.split(
        zxbc, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"]))
    xin, Bm, Cm = jnp.split(conv_out, [din, din + n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])                   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                  # (H,)
    y = _ssd_chunked(xin.reshape(b, s, h, pdim), dt, A, Bm, Cm, chunk)
    y = y + xin.reshape(b, s, h, pdim) * p["D_skip"][None, None, :, None]
    y = y.reshape(b, s, din) * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def mamba2_decode(x: jax.Array, p: dict, cfg, ssm_state, conv_state):
    """One-token decode. x: (B, 1, D); ssm_state: (B, H, N, P);
    conv_state: (B, CONV_K-1, C). Returns (y, ssm_state, conv_state)."""
    b = x.shape[0]
    h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    din = h * pdim
    zxbc = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xin, Bm, Cm, dt = jnp.split(
        zxbc, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)         # (B,1,C)
    window = jnp.concatenate([conv_state, conv_in], axis=1)   # (B,K,C)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window,
                                      p["conv_w"]))[:, None, :]
    new_conv_state = window[:, 1:]
    xin, Bm, Cm = jnp.split(conv_out, [din, din + n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])[:, 0]             # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                       # (B,H)
    xh = xin.reshape(b, h, pdim)
    dBx = jnp.einsum("bh,bn,bhp->bhnp", dt.astype(x.dtype),
                     Bm[:, 0].astype(x.dtype), xh)
    new_state = ssm_state * a[..., None, None].astype(x.dtype) + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(x.dtype), new_state)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(b, 1, din) * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    return (jnp.einsum("bse,ed->bsd", y, p["out_proj"]),
            new_state, new_conv_state)


def mamba2_param_shapes(cfg) -> dict:
    d = cfg.d_model
    h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    din = h * pdim
    conv_c = din + 2 * n
    return {
        "in_proj": (d, 2 * din + 2 * n + h),
        "conv_w": (CONV_K, conv_c),
        "dt_bias": (h,),
        "A_log": (h,),
        "D_skip": (h,),
        "out_norm": (din,),
        "out_proj": (din, d),
    }
