"""Sharded batched analytics engine over stacked wavelet-matrix shards.

Mirrors ``repro.index.sharded.ShardedTextIndex``: per-shard structures with
identical static geometry stack leaf-wise into one pytree with a leading
``(num_shards,)`` axis, so a query batch fans across all shards as a single
``vmap`` and the whole serving path is one jitted kernel.

Cross-shard reductions keep every op *exact* (not a merge of per-shard
approximations):

* ``count``     — per-shard orthogonal counts sum.
* ``quantile``  — count-then-refine: at each bit level the zero counts of
                  every shard's interval are summed before branching, so
                  all shards descend in lockstep on the global k.
* ``top-k``     — one greedy frontier whose nodes carry a per-shard
                  interval vector; a node's weight is the summed width.
* ``distinct``  — per-shard histograms sum, then count non-zeros (a symbol
                  present in several shards is counted once).

Module-level functions take the raw stacked ``WaveletMatrix`` + geometry so
``CompressedCorpus`` can delegate without a circular import; the
``ShardedAnalytics`` dataclass is the serving-layer handle.

Degraded mode: every op takes an optional per-shard ``available`` mask
(engine field, default all-available). An unavailable shard contributes an
*empty* local range — its ``hi`` clamps to ``lo`` before the reduction —
so every op serves exactly the surviving data with no special-casing in
the descent logic: counts/histograms/distinct cover only available
shards, quantiles rank within the covered positions. ``coverage`` reports
the covered fraction per query, and ``range_count_bounds`` /
``range_histogram_bounds`` bracket the true full-corpus answer (lower =
covered count, upper = lower + uncovered positions).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.wavelet_matrix import (WaveletMatrix, wm_child_interval,
                                       wm_interval_zeros)

from . import range_ops

_I32 = jnp.int32


def _num_shards(shards: WaveletMatrix) -> int:
    return jax.tree.leaves(shards)[0].shape[0]


def _shard(shards: WaveletMatrix, s) -> WaveletMatrix:
    return jax.tree.map(lambda l: l[s], shards)


def local_ranges(shard_bits: int, num_shards: int, n: int,
                 lo: jax.Array, hi: jax.Array):
    """Decompose global [lo, hi) into per-shard local ranges.

    Returns ``(los, his)`` of shape ``(S,) + lo.shape``: shard ``s`` covers
    global positions ``[s·2^shard_bits, (s+1)·2^shard_bits)``; its local
    range is the (possibly empty) intersection, clipped so the padded tail
    of the last shard (positions ≥ n) is never touched.
    """
    size = 1 << shard_bits
    lo = jnp.clip(jnp.asarray(lo, _I32), 0, n)
    hi = jnp.clip(jnp.asarray(hi, _I32), 0, n)
    hi = jnp.maximum(hi, lo)
    bases = (jnp.arange(num_shards, dtype=_I32) << shard_bits)
    bases = bases.reshape((num_shards,) + (1,) * jnp.ndim(lo))
    los = jnp.clip(lo[None] - bases, 0, size)
    his = jnp.clip(hi[None] - bases, 0, size)
    return los, his


def mask_ranges(los: jax.Array, his: jax.Array, available):
    """Clamp the local ranges of unavailable shards to empty.

    ``available``: (S,) bool mask or None (all available). Emptying the
    range is the single masking primitive every degraded-mode op shares —
    the descent/reduction logic downstream never sees the mask.
    """
    if available is None:
        return los, his
    S = los.shape[0]
    m = jnp.asarray(available, bool).reshape((S,) + (1,) * (los.ndim - 1))
    return los, jnp.where(m, his, los)


# --------------------------------------------------------------------------
# exact cross-shard ops on the stacked pytree
# --------------------------------------------------------------------------

def sharded_range_count(shards: WaveletMatrix, shard_bits: int, n: int,
                        lo, hi, sym_lo, sym_hi,
                        available=None) -> jax.Array:
    """Orthogonal range count over the whole corpus: per-shard counts sum.
    Broadcasts over batched query arrays. ``available`` masks shards out
    (degraded mode: the count covers surviving shards only)."""
    S = _num_shards(shards)
    los, his = mask_ranges(*local_ranges(shard_bits, S, n, lo, hi),
                           available)
    per = jax.vmap(
        lambda wm, a, b: range_ops.range_count(wm, a, b, sym_lo, sym_hi)
    )(shards, los, his)
    return jnp.sum(per, axis=0)


def sharded_coverage(shard_bits: int, num_shards: int, n: int, lo, hi,
                     available) -> jax.Array:
    """Fraction of [lo, hi) positions living on available shards.

    1.0 for fully-covered (or empty) queries; broadcasts over batches.
    The explicit honesty signal degraded-mode answers ship with.
    """
    los, his = local_ranges(shard_bits, num_shards, n, lo, hi)
    total = jnp.sum(his - los, axis=0)
    _, mhis = mask_ranges(los, his, available)
    covered = jnp.sum(mhis - los, axis=0)
    return jnp.where(total > 0,
                     covered.astype(jnp.float32)
                     / jnp.maximum(total, 1).astype(jnp.float32),
                     jnp.float32(1.0))


def sharded_range_count_bounds(shards: WaveletMatrix, shard_bits: int,
                               n: int, lo, hi, sym_lo, sym_hi,
                               available=None):
    """(lower, upper, coverage) bracketing the true full-corpus count.

    ``lower`` counts surviving shards; every uncovered position could hold
    a matching symbol, so ``upper = lower + uncovered``. With full
    availability lower == upper == the exact count.
    """
    S = _num_shards(shards)
    lower = sharded_range_count(shards, shard_bits, n, lo, hi,
                                sym_lo, sym_hi, available)
    los, his = local_ranges(shard_bits, S, n, lo, hi)
    total = jnp.sum(his - los, axis=0)
    _, mhis = mask_ranges(los, his, available)
    covered = jnp.sum(mhis - los, axis=0)
    cov = jnp.where(total > 0,
                    covered.astype(jnp.float32)
                    / jnp.maximum(total, 1).astype(jnp.float32),
                    jnp.float32(1.0))
    return lower, lower + (total - covered), cov


def sharded_range_quantile(shards: WaveletMatrix, shard_bits: int, n: int,
                           lo, hi, k, available=None) -> jax.Array:
    """Global k-th smallest symbol in [lo, hi): count-then-refine descent.

    Every shard keeps its own interval; the branch decision at each level
    compares k against the *summed* zero count, then all shards take the
    same child. O(S·logσ) rank probes per query. Broadcasts over batches.
    Under an ``available`` mask the descent ranks within the covered
    positions only (k clips to the covered total).
    """
    S = _num_shards(shards)
    nbits = shards.nbits
    los, his = mask_ranges(*local_ranges(shard_bits, S, n, lo, hi),
                           available)
    total = jnp.sum(his - los, axis=0)
    k = jnp.clip(jnp.asarray(k, _I32), 0, jnp.maximum(total - 1, 0))
    empty = total <= 0
    sym = jnp.zeros_like(k)
    for l in range(nbits):
        lo0, hi0 = jax.vmap(
            lambda wm, a, b: wm_interval_zeros(wm, l, a, b)
        )(shards, los, his)
        z = jnp.sum(hi0 - lo0, axis=0)
        bit = (k >= z).astype(_I32)
        k = jnp.where(bit == 1, k - z, k)
        sym = (sym << 1) | bit
        los, his = jax.vmap(
            lambda wm, a, b, z0, h0: wm_child_interval(wm, l, a, b, bit,
                                                       z0, h0)
        )(shards, los, his, lo0, hi0)
    return jnp.where(empty, jnp.asarray(-1, _I32), sym)


def sharded_range_quantile_fused(shards: WaveletMatrix, shard_bits: int,
                                 n: int, lo, hi, k,
                                 interpret: bool | None = None,
                                 available=None) -> jax.Array:
    """Kernel form of ``sharded_range_quantile``: the whole count-then-
    refine descent (all shards × all levels) runs as ONE fused Pallas
    launch per query block (``kernels.wm_quantile_sharded_batch``), with
    every shard's bitmaps + rank directories resident in VMEM. Exact same
    results; (Q,) batches only (the XLA path broadcasts arbitrary shapes).
    Degraded mode (an ``available`` mask) routes to the XLA descent — the
    fused kernel assumes full shard residency.
    """
    if available is not None:
        obs.counter("analytics.path", op="quantile",
                    path="degraded_xla").inc()
        return sharded_range_quantile(shards, shard_bits, n, lo, hi, k,
                                      available)
    obs.counter("analytics.path", op="quantile", path="kernel").inc()
    from repro.kernels import ops as _kops
    return _kops.wm_quantile_sharded_batch(shards, shard_bits, n, lo, hi, k,
                                           interpret=interpret)


def sharded_range_quantile_bracket(shards: WaveletMatrix, shard_bits: int,
                                   n: int, lo, hi, k, levels: int,
                                   available=None):
    """Reduced-refinement quantile: descend only the top ``levels`` of the
    ``nbits`` bit levels and return ``(sym_lo, sym_hi)`` — the half-open
    symbol bracket ``[sym_lo, sym_hi)`` that provably contains the exact
    k-th smallest. ``levels == nbits`` collapses the bracket to
    ``[sym, sym+1)`` (the exact answer); each level shaved halves the
    descent cost (O(S·levels) rank probes) and doubles the bracket width
    (``2^(nbits-levels)`` symbols). The degradation ladder's cheap
    quantile rung: honest because the bracket is reported, not a point
    estimate. Empty/uncovered ranges return ``(-1, -1)``.
    """
    S = _num_shards(shards)
    nbits = shards.nbits
    levels = max(0, min(int(levels), nbits))
    los, his = mask_ranges(*local_ranges(shard_bits, S, n, lo, hi),
                           available)
    total = jnp.sum(his - los, axis=0)
    k = jnp.clip(jnp.asarray(k, _I32), 0, jnp.maximum(total - 1, 0))
    empty = total <= 0
    sym = jnp.zeros_like(k)
    for l in range(levels):
        lo0, hi0 = jax.vmap(
            lambda wm, a, b: wm_interval_zeros(wm, l, a, b)
        )(shards, los, his)
        z = jnp.sum(hi0 - lo0, axis=0)
        bit = (k >= z).astype(_I32)
        k = jnp.where(bit == 1, k - z, k)
        sym = (sym << 1) | bit
        los, his = jax.vmap(
            lambda wm, a, b, z0, h0: wm_child_interval(wm, l, a, b, bit,
                                                       z0, h0)
        )(shards, los, his, lo0, hi0)
    width = nbits - levels
    sym_lo = sym << width
    sym_hi = (sym + 1) << width
    neg1 = jnp.asarray(-1, _I32)
    return (jnp.where(empty, neg1, sym_lo),
            jnp.where(empty, neg1, sym_hi))


def sharded_range_topk(shards: WaveletMatrix, shard_bits: int, n: int,
                       lo, hi, k: int, available=None):
    """Exact global top-k: per-shard histograms sum, then one ``top_k``.

    ``lo``/``hi`` may be scalars or (B,) batches; returns (..., k) syms and
    counts sorted by descending global count, (-1, 0) padded.
    """
    hist = sharded_range_histogram(shards, shard_bits, n, lo, hi, available)
    return range_ops.topk_from_histogram(hist, k)


def sharded_range_topk_greedy(shards: WaveletMatrix, shard_bits: int,
                              n: int, lo, hi, k: int,
                              budget: int | None = None,
                              prune: bool = True, available=None):
    """Greedy global top-k: ONE frontier whose nodes carry a per-shard
    interval vector (weight = summed width) — a true global walk, not a
    merge of per-shard top-k lists. Same budget/exactness/``prune``
    trade-offs as ``range_ops.range_topk_greedy``; O(budget·S·logσ)
    probes per query.
    """
    S = _num_shards(shards)
    wms = [_shard(shards, s) for s in range(S)]

    def one(lo_q, hi_q):
        los, his = mask_ranges(*local_ranges(shard_bits, S, n, lo_q, hi_q),
                               available)
        return range_ops._topk_frontier(
            wms, [los[s] for s in range(S)], [his[s] for s in range(S)],
            k, budget, prune)[:2]

    lo = jnp.asarray(lo, _I32)
    if lo.ndim == 0:
        return one(lo, hi)
    return jax.vmap(one)(lo, jnp.asarray(hi, _I32))


def sharded_range_histogram(shards: WaveletMatrix, shard_bits: int, n: int,
                            lo, hi, available=None) -> jax.Array:
    """Global per-symbol counts for [lo, hi): per-shard histograms sum.
    Scalar or (B,) queries → (..., 2^nbits) int32."""
    S = _num_shards(shards)

    def one(lo_q, hi_q):
        los, his = mask_ranges(*local_ranges(shard_bits, S, n, lo_q, hi_q),
                               available)
        per = jax.vmap(
            lambda wm, a, b: range_ops.range_histogram(wm, a, b)
        )(shards, los, his)
        return jnp.sum(per, axis=0)

    lo = jnp.asarray(lo, _I32)
    if lo.ndim == 0:
        return one(lo, hi)
    return jax.vmap(one)(lo, jnp.asarray(hi, _I32))


def sharded_range_histogram_bounds(shards: WaveletMatrix, shard_bits: int,
                                   n: int, lo, hi, available=None):
    """(hist_lower, uncovered, coverage): per-symbol lower bounds plus the
    per-query count of uncovered positions — any symbol's true count is in
    [hist_lower[c], hist_lower[c] + uncovered]."""
    S = _num_shards(shards)
    hist = sharded_range_histogram(shards, shard_bits, n, lo, hi, available)
    los, his = local_ranges(shard_bits, S, n, lo, hi)
    total = jnp.sum(his - los, axis=0)
    _, mhis = mask_ranges(los, his, available)
    covered = jnp.sum(mhis - los, axis=0)
    cov = jnp.where(total > 0,
                    covered.astype(jnp.float32)
                    / jnp.maximum(total, 1).astype(jnp.float32),
                    jnp.float32(1.0))
    return hist, total - covered, cov


def sharded_range_distinct(shards: WaveletMatrix, shard_bits: int, n: int,
                           lo, hi, available=None) -> jax.Array:
    """# of distinct symbols in global [lo, hi) (union across shards)."""
    hist = sharded_range_histogram(shards, shard_bits, n, lo, hi, available)
    return jnp.sum(hist > 0, axis=-1).astype(_I32)


# --------------------------------------------------------------------------
# serving-layer handle
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class ShardedAnalytics:
    """Stacked per-shard wavelet matrices + corpus geometry.

    The analytics twin of ``ShardedTextIndex``: build once (or adopt a
    ``CompressedCorpus``'s shards — same layout, zero copy), then serve
    batched range queries as single jitted vmapped calls.
    """
    shards: WaveletMatrix          # every leaf has a leading (S,) axis
    n: int = field(metadata=dict(static=True))
    sigma: int = field(metadata=dict(static=True))
    shard_bits: int = field(metadata=dict(static=True))
    #: (S,) bool per-shard availability, or None for full availability.
    #: Unavailable shards are served around, not crashed into — see the
    #: module docstring's degraded-mode contract.
    available: jax.Array | None = None

    @property
    def num_shards(self) -> int:
        return _num_shards(self.shards)

    @property
    def shard_size(self) -> int:
        return 1 << self.shard_bits

    @property
    def degraded(self) -> bool:
        return self.available is not None

    # ---- availability management --------------------------------------
    def with_availability(self, available) -> "ShardedAnalytics":
        """Engine serving only the shards where ``available`` is True
        (pass ``None`` to restore full availability)."""
        if available is not None:
            available = jnp.asarray(available, bool)
            if available.shape != (self.num_shards,):
                raise ValueError(
                    f"availability mask shape {available.shape} != "
                    f"({self.num_shards},)")
        return dataclasses.replace(self, available=available)

    def drop_shards(self, shard_ids) -> "ShardedAnalytics":
        """Mark the given shard indices unavailable (on top of the current
        mask) — the degraded-serving entry point for lost shards."""
        mask = (jnp.ones((self.num_shards,), bool)
                if self.available is None else self.available)
        mask = mask.at[jnp.asarray(shard_ids, _I32)].set(False)
        return dataclasses.replace(self, available=mask)

    def coverage(self, lo, hi) -> jax.Array:
        """Fraction of [lo, hi) positions on available shards (1.0 when
        the engine is fully available)."""
        return sharded_coverage(self.shard_bits, self.num_shards, self.n,
                                lo, hi, self.available)

    def shard(self, s) -> WaveletMatrix:
        return _shard(self.shards, s)

    def bits_per_token(self) -> float:
        total = sum(l.size * l.dtype.itemsize * 8
                    for l in jax.tree.leaves(self.shards))
        return total / max(1, self.n)

    @classmethod
    def from_corpus(cls, corpus) -> "ShardedAnalytics":
        """Adopt a ``CompressedCorpus``'s shards (no rebuild, no copy)."""
        return cls(shards=corpus.shards, n=corpus.n, sigma=corpus.sigma,
                   shard_bits=corpus.shard_bits)

    # ---- incremental ingest / hot swap ---------------------------------
    def add_shards(self, new_shards: WaveletMatrix, added_tokens: int,
                   new_available=None) -> "ShardedAnalytics":
        """Next-generation engine with ``new_shards`` appended.

        ``new_shards`` is a stacked ``(K,)``-leaf pytree with this
        engine's static geometry (same shard size, levels, sample rate);
        ``added_tokens`` is the true token count the new shards carry
        (``(K-1)·shard_size < added_tokens ≤ K·shard_size`` — only the
        final shard may be partial). ``new_available`` masks freshly
        quarantined shards (honest partial coverage during ingest); the
        combined mask collapses back to ``None`` when everything is
        available. The result is a *new value* — publish it through
        ``ingest.serving.GenerationServer.swap_generation`` so in-flight
        query batches finish against the old generation. ``n`` is a
        static field, so each generation compiles its query kernels once.
        """
        if self.n != self.num_shards << self.shard_bits:
            raise ValueError(
                f"cannot append to a corpus with a partial tail shard "
                f"(n={self.n}, {self.num_shards} shards of "
                f"{self.shard_size})")
        K = jax.tree.leaves(new_shards)[0].shape[0]
        added_tokens = int(added_tokens)
        if not ((K - 1) << self.shard_bits) < added_tokens \
                <= (K << self.shard_bits):
            raise ValueError(
                f"added_tokens={added_tokens} does not fill {K} shard(s) "
                f"of {self.shard_size}")
        merged = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                              self.shards, new_shards)
        if self.available is None and new_available is None:
            mask = None
        else:
            old = (jnp.ones((self.num_shards,), bool)
                   if self.available is None else self.available)
            new = (jnp.ones((K,), bool) if new_available is None
                   else jnp.asarray(new_available, bool).reshape((K,)))
            mask = jnp.concatenate([old, new])
            if bool(jnp.all(mask)):
                mask = None
        obs.counter("ingest.shard_swap", layer="analytics").inc()
        return dataclasses.replace(self, shards=merged,
                                   n=self.n + added_tokens,
                                   available=mask)

    # ---- batched queries (each one jittable, vmapped internally) -------
    def range_quantile(self, lo, hi, k, use_kernel: bool = False
                       ) -> jax.Array:
        """Global k-th smallest in [lo, hi). ``use_kernel`` routes (Q,)
        batches through the fused sharded Pallas descent (one launch per
        query block, identical results); a degraded engine always takes
        the XLA path."""
        obs.counter("analytics.op", op="quantile").inc()
        if use_kernel:
            return sharded_range_quantile_fused(self.shards, self.shard_bits,
                                                self.n, lo, hi, k,
                                                available=self.available)
        obs.counter("analytics.path", op="quantile", path="xla").inc()
        return sharded_range_quantile(self.shards, self.shard_bits, self.n,
                                      lo, hi, k, self.available)

    def range_quantile_bracket(self, lo, hi, k, levels: int):
        """(sym_lo, sym_hi) bracketing the exact k-th smallest after a
        descent truncated to ``levels`` bit levels — the degradation
        ladder's reduced-refinement quantile (see
        ``sharded_range_quantile_bracket``)."""
        obs.counter("analytics.op", op="quantile_bracket").inc()
        return sharded_range_quantile_bracket(self.shards, self.shard_bits,
                                              self.n, lo, hi, k, levels,
                                              self.available)

    def probe_shard(self, s: int, clock=None) -> bool:
        """Liveness probe of one shard: a minimal single-shard count that
        honours any chaos-armed ``robust.faults.shard_latency`` stall
        (slept on the injectable ``clock`` — real stall under the system
        clock, instant logical stall under ``FakeClock``). The serving
        front-end's circuit breakers hedge these probes under a timeout —
        a stuck shard turns into an open breaker (degraded coverage)
        instead of a stalled queue. Returns True on success.
        """
        from repro.robust.clock import SYSTEM_CLOCK
        from repro.robust.faults import shard_latency
        clock = clock if clock is not None else SYSTEM_CLOCK
        delay = shard_latency(s)
        if delay > 0:
            clock.sleep(delay)
        wm = self.shard(int(s))
        out = range_ops.range_count(wm, jnp.asarray(0, _I32),
                                    jnp.asarray(1, _I32),
                                    jnp.asarray(0, _I32),
                                    jnp.asarray(self.sigma, _I32))
        return bool(jax.block_until_ready(out) >= 0)

    def range_count(self, lo, hi, sym_lo, sym_hi) -> jax.Array:
        obs.counter("analytics.op", op="count").inc()
        return sharded_range_count(self.shards, self.shard_bits, self.n,
                                   lo, hi, sym_lo, sym_hi, self.available)

    def range_count_bounds(self, lo, hi, sym_lo, sym_hi):
        """(lower, upper, coverage) bracketing the full-corpus count —
        the honest degraded-mode answer."""
        obs.counter("analytics.op", op="count_bounds").inc()
        return sharded_range_count_bounds(self.shards, self.shard_bits,
                                          self.n, lo, hi, sym_lo, sym_hi,
                                          self.available)

    def range_topk(self, lo, hi, k: int):
        obs.counter("analytics.op", op="topk").inc()
        return sharded_range_topk(self.shards, self.shard_bits, self.n,
                                  lo, hi, k, self.available)

    def range_topk_greedy(self, lo, hi, k: int, budget: int | None = None,
                          prune: bool = True):
        obs.counter("analytics.op", op="topk_greedy").inc()
        return sharded_range_topk_greedy(self.shards, self.shard_bits,
                                         self.n, lo, hi, k, budget, prune,
                                         self.available)

    def range_distinct(self, lo, hi) -> jax.Array:
        obs.counter("analytics.op", op="distinct").inc()
        return sharded_range_distinct(self.shards, self.shard_bits, self.n,
                                      lo, hi, self.available)

    def range_histogram(self, lo, hi) -> jax.Array:
        obs.counter("analytics.op", op="histogram").inc()
        return sharded_range_histogram(self.shards, self.shard_bits, self.n,
                                       lo, hi, self.available)

    def range_histogram_bounds(self, lo, hi):
        """(hist_lower, uncovered, coverage): true per-symbol counts lie
        in [hist_lower[c], hist_lower[c] + uncovered]."""
        obs.counter("analytics.op", op="histogram_bounds").inc()
        return sharded_range_histogram_bounds(self.shards, self.shard_bits,
                                              self.n, lo, hi, self.available)


def build_sharded_analytics(tokens, sigma: int, *, shard_bits: int = 16,
                            tau: int = 8, big_step: str = "compose",
                            sample_rate: int = 512,
                            parallel: str | bool = "auto"
                            ) -> ShardedAnalytics:
    """Build the engine from a raw token stream (via the compressed-store
    shard builder, which pmaps/vmaps shard construction when it can)."""
    from repro.data.compressed_store import build_compressed_corpus
    corpus = build_compressed_corpus(tokens, sigma, shard_bits=shard_bits,
                                     tau=tau, big_step=big_step,
                                     sample_rate=sample_rate,
                                     parallel=parallel)
    return ShardedAnalytics.from_corpus(corpus)
