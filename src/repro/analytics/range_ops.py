"""Range-query ops over a single wavelet matrix.

These are the queries that justify building wavelet trees at all (cf.
"Wavelet Trees Meet Suffix Trees", arXiv:1408.6182): every op descends the
``nbits`` levels of the matrix, spending two ``rank0`` probes per level on
the position-interval boundaries, so a query costs O(logσ) directory
lookups regardless of range width.

All position ranges are half-open ``[lo, hi)`` over the original sequence;
symbol ranges are half-open ``[sym_lo, sym_hi)``. Every op is pure jnp on
static-shape state, so it jits and vmaps over query batches:

* ``range_quantile``  — k-th smallest symbol in the range (k 0-based).
* ``range_count``     — # of positions whose symbol falls in a symbol band
                        (orthogonal range counting: both symbol boundaries
                        walk down together).
* ``range_topk``      — heaviest-k symbols by occurrence count. Exact, via
                        the breadth-first range histogram + ``lax.top_k``
                        (O(σ) *vector* work, no sequential loop).
* ``range_topk_greedy`` — the classic greedy node expansion with a fixed
                        pop budget and slot capacity (the heap is a masked
                        argmax, so the loop is jittable): O(budget·logσ)
                        sequential pops independent of σ — the scalable
                        path for huge alphabets. Exact whenever the budget
                        covers every node outweighing the k-th answer
                        (always true at ``budget ≥ 2^(nbits+1)``; the
                        default heuristic budget is exact on skewed
                        distributions, best-effort on near-uniform ones).
* ``range_distinct``  — # of distinct symbols (breadth-first descent; O(σ)
                        vector work — see ``range_histogram``).

``range_quantile``/``range_count`` broadcast over batched ``lo``/``hi``
arrays directly; the top-k/histogram/distinct ops are written for one
scalar query — ``jax.vmap`` them over batches, as
``repro.analytics.engine`` does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.wavelet_matrix import (WaveletMatrix, wm_child_interval,
                                       wm_interval_zeros)

_I32 = jnp.int32


def _clip_range(wm: WaveletMatrix, lo: jax.Array, hi: jax.Array):
    lo = jnp.clip(jnp.asarray(lo, _I32), 0, wm.n)
    hi = jnp.clip(jnp.asarray(hi, _I32), 0, wm.n)
    hi = jnp.maximum(hi, lo)
    return lo, hi


# --------------------------------------------------------------------------
# range quantile
# --------------------------------------------------------------------------

def range_quantile(wm: WaveletMatrix, lo: jax.Array, hi: jax.Array,
                   k: jax.Array) -> jax.Array:
    """k-th smallest symbol (0-based) among positions [lo, hi).

    At each level the branch compares ``k`` against the number of zeros in
    the current interval: fewer than k zeros → the answer's bit is 1 and k
    shifts down by the zero count. ``k`` is clamped into [0, hi-lo);
    an empty range returns -1. Broadcasts over batched lo/hi/k.
    """
    lo, hi = _clip_range(wm, lo, hi)
    k = jnp.clip(jnp.asarray(k, _I32), 0, jnp.maximum(hi - lo - 1, 0))
    empty = hi <= lo
    sym = jnp.zeros_like(lo)
    for l in range(wm.nbits):
        lo0, hi0 = wm_interval_zeros(wm, l, lo, hi)
        z = hi0 - lo0
        bit = (k >= z).astype(_I32)
        sym = (sym << 1) | bit
        k = jnp.where(bit == 1, k - z, k)
        lo, hi = wm_child_interval(wm, l, lo, hi, bit, lo0, hi0)
    return jnp.where(empty, jnp.asarray(-1, _I32), sym)


# --------------------------------------------------------------------------
# orthogonal range counting
# --------------------------------------------------------------------------

def _count_below(wm: WaveletMatrix, lo: jax.Array, hi: jax.Array,
                 sym: jax.Array) -> jax.Array:
    """# of positions in [lo, hi) whose symbol is < sym (sym clamped to
    [0, 2^nbits]). One descent: whenever sym's bit is 1, everything in the
    zero-branch is smaller — add the interval's zero count and go right."""
    top = 1 << wm.nbits
    s = jnp.clip(jnp.asarray(sym, _I32), 0, top)
    full = s >= top
    total = hi - lo
    acc = jnp.zeros_like(lo)
    for l in range(wm.nbits):
        bit = (s >> (wm.nbits - 1 - l)) & 1
        lo0, hi0 = wm_interval_zeros(wm, l, lo, hi)
        acc = acc + jnp.where(bit == 1, hi0 - lo0, 0)
        lo, hi = wm_child_interval(wm, l, lo, hi, bit, lo0, hi0)
    return jnp.where(full, total, acc)


def range_count(wm: WaveletMatrix, lo: jax.Array, hi: jax.Array,
                sym_lo: jax.Array, sym_hi: jax.Array) -> jax.Array:
    """# of positions in [lo, hi) whose symbol lies in [sym_lo, sym_hi).

    Both symbol boundaries walk the levels together (two interval states
    sharing the descent), so the cost is O(logσ) like a single rank.
    Broadcasts over batched arguments.
    """
    lo, hi = _clip_range(wm, lo, hi)
    below_hi = _count_below(wm, lo, hi, sym_hi)
    below_lo = _count_below(wm, lo, hi, sym_lo)
    return jnp.maximum(below_hi - below_lo, 0)


# --------------------------------------------------------------------------
# range top-k (greedy frontier expansion)
# --------------------------------------------------------------------------

def topk_slot_budget(nbits: int, k: int) -> tuple[int, int]:
    """Default (pop budget, slot capacity) for the greedy expansion.

    Popping the heaviest node never misses (a child never outweighs its
    parent), and on skewed distributions the k answers surface within
    ~k·logσ pops (expanding only their root paths). Slots are append-only
    (each internal pop appends two children), so capacity is 1 + 2·pops.
    Near-uniform distributions can need up to 2^(nbits+1) pops for
    exactness — pass an explicit ``budget`` for that regime, or use the
    exact ``range_topk``.
    """
    iters = k * (nbits + 1)
    return iters, 2 * iters + 1


def topk_from_histogram(hist: jax.Array, k: int):
    """(syms, counts) of the k largest entries of ``hist`` (…, σ) along
    the last axis, descending, (-1, 0)-padded past the non-zero entries.
    Ties break toward the smaller symbol. Shared by the single-matrix and
    sharded (histogram-sum) top-k paths."""
    kk = min(k, hist.shape[-1])
    cnts, syms = jax.lax.top_k(hist, kk)
    syms = jnp.where(cnts > 0, syms.astype(_I32), jnp.asarray(-1, _I32))
    cnts = cnts.astype(_I32)
    if kk < k:
        pad = hist.shape[:-1] + (k - kk,)
        syms = jnp.concatenate([syms, jnp.full(pad, -1, _I32)], axis=-1)
        cnts = jnp.concatenate([cnts, jnp.zeros(pad, _I32)], axis=-1)
    return syms, cnts


def range_topk(wm: WaveletMatrix, lo: jax.Array, hi: jax.Array, k: int):
    """The k most frequent symbols in [lo, hi) with their counts. Exact.

    Returns ``(syms, counts)``, each (k,), ordered by descending count;
    slots past the number of distinct symbols in the range are (-1, 0).
    Ties break toward the smaller symbol. ``k`` is static; ``lo``/``hi``
    are scalar — vmap over query batches.

    Implementation: breadth-first range histogram + ``lax.top_k`` — O(σ)
    vector work with no sequential dependence, which on a vector machine
    beats the pointer-chasing greedy walk up to very large σ. For alphabets
    where O(σ) per query is unaffordable, see ``range_topk_greedy``.
    """
    return topk_from_histogram(range_histogram(wm, lo, hi), k)


def range_topk_greedy(wm: WaveletMatrix, lo: jax.Array, hi: jax.Array,
                      k: int, budget: int | None = None,
                      prune: bool = True):
    """Greedy best-first top-k with a fixed pop budget. Same contract as
    ``range_topk``; cost O(budget) sequential pops of O(logσ) work,
    independent of σ.

    The frontier is a fixed array of (level, symbol-prefix, interval)
    slots; each iteration pops the widest interval by masked argmax. A
    popped leaf (level == nbits) is the next-heaviest symbol — descendant
    intervals only shrink — an internal node is replaced by its two
    children. Exact iff every node heavier than the k-th answer fits in
    the budget (guaranteed at ``budget ≥ 2^(nbits+1)``); the default
    ``topk_slot_budget`` heuristic is exact on skewed (Zipf-like)
    distributions and best-effort on near-uniform ones.

    ``prune=True`` additionally tracks each frontier node's *lower* bound
    ``ceil(weight / leaves_below)`` — some symbol under the node must
    carry at least that count — and retires nodes whose upper bound
    (weight) is beaten by the remaining-(k−found) largest lower bounds:
    those nodes provably contain no answer, so the budget is spent on
    contenders instead (tightens the near-uniform regime where sibling
    weights are flat). Pruning never changes an exact result.
    """
    lo, hi = _clip_range(wm, lo, hi)
    syms, counts, _ = _topk_frontier([wm], [lo], [hi], k, budget, prune)
    return syms, counts


def _topk_frontier(wms, los, his, k: int, budget: int | None = None,
                   prune: bool = True):
    """Shared greedy top-k engine over a *list* of per-shard states.

    ``wms``: list of WaveletMatrix (identical nbits); slot intervals carry
    one (lo, hi) pair per shard and a node's weight is the summed width —
    this makes the sharded greedy top-k a single global frontier rather
    than a merge of per-shard approximations. Returns
    (syms (k,), counts (k,), n_found scalar).
    """
    nbits = wms[0].nbits
    S = len(wms)
    iters, cap = topk_slot_budget(nbits, k)
    if budget is not None:
        iters, cap = budget, 2 * budget + 1

    slot_lo = jnp.zeros((cap, S), _I32)
    slot_hi = jnp.zeros((cap, S), _I32)
    slot_lo = slot_lo.at[0].set(jnp.stack([jnp.asarray(l, _I32).reshape(())
                                           for l in los]))
    slot_hi = slot_hi.at[0].set(jnp.stack([jnp.asarray(h, _I32).reshape(())
                                           for h in his]))
    slot_sym = jnp.zeros((cap,), _I32)
    slot_level = jnp.zeros((cap,), _I32)
    alive = jnp.zeros((cap,), bool).at[0].set(True)
    nslots = jnp.asarray(1, _I32)

    out_syms = jnp.full((k,), -1, _I32)
    out_cnts = jnp.zeros((k,), _I32)
    nout = jnp.asarray(0, _I32)

    # per-level child maps for every shard, precomputed as closures so the
    # fori_loop body can switch on the popped node's level
    def children_at(level_static, wm, lo, hi):
        lo0, hi0 = wm_interval_zeros(wm, level_static, lo, hi)
        left = (lo0, hi0)
        right = wm_child_interval(wm, level_static, lo, hi,
                                  jnp.asarray(1, _I32), lo0, hi0)
        return left, right

    def body(_, state):
        (slot_lo, slot_hi, slot_sym, slot_level, alive, nslots,
         out_syms, out_cnts, nout) = state
        weight = jnp.where(alive, jnp.sum(slot_hi - slot_lo, axis=1), -1)
        best = jnp.argmax(weight)
        w = weight[best]
        stop = (w <= 0) | (nout >= k)
        is_leaf = slot_level[best] == nbits

        # ---- leaf: emit the symbol, retire the slot --------------------
        emit = (~stop) & is_leaf
        oidx = jnp.minimum(nout, k - 1)
        out_syms = out_syms.at[oidx].set(
            jnp.where(emit, slot_sym[best], out_syms[oidx]))
        out_cnts = out_cnts.at[oidx].set(
            jnp.where(emit, w, out_cnts[oidx]))
        nout = nout + emit.astype(_I32)

        # ---- internal: expand into two children ------------------------
        expand = (~stop) & (~is_leaf)
        # lax.switch on the popped node's level: only that level's rank
        # probes execute, keeping each pop at O(1) directory lookups
        def level_branch(l):
            def br(blo, bhi):
                cs = [children_at(l, wms[s], blo[s], bhi[s])
                      for s in range(S)]
                return (jnp.stack([c[0][0] for c in cs]),
                        jnp.stack([c[0][1] for c in cs]),
                        jnp.stack([c[1][0] for c in cs]),
                        jnp.stack([c[1][1] for c in cs]))
            return br

        lvl = jnp.clip(slot_level[best], 0, nbits - 1)
        lft_lo, lft_hi, rgt_lo, rgt_hi = jax.lax.switch(
            lvl, [level_branch(l) for l in range(nbits)],
            slot_lo[best], slot_hi[best])

        a = jnp.minimum(nslots, cap - 2)
        b = a + 1
        child_sym = slot_sym[best] << 1
        child_lvl = slot_level[best] + 1

        def put(arr, idx, val, on):
            return arr.at[idx].set(jnp.where(on, val, arr[idx]))

        slot_lo = put(slot_lo, a, lft_lo, expand)
        slot_hi = put(slot_hi, a, lft_hi, expand)
        slot_sym = put(slot_sym, a, child_sym, expand)
        slot_level = put(slot_level, a, child_lvl, expand)
        slot_lo = put(slot_lo, b, rgt_lo, expand)
        slot_hi = put(slot_hi, b, rgt_hi, expand)
        slot_sym = put(slot_sym, b, child_sym | 1, expand)
        slot_level = put(slot_level, b, child_lvl, expand)
        alive = put(alive, a, jnp.asarray(True), expand)
        alive = put(alive, b, jnp.asarray(True), expand)
        nslots = nslots + 2 * expand.astype(_I32)

        # the popped slot retires either way (unless we already stopped)
        alive = alive.at[best].set(jnp.where(stop, alive[best], False))

        if prune:
            # lower bound per node: ceil(weight / leaves below) — some
            # symbol under it has at least that count. A node whose upper
            # bound (weight) is strictly beaten by the (k - found)
            # largest lower bounds of *other* nodes can never contribute
            # an answer (frontier nodes are disjoint, so those bounds
            # name distinct symbols) — retire it and spend the budget on
            # contenders. The pruned node's own lb ≤ its weight < the
            # threshold, so it never sits among the bounding set.
            w_all = jnp.where(alive, jnp.sum(slot_hi - slot_lo, axis=1), 0)
            leaves_below = jnp.left_shift(
                jnp.asarray(1, _I32),
                jnp.maximum(nbits - slot_level, 0))
            lb = jnp.where(alive,
                           -(-w_all // jnp.maximum(leaves_below, 1)), -1)
            need = k - nout
            kk = min(k, int(lb.shape[0]))            # tiny explicit budgets
            kth = jax.lax.top_k(lb, kk)[0]           # descending
            thresh = kth[jnp.clip(need - 1, 0, kk - 1)]
            kill = (alive & (w_all < thresh) & (need > 0) & (need <= kk)
                    & (~stop))
            alive = alive & ~kill

        return (slot_lo, slot_hi, slot_sym, slot_level, alive, nslots,
                out_syms, out_cnts, nout)

    state = (slot_lo, slot_hi, slot_sym, slot_level, alive, nslots,
             out_syms, out_cnts, nout)
    state = jax.lax.fori_loop(0, iters, body, state)
    return state[6], state[7], state[8]


# --------------------------------------------------------------------------
# histogram / distinct (breadth-first full descent)
# --------------------------------------------------------------------------

def range_histogram(wm: WaveletMatrix, lo: jax.Array,
                    hi: jax.Array) -> jax.Array:
    """Occurrence count of *every* symbol in [lo, hi): (2^nbits,) int32.

    Breadth-first descent: the interval splits in two at every level, so
    after ``nbits`` levels slot ``c`` holds symbol c's sub-interval and its
    width is c's count. O(σ) vector work per query (vs O(logσ) for the
    point queries above) — this is the dense fallback that ``distinct``
    needs, and it vectorizes/vmaps cleanly. ``lo``/``hi`` are scalar.
    """
    lo, hi = _clip_range(wm, lo, hi)
    los = jnp.reshape(jnp.asarray(lo, _I32), (1,))
    his = jnp.reshape(jnp.asarray(hi, _I32), (1,))
    for l in range(wm.nbits):
        lo0, hi0 = wm_interval_zeros(wm, l, los, his)
        rl, rh = wm_child_interval(wm, l, los, his, jnp.asarray(1, _I32),
                                   lo0, hi0)
        # child order: appending the level's bit as the next prefix bit
        # keeps slot index == symbol after the last level
        los = jnp.stack([lo0, rl], axis=-1).reshape(-1)
        his = jnp.stack([hi0, rh], axis=-1).reshape(-1)
    return his - los


def range_distinct(wm: WaveletMatrix, lo: jax.Array,
                   hi: jax.Array) -> jax.Array:
    """# of distinct symbols in [lo, hi). Scalar lo/hi; vmap for batches."""
    return jnp.sum(range_histogram(wm, lo, hi) > 0).astype(_I32)
