"""Range-query analytics engine over wavelet matrices.

The downstream workload that motivates the paper's fast construction:
range quantile / orthogonal range counting / top-k / distinct-count in
O(logσ) rank probes per query, batched with ``vmap`` and fanned across
corpus shards by ``ShardedAnalytics`` (exact cross-shard reductions —
count-then-refine quantiles, shard-vector top-k frontier, histogram-union
distinct).

Single-matrix ops live in ``range_ops``; the sharded serving layer in
``engine``; the fused Pallas quantile kernel in ``repro.kernels``
(``wm_quantile_batch``).
"""
from .engine import (ShardedAnalytics, build_sharded_analytics,
                     local_ranges, sharded_range_count,
                     sharded_range_distinct, sharded_range_histogram,
                     sharded_range_quantile, sharded_range_topk,
                     sharded_range_topk_greedy)
from .range_ops import (range_count, range_distinct, range_histogram,
                        range_quantile, range_topk, range_topk_greedy,
                        topk_slot_budget)

__all__ = [
    "ShardedAnalytics", "build_sharded_analytics", "local_ranges",
    "sharded_range_count", "sharded_range_distinct",
    "sharded_range_histogram", "sharded_range_quantile",
    "sharded_range_topk", "sharded_range_topk_greedy",
    "range_count", "range_distinct", "range_histogram", "range_quantile",
    "range_topk", "range_topk_greedy", "topk_slot_budget",
]
