"""Range-query analytics engine over wavelet matrices.

The downstream workload that motivates the paper's fast construction:
range quantile / orthogonal range counting / top-k / distinct-count in
O(logσ) rank probes per query, batched with ``vmap`` and fanned across
corpus shards by ``ShardedAnalytics`` (exact cross-shard reductions —
count-then-refine quantiles, shard-vector top-k frontier, histogram-union
distinct).

Single-matrix ops live in ``range_ops``; the sharded serving layer in
``engine``; the fused Pallas quantile kernels in ``repro.kernels``
(``wm_quantile_batch`` for one matrix, ``wm_quantile_sharded_batch`` —
surfaced as ``sharded_range_quantile_fused`` — for the stacked shard
layout); persisted snapshots in ``snapshot`` (serving restarts skip the
build).
"""
from .engine import (ShardedAnalytics, build_sharded_analytics,
                     local_ranges, mask_ranges, sharded_coverage,
                     sharded_range_count, sharded_range_count_bounds,
                     sharded_range_distinct, sharded_range_histogram,
                     sharded_range_histogram_bounds,
                     sharded_range_quantile, sharded_range_quantile_fused,
                     sharded_range_topk, sharded_range_topk_greedy)
from .range_ops import (range_count, range_distinct, range_histogram,
                        range_quantile, range_topk, range_topk_greedy,
                        topk_slot_budget)
from .snapshot import load_analytics, save_analytics, snapshot_meta

__all__ = [
    "ShardedAnalytics", "build_sharded_analytics", "local_ranges",
    "mask_ranges", "sharded_coverage",
    "sharded_range_count", "sharded_range_count_bounds",
    "sharded_range_distinct",
    "sharded_range_histogram", "sharded_range_histogram_bounds",
    "sharded_range_quantile",
    "sharded_range_quantile_fused",
    "sharded_range_topk", "sharded_range_topk_greedy",
    "range_count", "range_distinct", "range_histogram", "range_quantile",
    "range_topk", "range_topk_greedy", "topk_slot_budget",
    "load_analytics", "save_analytics", "snapshot_meta",
]
