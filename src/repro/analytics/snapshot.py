"""Persisted analytics snapshots: save/restore a ``ShardedAnalytics``
engine through ``repro.checkpoint`` so serving restarts skip the build.

The stacked shard pytree is written with the atomic checkpoint layout
(``arrays.npz`` + ``meta.json``); the corpus geometry (n, sigma,
shard_bits, select sample rate) travels in ``meta.json``. Restore
reconstructs the exact pytree *structure* — every static field and leaf
shape is derivable from the geometry, because all shards share one static
shape — builds a ``ShapeDtypeStruct`` target from it, and loads the
arrays back into place. Round-trips are bit-exact (all leaves are integer
arrays), so a restored engine answers every query identically to the one
that was saved.

Restores are integrity-verified (per-leaf crc32 from ``meta.json``) and
self-healing: corrupted *derived* leaves are recomputed from the level
bitmaps and re-checked against the recorded checksums; only primary
bitmap corruption escapes as ``IntegrityError`` (rebuild from source).
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.rank_select import (BLOCK_WORDS, SUPERBLOCK_WORDS,
                                    BinaryRank, BinarySelect, BitVector)
from repro.core.wavelet_matrix import WaveletMatrix, num_levels

from .engine import ShardedAnalytics

_SNAPSHOT_STEP = 0


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def shards_struct(num_shards: int, sigma: int, shard_size: int,
                  sample_rate: int) -> WaveletMatrix:
    """ShapeDtypeStruct pytree of a stacked (S,)-leaf ``WaveletMatrix``.

    Mirrors exactly what ``build_wavelet_matrix`` + leaf-wise stacking
    produces for ``num_shards`` shards of ``shard_size`` positions each —
    the restore target for :func:`load_analytics`.
    """
    nbits = num_levels(sigma)
    W = (shard_size + 31) // 32
    nsb = (W + SUPERBLOCK_WORDS - 1) // SUPERBLOCK_WORDS
    nblk = (W + BLOCK_WORDS - 1) // BLOCK_WORDS
    nsamp = shard_size // sample_rate + 2
    lead = (num_shards, nbits)
    rank = BinaryRank(words=_struct(lead + (W,), jnp.uint32),
                      superblock=_struct(lead + (nsb,), jnp.uint32),
                      block=_struct(lead + (nblk,), jnp.uint16),
                      n=shard_size)

    def sel(zeros: bool) -> BinarySelect:
        return BinarySelect(sample=_struct(lead + (nsamp,), jnp.int32),
                            n=shard_size, sample_rate=sample_rate,
                            zeros=zeros)

    bv = BitVector(rank=rank, sel1=sel(False), sel0=sel(True))
    return WaveletMatrix(bitvectors=bv,
                         zeros=_struct(lead, jnp.int32),
                         n=shard_size, nbits=nbits)


def save_analytics(engine: ShardedAnalytics, directory: str | Path,
                   extra_meta: Optional[dict] = None) -> Path:
    """Atomically persist the engine (stacked shard pytree + geometry).

    ``extra_meta`` rides along in ``meta.json`` — callers use it to record
    corpus identity (e.g. a seed or content hash) so a restore can be
    validated against the stream it is meant to serve.
    """
    sample_rate = engine.shards.bitvectors.sel1.sample_rate
    meta = {
        "kind": "sharded_analytics",
        "n": int(engine.n),
        "sigma": int(engine.sigma),
        "shard_bits": int(engine.shard_bits),
        "num_shards": int(engine.num_shards),
        "sample_rate": int(sample_rate),
    }
    if extra_meta:
        meta.update(extra_meta)
    return save_checkpoint(directory, _SNAPSHOT_STEP, engine.shards,
                           extra_meta=meta, keep=1)


def snapshot_meta(directory: str | Path,
                  step: Optional[int] = None) -> dict:
    """Read a snapshot's ``meta.json`` (geometry + caller extras) WITHOUT
    loading the arrays — the cheap pre-restore compatibility probe."""
    import json

    from repro.checkpoint.checkpoint import latest_step
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no snapshot under {directory}")
    meta = json.loads((Path(directory) / f"step_{step:08d}" /
                       "meta.json").read_text())
    if meta.get("kind") != "sharded_analytics":
        raise ValueError(f"{directory} does not hold an analytics snapshot "
                         f"(kind={meta.get('kind')!r})")
    return meta


def load_analytics(directory: str | Path,
                   step: Optional[int] = None,
                   verify: bool = True,
                   repair: bool = True) -> ShardedAnalytics:
    """Restore a :func:`save_analytics` snapshot into a fresh engine.

    The self-healing restore path: leaves are checksum-verified against
    the ``leaf_crc32`` table in ``meta.json`` (``verify=True``); on a
    mismatch confined to *derived* leaves (rank/select directories,
    ``zeros``) the engine is repaired in place by recomputation from the
    level bitmaps and re-verified against the recorded checksums — the
    repaired engine is bit-identical to the one saved. Corruption of the
    primary bitmaps themselves cannot be repaired from the snapshot;
    ``IntegrityError`` escapes so the caller rebuilds from source
    (``launch.analytics`` does exactly that).
    """
    from repro import obs
    from repro.robust.integrity import IntegrityError, tree_checksums
    from repro.robust.repair import classify_bad_keys, repair_analytics
    meta = snapshot_meta(directory, step=step)
    target = shards_struct(meta["num_shards"], meta["sigma"],
                           1 << meta["shard_bits"], meta["sample_rate"])
    step = meta.get("step", _SNAPSHOT_STEP)

    def make(shards):
        return ShardedAnalytics(shards=shards, n=meta["n"],
                                sigma=meta["sigma"],
                                shard_bits=meta["shard_bits"])

    with obs.span("analytics.load", dir=str(directory), step=step) as lsp:
        try:
            with obs.span("analytics.load.restore", verify=verify):
                shards, _ = restore_checkpoint(directory, target, step=step,
                                               verify=verify)
            obs.counter("robust.restore", outcome="clean").inc()
            lsp.set("outcome", "clean")
            return make(shards)
        except IntegrityError as err:
            if not repair:
                obs.counter("robust.restore", outcome="corrupt_norepair").inc()
                lsp.set("outcome", "corrupt_norepair")
                raise
            derived, primary = classify_bad_keys(err.bad_keys)
            obs.event("integrity.corrupt", derived=len(derived),
                      primary=len(primary))
            if primary:
                obs.counter("robust.restore", outcome="primary_corrupt").inc()
                lsp.set("outcome", "primary_corrupt")
                raise IntegrityError(
                    primary, where=f"{directory} (primary bitmaps corrupt — "
                    "repair impossible, rebuild from source)") from err
            with obs.span("analytics.load.repair", bad_leaves=len(derived)):
                shards, _ = restore_checkpoint(directory, target, step=step,
                                               verify=False)
                engine = repair_analytics(make(shards))
                want = meta.get("leaf_crc32", {})
                got = tree_checksums(engine.shards)
                still_bad = sorted(k for k in derived
                                   if got.get(k) != want.get(k))
            if still_bad:
                obs.counter("robust.restore",
                            outcome="repair_diverged").inc()
                lsp.set("outcome", "repair_diverged")
                raise IntegrityError(
                    still_bad, where=f"{directory} (repair did not converge)"
                ) from err
            obs.counter("robust.restore", outcome="repaired").inc()
            lsp.set("outcome", "repaired")
            return engine
