"""End-to-end compressed corpus store: ingest rate, size, serving rate."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.data import TokenBatcher, build_compressed_corpus, make_corpus

from .common import record, save, time_fn


def run(n: int = 1 << 21, out: list | None = None) -> list:
    rows = out if out is not None else []
    for vocab in (50280, 151936):
        toks = make_corpus(n, vocab, seed=0)
        sw = obs.Stopwatch()
        corpus = build_compressed_corpus(toks, vocab, shard_bits=18)
        jax.block_until_ready(jax.tree.leaves(corpus.shards)[0])
        t_ing = sw.lap()
        record(rows, f"corpus_ingest_v{vocab}_n{n}", t_ing,
               mtok_per_s=round(n / t_ing / 1e6, 2),
               bits_per_token=round(corpus.bits_per_token(), 2),
               compression_vs_u32=round(32 / corpus.bits_per_token(), 2))

        pos = jnp.asarray(np.random.default_rng(1).integers(0, n, 1 << 14),
                          jnp.int32)
        f = jax.jit(corpus.access)
        t = time_fn(f, pos, iters=3)
        record(rows, f"corpus_random_access_v{vocab}_batch{1 << 14}", t,
               mtok_per_s=round(pos.shape[0] / t / 1e6, 2))

        batcher = TokenBatcher(corpus=corpus, batch=8, seq_len=1024, seed=0)
        sw = obs.Stopwatch()
        for step in range(3):
            batcher.batch_at(step)
        t_b = sw.lap() / 3
        record(rows, f"corpus_batcher_8x1024_v{vocab}", t_b,
               mtok_per_s=round(8 * 1025 / t_b / 1e6, 2))
    if out is None:
        save(rows, "corpus_store.json")
    return rows


if __name__ == "__main__":
    run()
