"""Paper Table 1, row 7: wavelet matrix construction (Theorem 4.5)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wavelet_matrix import (build_wavelet_matrix,
                                       build_wavelet_matrix_levelwise,
                                       num_levels)

from .common import record, save, time_fn


def run(n: int = 1 << 20, out: list | None = None) -> list:
    rows = out if out is not None else []
    for sigma in (256, 65536):
        seq = jnp.asarray(np.random.default_rng(0)
                          .integers(0, sigma, n).astype(np.uint32))
        nbits = num_levels(sigma)
        f = jax.jit(functools.partial(build_wavelet_matrix_levelwise,
                                      sigma=sigma))
        t = time_fn(f, seq, iters=3)
        record(rows, f"wm_levelwise_n{n}_s{sigma}", t,
               melem_per_s=round(n / t / 1e6, 1), bytes_per_elem=4 * nbits)
        for tau in (4, 8, 16):
            for big in ("compose", "radix", "xla"):
                if tau >= nbits and big != "compose":
                    continue     # single chunk: big step never runs
                f = jax.jit(functools.partial(build_wavelet_matrix,
                                              sigma=sigma, tau=tau,
                                              big_step=big))
                t = time_fn(f, seq, iters=3)
                record(rows, f"wm_tau{tau}_{big}_n{n}_s{sigma}", t,
                       melem_per_s=round(n / t / 1e6, 1),
                       bytes_per_elem=round(4 * nbits / tau + nbits, 1))
    if out is None:
        save(rows, "wavelet_matrix.json")
    return rows


if __name__ == "__main__":
    run()
