"""Roofline analysis from the compiled dry-run artifacts.

For every (arch × shape × mesh) cell produced by ``repro.launch.dryrun``:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE — a scan-over-layers
program under-reports by ~num_layers×. ``launch.hlo_analysis`` re-derives
dot FLOPs and collective bytes from the post-SPMD HLO with
known_trip_count multipliers; when the saved HLO is available we use those
and scale the cost-analysis byte count by the same trip-count ratio
(documented assumption: loop bodies dominate both terms equally).

MODEL_FLOPS uses 6·N·D for training (N params, D tokens) and 2·N_active·D
for inference; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy
waste (>1/3 for a remat-everything training step is good; decode is
memory-bound so the ratio matters less there).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax

from repro.configs.base import ARCHITECTURES, SHAPES, get_config
from repro.models.model import count_params
from repro.obs.prof import HW_MODELS, LINK_BW, analyze_hlo

# hardware model now lives in repro.obs.prof (shared with the runtime
# roofline gauges); this table is always priced for the TPU part.
PEAK_FLOPS, HBM_BW = HW_MODELS["tpu"]

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / devices
    tokens = shape.global_batch          # one new token per sequence
    return 2.0 * n_active * tokens / devices


def min_bytes_per_device(arch: str, shape_name: str, devices: int,
                         dp: int = 16, tp: int = 16) -> float:
    """Analytic irreducible HBM traffic per device per step (lower bound).

    Counts only unavoidable streams: parameter/optimizer state movement,
    saved activations at remat granularity, KV-cache reads. XLA's
    ``bytes accessed`` is the matching UPPER bound (every fusion operand
    billed as HBM). Truth lives between; both are reported.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = count_params(cfg)
    n_dev = n / devices                       # params fully sharded (FSDP)
    tokens_dev = shape.global_batch * shape.seq_len / (devices / tp)
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    if shape.kind == "train":
        # params: fwd read + bwd read + write (bf16) = 6 B/param
        # grads: write + read (f32)               = 8 B/param
        # adam m, v: read + write each (f32)      = 16 B/param
        pbytes = 30.0 * n_dev
        # remat: save + reload layer inputs (bf16) + block output write
        act = tokens_dev * L * d * 6.0
        logits = tokens_dev * V * 4.0 / tp      # f32 logits, vocab-sharded
        return pbytes + act + logits
    if shape.kind == "prefill":
        pbytes = 2.0 * n_dev
        act = tokens_dev * L * d * 4.0
        return pbytes + act
    # decode: read every (active) weight shard once + stream the KV cache
    from repro.models.model import cache_shapes
    import math as _m
    cache_elems = sum(_m.prod(s) for s in jax.tree.leaves(
        cache_shapes(cfg, shape.global_batch, shape.seq_len),
        is_leaf=lambda x: isinstance(x, tuple)))
    return 2.0 * n_dev + 2.0 * cache_elems / devices


def analyze_cell(rec: dict, hlo_path: Path | None) -> dict:
    arch, shape_name = rec["arch"], rec["shape"]
    devices = rec["devices"]
    flops_ca = rec.get("flops_per_device", 0.0)
    bytes_ca = rec.get("bytes_accessed_per_device", 0.0)
    coll = dict(rec.get("collective_bytes_per_device", {}))

    flops = flops_ca
    trip_ratio = 1.0
    if hlo_path and hlo_path.exists():
        h = analyze_hlo(hlo_path.read_text())
        if h["dot_flops_per_device"] > flops_ca:
            flops = h["dot_flops_per_device"]
            trip_ratio = flops / max(flops_ca, 1.0)
        if h["collective_bytes_per_device"]:
            coll = h["collective_bytes_per_device"]
    # raw cost-analysis bytes: while bodies counted once (under-count) but
    # every fusion operand billed as HBM (over-count); used UNSCALED — the
    # trip-corrected variant proved unstable across dtype changes. The
    # analytic min_bytes column bounds from below.
    mem_bytes = bytes_ca
    coll_bytes = sum(coll.values())
    min_bytes = min_bytes_per_device(arch, shape_name, devices)

    t_compute = flops / PEAK_FLOPS
    t_memory = mem_bytes / HBM_BW            # XLA upper bound
    t_memory_min = min_bytes / HBM_BW        # analytic lower bound
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # achievable bound: memory credited at the analytic minimum
    bound_min = max(t_compute, t_memory_min, t_coll)
    mf = model_flops_per_device(arch, shape_name, devices)
    return {
        "arch": arch, "shape": shape_name, "mesh": rec["mesh"],
        "compute_s": t_compute, "memory_s": t_memory,
        "memory_min_s": t_memory_min,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_ratio": mf / max(flops, 1.0),
        "roofline_step_s": bound,
        "roofline_fraction": mf / PEAK_FLOPS / bound if bound > 0 else 0.0,
        "roofline_fraction_achievable": (mf / PEAK_FLOPS / bound_min
                                         if bound_min > 0 else 0.0),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": mem_bytes,
        "min_bytes_per_device": min_bytes,
        "collective_bytes_per_device": coll_bytes,
        "peak_hbm_bytes": rec.get("memory", {}).get("peak_bytes"),
        "trip_ratio": round(trip_ratio, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", type=Path, default=DRYRUN_DIR)
    ap.add_argument("--mesh", default="16x16",
                    help="roofline table mesh (single-pod per spec)")
    ap.add_argument("--out", type=Path,
                    default=DRYRUN_DIR.parent / "roofline.json")
    args = ap.parse_args()

    results = []
    for f in sorted(args.dryrun_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            continue
        if rec["mesh"] != args.mesh:
            continue
        hlo = f.with_suffix("").with_suffix("")  # strip .json
        hlo = args.dryrun_dir / (f.stem + ".hlo.txt")
        results.append(analyze_cell(rec, hlo))

    results.sort(key=lambda r: (r["arch"], r["shape"]))
    args.out.write_text(json.dumps(results, indent=1))

    hdr = (f"{'arch':<22}{'shape':<13}{'compute_s':>10}{'mem_xla_s':>10}"
           f"{'mem_min_s':>10}{'coll_s':>9}  {'dominant':<11}{'useful':>7}"
           f"{'roofl%':>7}{'achv%':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in results:
        print(f"{r['arch']:<22}{r['shape']:<13}"
              f"{r['compute_s']:>10.4f}{r['memory_s']:>10.4f}"
              f"{r['memory_min_s']:>10.4f}"
              f"{r['collective_s']:>9.4f}  {r['dominant']:<11}"
              f"{r['useful_ratio']:>7.2f}"
              f"{100*r['roofline_fraction']:>6.1f}%"
              f"{100*r['roofline_fraction_achievable']:>6.1f}%")
    return results


if __name__ == "__main__":
    main()
