"""Serving front-end under load: sustained q/s at measured capacity,
tail latency + shed rate at 1×/2×/5× offered load, degradation-ladder
answer quality (bracket width / greedy recall vs the exact ops), and the
epoch-fenced hot-swap pause while the front-end is serving.

Load rows drive the same paced-trace machinery as the
``repro.launch.frontend`` CLI (catch-up submission of a seeded arrival
schedule), so the bench measures the production admission path — queue,
EWMA sojourn estimator, deadline shedding — not a synthetic loop. The
offered rates are calibrated from a measured steady-state batch, so
"2×" means 2× *this machine's* capacity on every host.
"""
from __future__ import annotations

import threading

import numpy as np

from repro import obs
from repro.analytics.engine import build_sharded_analytics
from repro.data import make_corpus
from repro.ingest.serving import GenerationServer
from repro.launch.frontend import drive, make_trace
from repro.serving import FrontendConfig, QueryFrontend, ShedError

from .common import BENCH_SEED, record, save

_DEADLINE_S = 0.1
_MAX_REQUESTS = 4000          # per load row — bounds bench wall time


def _warm_and_calibrate(fe: QueryFrontend, eng, n: int,
                        vocab: int) -> float:
    """Compile every (op, level, bucket) variant, re-seed the admission
    EWMA from one steady full batch, and return the steady per-batch
    seconds (the capacity calibration)."""
    with obs.disabled():
        for op, kw in (("count", {"sym_hi": vocab}),
                       ("quantile", {"k": 0}), ("topk", {})):
            for bucket in fe.config.buckets:
                for _ in range(bucket):
                    fe.submit(op, 0, n, deadline_s=600.0, **kw)
                while fe.queue.depth:
                    fe.pump()
                # degraded variants too — a mid-run ladder step must hit
                # a warm cache at every bucket, or one compile stalls the
                # pump for seconds and poisons every load row after it
                for level in (1, 2):
                    _, fn = fe._op_fn(op, level)
                    fe.runner.run((op, level), fn, eng,
                                  np.zeros((4, bucket), np.int32), bucket)
        # end-to-end capacity: submit+pump through the production path
        # (queue locks, span, session pin, resolve) — the jitted batch
        # alone understates per-request cost by an order of magnitude
        batch, reqs = fe.runner.max_batch, 512
        steady = None
        for _ in range(2):                    # second pass is the figure
            sw = obs.Stopwatch()
            done = 0
            while done < reqs:
                for _ in range(batch):
                    fe.submit("count", 0, n, deadline_s=600.0,
                              sym_hi=vocab)
                while fe.queue.depth:
                    done += fe.pump()
            steady = sw.lap() / (done / batch)
        for _ in range(30):
            fe.queue.observe_service(steady, batch)
    return steady


def _load_row(rows: list, fe: QueryFrontend, n: int, vocab: int,
              rate_qps: float, factor: float, tag: str = "") -> None:
    requests = min(_MAX_REQUESTS, max(64, int(rate_qps * 1.5)))
    trace = make_trace(n, requests, BENCH_SEED + int(factor * 10),
                       base_qps=rate_qps, burst_qps=rate_qps,
                       burst_every_s=1.0, burst_len_s=0.0,
                       deadline_s=_DEADLINE_S, topk_k=fe.config.topk_k)
    sw = obs.Stopwatch()
    tickets = drive(fe, trace, 1.0, vocab)
    lats, served, shed, degraded, misses = [], 0, 0, 0, 0
    for t in tickets:
        try:
            a = t.result(timeout=60.0)
        except ShedError:
            shed += 1
            continue
        served += 1
        lats.append(a.latency_s)
        degraded += bool(a.degraded)
        misses += not a.deadline_met
    wall = sw.lap()
    record(rows, f"frontend_load_{factor:g}x{tag}_n{n}",
           wall / max(1, served),
           offered_qps=round(rate_qps, 1),
           served_qps=round(served / max(wall, 1e-9), 1),
           served=served, shed=shed,
           shed_rate=round(shed / max(1, len(tickets)), 4),
           degraded=degraded, deadline_misses=misses,
           p50_ms=round(float(np.percentile(lats, 50)) * 1e3, 3)
           if lats else 0.0,
           p99_ms=round(float(np.percentile(lats, 99)) * 1e3, 3)
           if lats else 0.0)


def _quality_rows(rows: list, fe: QueryFrontend, eng, toks: np.ndarray,
                  n: int, vocab: int) -> None:
    """Ladder answer quality vs the numpy oracle: every degraded answer
    must bracket/contain the truth — quality is how *tight* it is."""
    rng = np.random.default_rng(BENCH_SEED)
    B = 32
    lo = rng.integers(0, n // 2, size=B)
    hi = lo + rng.integers(n // 8, n // 2, size=B)
    hi = np.minimum(hi, n)
    regions = [toks[a:b] for a, b in zip(lo, hi)]

    # quantile: bracket width (symbols) per ladder level
    ks = (hi - lo) // 2
    q = np.stack([lo, hi, ks, np.zeros(B, np.int64)]).astype(np.int32)
    exact_q = np.array([np.sort(r)[k] for r, k in zip(regions, ks)])
    for level in (1, 2):
        _, fn = fe._op_fn("quantile", level)
        sw = obs.Stopwatch()
        a, b, _ = fe.runner.run(("quantile", level), fn, eng, q, B)
        contained = np.all((a[:B] <= exact_q) & (exact_q < b[:B]))
        record(rows, f"ladder_quantile_bracket_l{level}_n{n}", sw.lap(),
               mean_width_syms=round(float(np.mean(b[:B] - a[:B])), 2),
               vocab=vocab, contained=bool(contained))
        assert contained, "degraded quantile bracket missed the oracle"

    # top-k: greedy frontier recall vs the exact heavy hitters
    k = fe.config.topk_k
    t = np.stack([lo, hi, np.zeros(B, np.int64),
                  np.zeros(B, np.int64)]).astype(np.int32)
    exact_t = [set(np.argsort(np.bincount(r, minlength=vocab))[-k:])
               for r in regions]
    for level in (1, 2):
        _, fn = fe._op_fn("topk", level)
        sw = obs.Stopwatch()
        syms, _, _ = fe.runner.run(("topk", level), fn, eng, t, B)
        recall = np.mean([len(set(syms[i].tolist()) & exact_t[i]) / k
                          for i in range(B)])
        record(rows, f"ladder_topk_greedy_l{level}_n{n}", sw.lap(),
               recall=round(float(recall), 4), k=k)

    # count: bounds width relative to the queried range length
    c = np.stack([lo, hi, np.full(B, 8), np.full(B, 24)]).astype(np.int32)
    exact_c = np.array([((r >= 8) & (r < 24)).sum() for r in regions])
    _, fn = fe._op_fn("count", 1)
    sw = obs.Stopwatch()
    a, b, _ = fe.runner.run(("count", 1), fn, eng, c, B)
    ok = np.all((a[:B] <= exact_c) & (exact_c <= b[:B]))
    record(rows, f"ladder_count_bounds_l1_n{n}", sw.lap(),
           mean_rel_width=round(float(np.mean((b[:B] - a[:B])
                                              / (hi - lo))), 4),
           bracketing=bool(ok))
    assert ok, "count bounds failed to bracket the oracle"


def run(n: int = 1 << 16, out: list | None = None) -> list:
    rows = out if out is not None else []
    n = int(min(n, 1 << 15))   # serving cost is per-query, not per-corpus
    vocab = 64
    shard_bits = max(10, n.bit_length() - 4)
    toks = np.asarray(make_corpus(n, vocab, seed=BENCH_SEED), np.int64)
    eng = build_sharded_analytics(toks, vocab, shard_bits=shard_bits)

    fe = QueryFrontend(
        GenerationServer(eng),
        config=FrontendConfig(buckets=(8, 32), capacity=256,
                              default_deadline_s=_DEADLINE_S,
                              probe_shards=False))
    steady_s = _warm_and_calibrate(fe, eng, n, vocab)
    batch = fe.runner.max_batch
    sync_qps = batch / max(steady_s, 1e-9)

    fe.start()
    # threaded calibration: the synchronous figure ignores pacing sleeps
    # and GIL contention with the worker; true capacity is what the
    # running front-end actually sustains when offered that rate
    with obs.disabled():
        trace = make_trace(n, min(_MAX_REQUESTS, int(sync_qps)),
                           BENCH_SEED, base_qps=sync_qps,
                           burst_qps=sync_qps, burst_every_s=1.0,
                           burst_len_s=0.0, deadline_s=_DEADLINE_S,
                           topk_k=fe.config.topk_k)
        sw = obs.Stopwatch()
        tickets = drive(fe, trace, 1.0, vocab)
        served = 0
        for t in tickets:
            try:
                t.result(timeout=60.0)
                served += 1
            except ShedError:
                pass
        capacity_qps = max(1.0, served / max(sw.lap(), 1e-9))
    record(rows, f"frontend_steady_batch{batch}_n{n}", steady_s,
           sync_qps=round(sync_qps, 1),
           capacity_qps=round(capacity_qps, 1),
           us_per_query=round(steady_s / batch * 1e6, 2))
    for factor in (1.0, 2.0, 5.0):
        _load_row(rows, fe, n, vocab, capacity_qps * factor, factor)

    # hot-swap pause while the front-end is live: swapper thread fences
    # three generation swaps against a concurrent 1× load
    pauses: list = []

    def swapper():
        srv = fe.server
        sw = obs.Stopwatch()
        for _ in range(3):
            fe.clock.sleep(0.2)
            sw.lap()
            srv.swap_generation(srv.engine, wait_drain=True)
            pauses.append(sw.lap())

    th = threading.Thread(target=swapper)
    th.start()
    _load_row(rows, fe, n, vocab, capacity_qps, 1.0, tag="_during_swaps")
    th.join()
    record(rows, f"swap_pause_under_load_n{n}",
           sorted(pauses)[len(pauses) // 2], swaps=len(pauses))

    fe.stop(drain=True)
    _quality_rows(rows, fe, eng, toks, n, vocab)
    return rows


if __name__ == "__main__":
    save(run(), "serving.json")
