"""Paper Table 1, rows 8–10: rank/select structure construction.

Binary (Theorem 5.1): O(n/log n) work — construction runs on the packed
words (popcount + prefix sum), so throughput is reported in bits/s.
Generalized (Theorem 5.2): σ-ary structures for σ ∈ {2,4,16}.
Also times query throughput (rank / select / access), since wavelet-tree
query cost is what the structures exist for.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.core.rank_select import (build_binary_rank, build_binary_select,
                                    build_bitvector, build_generalized,
                                    generalized_rank, rank1, select1)

from .common import record, save, time_fn


def run(n: int = 1 << 24, out: list | None = None) -> list:
    rows = out if out is not None else []
    rng = np.random.default_rng(0)
    bits = (rng.random(n) < 0.5).astype(np.uint8)
    words = bitops.pack_bits(bitops.pad_bits(jnp.asarray(bits)))

    f = jax.jit(functools.partial(build_binary_rank, n=n))
    t = time_fn(f, words, iters=5)
    record(rows, f"binary_rank_build_n{n}", t,
           gbits_per_s=round(n / t / 1e9, 2))

    f = jax.jit(functools.partial(build_binary_select, n=n, sample_rate=512))
    t = time_fn(f, words, iters=5)
    record(rows, f"binary_select_build_n{n}", t,
           gbits_per_s=round(n / t / 1e9, 2))

    bv = build_bitvector(words, n, 512)
    q = jnp.asarray(rng.integers(0, n, 1 << 16), jnp.int32)
    f = jax.jit(lambda idx: rank1(bv.rank, idx))
    t = time_fn(f, q, iters=5)
    record(rows, f"rank1_query_batch{1 << 16}", t,
           mq_per_s=round(q.shape[0] / t / 1e6, 1))

    total_ones = int(bits.sum())
    k = jnp.asarray(rng.integers(0, total_ones, 1 << 16), jnp.int32)
    f = jax.jit(lambda kk: select1(bv.rank, bv.sel1, kk))
    t = time_fn(f, k, iters=5)
    record(rows, f"select1_query_batch{1 << 16}", t,
           mq_per_s=round(k.shape[0] / t / 1e6, 1))

    # generalized structures (σ-ary)
    gn = 1 << 22
    for width in (1, 2, 4):
        sigma = 1 << width
        seq = jnp.asarray(rng.integers(0, sigma, gn).astype(np.uint32))
        f = jax.jit(functools.partial(build_generalized, width=width, n=gn))
        t = time_fn(f, seq, iters=3)
        record(rows, f"generalized_build_s{sigma}_n{gn}", t,
               msym_per_s=round(gn / t / 1e6, 1))
        g = f(seq)
        qq = jnp.asarray(rng.integers(0, gn, 4096), jnp.int32)
        cc = jnp.asarray(rng.integers(0, sigma, 4096), jnp.int32)
        fq = jax.jit(lambda c, i: generalized_rank(g, c, i))
        t = time_fn(fq, cc, qq, iters=5)
        record(rows, f"generalized_rank_s{sigma}_batch4096", t,
               mq_per_s=round(4096 / t / 1e6, 2))
    if out is None:
        save(rows, "rank_select.json")
    return rows


if __name__ == "__main__":
    run()
