"""Benchmark harness entry point: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``          — everything
``PYTHONPATH=src python -m benchmarks.run --only wt`` — one suite

Each suite prints ``name,us_per_call,derived`` CSV lines and persists JSON
under results/bench/. ``--fast`` runs CI-sized inputs into
``<suite>.fast.json`` (meta records ``fast: true``) and warns when a
suite's *full-size* trajectory is missing or was last recorded at a
different commit — a fast artifact is a smoke signal, not a perf number.
"""
from __future__ import annotations

import argparse
import json

from repro import obs

from . import (bench_analytics, bench_construction, bench_corpus_store,
               bench_huffman, bench_index, bench_kernels, bench_multiary,
               bench_rank_select, bench_robust, bench_serving,
               bench_wavelet_matrix, bench_wavelet_tree)
from .common import RESULTS_DIR, run_meta, save

SUITES = {
    "wt": ("wavelet_tree.json", bench_wavelet_tree.run),
    "wm": ("wavelet_matrix.json", bench_wavelet_matrix.run),
    "construction": ("construction.json", bench_construction.run),
    "huffman": ("huffman.json", bench_huffman.run),
    "multiary": ("multiary.json", bench_multiary.run),
    "rank_select": ("rank_select.json", bench_rank_select.run),
    "kernels": ("kernels.json", bench_kernels.run),
    "corpus": ("corpus_store.json", bench_corpus_store.run),
    "index": ("index.json", bench_index.run),
    "analytics": ("analytics.json", bench_analytics.run),
    "robust": ("robust.json", bench_robust.run),
    "serving": ("serving.json", bench_serving.run),
}


def stale_full_runs(suites: dict, commit: str) -> list:
    """[(key, reason)] for suites whose full-size artifact is missing or
    was recorded at a different commit than ``commit`` — the drift a fast
    run can hide (e.g. ``robust.fast.json`` exists, ``robust.json`` never
    ran)."""
    out = []
    for key, (fname, _) in suites.items():
        path = RESULTS_DIR / fname
        if not path.exists():
            out.append((key, f"{fname} missing (full-size run never "
                             f"recorded)"))
            continue
        try:
            data = json.loads(path.read_text())
            meta = data.get("meta", {}) if isinstance(data, dict) else {}
        except Exception:                                 # noqa: BLE001
            out.append((key, f"{fname} unreadable"))
            continue
        if not meta:
            out.append((key, f"{fname} has no provenance meta (predates "
                             f"the meta block — rerun full-size)"))
            continue
        got = meta.get("git_commit", "unknown")
        if got != commit:
            out.append((key, f"{fname} recorded at {got[:12]} ≠ HEAD "
                             f"{commit[:12]} (full-size trajectory stale)"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    ap.add_argument("--fast", action="store_true",
                    help="smaller n (CI-sized); results land in "
                         "<suite>.fast.json so the full-size perf "
                         "trajectory in results/bench/<suite>.json stays "
                         "comparable across runs")
    args = ap.parse_args()

    todo = {args.only: SUITES[args.only]} if args.only else SUITES
    sw = obs.Stopwatch()
    for key, (fname, fn) in todo.items():
        print(f"== {key} ==", flush=True)
        # pass `out` so the suite never self-saves under its default name
        # (a fast run must only ever touch the .fast.json artifact)
        kwargs = {"out": []}
        if args.fast:
            kwargs["n"] = 1 << 16
            fname = fname.replace(".json", ".fast.json")
        rows = fn(**kwargs)
        save(rows, fname, extra_meta={"fast": True} if args.fast else None)
    if args.fast:
        # staleness is a repo-wide property: check EVERY registered suite,
        # not just the ones this invocation ran — a suite with no full-size
        # JSON at all must warn even under `--only`
        stale = stale_full_runs(SUITES, run_meta()["git_commit"])
        for key, reason in stale:
            print(f"WARNING: [{key}] {reason}")
        if stale:
            print(f"({len(stale)} suite(s) have no up-to-date full-size "
                  f"run — run `python -m benchmarks.run` without --fast "
                  f"to refresh the trajectory)")
    print(f"total {sw.total():.1f}s")


if __name__ == "__main__":
    main()
