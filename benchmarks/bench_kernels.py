"""Kernel microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python
emulation — correctness, not speed), so the timed numbers that matter here
are the pure-jnp reference paths the XLA:CPU backend compiles. The
interpret-mode numbers are recorded once for completeness and marked as
such; on TPU the pallas_call path replaces both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import record, save, time_fn


def run(n: int = 1 << 22, out: list | None = None) -> list:
    rows = out if out is not None else []
    rng = np.random.default_rng(0)
    bits = jnp.asarray(rng.integers(0, 2, n).astype(np.uint8))

    f = jax.jit(ref.bitpack_ref)
    t = time_fn(f, bits, iters=5)
    record(rows, f"bitpack_ref_n{n}", t, gbits_per_s=round(n / t / 1e9, 2))

    words = ref.bitpack_ref(bits)
    f = jax.jit(functools.partial(ref.rank_build_ref, n=n))
    t = time_fn(f, words, iters=5)
    record(rows, f"rank_build_ref_n{n}", t, gbits_per_s=round(n / t / 1e9, 2))

    sub = jnp.asarray(rng.integers(0, 256, n).astype(np.uint32))
    f = jax.jit(functools.partial(ref.wm_level_step_ref, shift=3, n=n))
    t = time_fn(f, sub, iters=3)
    record(rows, f"wm_level_ref_n{n}", t, melem_per_s=round(n / t / 1e6, 1))

    # interpret-mode sanity timings on a small size (Python emulation)
    small = 1 << 16
    bs = jnp.asarray(rng.integers(0, 2, small).astype(np.uint8))
    t = time_fn(lambda x: ops.bitpack(x, interpret=True), bs, iters=1,
                warmup=1)
    record(rows, f"bitpack_pallas_interpret_n{small}", t, note="emulation")
    ss = jnp.asarray(rng.integers(0, 256, small).astype(np.uint32))
    t = time_fn(lambda x: ops.wm_level_step(x, 3, small, interpret=True),
                ss, iters=1, warmup=1)
    record(rows, f"wm_level_pallas_interpret_n{small}", t, note="emulation")
    if out is None:
        save(rows, "kernels.json")
    return rows


if __name__ == "__main__":
    run()
