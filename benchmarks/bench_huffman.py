"""Paper Table 1, rows 4–5: arbitrary-shaped (Huffman) wavelet trees.

Construction throughput on Zipf-skewed data plus the entropy win: the
Huffman tree's total bits vs the balanced tree's n·⌈logσ⌉.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.huffman import build_huffman_wavelet_tree, huffman_codebook
from repro.core.wavelet_matrix import num_levels

from .common import record, save, time_fn


def run(n: int = 1 << 19, out: list | None = None) -> list:
    rows = out if out is not None else []
    rng = np.random.default_rng(0)
    for sigma, zipf in ((256, 1.2), (4096, 1.2)):
        p = np.arange(1, sigma + 1) ** (-zipf)
        seq = rng.choice(sigma, size=n, p=p / p.sum()).astype(np.uint32)
        freqs = np.bincount(seq, minlength=sigma) + 1
        codes, lengths, max_len = huffman_codebook(freqs)
        seqj = jnp.asarray(seq)
        cj, lj = jnp.asarray(codes), jnp.asarray(lengths)
        # close over the (tiny, static) codebook so the builder sees
        # concrete codewords and takes the fused run-table fast path
        f = jax.jit(lambda s: build_huffman_wavelet_tree(s, cj, lj,
                                                         max_len=max_len))
        t = time_fn(f, seqj, iters=3)
        tree = f(seqj)
        total_bits = int(tree.total_bits)
        balanced = n * num_levels(sigma)
        record(rows, f"huffman_n{n}_s{sigma}_z{zipf}", t,
               melem_per_s=round(n / t / 1e6, 1),
               height=max_len,
               bits_vs_balanced=round(total_bits / balanced, 3),
               avg_code_len=round(total_bits / n, 2))
    if out is None:
        save(rows, "huffman.json")
    return rows


if __name__ == "__main__":
    run()
