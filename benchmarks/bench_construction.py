"""Construction fast path: before/after evidence for the fused builder.

Rows per (n, σ): the levelwise prior-work baseline [Shun'15], the
historical step-by-step XLA τ-chunk path (``fused=False`` — the "before"),
and the fused fast path (``fused=True`` — select-gather partitions,
batched directory build). ``speedup_vs_xla`` on the fused rows is the
headline number; the acceptance bar is ≥ 2× at n ≥ 2^20, σ = 256.

A second section times the stable counting rank that drives the big-node
sort and every suffix-array doubling round (one-hot-free blocked path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sort import counting_rank
from repro.core.wavelet_matrix import (build_wavelet_matrix,
                                       build_wavelet_matrix_levelwise)

from .common import record, save, time_fn


def run(n: int = 1 << 20, out: list | None = None) -> list:
    rows = out if out is not None else []
    tau = 8
    for sigma in (256, 65536):
        seq = jnp.asarray(np.random.default_rng(0)
                          .integers(0, sigma, n).astype(np.uint32))

        f = jax.jit(functools.partial(build_wavelet_matrix_levelwise,
                                      sigma=sigma))
        t_lvl = time_fn(f, seq, iters=3)
        record(rows, f"construct_levelwise_n{n}_s{sigma}", t_lvl,
               melem_per_s=round(n / t_lvl / 1e6, 1))

        f = jax.jit(functools.partial(build_wavelet_matrix, sigma=sigma,
                                      tau=tau, fused=False))
        t_xla = time_fn(f, seq, iters=3)
        record(rows, f"construct_xla_tau{tau}_n{n}_s{sigma}", t_xla,
               melem_per_s=round(n / t_xla / 1e6, 1),
               speedup_vs_levelwise=round(t_lvl / t_xla, 2))

        f = jax.jit(functools.partial(build_wavelet_matrix, sigma=sigma,
                                      tau=tau, fused=True,
                                      use_kernels=False))
        t_fused = time_fn(f, seq, iters=3)
        record(rows, f"construct_fused_tau{tau}_n{n}_s{sigma}", t_fused,
               melem_per_s=round(n / t_fused / 1e6, 1),
               speedup_vs_xla=round(t_xla / t_fused, 2),
               speedup_vs_levelwise=round(t_lvl / t_fused, 2))

    # the big-node / suffix-array sort primitive (8-bit digits)
    nb = 256
    digits = jnp.asarray(np.random.default_rng(1)
                         .integers(0, nb, n).astype(np.int32))
    f = jax.jit(functools.partial(counting_rank, num_buckets=nb,
                                  use_kernel=False))
    t_cr = time_fn(f, digits, iters=3)
    record(rows, f"counting_rank_blocked_n{n}_b{nb}", t_cr,
           melem_per_s=round(n / t_cr / 1e6, 1))

    if out is None:
        save(rows, "construction.json")
    return rows


if __name__ == "__main__":
    run()
