"""Construction fast path: before/after evidence for the fused builders.

Rows per (n, σ): the levelwise prior-work baseline [Shun'15], the
historical step-by-step XLA τ-chunk path (``fused=False`` — the "before"),
and the fused fast path (``fused=True`` — select-gather partitions,
batched directory build). ``speedup_vs_xla`` on the fused rows is the
headline number; the acceptance bar is ≥ 2× at n ≥ 2^20, σ = 256.

The tree-family section extends the evidence to the *segmented*
select-gather fast path: ``build_wavelet_tree`` (node-segmented
partitions), the domain-decomposed variant (gather merge), the
Huffman-shaped tree (static run tables + select-gather), and the multiary
d-way split — each fused row against its own scatter baseline.

A final section times the stable counting rank that drives the big-node
sort and every suffix-array doubling round (one-hot-free blocked path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.huffman import build_huffman_wavelet_tree, huffman_codebook
from repro.core.multiary import build_multiary_wavelet_tree
from repro.core.sort import counting_rank
from repro.core.wavelet_matrix import (build_wavelet_matrix,
                                       build_wavelet_matrix_levelwise)
from repro.core.wavelet_tree import build_wavelet_tree, build_wavelet_tree_dd

from .common import record, save, time_fn


def run(n: int = 1 << 20, out: list | None = None) -> list:
    rows = out if out is not None else []
    tau = 8
    for sigma in (256, 65536):
        seq = jnp.asarray(np.random.default_rng(0)
                          .integers(0, sigma, n).astype(np.uint32))

        f = jax.jit(functools.partial(build_wavelet_matrix_levelwise,
                                      sigma=sigma))
        t_lvl = time_fn(f, seq, iters=3)
        record(rows, f"construct_levelwise_n{n}_s{sigma}", t_lvl,
               melem_per_s=round(n / t_lvl / 1e6, 1))

        f = jax.jit(functools.partial(build_wavelet_matrix, sigma=sigma,
                                      tau=tau, fused=False))
        t_xla = time_fn(f, seq, iters=3)
        record(rows, f"construct_xla_tau{tau}_n{n}_s{sigma}", t_xla,
               melem_per_s=round(n / t_xla / 1e6, 1),
               speedup_vs_levelwise=round(t_lvl / t_xla, 2))

        f = jax.jit(functools.partial(build_wavelet_matrix, sigma=sigma,
                                      tau=tau, fused=True,
                                      use_kernels=False))
        t_fused = time_fn(f, seq, iters=3)
        record(rows, f"construct_fused_tau{tau}_n{n}_s{sigma}", t_fused,
               melem_per_s=round(n / t_fused / 1e6, 1),
               speedup_vs_xla=round(t_xla / t_fused, 2),
               speedup_vs_levelwise=round(t_lvl / t_fused, 2))

    # ---- tree family: segmented select-gather fast path ----------------
    sigma = 256
    seq = jnp.asarray(np.random.default_rng(2)
                      .integers(0, sigma, n).astype(np.uint32))

    f = jax.jit(functools.partial(build_wavelet_tree, sigma=sigma, tau=8,
                                  fused=False))
    t_xla = time_fn(f, seq, iters=3)
    record(rows, f"wt_xla_tau8_n{n}_s{sigma}", t_xla,
           melem_per_s=round(n / t_xla / 1e6, 1))
    f = jax.jit(functools.partial(build_wavelet_tree, sigma=sigma, tau=8,
                                  fused=True, use_kernels=False))
    t_fused = time_fn(f, seq, iters=3)
    record(rows, f"wt_fused_tau8_n{n}_s{sigma}", t_fused,
           melem_per_s=round(n / t_fused / 1e6, 1),
           speedup_vs_xla=round(t_xla / t_fused, 2))

    chunks = 16
    f = jax.jit(functools.partial(build_wavelet_tree_dd, sigma=sigma,
                                  num_chunks=chunks, fused=False))
    t_xla = time_fn(f, seq, iters=3)
    record(rows, f"wt_dd_xla_P{chunks}_n{n}_s{sigma}", t_xla,
           melem_per_s=round(n / t_xla / 1e6, 1))
    f = jax.jit(functools.partial(build_wavelet_tree_dd, sigma=sigma,
                                  num_chunks=chunks, fused=True))
    t_fused = time_fn(f, seq, iters=3)
    record(rows, f"wt_dd_fused_P{chunks}_n{n}_s{sigma}", t_fused,
           melem_per_s=round(n / t_fused / 1e6, 1),
           speedup_vs_xla=round(t_xla / t_fused, 2))

    zipf = 1.2
    p = np.arange(1, sigma + 1) ** (-zipf)
    hseq = np.random.default_rng(3).choice(
        sigma, size=n, p=p / p.sum()).astype(np.uint32)
    freqs = np.bincount(hseq, minlength=sigma) + 1
    codes, lengths, max_len = huffman_codebook(freqs)
    cj, lj = jnp.asarray(codes), jnp.asarray(lengths)
    hseqj = jnp.asarray(hseq)
    # the codebook is closed over (concrete), so jit traces the fused
    # run-table path; only the sequence is an argument
    f = jax.jit(lambda s: build_huffman_wavelet_tree(s, cj, lj, max_len,
                                                     fused=False))
    t_xla = time_fn(f, hseqj, iters=3)
    record(rows, f"huffman_xla_n{n}_s{sigma}_z{zipf}", t_xla,
           melem_per_s=round(n / t_xla / 1e6, 1), height=max_len)
    f = jax.jit(lambda s: build_huffman_wavelet_tree(s, cj, lj, max_len))
    t_fused = time_fn(f, hseqj, iters=3)
    record(rows, f"huffman_fused_n{n}_s{sigma}_z{zipf}", t_fused,
           melem_per_s=round(n / t_fused / 1e6, 1), height=max_len,
           speedup_vs_xla=round(t_xla / t_fused, 2))

    for width in (2, 4):
        f = jax.jit(functools.partial(build_multiary_wavelet_tree,
                                      sigma=sigma, width=width,
                                      fused=False))
        t_xla = time_fn(f, seq, iters=3)
        record(rows, f"multiary_xla_d{1 << width}_n{n}_s{sigma}", t_xla,
               melem_per_s=round(n / t_xla / 1e6, 1))
        f = jax.jit(functools.partial(build_multiary_wavelet_tree,
                                      sigma=sigma, width=width))
        t_fused = time_fn(f, seq, iters=3)
        record(rows, f"multiary_fused_d{1 << width}_n{n}_s{sigma}", t_fused,
               melem_per_s=round(n / t_fused / 1e6, 1),
               speedup_vs_xla=round(t_xla / t_fused, 2))

    # the big-node / suffix-array sort primitive (8-bit digits)
    nb = 256
    digits = jnp.asarray(np.random.default_rng(1)
                         .integers(0, nb, n).astype(np.int32))
    f = jax.jit(functools.partial(counting_rank, num_buckets=nb,
                                  use_kernel=False))
    t_cr = time_fn(f, digits, iters=3)
    record(rows, f"counting_rank_blocked_n{n}_b{nb}", t_cr,
           melem_per_s=round(n / t_cr / 1e6, 1))

    if out is None:
        save(rows, "construction.json")
    return rows


if __name__ == "__main__":
    run()
