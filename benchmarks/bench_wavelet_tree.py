"""Paper Table 1, rows 1–3: binary wavelet tree construction.

Compares the prior-work levelwise baseline [Shun'15] (O(n logσ) work: full
32-bit symbols reshuffled at every level) against this paper's τ-chunked
algorithm (narrow τ-bit short lists between big-node sorts) and the
domain-decomposition algorithm (Theorem 4.2). The derived column
``bytes_per_elem`` is the data-movement proxy for PRAM work on a
bandwidth-bound machine (DESIGN.md §2): levelwise moves 4·logσ B/elem,
τ-chunked ≈ (4·logσ/τ + 1·logσ) B/elem.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wavelet_matrix import num_levels
from repro.core.wavelet_tree import (build_wavelet_tree,
                                     build_wavelet_tree_dd,
                                     build_wavelet_tree_levelwise)

from .common import record, save, time_fn


def _data(n, sigma, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).integers(0, sigma, n).astype(np.uint32))


def run(n: int = 1 << 20, out: list | None = None) -> list:
    rows = out if out is not None else []
    for sigma in (256, 65536):
        seq = _data(n, sigma)
        nbits = num_levels(sigma)

        f = jax.jit(functools.partial(build_wavelet_tree_levelwise,
                                      sigma=sigma))
        t = time_fn(f, seq, iters=3)
        record(rows, f"wt_levelwise_n{n}_s{sigma}", t,
               melem_per_s=round(n / t / 1e6, 1),
               bytes_per_elem=4 * nbits)

        for tau in (4, 8):
            for big in ("compose", "radix"):
                f = jax.jit(functools.partial(build_wavelet_tree,
                                              sigma=sigma, tau=tau,
                                              big_step=big))
                t = time_fn(f, seq, iters=3)
                record(rows, f"wt_tau{tau}_{big}_n{n}_s{sigma}", t,
                       melem_per_s=round(n / t / 1e6, 1),
                       bytes_per_elem=round(4 * nbits / tau + nbits, 1))

        for chunks in (16, 64):
            f = jax.jit(functools.partial(build_wavelet_tree_dd,
                                          sigma=sigma, num_chunks=chunks))
            t = time_fn(f, seq, iters=3)
            record(rows, f"wt_dd_P{chunks}_n{n}_s{sigma}", t,
                   melem_per_s=round(n / t / 1e6, 1))
    if out is None:
        save(rows, "wavelet_tree.json")
    return rows


if __name__ == "__main__":
    run()
