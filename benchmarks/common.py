"""Benchmark helpers: jit-compile once, time steady-state executions."""
from __future__ import annotations

import json
import subprocess
import time
from pathlib import Path
from typing import Callable

import jax

RESULTS_DIR = Path(__file__).resolve().parent.parent / "results" / "bench"

#: default RNG seed shared by every suite's synthetic inputs — stamped
#: into the bench JSON so a result is reproducible from its artifact.
BENCH_SEED = 0


def run_meta(seed: int = BENCH_SEED) -> dict:
    """Provenance stamped into every bench JSON: the exact code (git
    commit + dirty flag), runtime (jax version, backend, device count),
    and RNG seed a run used."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=Path(__file__).parent,
            capture_output=True, text=True, timeout=10).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=Path(__file__).parent,
            capture_output=True, text=True, timeout=10).stdout.strip())
    except Exception:                                     # noqa: BLE001
        commit, dirty = "unknown", False
    return {
        "git_commit": commit or "unknown",
        "git_dirty": dirty,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.local_device_count(),
        "seed": int(seed),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of ``fn(*args)`` after warmup (handles jit)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def record(rows: list, name: str, seconds: float, **derived) -> dict:
    row = {"name": name, "us_per_call": round(seconds * 1e6, 1), **derived}
    rows.append(row)
    flat = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{row['us_per_call']}us,{flat}", flush=True)
    return row


def save(rows: list, fname: str, seed: int = BENCH_SEED) -> Path:
    """Persist ``{"meta": provenance, "rows": rows}`` under results/bench/,
    creating the directory tree on first run. The meta block (git commit,
    jax version, RNG seed, …) makes every artifact self-describing. numpy
    scalars in derived fields serialize as plain floats."""
    path = RESULTS_DIR / fname
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"meta": run_meta(seed), "rows": rows},
                               indent=1, default=float))
    return path
