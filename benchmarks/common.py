"""Benchmark helpers: jit-compile once, time steady-state executions."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable

import jax

RESULTS_DIR = Path(__file__).resolve().parent.parent / "results" / "bench"


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of ``fn(*args)`` after warmup (handles jit)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def record(rows: list, name: str, seconds: float, **derived) -> dict:
    row = {"name": name, "us_per_call": round(seconds * 1e6, 1), **derived}
    rows.append(row)
    flat = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{row['us_per_call']}us,{flat}", flush=True)
    return row


def save(rows: list, fname: str) -> Path:
    """Persist rows under results/bench/, creating the directory tree on
    first run. numpy scalars in derived fields serialize as plain floats."""
    path = RESULTS_DIR / fname
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rows, indent=1, default=float))
    return path
