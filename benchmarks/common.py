"""Benchmark helpers: jit-compile once, time steady-state executions.

Timing is delegated to ``repro.obs.time_compiled`` — the same timer the
serving CLIs use — so every suite separates ``compile_s`` (first-call
cost: trace + lower + compile + run) from the steady-state median that
``us_per_call`` reports.
"""
from __future__ import annotations

import json
import platform
import subprocess
import time
from pathlib import Path
from typing import Callable, Tuple

import jax

from repro import obs
from repro.obs.history import HISTORY_FILE, append_history

RESULTS_DIR = Path(__file__).resolve().parent.parent / "results" / "bench"

#: default RNG seed shared by every suite's synthetic inputs — stamped
#: into the bench JSON so a result is reproducible from its artifact.
BENCH_SEED = 0


def run_meta(seed: int = BENCH_SEED) -> dict:
    """Provenance stamped into every bench JSON: the exact code (git
    commit + dirty flag), runtime (jax version, backend, device count),
    and RNG seed a run used."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=Path(__file__).parent,
            capture_output=True, text=True, timeout=10).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=Path(__file__).parent,
            capture_output=True, text=True, timeout=10).stdout.strip())
    except Exception:                                     # noqa: BLE001
        commit, dirty = "unknown", False
    return {
        "git_commit": commit or "unknown",
        "git_dirty": dirty,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.local_device_count(),
        "host": platform.node() or "unknown",
        "seed": int(seed),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def time_fn_split(fn: Callable, *args, warmup: int = 1,
                  iters: int = 3) -> Tuple[float, float]:
    """(steady_s, compile_s): first call (compile + run) timed apart from
    the steady-state median — ``repro.obs.time_compiled`` under the hood.
    ``warmup`` > 1 adds extra untimed calls between the two phases."""
    _, steady_s, compile_s = obs.time_compiled(fn, *args, iters=iters)
    for _ in range(max(0, warmup - 1)):
        jax.block_until_ready(fn(*args))
    return steady_s, compile_s


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of ``fn(*args)`` after warmup (handles jit)."""
    return time_fn_split(fn, *args, warmup=warmup, iters=iters)[0]


def record(rows: list, name: str, seconds: float, **derived) -> dict:
    row = {"name": name, "us_per_call": round(seconds * 1e6, 1), **derived}
    rows.append(row)
    flat = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{row['us_per_call']}us,{flat}", flush=True)
    return row


def suite_of(fname: str) -> Tuple[str, bool]:
    """(suite, fast) derived from a bench artifact name —
    ``"robust.fast.json"`` → ``("robust", True)``."""
    stem = fname
    fast = stem.endswith(".fast.json")
    for suffix in (".fast.json", ".json"):
        if stem.endswith(suffix):
            stem = stem[: -len(suffix)]
            break
    return stem, fast


def save(rows: list, fname: str, seed: int = BENCH_SEED,
         extra_meta: dict | None = None) -> Path:
    """Persist ``{"meta": provenance, "rows": rows}`` under results/bench/,
    creating the directory tree on first run. The meta block (git commit,
    jax version, RNG seed, …) makes every artifact self-describing —
    ``extra_meta`` extends it (e.g. ``{"fast": True}``); ``suite`` and
    ``fast`` are stamped uniformly from ``fname``. numpy scalars in
    derived fields serialize as plain floats.

    Every save also appends one record per row to the per-commit
    trajectory ``results/bench/history.jsonl`` (append-only; the JSON
    artifact is the latest snapshot, the history is what
    ``repro.launch.regress`` gates on).
    """
    path = RESULTS_DIR / fname
    path.parent.mkdir(parents=True, exist_ok=True)
    suite, fast = suite_of(fname)
    meta = run_meta(seed)
    meta["suite"] = suite
    meta["fast"] = fast
    if extra_meta:
        meta.update(extra_meta)
    path.write_text(json.dumps({"meta": meta, "rows": rows},
                               indent=1, default=float))
    try:
        append_history(RESULTS_DIR / HISTORY_FILE, suite, rows, meta)
    except OSError as e:
        print(f"WARNING: could not append bench history: {e}", flush=True)
    return path
