"""Summarize results/dryrun/*.json into the §Dry-run markdown table."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def main():
    rows = []
    n_ok = n_skip = n_fail = 0
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("skipped"):
            n_skip += 1
            continue
        if not r.get("ok"):
            n_fail += 1
            rows.append((r["arch"], r["shape"], r["mesh"], None, None, "FAIL"))
            continue
        n_ok += 1
        peak = r["memory"]["peak_bytes"] / 1e9
        coll = sum(r["collective_bytes_per_device"].values()) / 1e9
        rows.append((r["arch"], r["shape"], r["mesh"], peak, coll, "ok"))
    print(f"cells ok={n_ok} skip={n_skip} fail={n_fail}\n")
    print(f"| arch | shape | mesh | peak HBM (GB) | coll (GB/step) |")
    print("|---|---|---|---|---|")
    for arch, shape, mesh, peak, coll, st in rows:
        if st == "FAIL":
            print(f"| {arch} | {shape} | {mesh} | FAIL | |")
        else:
            print(f"| {arch} | {shape} | {mesh} | {peak:.1f} | {coll:.2f} |")


if __name__ == "__main__":
    main()
