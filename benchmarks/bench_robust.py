"""Fault-tolerance overhead: snapshot restore with/without checksum
verification (the ≤10% clean-restore budget), structural self-check and
repair cost, and degraded-mode query overhead vs full availability.

Verification design under test: the clean restore path pays ONLY the
per-leaf crc32 pass (memory-bandwidth); the structural recomputation in
``robust.verify`` and the rebuilds in ``robust.repair`` are incident
paths, priced here so an operator knows what a detection costs.
"""
from __future__ import annotations

import shutil
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analytics import build_sharded_analytics, load_analytics, \
    save_analytics
from repro.data import make_corpus
from repro.robust import (corrupt_snapshot_leaf, repair_analytics,
                          verify_analytics)

from .common import record, save, time_fn


def _median_restore_s(directory, iters: int = 3, **kwargs) -> float:
    ts = []
    sw = obs.Stopwatch()
    for _ in range(iters):
        sw.lap()
        eng = load_analytics(directory, **kwargs)
        jax.block_until_ready(jax.tree.leaves(eng.shards)[0])
        ts.append(sw.lap())
    ts.sort()
    return ts[len(ts) // 2]


def _bench_ingest(rows: list, toks: np.ndarray, vocab: int, n: int) -> None:
    """Crash-safe ingest: commit throughput, journal replay cost vs
    journal length, and the epoch-fence hot-swap pause."""
    from repro.ingest import GenerationServer, analytics_ingester

    sw = obs.Stopwatch()
    for shard_bits, tag in ((14, "short"), (11, "long")):
        scratch = Path(tempfile.mkdtemp(prefix=f"bench_ingest_{tag}_"))
        try:
            ing = analytics_ingester(scratch, vocab, shard_bits=shard_bits,
                                     fsync=False)
            ing.recover()
            sw.lap()
            ing.append_tokens(toks)
            ing.flush()
            t_ingest = sw.lap()
            shards = len(ing.serve_entries())
            record(rows, f"ingest_commit_{tag}_journal_n{n}", t_ingest,
                   shards=shards, journal_records=2 * shards,
                   shards_per_s=round(shards / max(t_ingest, 1e-9), 1),
                   tokens_per_s=round(n / max(t_ingest, 1e-9), 1))

            # replay cost grows with journal length, not corpus size
            t_recover = time_fn(
                lambda: analytics_ingester(
                    scratch, vocab, shard_bits=shard_bits).recover(),
                iters=3)
            record(rows, f"ingest_recover_{tag}_journal_n{n}", t_recover,
                   journal_records=2 * shards,
                   records_per_s=round(2 * shards / max(t_recover, 1e-9), 1))

            if tag == "short":
                # hot-swap pause: fenced swap with no reader in flight is
                # the protocol floor (lock + gauge + drain check)
                srv = GenerationServer(ing.engine())
                pauses = []
                for _ in range(5):
                    sw.lap()
                    srv.swap_generation(srv.engine, wait_drain=True)
                    pauses.append(sw.lap())
                record(rows, f"ingest_hot_swap_pause_n{n}",
                       sorted(pauses)[len(pauses) // 2], swaps=len(pauses))
        finally:
            shutil.rmtree(scratch, ignore_errors=True)


def run(n: int = 1 << 18, out: list | None = None) -> list:
    rows = out if out is not None else []
    vocab = 4096
    toks = np.asarray(make_corpus(n, vocab, seed=0), np.int64)
    eng = build_sharded_analytics(toks, vocab, shard_bits=14)
    jax.block_until_ready(jax.tree.leaves(eng.shards)[0])

    scratch = Path(tempfile.mkdtemp(prefix="bench_robust_"))
    try:
        snap = scratch / "snapshot"
        sw = obs.Stopwatch()
        save_analytics(eng, snap, extra_meta={"corpus_seed": 0})
        t_save = sw.lap()
        record(rows, f"snapshot_save_n{n}", t_save,
               mb=round(sum(leaf.size * leaf.dtype.itemsize for leaf in
                            jax.tree.leaves(eng.shards)) / 2**20, 1))

        # --- clean restore: unverified vs checksum-verified --------------
        t_plain = _median_restore_s(snap, verify=False)
        t_verified = _median_restore_s(snap, verify=True)
        overhead_pct = 100.0 * (t_verified - t_plain) / t_plain
        record(rows, f"restore_unverified_n{n}", t_plain)
        record(rows, f"restore_verified_n{n}", t_verified,
               verify_overhead_pct=round(overhead_pct, 1),
               within_10pct_budget=bool(overhead_pct <= 10.0))

        # --- incident paths: structural verify, checksum repair ----------
        sw.lap()
        report = verify_analytics(eng)
        t_structural = sw.lap()
        record(rows, f"structural_verify_n{n}", t_structural,
               ok=report.ok, violations=len(report.violations))

        sw.lap()
        healed = repair_analytics(eng)
        jax.block_until_ready(jax.tree.leaves(healed.shards)[0])
        t_repair = sw.lap()
        record(rows, f"repair_all_shards_n{n}", t_repair,
               num_shards=eng.num_shards)

        # --- detect + repair round trip on a corrupted snapshot ----------
        corrupt_snapshot_leaf(snap, seed=1, leaf_match="superblock")
        sw.lap()
        healed = load_analytics(snap)
        jax.block_until_ready(jax.tree.leaves(healed.shards)[0])
        t_heal = sw.lap()
        record(rows, f"restore_detect_repair_n{n}", t_heal,
               x_clean_restore=round(t_heal / max(t_verified, 1e-9), 1))
    finally:
        shutil.rmtree(scratch, ignore_errors=True)

    # --- degraded-mode query overhead (mask vs no mask) -------------------
    rng = np.random.default_rng(2)
    B = 1024
    lo = jnp.asarray(rng.integers(0, max(1, n - 1), B).astype(np.int32))
    hi = jnp.minimum(lo + jnp.asarray(
        rng.integers(1, max(2, n // 4), B).astype(np.int32)), n)
    k = jnp.asarray(rng.integers(0, 8, B).astype(np.int32))
    q = jax.jit(lambda e, a, b, c: e.range_quantile(a, b, c))
    t_full = time_fn(q, eng, lo, hi, k)
    record(rows, f"quantile_full_b{B}_n{n}", t_full,
           queries_per_s=round(B / t_full, 1))
    deg = eng.drop_shards(np.asarray([0], np.int32))
    t_deg = time_fn(q, deg, lo, hi, k)
    record(rows, f"quantile_degraded_b{B}_n{n}", t_deg,
           queries_per_s=round(B / t_deg, 1),
           overhead_pct=round(100.0 * (t_deg - t_full) / t_full, 1))
    bounds = jax.jit(lambda e, a, b: e.range_count_bounds(a, b, 0, 64))
    t_b = time_fn(bounds, deg, lo, hi)
    record(rows, f"count_bounds_degraded_b{B}_n{n}", t_b,
           queries_per_s=round(B / t_b, 1))

    # --- crash-safe streaming ingest ---------------------------------------
    _bench_ingest(rows, toks, vocab, n)

    if out is None:
        save(rows, "robust.json")
    return rows


if __name__ == "__main__":
    run()
