"""Range-analytics engine: build throughput + per-op batched query
throughput (quantile / count / top-k / distinct), single-shard fused
Pallas quantile kernel vs the XLA descent, sharded fan-out scaling —
plus the telemetry acceptance rows: per-op rows carry ``compile_s``
separately from steady-state, and the ``obs_*`` rows prove the metrics
layer costs nothing when disabled (and near-nothing when enabled) on the
serving path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analytics import build_sharded_analytics, range_quantile
from repro.data import make_corpus
from repro.kernels.ops import wm_quantile_batch

from .common import record, save, time_fn, time_fn_split


def _queries(n: int, num: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, max(1, n - 1), num).astype(np.int32)
    hi = np.minimum(lo + rng.integers(1, max(2, n // 4), num), n)
    k = rng.integers(0, np.maximum(hi - lo, 1)).astype(np.int32)
    return jnp.asarray(lo), jnp.asarray(hi.astype(np.int32)), jnp.asarray(k)


def _obs_overhead_rows(rows: list, eng, n: int) -> None:
    """Telemetry overhead acceptance: the instrumented serving path timed
    with metrics disabled vs enabled (Python-side counters fire at trace
    time, so steady-state jitted calls must be unaffected — within
    noise), plus the raw per-call cost of the instruments themselves."""
    lo, hi, k = _queries(n, 256, seed=3)
    q = jax.jit(lambda e, a, b, c: e.range_quantile(a, b, c))

    with obs.disabled():
        t_off = time_fn(q, eng, lo, hi, k, iters=5)
    record(rows, f"analytics_quantile_b256_n{n}_obs_disabled", t_off,
           queries_per_s=round(256 / t_off, 1))
    t_on = time_fn(q, eng, lo, hi, k, iters=5)
    record(rows, f"analytics_quantile_b256_n{n}_obs_enabled", t_on,
           queries_per_s=round(256 / t_on, 1),
           overhead_pct=round((t_on - t_off) / t_off * 100, 2))

    # raw instrument cost, per call (counter inc / histogram observe),
    # disabled mode must be a dict-lookup + early-return no-op
    iters = 100_000
    c = obs.counter("bench.obs_overhead")
    h = obs.histogram("bench.obs_overhead_h")

    def _loop(op):
        sw = obs.Stopwatch()
        for _ in range(iters):
            op()
        return sw.lap() / iters

    record(rows, "obs_counter_inc_enabled", _loop(c.inc))
    record(rows, "obs_histogram_observe_enabled",
           _loop(lambda: h.observe(1e-3)))
    with obs.disabled():
        record(rows, "obs_counter_inc_disabled", _loop(c.inc))
        record(rows, "obs_histogram_observe_disabled",
               _loop(lambda: h.observe(1e-3)))


def run(n: int = 1 << 18, out: list | None = None) -> list:
    rows = out if out is not None else []
    vocab = 4096
    toks = np.asarray(make_corpus(n, vocab, seed=0), np.int64)

    # --- build ------------------------------------------------------------
    sw = obs.Stopwatch()
    eng = build_sharded_analytics(toks, vocab, shard_bits=14)
    jax.block_until_ready(jax.tree.leaves(eng.shards)[0])
    t_build = sw.lap()
    record(rows, f"analytics_build_n{n}_sb14", t_build,
           ktok_per_s=round(n / t_build / 1e3, 1),
           bits_per_token=round(eng.bits_per_token(), 1),
           num_shards=eng.num_shards)

    # --- per-op batched throughput (steady vs compile) --------------------
    for batch in (256, 1024):
        lo, hi, k = _queries(n, batch)
        sym_lo = jnp.asarray(np.arange(batch, dtype=np.int32) % vocab)
        sym_hi = jnp.minimum(sym_lo + 64, vocab)

        q = jax.jit(lambda e, a, b, c: e.range_quantile(a, b, c))
        t, t_c = time_fn_split(q, eng, lo, hi, k)
        record(rows, f"analytics_quantile_b{batch}_n{n}", t,
               queries_per_s=round(batch / t, 1), compile_s=round(t_c, 2))

        c = jax.jit(lambda e, a, b, s0, s1: e.range_count(a, b, s0, s1))
        t, t_c = time_fn_split(c, eng, lo, hi, sym_lo, sym_hi)
        record(rows, f"analytics_count_b{batch}_n{n}", t,
               queries_per_s=round(batch / t, 1), compile_s=round(t_c, 2))

    lo, hi, k = _queries(n, 256)
    tk = jax.jit(lambda e, a, b: e.range_topk(a, b, 8))
    t, t_c = time_fn_split(tk, eng, lo, hi)
    record(rows, f"analytics_topk8_b256_n{n}", t,
           queries_per_s=round(256 / t, 1), compile_s=round(t_c, 2))

    d = jax.jit(lambda e, a, b: e.range_distinct(a, b))
    t, t_c = time_fn_split(d, eng, lo, hi)
    record(rows, f"analytics_distinct_b256_n{n}", t,
           queries_per_s=round(256 / t, 1), compile_s=round(t_c, 2))

    # --- fused Pallas quantile kernel vs XLA descent (one shard) ----------
    wm = eng.shard(0)
    m = wm.n
    lo1, hi1, k1 = _queries(m, 1024, seed=2)
    f_fused = jax.jit(lambda w, a, b, c: wm_quantile_batch(w, a, b, c))
    t, t_c = time_fn_split(f_fused, wm, lo1, hi1, k1)
    record(rows, f"quantile_kernel_fused_b1024_m{m}", t,
           queries_per_s=round(1024 / t, 1), compile_s=round(t_c, 2))
    f_xla = jax.jit(lambda w, a, b, c: range_quantile(w, a, b, c))
    t, t_c = time_fn_split(f_xla, wm, lo1, hi1, k1)
    record(rows, f"quantile_xla_b1024_m{m}", t,
           queries_per_s=round(1024 / t, 1), compile_s=round(t_c, 2))

    # --- telemetry overhead acceptance ------------------------------------
    _obs_overhead_rows(rows, eng, n)

    if out is None:
        save(rows, "analytics.json")
    return rows


if __name__ == "__main__":
    run()
