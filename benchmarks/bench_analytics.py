"""Range-analytics engine: build throughput + per-op batched query
throughput (quantile / count / top-k / distinct), single-shard fused
Pallas quantile kernel vs the XLA descent, sharded fan-out scaling."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics import build_sharded_analytics, range_quantile
from repro.data import make_corpus
from repro.kernels.ops import wm_quantile_batch

from .common import record, save, time_fn


def _queries(n: int, num: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, max(1, n - 1), num).astype(np.int32)
    hi = np.minimum(lo + rng.integers(1, max(2, n // 4), num), n)
    k = rng.integers(0, np.maximum(hi - lo, 1)).astype(np.int32)
    return jnp.asarray(lo), jnp.asarray(hi.astype(np.int32)), jnp.asarray(k)


def run(n: int = 1 << 18, out: list | None = None) -> list:
    rows = out if out is not None else []
    vocab = 4096
    toks = np.asarray(make_corpus(n, vocab, seed=0), np.int64)

    # --- build ------------------------------------------------------------
    t0 = time.perf_counter()
    eng = build_sharded_analytics(toks, vocab, shard_bits=14)
    jax.block_until_ready(jax.tree.leaves(eng.shards)[0])
    t_build = time.perf_counter() - t0
    record(rows, f"analytics_build_n{n}_sb14", t_build,
           ktok_per_s=round(n / t_build / 1e3, 1),
           bits_per_token=round(eng.bits_per_token(), 1),
           num_shards=eng.num_shards)

    # --- per-op batched throughput ---------------------------------------
    for batch in (256, 1024):
        lo, hi, k = _queries(n, batch)
        sym_lo = jnp.asarray(np.arange(batch, dtype=np.int32) % vocab)
        sym_hi = jnp.minimum(sym_lo + 64, vocab)

        q = jax.jit(lambda e, a, b, c: e.range_quantile(a, b, c))
        t = time_fn(q, eng, lo, hi, k)
        record(rows, f"analytics_quantile_b{batch}_n{n}", t,
               queries_per_s=round(batch / t, 1))

        c = jax.jit(lambda e, a, b, s0, s1: e.range_count(a, b, s0, s1))
        t = time_fn(c, eng, lo, hi, sym_lo, sym_hi)
        record(rows, f"analytics_count_b{batch}_n{n}", t,
               queries_per_s=round(batch / t, 1))

    lo, hi, k = _queries(n, 256)
    tk = jax.jit(lambda e, a, b: e.range_topk(a, b, 8))
    t = time_fn(tk, eng, lo, hi)
    record(rows, f"analytics_topk8_b256_n{n}", t,
           queries_per_s=round(256 / t, 1))

    d = jax.jit(lambda e, a, b: e.range_distinct(a, b))
    t = time_fn(d, eng, lo, hi)
    record(rows, f"analytics_distinct_b256_n{n}", t,
           queries_per_s=round(256 / t, 1))

    # --- fused Pallas quantile kernel vs XLA descent (one shard) ----------
    wm = eng.shard(0)
    m = wm.n
    lo1, hi1, k1 = _queries(m, 1024, seed=2)
    f_fused = jax.jit(lambda w, a, b, c: wm_quantile_batch(w, a, b, c))
    t = time_fn(f_fused, wm, lo1, hi1, k1)
    record(rows, f"quantile_kernel_fused_b1024_m{m}", t,
           queries_per_s=round(1024 / t, 1))
    f_xla = jax.jit(lambda w, a, b, c: range_quantile(w, a, b, c))
    t = time_fn(f_xla, wm, lo1, hi1, k1)
    record(rows, f"quantile_xla_b1024_m{m}", t,
           queries_per_s=round(1024 / t, 1))

    if out is None:
        save(rows, "analytics.json")
    return rows


if __name__ == "__main__":
    run()
