"""Full-text index: build throughput (SA + BWT + WM) and query throughput
(batched backward-search count, sampled-SA locate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_corpus
from repro.index import (build_fm_index, build_sharded_index,
                         sample_patterns, suffix_array)

from repro import obs

from .common import record, save, time_fn, time_fn_split


def _patterns(toks: np.ndarray, num: int, max_len: int, pad: int):
    pats, lens = sample_patterns(toks, num, max_len, pad,
                                 miss_every=None, min_len=2)
    return jnp.asarray(pats), jnp.asarray(lens)


def run(n: int = 1 << 18, out: list | None = None) -> list:
    rows = out if out is not None else []
    vocab = 4096
    toks = np.asarray(make_corpus(n, vocab, seed=0), np.int64)

    # --- suffix array construction alone (single shard of 2^14) ----------
    shard = jnp.asarray(toks[:1 << 14], jnp.int32)
    for backend in ("counting", "xla"):
        t = time_fn(lambda: jax.block_until_ready(
            suffix_array(shard, backend=backend, max_rounds=14)))
        record(rows, f"suffix_array_n{1 << 14}_{backend}", t,
               ktok_per_s=round((1 << 14) / t / 1e3, 1))

    # --- full sharded build ----------------------------------------------
    shard_bits = 13
    sw = obs.Stopwatch()
    idx = build_sharded_index(toks, vocab, shard_bits=shard_bits)
    jax.block_until_ready(jax.tree.leaves(idx.shards)[0])
    t_build = sw.lap()
    record(rows, f"index_build_n{n}_sb{shard_bits}", t_build,
           ktok_per_s=round(n / t_build / 1e3, 1),
           bits_per_token=round(idx.bits_per_token(), 1),
           num_shards=idx.num_shards)

    # --- batched count (the 2·B·L·S rank workload) ------------------------
    for batch in (64, 512):
        pats, lens = _patterns(toks, batch, 8, pad=vocab)
        f = jax.jit(lambda ix, p, l: ix.count(p, l))
        t, t_c = time_fn_split(f, idx, pats, lens)
        record(rows, f"index_count_b{batch}_n{n}", t,
               patterns_per_s=round(batch / t, 1),
               rank_calls=2 * batch * 8 * idx.num_shards,
               compile_s=round(t_c, 2))

    # --- locate ------------------------------------------------------------
    pats, lens = _patterns(toks, 64, 8, pad=vocab)
    g = jax.jit(lambda ix, p, l: ix.locate(p, l, 4))
    t, t_c = time_fn_split(g, idx, pats, lens)
    record(rows, f"index_locate_b64_h4_n{n}", t,
           patterns_per_s=round(64 / t, 1), compile_s=round(t_c, 2))

    # --- single-shard FM-index count (no shard fan-out, larger text) ------
    one = jnp.asarray(toks[:1 << 15], jnp.int32)
    fm = build_fm_index(one, vocab)
    pats, lens = _patterns(toks[:1 << 15], 256, 8, pad=vocab)
    h = jax.jit(lambda f_, p, l: f_.count(p, l))
    t, t_c = time_fn_split(h, fm, pats, lens)
    record(rows, f"fm_count_single_n{1 << 15}_b256", t,
           patterns_per_s=round(256 / t, 1), compile_s=round(t_c, 2))

    if out is None:
        save(rows, "index.json")
    return rows


if __name__ == "__main__":
    run()
