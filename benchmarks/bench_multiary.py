"""Paper Table 1, row 6: multiary wavelet trees (Theorem 4.4).

Degree d = 2^width cuts the number of levels by ⌈logσ⌉/log d; each level
stores a generalized rank/select structure (Section 5.2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.multiary import build_multiary_wavelet_tree
from repro.core.wavelet_matrix import num_levels

from .common import record, save, time_fn


def run(n: int = 1 << 19, out: list | None = None) -> list:
    rows = out if out is not None else []
    sigma = 4096
    seq = jnp.asarray(np.random.default_rng(0)
                      .integers(0, sigma, n).astype(np.uint32))
    for width in (1, 2, 4):
        f = jax.jit(functools.partial(build_multiary_wavelet_tree,
                                      sigma=sigma, width=width))
        t = time_fn(f, seq, iters=3)
        record(rows, f"multiary_d{1 << width}_n{n}_s{sigma}", t,
               melem_per_s=round(n / t / 1e6, 1),
               levels=-(-num_levels(sigma) // width))
    if out is None:
        save(rows, "multiary.json")
    return rows


if __name__ == "__main__":
    run()
