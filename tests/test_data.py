"""Data substrate: compressed corpus store + deterministic batch pipeline."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (TokenBatcher, batch_offsets, build_compressed_corpus,
                        make_corpus, token_histogram)


@pytest.fixture(scope="module")
def corpus_pair():
    toks = make_corpus(120_000, vocab_size=2003, seed=3)
    corpus = build_compressed_corpus(toks, sigma=2003, shard_bits=14)
    return toks, corpus


def test_access_random_positions(corpus_pair):
    toks, corpus = corpus_pair
    rng = np.random.default_rng(0)
    pos = rng.integers(0, corpus.n, 400)
    got = np.asarray(corpus.access(jnp.asarray(pos)))
    assert np.array_equal(got, toks[pos].astype(got.dtype))


def test_decode_slice_across_shards(corpus_pair):
    toks, corpus = corpus_pair
    start = corpus.shard_size - 50
    got = np.asarray(corpus.decode_slice(jnp.int32(start), 150))
    assert np.array_equal(got, toks[start:start + 150].astype(got.dtype))


def test_histogram_and_count(corpus_pair):
    toks, corpus = corpus_pair
    hist = np.asarray(token_histogram(corpus))
    assert np.array_equal(hist, np.bincount(toks, minlength=2003)[:2003])
    c = int(np.argmax(hist))
    for upto in (1, 1000, 55555, corpus.n):
        got = int(corpus.count(jnp.int32(c), jnp.int32(upto)))
        assert got == int((toks[:upto] == c).sum())


def test_locate(corpus_pair):
    toks, corpus = corpus_pair
    hist = np.asarray(token_histogram(corpus))
    for c in np.argsort(hist)[-3:]:
        occ = np.flatnonzero(toks == c)
        ks = np.unique(np.random.default_rng(1).integers(0, len(occ), 20))
        got = np.asarray(corpus.locate(jnp.full(len(ks), int(c)),
                                       jnp.asarray(ks)))
        assert np.array_equal(got, occ[ks])


def test_compression_beats_raw(corpus_pair):
    _, corpus = corpus_pair
    # ceil(log2 2003) = 11 bits + directories ≪ 32-bit raw
    assert corpus.bits_per_token() < 20


def test_batch_addressing_deterministic():
    offs1 = batch_offsets(step=7, batch=16, n_tokens=100_000, seq_len=128,
                          seed=5)
    offs2 = batch_offsets(step=7, batch=16, n_tokens=100_000, seq_len=128,
                          seed=5)
    assert np.array_equal(offs1, offs2)
    offs3 = batch_offsets(step=8, batch=16, n_tokens=100_000, seq_len=128,
                          seed=5)
    assert not np.array_equal(offs1, offs3)
    assert offs1.max() < 100_000 - 128 - 1


def test_batcher_compressed_equals_raw(corpus_pair):
    toks, corpus = corpus_pair
    b_raw = TokenBatcher(tokens=toks, batch=4, seq_len=64, seed=9)
    b_wm = TokenBatcher(corpus=corpus, batch=4, seq_len=64, seed=9)
    for step in (0, 3, 1000):
        assert np.array_equal(b_raw.batch_at(step), b_wm.batch_at(step))


def test_prefetch_iterator(corpus_pair):
    toks, _ = corpus_pair
    b = TokenBatcher(tokens=toks, batch=2, seq_len=32, seed=1)
    it = b.iterate(start_step=5, prefetch=2)
    first = next(it)
    assert np.array_equal(first, b.batch_at(5))
    second = next(it)
    assert np.array_equal(second, b.batch_at(6))
