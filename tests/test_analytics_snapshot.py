"""Persisted analytics snapshots: save → load must be bit-exact and the
restored engine must answer queries identically (serving restarts skip
the build)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import (build_sharded_analytics, load_analytics,
                             save_analytics)


def _make_engine(n=3000, sigma=97, shard_bits=10, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, sigma, n).astype(np.int64)
    return toks, build_sharded_analytics(toks, sigma, shard_bits=shard_bits)


def test_snapshot_roundtrip_bit_exact(tmp_path):
    _, eng = _make_engine()
    save_analytics(eng, tmp_path)
    eng2 = load_analytics(tmp_path)
    assert (eng2.n, eng2.sigma, eng2.shard_bits) == (eng.n, eng.sigma,
                                                     eng.shard_bits)
    la, lb = jax.tree.leaves(eng), jax.tree.leaves(eng2)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype and x.shape == y.shape
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_snapshot_restored_engine_serves_identically(tmp_path):
    toks, eng = _make_engine(n=2500, sigma=64, shard_bits=9, seed=3)
    save_analytics(eng, tmp_path)
    eng2 = load_analytics(tmp_path)
    rng = np.random.default_rng(1)
    q = 64
    lo = rng.integers(0, 2501, q).astype(np.int32)
    hi = rng.integers(0, 2501, q).astype(np.int32)
    lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
    k = rng.integers(0, 2500, q).astype(np.int32)
    loj, hij, kj = jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(k)
    for name, a, b in [
        ("quantile", eng.range_quantile(loj, hij, kj),
         eng2.range_quantile(loj, hij, kj)),
        ("distinct", eng.range_distinct(loj, hij),
         eng2.range_distinct(loj, hij)),
        ("count", eng.range_count(loj, hij, 3, 40),
         eng2.range_count(loj, hij, 3, 40)),
    ]:
        assert np.array_equal(np.asarray(a), np.asarray(b)), name
    # spot check against numpy on the raw stream
    got = np.asarray(eng2.range_quantile(loj, hij, kj))
    for i in range(16):
        sl = np.sort(toks[lo[i]:hi[i]])
        want = sl[min(k[i], len(sl) - 1)] if len(sl) else -1
        assert got[i] == want, i


def test_snapshot_rejects_foreign_checkpoint(tmp_path):
    from repro.checkpoint import save_checkpoint
    save_checkpoint(tmp_path, 0, {"w": jnp.zeros((3,))},
                    extra_meta={"kind": "model"})
    with pytest.raises(ValueError):
        load_analytics(tmp_path)


def test_snapshot_missing_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_analytics(tmp_path / "nope")
