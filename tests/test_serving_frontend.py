"""Serving front-end: admission, deadlines, ladder, breakers, hot swap.

Everything timing-dependent runs on a ``FakeClock`` — deadlines, CoDel
sojourn estimates, ladder cooldowns, and chaos-injected shard latency all
advance logical time deterministically, so the tests assert exact shed /
degrade / breaker decisions with zero real sleeping. The one exception is
the concurrent hot-swap test, which (mirroring ``test_ingest``) runs real
threads against the system clock.
"""
import threading

import numpy as np
import pytest

from repro.analytics.engine import build_sharded_analytics
from repro.ingest.serving import GenerationServer
from repro.robust import FakeClock, inject_shard_latency
from repro.serving import (AdmissionQueue, BatchRunner, FrontendConfig,
                           LadderConfig, QueryFrontend, Request, ShedError,
                           Ticket)
from repro.serving.ladder import DegradeLadder

N, SIGMA, SHARD_BITS = 1024, 64, 8


@pytest.fixture(scope="module")
def tokens():
    return np.random.default_rng(7).integers(0, 50, N).astype(np.uint32)


@pytest.fixture(scope="module")
def engine(tokens):
    return build_sharded_analytics(tokens, SIGMA, shard_bits=SHARD_BITS)


@pytest.fixture
def frontend(engine):
    """Factory: (clock, **config overrides) → started-nothing frontend;
    every instance's probe pool is shut down at teardown."""
    made = []

    def make(clock=None, **over):
        over.setdefault("probe_shards", False)
        fe = QueryFrontend(GenerationServer(engine),
                           config=FrontendConfig(**over),
                           clock=clock or FakeClock())
        made.append(fe)
        return fe

    yield make
    for fe in made:
        fe.breakers.close_pool()


def _drain(fe, want):
    served = 0
    for _ in range(1000):
        served += fe.pump()
        if served >= want:
            return served
    raise AssertionError(f"only {served}/{want} served")


# ---------------------------------------------------------------------------
# admission queue: bounds, reject-early, shed-before-dispatch
# ---------------------------------------------------------------------------

def test_queue_bounded_and_explicitly_rejecting(frontend):
    fe = frontend(capacity=4)
    tickets = [fe.submit("count", 0, N, deadline_s=10.0) for _ in range(9)]
    shed = [t for t in tickets if t.shed]
    assert len(shed) == 5 and fe.queue.depth == 4
    with pytest.raises(ShedError) as ei:
        shed[0].result(0)
    assert ei.value.reason == "queue_full"
    # every admitted request still resolves
    _drain(fe, 4)
    for t in tickets:
        assert t.done()


def test_codel_over_budget_shed_at_submit(frontend):
    """A request whose deadline cannot survive the estimated sojourn is
    rejected in-line (reject-early), not left to time out in the queue."""
    fe = frontend(capacity=64)
    fe.queue.observe_service(5.0, 1)            # ~1s/request after EWMA
    assert fe.queue.service_s > 0.5
    backlog = [fe.submit("count", 0, N, deadline_s=60.0) for _ in range(10)]
    t = fe.submit("count", 0, N, deadline_s=0.5)   # 10 × ~1s wait ahead
    assert t.shed
    with pytest.raises(ShedError) as ei:
        t.result(0)
    assert ei.value.reason == "over_budget"
    assert ei.value.est_wait_s > 0.5
    assert not any(b.shed for b in backlog)


def test_expired_requests_shed_before_dispatch(frontend):
    """Dispatch never wastes kernel time on dead requests: expired ones
    are shed with explicit rejections and live ones still serve."""
    clock = FakeClock()
    fe = frontend(clock=clock)
    dead = fe.submit("count", 0, N, deadline_s=0.3)
    clock.advance(0.5)
    live = fe.submit("count", 0, N, deadline_s=10.0)
    assert fe.pump() == 1                       # only the live one ran
    assert dead.shed and fe.queue.shed_counts["expired"] == 1
    with pytest.raises(ShedError) as ei:
        dead.result(0)
    assert ei.value.reason == "expired"
    assert live.result(0).deadline_met
    st = fe.stats()
    assert st["submitted"] == st["served"] + st["total_shed"]


def test_ticket_timeout_and_unknown_op(frontend):
    fe = frontend()
    t = fe.submit("count", 0, N, deadline_s=10.0)
    with pytest.raises(TimeoutError):
        t.result(timeout=0.01)                  # never pumped
    with pytest.raises(ValueError):
        fe.submit("median", 0, N)
    with pytest.raises(ValueError):
        fe.submit("quantile", 0, N)             # k required
    fe.pump()
    assert t.result(0).mode == "exact"


# ---------------------------------------------------------------------------
# deadline propagation through batching
# ---------------------------------------------------------------------------

def test_deadline_miss_tagged_not_dropped(frontend, engine):
    """A request admitted in time but finished late (chaos latency on the
    batch path) resolves with ``deadline_met=False`` and bumps the miss
    counter — accepted work is answered, and honestly timestamped."""
    clock = FakeClock()
    fe = frontend(clock=clock, probe_shards=True)
    with inject_shard_latency(0, 2.0):          # probe advances the clock
        t = fe.submit("count", 0, N, deadline_s=1.0)
        fe.pump()
    a = t.result(0)
    assert a.deadline_met is False
    assert a.latency_s >= 2.0
    assert fe.stats()["deadline_misses"] == 1


def test_deadline_met_within_budget(frontend):
    clock = FakeClock()
    fe = frontend(clock=clock)
    t = fe.submit("count", 0, N, deadline_s=1.0)
    clock.advance(0.25)                         # queue wait, within budget
    fe.pump()
    a = t.result(0)
    assert a.deadline_met and a.latency_s == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

def test_ladder_monotone_within_burst():
    """Pressure above ``down_pressure`` ⇒ the level can only hold or
    climb; a downgrade needs ``cooldown_s`` of sustained calm."""
    clock = FakeClock()
    lad = DegradeLadder(LadderConfig(up_pressure=0.75, down_pressure=0.25,
                                     cooldown_s=1.0), clock=clock)
    levels = []
    for p in [0.8, 0.5, 0.9, 0.4, 0.8, 0.3]:    # burst: never calm
        levels.append(lad.observe(p))
        clock.advance(0.2)
    assert levels == sorted(levels) and levels[-1] == 2
    # calm but inside cooldown: still holds
    assert lad.observe(0.0) == 2
    clock.advance(1.5)
    assert lad.observe(0.0) == 1                # one rung per window
    assert lad.observe(0.0) == 1
    clock.advance(1.5)
    assert lad.observe(0.0) == 0


def test_burst_degrades_answers_and_tags_them(frontend, tokens):
    """Overload flips quantile answers to tagged brackets that still
    contain the exact numpy-oracle answer."""
    fe = frontend(capacity=16, ladder=LadderConfig(up_pressure=0.5))
    tickets = [fe.submit("quantile", 0, N, k=i * 37, deadline_s=50.0)
               for i in range(14)]
    _drain(fe, 14)
    srt = np.sort(tokens)
    degraded = 0
    for i, t in enumerate(tickets):
        a = t.result(0)
        oracle = int(srt[i * 37])
        if a.mode == "exact":
            assert a.value == oracle
        else:
            assert a.mode == "quantile_bracket" and a.degraded
            lo, hi = a.value
            assert lo <= oracle < hi
            degraded += 1
    assert degraded > 0


def test_op_variants_bracket_numpy_oracle(frontend, tokens):
    """Every ladder rung of every op is honest against numpy, including
    the deepest one."""
    import jax.numpy as jnp
    fe = frontend()
    eng = fe.server.engine
    lo, hi = 37, 1001
    q = jnp.asarray(np.array([[lo, lo, lo], [hi, hi, hi],
                              [5, 200, 0], [30, 700, 0]], np.int32))
    window = tokens[lo:hi]
    for level in (1, 2):
        mode, fn = fe._op_fn("count", level)
        lo_c, up_c, cov = fn(eng, q)
        exact = int(np.sum((window >= 5) & (window < 30)))
        assert mode == "count_bounds"
        assert int(lo_c[0]) <= exact <= int(up_c[0])
        assert float(cov[0]) == 1.0

        mode, fn = fe._op_fn("quantile", level)
        a, b, _ = fn(eng, q)
        assert mode == "quantile_bracket"
        oracle = int(np.sort(window)[200])
        assert int(a[1]) <= oracle < int(b[1])

        mode, fn = fe._op_fn("topk", level)
        syms, counts, _ = fn(eng, q)
        assert mode == "topk_greedy"
        hist = np.bincount(window, minlength=SIGMA)
        for s, c in zip(np.asarray(syms[2]), np.asarray(counts[2])):
            if s >= 0:                      # greedy counts are true counts
                assert hist[int(s)] == int(c)


# ---------------------------------------------------------------------------
# batching: buckets, padding neutrality, jit reuse
# ---------------------------------------------------------------------------

def test_bucket_padding_is_neutral_and_cache_reused(frontend, tokens):
    fe = frontend(buckets=(4, 16))
    assert fe.runner.bucket_for(3) == 4 and fe.runner.bucket_for(9) == 16
    t3 = [fe.submit("count", i, N - i, deadline_s=10.0) for i in range(3)]
    fe.pump()
    assert fe.runner.compiled == 1              # bucket 4
    t2 = [fe.submit("count", i, N - i, deadline_s=10.0) for i in range(2)]
    fe.pump()
    assert fe.runner.compiled == 1              # same bucket, cache hit
    for i, t in enumerate(t3 + t2):
        i = i % 3 if i < 3 else i - 3
        exact = int(np.sum(tokens[i:N - i] < SIGMA))
        assert t.result(0).value == exact


def test_mixed_ops_batch_homogeneously(frontend):
    """One pump serves one op; other ops stay queued in order."""
    fe = frontend()
    tc = fe.submit("count", 0, N, deadline_s=10.0)
    tq = fe.submit("quantile", 0, N, k=5, deadline_s=10.0)
    tc2 = fe.submit("count", 0, N, deadline_s=10.0)
    assert fe.pump() == 2                       # both counts
    assert tc.done() and tc2.done() and not tq.done()
    assert fe.pump() == 1
    assert tq.done()


# ---------------------------------------------------------------------------
# hedged shard timeout vs availability-mask oracle
# ---------------------------------------------------------------------------

def test_slow_shard_opens_breaker_matches_drop_shards_oracle(frontend,
                                                             engine):
    """A chaos-stalled shard times out its hedged probe, the breaker
    opens, and from then on every answer equals the ``drop_shards``
    availability-mask oracle (PR 6 semantics) with coverage < 1."""
    clock = FakeClock()
    fe = frontend(clock=clock, probe_shards=True)
    thresh = fe.config.breaker.fail_threshold
    with inject_shard_latency(2, 9.0):
        for _ in range(thresh):
            t = fe.submit("count", 0, N, deadline_s=1e6)
            fe.pump()
    assert fe.stats()["open_breakers"] == [2]
    t = fe.submit("count", 0, N, deadline_s=1e6)
    fe.pump()
    a = t.result(0)
    oracle_eng = engine.drop_shards([2])
    assert a.value == int(oracle_eng.range_count(0, N, 0, SIGMA))
    assert a.degraded and a.coverage == pytest.approx(0.75)
    # recovery: past the reset window the half-open probe closes it
    clock.advance(fe.config.breaker.reset_after_s + 1)
    fe.submit("count", 0, N, deadline_s=1e6)
    fe.pump()
    assert fe.stats()["open_breakers"] == []


# ---------------------------------------------------------------------------
# epoch-pinned serving across hot swaps (real threads, system clock)
# ---------------------------------------------------------------------------

def test_concurrent_hot_swap_answers_pin_one_generation(tokens):
    """Mirrors ``test_ingest.test_hot_swap_under_concurrent_queries``:
    with the worker thread pumping and generations swapping live, every
    answer's value matches the oracle of the generation it is tagged
    with — never a mixed corpus."""
    shard = 1 << SHARD_BITS
    engines = {g: build_sharded_analytics(tokens[:(g + 2) * shard], SIGMA,
                                          shard_bits=SHARD_BITS)
               for g in range(3)}
    expected = {g: (g + 2) * shard for g in range(3)}
    srv = GenerationServer(engines[0])
    fe = QueryFrontend(srv, config=FrontendConfig(probe_shards=False,
                                                  capacity=2048))
    fe.start()
    tickets = []
    try:
        stop = threading.Event()

        def swapper():
            for g in (1, 2):
                srv.swap_generation(engines[g], wait_drain=True,
                                    timeout_s=30)
            stop.set()

        sw = threading.Thread(target=swapper)
        sw.start()
        while not stop.is_set() or len(tickets) < 50:
            tickets.append(fe.submit("count", 0, 2 ** 30,
                                     deadline_s=30.0))
            if len(tickets) > 3000:
                break
        sw.join()
    finally:
        fe.stop(drain=True)
    gens_seen = set()
    for t in tickets:
        try:
            a = t.result(5)
        except ShedError:
            continue
        gens_seen.add(a.generation)
        assert a.value == expected[a.generation], (
            a.generation, a.value, expected[a.generation])
    assert 2 in gens_seen                   # the final generation served
    assert srv.generation == 2


def test_stats_accounting_identity(frontend):
    clock = FakeClock()
    fe = frontend(clock=clock, capacity=8)
    for i in range(20):
        fe.submit("count", 0, N, deadline_s=(0.1 if i % 3 else 5.0))
        if i % 5 == 0:
            clock.advance(0.2)
            fe.pump()
    while fe.pump():
        pass
    st = fe.stats()
    assert st["submitted"] == 20
    assert st["submitted"] == st["served"] + st["total_shed"] + st["queued"]
