"""Checkpoint subsystem: atomic save/restore, pruning, dtype round-trips."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (checkpoint_steps, latest_step,
                              restore_checkpoint, save_checkpoint)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4), jnp.float32),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": {"m": jnp.zeros((8, 4)), "step": jnp.int32(17)},
    }


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 100, state)
    restored, meta = restore_checkpoint(tmp_path, state)
    assert meta["step"] == 100
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_bfloat16_roundtrip_exact(tmp_path):
    x = {"p": (jnp.arange(100, dtype=jnp.float32) / 7).astype(jnp.bfloat16)}
    save_checkpoint(tmp_path, 1, x)
    y, _ = restore_checkpoint(tmp_path, x)
    assert y["p"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(x["p"], np.float32),
                          np.asarray(y["p"], np.float32))


def test_latest_and_prune(tmp_path):
    s = _state()
    for step in (10, 20, 30, 40):
        save_checkpoint(tmp_path, step, s, keep=2)
    assert latest_step(tmp_path) == 40
    assert checkpoint_steps(tmp_path) == [30, 40]


def test_atomicity_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 5, _state())
    leftovers = [p for p in Path(tmp_path).iterdir()
                 if p.name.startswith(".tmp")]
    assert not leftovers


def test_partial_checkpoint_ignored(tmp_path):
    """A directory without meta.json (interrupted write) is not listed."""
    save_checkpoint(tmp_path, 5, _state())
    bad = Path(tmp_path) / "step_00000009"
    bad.mkdir()
    assert latest_step(tmp_path) == 5


def test_restore_into_different_sharding(tmp_path):
    """Elastic restore: place leaves with explicit shardings on a mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = _state()
    save_checkpoint(tmp_path, 7, state)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, _ = restore_checkpoint(tmp_path, state, shardings=shardings)
    leaf = restored["params"]["w"]
    assert isinstance(leaf.sharding, NamedSharding)


def test_shape_mismatch_raises(tmp_path):
    state = _state()
    save_checkpoint(tmp_path, 3, state)
    bad_target = {**state,
                  "params": {"w": jnp.zeros((4, 4)), "b": state["params"]["b"]}}
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(tmp_path, bad_target)


def test_meta_contents(tmp_path):
    d = save_checkpoint(tmp_path, 12, _state(), extra_meta={"arch": "x"})
    meta = json.loads((d / "meta.json").read_text())
    assert meta["arch"] == "x" and meta["step"] == 12
    assert meta["num_arrays"] == 4
