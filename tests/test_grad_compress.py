"""Error-feedback bitplane gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim.grad_compress import (compression_ratio,
                                       dequantize_bitplanes,
                                       ef_compress_tree, quantize_bitplanes,
                                       zero_residuals)


@given(st.integers(1, 5000), st.sampled_from([2, 4, 8, 12]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=10)
def test_quantization_error_bound(n, bits, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=n)
                    .astype(np.float32))
    words, scale = quantize_bitplanes(x, bits)
    dq = dequantize_bitplanes(words, scale, bits, x.shape)
    # round-to-nearest: |err| <= scale/2 elementwise
    assert float(jnp.max(jnp.abs(dq - x))) <= float(scale) * 0.5 + 1e-7


def test_wire_format_size():
    x = jnp.ones((1000,), jnp.float32)
    for bits in (4, 8):
        words, _ = quantize_bitplanes(x, bits)
        assert words.shape == (bits, (1000 + 31) // 32)
        assert compression_ratio(bits) == bits / 32


def test_plane_truncation_degrades_gracefully():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=4096).astype(np.float32))
    words, scale = quantize_bitplanes(x, 8)
    errs = []
    for keep in (8, 6, 4, 2):
        dq = dequantize_bitplanes(words, scale, 8, x.shape,
                                  keep_planes=keep)
        errs.append(float(jnp.mean(jnp.abs(dq - x))))
    assert errs == sorted(errs)          # fewer planes → larger error


def test_error_feedback_unbiased_over_time():
    """With EF, the *sum* of compressed grads tracks the sum of true grads
    (residual is bounded), even at 2-bit sign-ish quantization."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.normal(size=256).astype(np.float32))
              for _ in range(30)]
    residual = jnp.zeros((256,), jnp.float32)
    total_sent = jnp.zeros((256,), jnp.float32)
    for g in g_true:
        (sent,), (residual,) = ef_compress_tree((g,), (residual,), bits=3)
        total_sent = total_sent + sent
    total_true = sum(g_true)
    # EF guarantee: |Σ sent − Σ true| = |final residual| ≤ max per-step scale
    drift = np.abs(np.asarray(total_sent - total_true))
    assert drift.max() <= float(jnp.abs(residual).max()) + 1e-5
    # and the relative tracking error is small
    assert drift.max() / (np.abs(np.asarray(total_true)).max() + 1e-9) < 0.5


def test_tree_structure_preserved():
    params = {"a": jnp.ones((8, 8)), "b": {"c": jnp.ones((3,))}}
    res = zero_residuals(params)
    grads = jax.tree.map(lambda p: p * 0.5, params)
    q, new_res = ef_compress_tree(grads, res, bits=8)
    assert jax.tree_util.tree_structure(q) == \
        jax.tree_util.tree_structure(params)
    assert jax.tree_util.tree_structure(new_res) == \
        jax.tree_util.tree_structure(params)


def test_compressed_allreduce_under_shard_map():
    """Numerical check of the wire collective on a multi-device host mesh.

    Runs in a subprocess because it needs forced host devices and the test
    session must keep the single-device default."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.grad_compress import compressed_allreduce_mean
if hasattr(jax.sharding, "AxisType"):
    mesh = jax.make_mesh((4,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
else:
    mesh = jax.make_mesh((4,), ("pod",))
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64))
                .astype(np.float32))
f = shard_map(lambda g: compressed_allreduce_mean(g, "pod", bits=8),
              mesh=mesh, in_specs=P("pod", None),
              out_specs=P("pod", None))
out = np.asarray(f(x))
want = np.mean(np.asarray(x), axis=0)
err = np.abs(out - want).max() / (np.abs(want).max() + 1e-9)
assert err < 0.02, err
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
