"""Exact-equality tests for the succinct rank/select structures (paper §5)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bitops
from repro.core.rank_select import (bitvector_bits, build_binary_rank,
                                    build_bitvector, build_generalized,
                                    generalized_access, generalized_rank,
                                    generalized_select, rank0, rank1,
                                    select0, select1)


def _bv(bits, sr=512):
    words = bitops.pack_bits(bitops.pad_bits(jnp.asarray(bits)))
    return build_bitvector(words, len(bits), sr)


@given(st.integers(1, 20000), st.floats(0.01, 0.99),
       st.sampled_from([128, 512, 2048]), st.integers(0, 2**32 - 1))
def test_binary_rank_select_exact(n, density, sr, seed):
    rng = np.random.default_rng(seed)
    bits = (rng.random(n) < density).astype(np.uint8)
    bv = _bv(bits, sr)
    idx = np.unique(rng.integers(0, n + 1, 64))
    got1 = np.asarray(rank1(bv.rank, jnp.asarray(idx)))
    cum = np.concatenate([[0], np.cumsum(bits)])
    assert np.array_equal(got1, cum[idx])
    got0 = np.asarray(rank0(bv.rank, jnp.asarray(idx)))
    assert np.array_equal(got0, idx - cum[idx])

    ones = np.flatnonzero(bits == 1)
    zeros = np.flatnonzero(bits == 0)
    if len(ones):
        ks = np.unique(rng.integers(0, len(ones), 32))
        got = np.asarray(select1(bv.rank, bv.sel1, jnp.asarray(ks)))
        assert np.array_equal(got, ones[ks])
    if len(zeros):
        ks = np.unique(rng.integers(0, len(zeros), 32))
        got = np.asarray(select0(bv.rank, bv.sel0, jnp.asarray(ks)))
        assert np.array_equal(got, zeros[ks])


def test_rank_select_adversarial_patterns():
    """All-zeros, all-ones, alternating, single-bit, block boundaries."""
    for n in (1, 31, 32, 33, 127, 128, 129, 1024, 1025):
        for pat in ("zeros", "ones", "alt", "first", "last"):
            bits = {
                "zeros": np.zeros(n, np.uint8),
                "ones": np.ones(n, np.uint8),
                "alt": (np.arange(n) % 2).astype(np.uint8),
                "first": np.eye(1, n, 0, dtype=np.uint8)[0],
                "last": np.eye(1, n, n - 1, dtype=np.uint8)[0],
            }[pat]
            bv = _bv(bits, sr=128)
            cum = np.concatenate([[0], np.cumsum(bits)])
            idx = np.arange(n + 1)
            assert np.array_equal(
                np.asarray(rank1(bv.rank, jnp.asarray(idx))), cum[idx]), \
                (n, pat)
            ones = np.flatnonzero(bits == 1)
            if len(ones):
                got = np.asarray(select1(bv.rank, bv.sel1,
                                         jnp.arange(len(ones))))
                assert np.array_equal(got, ones), (n, pat)
            zeros = np.flatnonzero(bits == 0)
            if len(zeros):
                got = np.asarray(select0(bv.rank, bv.sel0,
                                         jnp.arange(len(zeros))))
                assert np.array_equal(got, zeros), (n, pat)


def test_structure_is_succinct():
    """Directory overhead must be o(n)-ish: < 35% of the bitmap at 1M bits."""
    rng = np.random.default_rng(0)
    n = 1 << 20
    bits = (rng.random(n) < 0.5).astype(np.uint8)
    bv = _bv(bits, sr=512)
    assert bitvector_bits(bv) / n < 1.35


def test_total_ones():
    rng = np.random.default_rng(3)
    bits = (rng.random(12345) < 0.3).astype(np.uint8)
    words = bitops.pack_bits(bitops.pad_bits(jnp.asarray(bits)))
    rs = build_binary_rank(words, len(bits))
    assert int(rs.total_ones) == int(bits.sum())


# ---------------------------------------------------------------------------
# Generalized (σ-ary) structures — paper Section 5.2
# ---------------------------------------------------------------------------

@given(st.sampled_from([1, 2, 4]), st.integers(1, 5000),
       st.integers(0, 2**32 - 1))
@settings(max_examples=10)
def test_generalized_rank_select_access(width, n, seed):
    sigma = 1 << width
    rng = np.random.default_rng(seed)
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    g = build_generalized(jnp.asarray(seq), width, n)
    assert np.array_equal(np.asarray(generalized_access(g, jnp.arange(n))),
                          seq)
    for c in range(sigma):
        idx = np.unique(rng.integers(0, n + 1, 24))
        got = np.asarray(generalized_rank(g, jnp.full(len(idx), c),
                                          jnp.asarray(idx)))
        expect = np.array([(seq[:i] == c).sum() for i in idx])
        assert np.array_equal(got, expect), c
        occ = np.flatnonzero(seq == c)
        if len(occ):
            ks = np.unique(rng.integers(0, len(occ), 16))
            got = np.asarray(generalized_select(g, jnp.full(len(ks), c),
                                                jnp.asarray(ks)))
            assert np.array_equal(got, occ[ks]), c
