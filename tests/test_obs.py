"""Telemetry layer contract tests.

* histogram quantiles vs the numpy nearest-rank oracle across
  distributions (exact inside the raw-sample head, bucket-bounded beyond)
* counter/gauge thread-safety under concurrent writers and under
  vmapped shard builds (trace-time increments must not corrupt state)
* disabled mode is a true no-op (no state mutation, no export)
* span nesting, attribute propagation, and event correlation
* exporter round trip: snapshot + JSONL events, Prometheus text
* timed_op emits the full ``serve.*`` metric family; track_shapes counts
  distinct signatures once
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import Histogram

bucket_index = Histogram.bucket_index
from repro.obs.report import check_slos, op_rows, render_span_tree


@pytest.fixture(autouse=True)
def _clean_registry():
    obs.REGISTRY.reset()
    obs.reset_shape_tracking()
    yield
    obs.REGISTRY.reset()


# -------------------------------------------------------------------------
# histogram quantiles
# -------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["uniform", "lognormal", "exponential",
                                  "bimodal"])
def test_quantiles_exact_within_raw_head(dist):
    """With count ≤ raw_cap the quantiles are exact nearest-rank order
    statistics — identical to numpy's inverted_cdf method."""
    rng = np.random.default_rng(hash(dist) % (1 << 31))
    n = 5000
    xs = {
        "uniform": rng.uniform(1e-6, 10.0, n),
        "lognormal": rng.lognormal(-7, 2.5, n),
        "exponential": rng.exponential(0.01, n),
        "bimodal": np.concatenate([rng.normal(1e-4, 1e-5, n // 2),
                                   rng.normal(5.0, 0.5, n - n // 2)]),
    }[dist]
    xs = np.abs(xs) + 1e-9
    h = obs.histogram("t.q", dist=dist)
    for x in xs:
        h.observe(float(x))
    for q in (0.5, 0.9, 0.95, 0.99, 1.0):
        want = float(np.quantile(xs, q, method="inverted_cdf"))
        assert h.quantile(q) == pytest.approx(want, rel=1e-12), (dist, q)
    assert h.count == len(xs)
    assert h.max == pytest.approx(xs.max())
    assert h.min == pytest.approx(xs.min())
    assert h.sum == pytest.approx(xs.sum())


def test_quantiles_bucket_fallback_beyond_cap():
    """Past raw_cap the quantile comes from the log buckets: within one
    bucket's relative width (2^(1/16) ≈ 4.4%) of the true value."""
    rng = np.random.default_rng(7)
    xs = np.abs(rng.lognormal(-5, 2, 30000)) + 1e-9
    h = Histogram("t.big", raw_cap=1024)
    for x in xs:
        h.observe(float(x))
    assert h.count > h.raw_cap
    for q in (0.5, 0.95, 0.99):
        want = float(np.quantile(xs, q, method="inverted_cdf"))
        assert h.quantile(q) == pytest.approx(want, rel=0.05), q


def test_bucket_index_monotone():
    vals = np.logspace(-8, 5, 400)
    idx = [bucket_index(float(v)) for v in vals]
    assert idx == sorted(idx)
    assert bucket_index(0.0) == 0                      # underflow bucket
    assert bucket_index(1e9) == bucket_index(1e8)      # overflow bucket


# -------------------------------------------------------------------------
# thread safety
# -------------------------------------------------------------------------

def test_counter_thread_safety():
    c = obs.counter("t.threads")
    h = obs.histogram("t.threads_h")
    N, T = 2000, 8

    def work():
        for _ in range(N):
            c.inc()
            h.observe(0.001)

    ts = [threading.Thread(target=work) for _ in range(T)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert c.value == N * T
    assert h.count == N * T


def test_counters_under_vmap_shard_builds():
    """Trace-time counter increments from inside vmapped/jitted builds
    must leave the registry consistent (and count traces, not calls)."""
    from repro.core.wavelet_matrix import build_wavelet_matrix
    rng = np.random.default_rng(3)
    shards = jnp.asarray(rng.integers(0, 64, (4, 256)).astype(np.uint32))

    def build(s):
        return build_wavelet_matrix(s, 64, sample_rate=128,
                                    use_kernels=False)

    jax.vmap(build)(shards)
    snap = obs.REGISTRY.snapshot()
    builds = {k: v for k, v in snap["counters"].items()
              if k.startswith("core.build")}
    # one vmapped build = ONE trace of the builder
    assert sum(builds.values()) == 1
    key = "core.build{builder=wm,path=fused}"
    assert builds.get(key) == 1


# -------------------------------------------------------------------------
# disabled mode
# -------------------------------------------------------------------------

def test_disabled_mode_is_noop():
    c = obs.counter("t.off")
    h = obs.histogram("t.off_h")
    g = obs.gauge("t.off_g")
    with obs.disabled():
        c.inc(5)
        h.observe(1.0)
        g.set(3.0)
        with obs.span("t.off_span") as sp:
            sp.set("k", "v")        # must not blow up on the null span
            assert sp.sync(42) == 42
        obs.event("t.off_event")
    assert c.value == 0
    assert h.count == 0
    assert g.value is None
    assert "span.t.off_span" not in obs.REGISTRY.snapshot()["histograms"]


def test_disabled_mode_histogram_state_frozen():
    h = obs.histogram("t.frozen")
    h.observe(1.0)
    before = (h.count, h.sum, h.min, h.max)
    with obs.disabled():
        for _ in range(100):
            h.observe(9.0)
    assert (h.count, h.sum, h.min, h.max) == before


# -------------------------------------------------------------------------
# spans
# -------------------------------------------------------------------------

def test_span_nesting_and_attrs():
    with obs.span("outer", a=1) as so:
        assert obs.current_span() is so
        assert so.path == "outer"
        with obs.span("inner") as si:
            assert si.parent_id == so.span_id
            assert si.path == "outer/inner"
            si.set("found", "late")
        assert obs.current_span() is so
    assert obs.current_span() is None
    assert si.attrs["found"] == "late"
    assert so.dur_s >= si.dur_s
    snap = obs.REGISTRY.snapshot()
    assert snap["histograms"]["span.outer"]["count"] == 1
    assert snap["histograms"]["span.inner"]["count"] == 1


def test_span_sync_blocks_on_device_value():
    with obs.span("jitted") as sp:
        out = sp.sync(jnp.arange(8) * 2)
    assert sp.dur_s is not None
    assert int(np.asarray(out)[-1]) == 14


# -------------------------------------------------------------------------
# export + report
# -------------------------------------------------------------------------

def test_export_roundtrip_and_span_tree(tmp_path):
    obs.configure(tmp_path)
    try:
        with obs.span("load"):
            with obs.span("verify"):
                obs.event("fault.test", kind="fault", leaf="rank/words")
        obs.timed_op("analytics", "quantile",
                     lambda x: jnp.sum(x), jnp.arange(100), batch=100)
        obs.write_snapshot()
    finally:
        obs.configure(None)

    snap = obs.read_snapshot(tmp_path)
    assert "serve.analytics.quantile.latency_s" in snap["histograms"]
    assert snap["meta"]["jax_version"] == jax.__version__

    events = obs.read_events(tmp_path)
    kinds = {e["kind"] for e in events}
    assert {"span", "fault"} <= kinds
    tree = render_span_tree(events)
    lines = tree.splitlines()
    assert lines[0].startswith("load")
    assert any("verify" in ln for ln in lines)
    # the fault event is nested under the verify span, deeper than it
    fault_ln = next(ln for ln in lines if "fault.test" in ln)
    verify_ln = next(ln for ln in lines if ln.lstrip().startswith("verify"))
    assert (len(fault_ln) - len(fault_ln.lstrip())
            > len(verify_ln) - len(verify_ln.lstrip()))

    rows = op_rows(snap)
    assert [r.op for r in rows] == ["analytics.quantile"]
    assert rows[0].batch == 100
    ok = check_slos(rows, ["analytics.*:p99_ms<=60000"])
    assert ok and all(r.ok for r in ok)
    bad = check_slos(rows, ["analytics.*:qps>=1e18"])
    assert any(not r.ok for r in bad)
    missing = check_slos(rows, ["nosuch.*:p99_ms<=1"])
    assert any(not r.ok for r in missing)   # no-match = violation

    prom = obs.prometheus_text(snap)
    assert "serve_analytics_quantile_latency_s" in prom.replace(".", "_")


def test_jsonl_skips_torn_lines(tmp_path):
    obs.configure(tmp_path)
    try:
        obs.event("fine")
    finally:
        obs.configure(None)
    with open(tmp_path / "events.jsonl", "a") as f:
        f.write('{"ts": 1, "kind": "event", "name": "torn...')
    events = obs.read_events(tmp_path)
    assert [e["name"] for e in events] == ["fine"]


def test_timed_op_metric_family():
    obs.timed_op("index", "count", lambda x: x + 1, jnp.arange(16),
                 batch=16, iters=2)
    snap = obs.REGISTRY.snapshot()
    assert snap["histograms"]["serve.index.count.latency_s"]["count"] == 1
    assert snap["gauges"]["serve.index.count.batch"] == 16
    assert snap["gauges"]["serve.index.count.compile_s"] > 0
    assert snap["counters"]["serve.index.count.calls"] == 3
    assert snap["counters"]["jit.shapes{op=index.count}"] == 1


def test_track_shapes_counts_distinct_signatures():
    assert obs.track_shapes("op", jnp.zeros((4,))) is True
    assert obs.track_shapes("op", jnp.zeros((4,))) is False
    assert obs.track_shapes("op", jnp.zeros((8,))) is True
    assert obs.track_shapes("op", jnp.zeros((8,), jnp.int32)) is True
    snap = obs.REGISTRY.snapshot()
    assert snap["counters"]["jit.shapes{op=op}"] == 3
    assert snap["counters"]["jit.calls{op=op}"] == 4


def test_key_roundtrip():
    c = obs.counter("a.b", z="1", a="2")
    assert c.key == "a.b{a=2,z=1}"          # labels sorted
    name, labels = obs.parse_key(c.key)
    assert name == "a.b" and labels == {"a": "2", "z": "1"}


# per https://prometheus.io/docs/instrumenting/exposition_formats/:
# metric names [a-zA-Z_:][a-zA-Z0-9_:]*, label names [a-zA-Z_][a-zA-Z0-9_]*,
# label values with \\, \" and \n escaped, sample value a float
_PROM_LINE = (r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
              r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\\\|\\"|\\n)*"'
              r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\\\|\\"|\\n)*")*\})?'
              r' -?[0-9.eE+-]+(\.[0-9]+)?$')


def test_prometheus_text_is_valid_exposition_format():
    """Every emitted line must parse under the exposition-format grammar,
    including metric names with dots/dashes and label values containing
    quotes, backslashes and newlines."""
    import re
    obs.counter("kernels.trace", op="wm-level_step", interpret="false").inc()
    obs.counter("prof.bound", op="analytics.quantile", term="memory").inc()
    obs.gauge("prof.roofline_util", op="analytics.quantile").set(0.42)
    obs.gauge("weird-name.metric", path='a"b\\c\nd').set(-1.5e-3)
    obs.histogram("serve.analytics.quantile.latency_s").observe(0.01)
    snap = obs.REGISTRY.snapshot()
    text = obs.prometheus_text(snap)
    line_re = re.compile(_PROM_LINE)
    lines = [ln for ln in text.splitlines() if ln]
    assert lines, "prometheus_text produced no samples"
    for ln in lines:
        assert line_re.match(ln), f"invalid exposition line: {ln!r}"
        float(ln.rsplit(" ", 1)[1])        # sample value parses
    # dots in names become underscores, label values keep their content
    assert any(ln.startswith("kernels_trace_total{") for ln in lines)
    assert any("prof_roofline_util" in ln and "0.42" in ln for ln in lines)
    assert any(r'path="a\"b\\c\nd"' in ln for ln in lines)
    # histograms expand to _count/_sum + quantile samples
    assert any("serve_analytics_quantile_latency_s_count" in ln
               for ln in lines)
