"""Corruption round-trip suite for the fault-tolerance subsystem.

Every fault class must be *detected* and then either *repaired
bit-identically* (derived structures recompute from the bitmaps) or
*served degraded* with an explicit coverage report — never a silent
wrong answer, never an unhandled crash.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import (build_sharded_analytics, load_analytics,
                             save_analytics)
from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, step_dir_valid)
from repro.index import build_sharded_index
from repro.robust import (FakeClock, IntegrityError, checksum_array,
                          classify_bad_keys, corrupt_snapshot_leaf,
                          delete_file, flip_leaf_bit, inject_partial_tmp,
                          is_primary_key, repair_analytics,
                          repair_fm_index, repair_sharded_index,
                          repair_wavelet_tree, tree_checksums,
                          trees_identical, truncate_file, verify_analytics,
                          verify_fm_index, verify_sharded_index,
                          verify_wavelet_matrix, verify_wavelet_tree,
                          with_retry)

N, SIGMA, SHARD_BITS = 3000, 97, 10


@pytest.fixture(scope="module")
def corpus_engine():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, SIGMA, N).astype(np.int64)
    return toks, build_sharded_analytics(toks, SIGMA,
                                         shard_bits=SHARD_BITS)


@pytest.fixture(scope="module")
def text_index():
    rng = np.random.default_rng(1)
    n, vocab = 1024, 64
    toks = rng.integers(0, vocab, n).astype(np.int64)
    idx = build_sharded_index(toks, vocab, shard_bits=9, sample_rate=32,
                              seam_overlap=7)
    return toks, idx


def _snap(eng, directory) -> Path:
    return save_analytics(eng, directory, extra_meta={"corpus_seed": 0})


# ---------------------------------------------------------------------------
# integrity: checksums recorded, verified, localized
# ---------------------------------------------------------------------------

def test_checksums_recorded_in_meta(corpus_engine, tmp_path):
    _, eng = corpus_engine
    step_dir = _snap(eng, tmp_path)
    meta = json.loads((step_dir / "meta.json").read_text())
    crc = meta["leaf_crc32"]
    with np.load(step_dir / "arrays.npz") as z:
        stored = set(z.files)
    assert set(crc) == stored and stored
    assert all(len(v) == 8 for v in crc.values())


def test_checksum_tags_shape_and_dtype():
    a = np.arange(8, dtype=np.int32)
    assert checksum_array(a) != checksum_array(a.view(np.uint32))
    assert checksum_array(a) != checksum_array(a.reshape(2, 4))
    assert checksum_array(a) == checksum_array(a.copy())


def test_restore_detects_any_leaf_flip(corpus_engine, tmp_path):
    _, eng = corpus_engine
    _snap(eng, tmp_path)
    where = corrupt_snapshot_leaf(tmp_path, seed=5)
    with pytest.raises(IntegrityError) as exc:
        load_analytics(tmp_path, repair=False)
    bad_key = where.split(":")[0]
    assert bad_key in exc.value.bad_keys


def test_derived_flip_repaired_bit_identical(corpus_engine, tmp_path):
    toks, eng = corpus_engine
    for frag in ("superblock", "block", "sel1", "sel0", "zeros"):
        d = tmp_path / frag.replace("/", "_")
        _snap(eng, d)
        corrupt_snapshot_leaf(d, seed=7, leaf_match=frag)
        healed = load_analytics(d)
        assert trees_identical(healed.shards, eng.shards), frag
        lo, hi = jnp.asarray([5, 900]), jnp.asarray([64, 2600])
        assert np.array_equal(
            np.asarray(healed.range_histogram(lo, hi)),
            np.asarray(eng.range_histogram(lo, hi))), frag


def test_primary_flip_escalates_to_rebuild(corpus_engine, tmp_path):
    _, eng = corpus_engine
    _snap(eng, tmp_path)
    corrupt_snapshot_leaf(tmp_path, seed=9, leaf_match="rank/words")
    with pytest.raises(IntegrityError, match="primary"):
        load_analytics(tmp_path)
    # verify=False opts out entirely — the raw (corrupt) state loads
    assert load_analytics(tmp_path, verify=False) is not None


def test_classify_bad_keys():
    derived, primary = classify_bad_keys([
        ".bitvectors/.rank/.words", ".bitvectors/.rank/.block",
        ".zeros", "seam_windows"])
    assert primary == [".bitvectors/.rank/.words", "seam_windows"]
    assert derived == [".bitvectors/.rank/.block", ".zeros"]
    assert is_primary_key(".shards/.wm/.bitvectors/.rank/.words")
    assert not is_primary_key(".shards/.mark/.words")


# ---------------------------------------------------------------------------
# step discovery: torn writes, half-deleted dirs, stale partials
# ---------------------------------------------------------------------------

def test_latest_step_skips_truncated_npz(tmp_path):
    state = {"w": jnp.arange(4096, dtype=jnp.int32)}
    save_checkpoint(tmp_path, 0, state)
    save_checkpoint(tmp_path, 1, jax.tree.map(lambda x: x + 1, state))
    truncate_file(tmp_path, "arrays.npz", keep_frac=0.3)   # newest = step 1
    assert latest_step(tmp_path) == 0
    restored, meta = restore_checkpoint(tmp_path, state)
    assert meta["step"] == 0
    assert np.array_equal(np.asarray(restored["w"]), np.arange(4096))


def test_latest_step_skips_half_deleted_dir(tmp_path):
    state = {"w": jnp.ones((8,), jnp.int32)}
    save_checkpoint(tmp_path, 0, state)
    save_checkpoint(tmp_path, 1, state)
    delete_file(tmp_path, "meta.json")
    assert latest_step(tmp_path) == 0
    assert not step_dir_valid(tmp_path / "step_00000001")


def test_latest_step_ignores_partial_tmp_and_junk(tmp_path):
    state = {"w": jnp.ones((8,), jnp.int32)}
    save_checkpoint(tmp_path, 3, state)
    inject_partial_tmp(tmp_path, step=99)
    (tmp_path / "step_junk").mkdir()
    assert latest_step(tmp_path) == 3


def test_no_valid_step_raises_filenotfound(corpus_engine, tmp_path):
    _, eng = corpus_engine
    _snap(eng, tmp_path)
    truncate_file(tmp_path, "arrays.npz")
    with pytest.raises(FileNotFoundError):
        load_analytics(tmp_path)


def test_stale_geometry_detected_by_meta(corpus_engine, tmp_path):
    _, eng = corpus_engine
    from repro.analytics import snapshot_meta
    _snap(eng, tmp_path)
    meta = snapshot_meta(tmp_path)
    assert (meta["n"], meta["sigma"]) == (N, SIGMA)
    assert meta["corpus_seed"] == 0          # identity travels with it


# ---------------------------------------------------------------------------
# structural verification + in-memory repair
# ---------------------------------------------------------------------------

def test_structural_verify_clean(corpus_engine):
    _, eng = corpus_engine
    assert verify_analytics(eng).ok


def test_structural_verify_localizes_and_repairs(corpus_engine):
    _, eng = corpus_engine
    bad, where = flip_leaf_bit(eng, seed=11, leaf_match="sel1")
    report = verify_analytics(bad)
    assert not report.ok and report.repairable
    assert any("sel1" in v.structure for v in report.violations)
    healed = repair_analytics(bad)
    assert verify_analytics(healed).ok
    assert trees_identical(healed.shards, eng.shards)


def test_structural_verify_flags_bitmap_corruption(corpus_engine):
    _, eng = corpus_engine
    # repair built on a corrupt bitmap must NOT reproduce the original:
    # the checksum comparison is the backstop that catches it
    want = tree_checksums(eng.shards)
    bad, _ = flip_leaf_bit(eng, seed=13, leaf_match="rank/words")
    assert not verify_analytics(bad).ok
    attempted = repair_analytics(bad)
    got = tree_checksums(attempted.shards)
    assert any(got[k] != want[k] for k in want)


def test_verify_single_wavelet_matrix(corpus_engine):
    _, eng = corpus_engine
    wm = eng.shard(0)
    assert verify_wavelet_matrix(wm).ok
    bad, _ = flip_leaf_bit(wm, seed=17, leaf_match="zeros")
    report = verify_wavelet_matrix(bad)
    assert not report.ok and report.repairable


def test_fm_index_verify_and_repair(text_index):
    _, idx = text_index
    assert verify_sharded_index(idx).ok
    for frag in ("C", "mark", "sa_sample"):
        bad, _ = flip_leaf_bit(idx, seed=19, leaf_match=frag)
        report = verify_sharded_index(bad)
        assert not report.ok and report.repairable, frag
        healed = repair_sharded_index(bad, deep=True)
        assert trees_identical(healed.shards, idx.shards), frag


def test_fm_index_shallow_repair_skips_sa(text_index):
    _, idx = text_index
    fm = jax.tree.map(lambda l: l[0], idx.shards)
    assert verify_fm_index(fm).ok
    bad, _ = flip_leaf_bit(fm, seed=23, leaf_match="C")
    healed = repair_fm_index(bad, deep=False)
    assert np.array_equal(np.asarray(healed.C), np.asarray(fm.C))
    # deep repair additionally rebuilds the SA directories
    deep = repair_fm_index(bad, deep=True)
    assert trees_identical(deep, fm)


def test_wavelet_tree_repair(text_index):
    from repro.core.wavelet_tree import build_wavelet_tree
    rng = np.random.default_rng(29)
    seq = jnp.asarray(rng.integers(0, 16, 800).astype(np.uint32))
    wt = build_wavelet_tree(seq, 16)
    assert verify_wavelet_tree(wt).ok
    bad, _ = flip_leaf_bit(wt, seed=31, leaf_match="node_starts")
    healed = repair_wavelet_tree(bad)
    assert trees_identical(healed, wt)


def test_node_starts_monotone_violation():
    from repro.core.wavelet_tree import build_wavelet_tree
    rng = np.random.default_rng(37)
    seq = jnp.asarray(rng.integers(0, 16, 500).astype(np.uint32))
    wt = build_wavelet_tree(seq, 16)
    ns = np.asarray(wt.node_starts).copy()
    ns[2, 0], ns[2, 1] = ns[2, 1] + 5, ns[2, 0]          # break monotone
    import dataclasses
    bad = dataclasses.replace(wt, node_starts=jnp.asarray(ns))
    report = verify_wavelet_tree(bad)
    assert any(v.kind == "node_starts_monotone" for v in report.violations)


# ---------------------------------------------------------------------------
# degraded-mode serving
# ---------------------------------------------------------------------------

def _covered_slice(toks, lo, hi, avail, shard_size):
    parts = [toks[max(lo, s * shard_size):min(hi, (s + 1) * shard_size)]
             for s in range(len(avail)) if avail[s]]
    return np.concatenate(parts) if parts else np.empty(0, toks.dtype)


def test_degraded_analytics_matches_survivor_oracle(corpus_engine):
    toks, eng = corpus_engine
    deg = eng.drop_shards(np.asarray([1], np.int32))
    avail = np.asarray(deg.available)
    assert not avail[1] and avail[0] and deg.degraded and not eng.degraded
    sz = eng.shard_size
    rng = np.random.default_rng(41)
    for _ in range(8):
        lo = int(rng.integers(0, N - 1))
        hi = int(rng.integers(lo + 1, N + 1))
        sl = _covered_slice(toks, lo, hi, avail, sz)
        # count over surviving shards
        got = int(deg.range_count(lo, hi, 3, 40))
        assert got == int(((sl >= 3) & (sl < 40)).sum())
        # quantile ranks within covered positions
        k = int(rng.integers(0, max(1, hi - lo)))
        got_q = int(deg.range_quantile(lo, hi, k))
        want_q = (int(np.sort(sl)[min(k, len(sl) - 1)]) if len(sl)
                  else -1)
        assert got_q == want_q
        # histogram/distinct over survivors
        assert np.array_equal(
            np.asarray(deg.range_histogram(lo, hi)),
            np.bincount(sl, minlength=1 << eng.shards.nbits))
        assert int(deg.range_distinct(lo, hi)) == len(np.unique(sl))


def test_degraded_bounds_bracket_truth(corpus_engine):
    toks, eng = corpus_engine
    deg = eng.drop_shards(np.asarray([0, 2], np.int32))
    lo = jnp.asarray([0, 100, 1500], jnp.int32)
    hi = jnp.asarray([N, 1200, 2900], jnp.int32)
    lower, upper, cov = deg.range_count_bounds(lo, hi, 3, 40)
    cov = np.asarray(cov)
    assert np.all((cov >= 0.0) & (cov <= 1.0))
    truth = np.asarray(eng.range_count(lo, hi, 3, 40))
    assert np.all(np.asarray(lower) <= truth)
    assert np.all(truth <= np.asarray(upper))
    hl, unc, hcov = deg.range_histogram_bounds(lo, hi)
    htruth = np.asarray(eng.range_histogram(lo, hi))
    assert np.all(np.asarray(hl) <= htruth)
    assert np.all(htruth <= np.asarray(hl) + np.asarray(unc)[:, None])
    assert np.allclose(np.asarray(hcov), cov)


def test_full_availability_bounds_are_tight(corpus_engine):
    _, eng = corpus_engine
    lower, upper, cov = eng.range_count_bounds(10, 2000, 3, 40)
    assert int(lower) == int(upper)
    assert float(cov) == 1.0
    assert float(eng.coverage(0, N)) == 1.0


def test_restored_availability_roundtrip(corpus_engine):
    _, eng = corpus_engine
    deg = eng.with_availability(np.asarray([True, False, True]))
    back = deg.with_availability(None)
    assert back.available is None
    with pytest.raises(ValueError):
        eng.with_availability(np.asarray([True, False]))


def test_degraded_index_counts_and_locate(text_index):
    toks, idx = text_index
    deg = idx.drop_shards(np.asarray([1], np.int32))
    assert 0.0 < float(deg.coverage()) < 1.0
    plen = 3
    pats = np.stack([toks[50:53], toks[600:603]]).astype(np.int32)
    lens = np.asarray([plen, plen], np.int32)
    win = np.lib.stride_tricks.sliding_window_view(toks, plen)
    lower, upper, _ = deg.count_bounds(pats, lens)
    for b in range(2):
        hits = np.nonzero((win == pats[b]).all(axis=1))[0]
        start_sh, end_sh = hits >> 9, (hits + plen - 1) >> 9
        want = int(np.sum((start_sh != 1) & (end_sh != 1)))
        assert int(np.asarray(deg.count(pats, lens))[b]) == want
        full = int(np.asarray(idx.count(pats, lens))[b])
        assert int(lower[b]) <= full <= int(upper[b])
    # locate never reports positions on the lost shard
    pos = np.asarray(deg.locate(pats, lens, max_hits_per_shard=4))
    live = pos[pos >= 0]
    assert np.all((live >> 9) != 1)


# ---------------------------------------------------------------------------
# retry / backoff
# ---------------------------------------------------------------------------

def test_with_retry_succeeds_after_transient_failures():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    seen = []
    assert with_retry(flaky, retries=3, backoff_s=0.0,
                      on_retry=lambda a, e: seen.append(a)) == "ok"
    assert calls["n"] == 3 and seen == [0, 1]


def test_with_retry_exhausts_budget():
    calls = {"n": 0}

    def always_fails():
        calls["n"] += 1
        raise ValueError("permanent")

    with pytest.raises(ValueError):
        with_retry(always_fails, retries=2, backoff_s=0.0)
    assert calls["n"] == 3


def test_with_retry_only_catches_listed_exceptions():
    def raises_type_error():
        raise TypeError("not retryable")

    with pytest.raises(TypeError):
        with_retry(raises_type_error, retries=5, backoff_s=0.0,
                   exceptions=(OSError,))


def test_with_retry_full_jitter_draws_within_envelope():
    """Sleep before attempt a+1 is uniform on [0, backoff·2^a] (full
    jitter) — deterministic under an injected rng, and reproducing the
    same rng reproduces the exact draws."""
    clock = FakeClock()

    def always_fails():
        raise OSError("transient")

    with pytest.raises(OSError):
        with_retry(always_fails, retries=4, backoff_s=0.1,
                   rng=np.random.default_rng(42), clock=clock)
    caps = [0.1 * (2 ** a) for a in range(4)]
    assert len(clock.sleeps) == 4
    assert all(0.0 <= s <= c for s, c in zip(clock.sleeps, caps))
    # full jitter, not the deterministic cap
    assert any(s < c for s, c in zip(clock.sleeps, caps))
    replay = FakeClock()
    with pytest.raises(OSError):
        with_retry(always_fails, retries=4, backoff_s=0.1,
                   rng=np.random.default_rng(42), clock=replay)
    assert replay.sleeps == clock.sleeps


def test_with_retry_jitter_off_is_deterministic_cap():
    clock = FakeClock()

    def always_fails():
        raise OSError("transient")

    with pytest.raises(OSError):
        with_retry(always_fails, retries=3, backoff_s=0.05, jitter=False,
                   clock=clock)
    assert clock.sleeps == [0.05, 0.1, 0.2]


def test_with_retry_deadline_cuts_retry_budget():
    """deadline_s=0 expires at the first failure: the exception re-raises
    immediately even though the retry budget would allow more attempts."""
    calls = {"n": 0}

    def always_fails():
        calls["n"] += 1
        raise OSError("transient")

    with pytest.raises(OSError):
        with_retry(always_fails, retries=10, backoff_s=0.0, deadline_s=0.0)
    assert calls["n"] == 1


def test_with_retry_deadline_clips_sleeps():
    clock = FakeClock()

    def always_fails():
        raise OSError("transient")

    with pytest.raises(OSError):
        with_retry(always_fails, retries=5, backoff_s=100.0, jitter=False,
                   deadline_s=0.25, clock=clock)
    # every backoff is clipped to the remaining deadline, never 100s
    assert clock.sleeps and all(s <= 0.25 for s in clock.sleeps)


# ---------------------------------------------------------------------------
# checkpoint step validation: meta/npz key agreement
# ---------------------------------------------------------------------------

def test_step_dir_rejects_incomplete_leaf_crc32(corpus_engine, tmp_path):
    """A meta.json that parses but whose leaf_crc32 map is missing npz
    keys is not a safe restore target — the step must be screened out so
    latest_step falls back to the previous valid one."""
    from repro.checkpoint import checkpoint_steps

    _, eng = corpus_engine
    save_checkpoint(tmp_path, 1, eng.shards)
    save_checkpoint(tmp_path, 2, eng.shards, keep=3)
    step2 = tmp_path / "step_00000002"
    meta = json.loads((step2 / "meta.json").read_text())
    victim = sorted(meta["leaf_crc32"])[0]
    del meta["leaf_crc32"][victim]
    (step2 / "meta.json").write_text(json.dumps(meta))
    assert not step_dir_valid(step2)
    assert step_dir_valid(step2, deep=False)        # listing-only view
    assert checkpoint_steps(tmp_path) == [1]
    assert latest_step(tmp_path) == 1
    # an absent map entirely (pre-integrity checkpoints) stays valid
    meta.pop("leaf_crc32")
    (step2 / "meta.json").write_text(json.dumps(meta))
    assert step_dir_valid(step2)
