"""Crash-safe streaming ingest suite.

The contract under test: a process dying after ANY step of the two-phase
shard commit protocol — or any journal append — recovers by replay to a
serving state *bit-identical* to a clean from-scratch build over the same
stream; hot swaps between corpus generations never tear a query batch;
quarantined generations degrade coverage honestly instead of crashing.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics.engine import ShardedAnalytics
from repro.data.compressed_store import build_compressed_corpus
from repro.index.sharded import build_sharded_index
from repro.ingest import (COMMIT_STEPS, QUARANTINE_STEP, GenerationServer,
                          IngestError, JournalCorrupt, ShardIngester,
                          analytics_ingester, append_record, index_ingester,
                          load_manifest, read_journal, record_crc)
from repro.robust import (CrashInjected, crash_after, trees_identical,
                          verify_manifest)

SIGMA = 8
SHARD_BITS = 8                                 # 256-token shards: fast
N = 1500                                       # 5 full shards + tail


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(7)
    return rng.integers(0, SIGMA, N).astype(np.int64)


@pytest.fixture(scope="module")
def ref_analytics(tokens):
    corpus = build_compressed_corpus(tokens, SIGMA, shard_bits=SHARD_BITS,
                                     parallel=False)
    return ShardedAnalytics.from_corpus(corpus)


@pytest.fixture(scope="module")
def ref_index(tokens):
    return build_sharded_index(tokens, SIGMA, shard_bits=SHARD_BITS,
                               sample_rate=16, seam_overlap=7,
                               parallel=False)


def _analytics(d, **kw):
    return analytics_ingester(d, SIGMA, shard_bits=SHARD_BITS,
                              backoff_s=0.0, **kw)


def _index(d, **kw):
    return index_ingester(d, SIGMA, shard_bits=SHARD_BITS, sample_rate=16,
                          seam_overlap=7, backoff_s=0.0, **kw)


def _feed(ing, toks):
    ing.recover()
    ing.append_tokens(toks)
    ing.flush()
    return ing


def _index_identical(eng, ref):
    return (eng.n == ref.n
            and trees_identical(eng.shards, ref.shards)
            and np.array_equal(np.asarray(eng.seam_windows),
                               np.asarray(ref.seam_windows)))


# ---------------------------------------------------------------------------
# journal: append-only, checksummed, torn-tail tolerant
# ---------------------------------------------------------------------------

def test_journal_roundtrip_and_crc(tmp_path):
    j = tmp_path / "manifest.jsonl"
    recs = [{"type": "INTENT", "gen": 0, "file": "shard_00000000.npz",
             "n_tokens": 10, "leaf_crc32": {"a": 1}},
            {"type": "COMMIT", "gen": 0}]
    for r in recs:
        append_record(j, r)
    back, torn = read_journal(j)
    assert not torn and len(back) == 2
    assert back[0]["file"] == "shard_00000000.npz"
    # every stored line carries a crc over its canonical JSON
    for line in j.read_text().splitlines():
        rec = json.loads(line)
        assert rec.pop("crc32") == record_crc(rec)


def test_journal_rejects_bad_record_type(tmp_path):
    with pytest.raises(ValueError):
        append_record(tmp_path / "m.jsonl", {"type": "PUBLISH", "gen": 0})


def test_torn_tail_is_dropped_not_fatal(tmp_path):
    j = tmp_path / "manifest.jsonl"
    append_record(j, {"type": "INTENT", "gen": 0, "file": "f.npz",
                      "n_tokens": 4})
    append_record(j, {"type": "COMMIT", "gen": 0})
    whole = j.read_bytes()
    j.write_bytes(whole[:-9])                  # crash mid-append
    back, torn = read_journal(j)
    assert torn and len(back) == 1 and back[0]["type"] == "INTENT"
    st = load_manifest(tmp_path)
    assert st.torn_tail and [e.gen for e in st.pending] == [0]


def test_mid_journal_corruption_is_fatal(tmp_path):
    j = tmp_path / "manifest.jsonl"
    for g in range(3):
        append_record(j, {"type": "INTENT", "gen": g, "file": f"{g}.npz",
                          "n_tokens": 1})
    lines = j.read_text().splitlines()
    lines[1] = lines[1][:-5] + "x}"            # bit-rot before the tail
    j.write_text("\n".join(lines) + "\n")
    with pytest.raises(JournalCorrupt):
        read_journal(j, strict=True)
    back, torn = read_journal(j, strict=False)
    assert torn and len(back) == 1             # scan stops at the bad line


# ---------------------------------------------------------------------------
# clean ingest ≡ from-scratch build (both engine kinds)
# ---------------------------------------------------------------------------

def test_analytics_ingest_bit_identical(tokens, ref_analytics, tmp_path):
    ing = _feed(_analytics(tmp_path), tokens)
    eng = ing.engine()
    assert eng.n == ref_analytics.n and eng.available is None
    assert trees_identical(eng.shards, ref_analytics.shards)
    # and the answers match a numpy oracle
    lo, hi, s0, s1 = 100, 1400, 2, 6
    truth = int(np.sum((tokens[lo:hi] >= s0) & (tokens[lo:hi] < s1)))
    assert int(eng.range_count(lo, hi, s0, s1)) == truth


def test_index_ingest_bit_identical(tokens, ref_index, tmp_path):
    ing = _feed(_index(tmp_path), tokens)
    eng = ing.engine()
    assert _index_identical(eng, ref_index)
    pat = np.asarray(tokens[40:43])[None, :].astype(np.int32)
    ln = np.asarray([3], np.int32)
    assert int(eng.count(pat, ln)[0]) == int(ref_index.count(pat, ln)[0])


def test_append_validates_token_range(tmp_path):
    ing = _analytics(tmp_path)
    ing.recover()
    with pytest.raises(ValueError):
        ing.append_tokens(np.asarray([0, SIGMA]))
    with pytest.raises(ValueError):
        ing.append_tokens(np.asarray([-1, 0]))  # must not wrap via uint cast
    ing.flush()
    with pytest.raises(IngestError):
        ing.append_tokens(np.asarray([1]))      # finalized


# ---------------------------------------------------------------------------
# the crash-point matrix: kill after every protocol step, recover, re-feed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("step", COMMIT_STEPS)
def test_crash_matrix_analytics(step, tokens, ref_analytics, tmp_path):
    ing = _analytics(tmp_path)
    ing.recover()
    with pytest.raises(CrashInjected):
        with crash_after(step):
            ing.append_tokens(tokens)
            ing.flush()
    # "new process": fresh ingester, journal replay, resume the stream
    ing2 = _analytics(tmp_path)
    rep = ing2.recover()
    assert rep.resume_offset <= N
    ing2.append_tokens(tokens[rep.resume_offset:])
    ing2.flush()
    eng = ing2.engine()
    assert eng.available is None               # nothing quarantined
    assert trees_identical(eng.shards, ref_analytics.shards)
    assert verify_manifest(tmp_path).ok


@pytest.mark.parametrize("step", COMMIT_STEPS)
def test_crash_matrix_index(step, tokens, ref_index, tmp_path):
    ing = _index(tmp_path)
    ing.recover()
    with pytest.raises(CrashInjected):
        with crash_after(step):
            ing.append_tokens(tokens)
            ing.flush()
    ing2 = _index(tmp_path)
    rep = ing2.recover()
    ing2.append_tokens(tokens[rep.resume_offset:])
    ing2.flush()
    assert _index_identical(ing2.engine(), ref_index)
    assert verify_manifest(tmp_path).ok


def test_crash_during_quarantine_append(tokens, ref_analytics, tmp_path):
    """Crash right after the QUARANTINE record lands: the record is
    durable, so replay resumes past the poisoned shard, and a later
    healthy re-feed of the same data serves under a fresh generation."""
    boom = {"on": True}

    def build(s):
        if boom["on"]:
            raise RuntimeError("poisoned batch")
        from repro.core.wavelet_matrix import build_wavelet_matrix
        return build_wavelet_matrix(s, SIGMA, sample_rate=512)

    ing = ShardIngester(tmp_path, build, SHARD_BITS, sigma=SIGMA,
                        kind="analytics", token_dtype=np.uint32,
                        retries=0, backoff_s=0.0, jit_build=True)
    ing.recover()
    with pytest.raises(CrashInjected):
        with crash_after(QUARANTINE_STEP):
            ing.append_tokens(tokens)
    boom["on"] = False
    ing2 = _analytics(tmp_path)
    rep = ing2.recover()
    assert rep.quarantined == [0]
    assert rep.resume_offset == 1 << SHARD_BITS   # gen 0 consumed its data
    # upstream replays the lost tokens (at-least-once) → full corpus, but
    # the quarantined slot stays masked until operators drop it
    ing2.append_tokens(tokens)                    # full replay from 0
    ing2.flush()
    eng = ing2.engine()
    assert eng.available is not None and not bool(eng.available[0])
    assert int(np.asarray(eng.available).sum()) == eng.num_shards - 1


def test_recovery_is_idempotent(tokens, tmp_path):
    ing = _analytics(tmp_path)
    ing.recover()
    with pytest.raises(CrashInjected):
        with crash_after("intent"):
            ing.append_tokens(tokens)
    a = _analytics(tmp_path)
    r1 = a.recover()
    b = _analytics(tmp_path)
    r2 = b.recover()
    assert r1.resume_offset == r2.resume_offset
    assert [e.gen for e in b.state.pending] == []
    # a third replay appends no further ABORT records
    n_lines = len((tmp_path / "manifest.jsonl").read_text().splitlines())
    _analytics(tmp_path).recover()
    assert len((tmp_path / "manifest.jsonl").read_text()
               .splitlines()) == n_lines


def test_corrupt_committed_shard_demoted_on_recovery(tokens, tmp_path):
    ing = _feed(_analytics(tmp_path), tokens)
    victim = ing.serve_entries()[1]
    path = tmp_path / "shards" / victim.file
    blob = bytearray(path.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    path.write_bytes(bytes(blob))
    ing2 = _analytics(tmp_path)
    rep = ing2.recover()
    assert rep.quarantined == [victim.gen]
    eng = ing2.engine()
    assert eng.available is not None and not bool(eng.available[1])
    # resume offset unchanged: the generation still owns its stream slot
    assert rep.resume_offset == N


# ---------------------------------------------------------------------------
# quarantine → honest partial coverage
# ---------------------------------------------------------------------------

def test_quarantined_shard_coverage_bounds(tokens, ref_analytics, tmp_path):
    calls = {"n": 0}

    def build(s):
        calls["n"] += 1
        if calls["n"] == 3:                    # third shard always fails
            raise RuntimeError("permanent")
        from repro.core.wavelet_matrix import build_wavelet_matrix
        return build_wavelet_matrix(s, SIGMA, sample_rate=512)

    ing = ShardIngester(tmp_path, build, SHARD_BITS, sigma=SIGMA,
                        kind="analytics", token_dtype=np.uint32,
                        retries=0, backoff_s=0.0)
    _feed(ing, tokens)
    eng = ing.engine()
    assert eng.degraded and eng.n == N
    lo, hi, s0, s1 = 0, N, 2, 6
    lower, upper, cov = eng.range_count_bounds(lo, hi, s0, s1)
    truth = int(ref_analytics.range_count(lo, hi, s0, s1))
    assert int(lower) <= truth <= int(upper)
    assert 0.0 < float(cov) < 1.0
    # verify_manifest flags nothing: a journaled quarantine is a valid
    # (if degraded) state, not a protocol violation
    assert verify_manifest(tmp_path).ok


# ---------------------------------------------------------------------------
# manifest self-checks (robust.verify.verify_manifest)
# ---------------------------------------------------------------------------

def test_verify_manifest_commit_without_file_is_fatal(tokens, tmp_path):
    ing = _feed(_analytics(tmp_path), tokens)
    victim = ing.serve_entries()[0]
    (tmp_path / "shards" / victim.file).unlink()
    rep = verify_manifest(tmp_path)
    assert not rep.ok and not rep.repairable
    assert any(v.kind == "commit_missing_shard" for v in rep.violations)


def test_verify_manifest_checksum_mismatch_repairable(tokens, tmp_path):
    ing = _feed(_analytics(tmp_path), tokens)
    victim = ing.serve_entries()[0]
    path = tmp_path / "shards" / victim.file
    arrays = dict(np.load(path))
    k = sorted(arrays)[0]
    arrays[k] = arrays[k].copy()
    arrays[k].flat[0] ^= 1
    np.savez(path, **arrays)
    rep = verify_manifest(tmp_path)
    assert not rep.ok and rep.repairable
    assert any(v.kind == "commit_checksum_mismatch" for v in rep.violations)


def test_verify_manifest_dangling_intent_repairable(tokens, tmp_path):
    ing = _analytics(tmp_path)
    ing.recover()
    with pytest.raises(CrashInjected):
        with crash_after("rename"):
            ing.append_tokens(tokens)
    rep = verify_manifest(tmp_path)
    assert not rep.ok and rep.repairable
    assert any(v.kind == "dangling_intent" for v in rep.violations)


def test_verify_manifest_nonmonotone_generation_fatal(tmp_path):
    j = tmp_path / "manifest.jsonl"
    append_record(j, {"type": "INTENT", "gen": 1, "file": "a.npz",
                      "n_tokens": 1})
    append_record(j, {"type": "INTENT", "gen": 0, "file": "b.npz",
                      "n_tokens": 1})
    rep = verify_manifest(tmp_path, deep=False)
    assert any(v.kind == "generation_monotonicity" and not v.derived
               for v in rep.violations)


# ---------------------------------------------------------------------------
# hot swap: add_shards + GenerationServer epoch fencing
# ---------------------------------------------------------------------------

def _stack_entries(ing, entries):
    trees = [ing.shard_tree(e) for e in entries]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def test_add_shards_matches_full_rebuild(tokens, ref_analytics, tmp_path):
    ing = _analytics(tmp_path)
    ing.recover()
    cut = 4 * (1 << SHARD_BITS)
    ing.append_tokens(tokens[:cut])
    eng0 = ing.engine()
    ing.append_tokens(tokens[cut:])
    ing.flush()
    new = ing.serve_entries()[4:]
    eng1 = eng0.add_shards(_stack_entries(ing, new),
                           sum(e.n_tokens for e in new))
    assert eng1.n == N and eng1.available is None
    assert trees_identical(eng1.shards, ref_analytics.shards)


def test_index_add_shards_matches_full_rebuild(tokens, ref_index, tmp_path):
    ing = _index(tmp_path)
    ing.recover()
    cut = 4 * (1 << SHARD_BITS)
    ing.append_tokens(tokens[:cut])
    eng0 = ing.engine()
    ing.append_tokens(tokens[cut:])
    ing.flush()
    entries = ing.serve_entries()
    new = entries[4:]
    seams = ing.seam_windows(entries)[3:]      # seam preceding each new shard
    eng1 = eng0.add_shards(_stack_entries(ing, new), jnp.asarray(seams),
                           sum(e.n_tokens for e in new))
    assert _index_identical(eng1, ref_index)


def test_add_shards_rejects_partial_tail_and_bad_counts(tokens, tmp_path):
    ing = _feed(_analytics(tmp_path), tokens)          # partial tail shard
    eng = ing.engine()
    one = jax.tree.map(lambda x: x[:1], eng.shards)
    with pytest.raises(ValueError):
        eng.add_shards(one, 10)                        # n not shard-aligned
    full = _feed(_analytics(tmp_path / "full"),
                 tokens[:4 * (1 << SHARD_BITS)]).engine()
    with pytest.raises(ValueError):
        full.add_shards(one, 2 * (1 << SHARD_BITS))    # count ≠ K shards


def test_hot_swap_under_concurrent_queries(tokens, tmp_path):
    """No query batch ever observes a mixed-generation corpus: inside a
    pinned session the engine's answer must equal that generation's
    oracle, no matter how many swaps land meanwhile."""
    ing = _analytics(tmp_path)
    ing.recover()
    shard = 1 << SHARD_BITS
    ing.append_tokens(tokens[:2 * shard])
    srv = GenerationServer(ing.engine())
    expected = {0: 2 * shard}
    stop = threading.Event()
    errors = []

    def reader():
        while not stop.is_set():
            with srv.session() as (gen, eng):
                n = int(eng.range_count(0, eng.n, 0, SIGMA))
                if n != expected[gen]:
                    errors.append((gen, n, expected[gen]))

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for k in (3, 4, 5):                        # three live swaps
        ing.append_tokens(tokens[(k - 1) * shard:k * shard])
        new = ing.serve_entries()[k - 1:]
        eng1 = srv.engine.add_shards(_stack_entries(ing, new), shard)
        expected[srv.generation + 1] = k * shard
        srv.swap_generation(eng1, wait_drain=True, timeout_s=30)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[:3]
    assert srv.generation == 3


def test_swap_fence_waits_for_drain(tmp_path, tokens):
    ing = _feed(_analytics(tmp_path), tokens)
    srv = GenerationServer(ing.engine())
    entered = threading.Event()
    release = threading.Event()
    order = []

    def holder():
        with srv.session():
            entered.set()
            release.wait(5)
            order.append("session_exit")

    t = threading.Thread(target=holder)
    t.start()
    entered.wait(5)
    with pytest.raises(TimeoutError):
        srv.swap_generation(ing.engine(), wait_drain=True, timeout_s=0.05)
    # the swap itself landed despite the fence timing out
    assert srv.generation == 1

    def swapper():
        srv.swap_generation(ing.engine(), wait_drain=True, timeout_s=10)
        order.append("swap_done")

    t2 = threading.Thread(target=swapper)
    t2.start()
    release.set()
    t.join(5)
    t2.join(5)
    assert order == ["session_exit", "swap_done"]
