"""Regression-sentry contract tests: history append/read round trip and
the noise-aware detector against synthetic trajectories.

The detector must gate on a genuine step regression (2× slowdown) while
NOT gating on: a flat series, a noisy-but-flat series (MAD-scaled slack),
or a fresh series with too little history. Gradual drift that never trips
the step test is reported as ``drift`` (not a hard gate), and a large
speedup as ``improvement``. Cross-host baselines are filtered by default.
"""
import json

import pytest

from repro.obs.history import (HISTORY_FILE, append_history,
                               detect_regression, group_history,
                               read_history, regress_report)
from repro.launch.regress import main as regress_main


def _meta(commit="c0", host="h1", fast=True, backend="cpu", seed=0):
    return {"git_commit": commit, "git_dirty": False, "backend": backend,
            "host": host, "fast": fast, "timestamp": "2026-08-09T00:00:00",
            "seed": seed}


# -------------------------------------------------------------------------
# detector verdicts on synthetic series
# -------------------------------------------------------------------------

def test_flat_series_is_ok():
    assert detect_regression([100.0] * 10).verdict == "ok"


def test_noisy_flat_series_is_ok():
    """±30% jitter around a flat mean must not gate: the MAD-scaled slack
    grows with the series' own noise."""
    vals = [100, 128, 84, 117, 92, 109, 78, 122, 95, 118]
    assert detect_regression([float(v) for v in vals]).verdict == "ok"


def test_step_regression_detected():
    vd = detect_regression([100.0] * 8 + [200.0])
    assert vd.verdict == "regression"
    assert vd.baseline == pytest.approx(100.0)
    assert vd.delta_pct == pytest.approx(100.0)
    assert vd.threshold is not None and vd.latest > vd.threshold


def test_single_noisy_run_does_not_gate_under_own_noise():
    """A last value within the series' historical spread stays ok even
    when it is the max seen so far."""
    vals = [100, 130, 85, 115, 90, 125, 95, 120, 132]
    assert detect_regression([float(v) for v in vals]).verdict == "ok"


def test_gradual_drift_flagged_not_gated():
    """+7% per run: no single step trips the MAD test, but the recent
    median vs the oldest window does."""
    vals = [100.0 * 1.07 ** i for i in range(12)]
    vd = detect_regression(vals)
    assert vd.verdict == "drift"


def test_improvement_detected():
    vd = detect_regression([100.0] * 8 + [40.0])
    assert vd.verdict == "improvement"


def test_too_little_history_is_new():
    vd = detect_regression([100.0, 200.0])
    assert vd.verdict == "new"
    vd = detect_regression([500.0])
    assert vd.verdict == "new"


def test_baseline_excludes_latest():
    # baseline is the *prior* runs: a repeated regression keeps gating
    # until the window fills with the new level
    vd = detect_regression([100.0] * 6 + [200.0, 200.0])
    assert vd.verdict == "regression"


# -------------------------------------------------------------------------
# history file round trip
# -------------------------------------------------------------------------

def test_append_read_roundtrip(tmp_path):
    path = tmp_path / HISTORY_FILE
    rows = [{"name": "build_n65536", "us_per_call": 1234.5,
             "mtok_per_s": 53.1},
            {"name": "query_b1024", "us_per_call": 88.0}]
    recs = append_history(path, "construction", rows, _meta())
    assert len(recs) == 2
    got = read_history(path)
    assert [r["row"] for r in got] == ["build_n65536", "query_b1024"]
    assert got[0]["suite"] == "construction"
    assert got[0]["commit"] == "c0" and got[0]["host"] == "h1"
    assert got[0]["us_per_call"] == pytest.approx(1234.5)
    assert got[0]["metrics"]["mtok_per_s"] == pytest.approx(53.1)
    key = group_history(got)
    assert len(key) == 2               # two distinct rows → two series


def test_read_skips_torn_last_line(tmp_path):
    path = tmp_path / HISTORY_FILE
    append_history(path, "wt", [{"name": "a", "us_per_call": 1.0}], _meta())
    with path.open("a") as fh:
        fh.write('{"suite": "wt", "row": "b", "us_per_call": 2.')
    got = read_history(path)
    assert [r["row"] for r in got] == ["a"]


def test_read_missing_file(tmp_path):
    assert read_history(tmp_path / "nope.jsonl") == []


# -------------------------------------------------------------------------
# report grouping / filters
# -------------------------------------------------------------------------

def _series(path, values, row="build", suite="wt", host="h1", fast=True):
    for i, v in enumerate(values):
        append_history(path, suite, [{"name": row, "us_per_call": v}],
                       _meta(commit=f"c{i}", host=host, fast=fast))


def test_regress_report_step(tmp_path):
    path = tmp_path / HISTORY_FILE
    _series(path, [100, 101, 99, 100, 100, 210])
    rows = regress_report(read_history(path))
    assert len(rows) == 1
    assert rows[0]["verdict"] == "regression"
    assert rows[0]["suite"] == "wt" and rows[0]["row"] == "build"


def test_cross_host_baseline_filtered_by_default(tmp_path):
    """A trajectory seeded on a faster machine must read as 'new' on this
    host, not as a phantom regression."""
    path = tmp_path / HISTORY_FILE
    _series(path, [50, 51, 49, 50, 50], host="fastbox")
    _series(path, [120], host="slowbox")
    rows = regress_report(read_history(path))
    assert rows[0]["verdict"] == "new"
    rows = regress_report(read_history(path), same_host=False)
    assert rows[0]["verdict"] == "regression"


def test_fast_full_series_never_mixed(tmp_path):
    path = tmp_path / HISTORY_FILE
    _series(path, [10, 10, 10, 10], fast=True)
    _series(path, [1000, 1000, 1000, 1000], fast=False)
    rows = regress_report(read_history(path))
    assert len(rows) == 2 and all(r["verdict"] == "ok" for r in rows)
    only_fast = regress_report(read_history(path), fast=True)
    assert len(only_fast) == 1 and only_fast[0]["fast"] is True


# -------------------------------------------------------------------------
# the CLI gate
# -------------------------------------------------------------------------

def test_cli_exits_nonzero_on_injected_2x_slowdown(tmp_path, capsys):
    path = tmp_path / HISTORY_FILE
    _series(path, [100, 101, 99, 100, 100, 200])
    assert regress_main(["--history", str(path)]) == 1
    out = capsys.readouterr()
    assert "REGRESS" in out.out and "CONFIRMED" in out.err


def test_cli_passes_noisy_flat_history(tmp_path):
    path = tmp_path / HISTORY_FILE
    _series(path, [100, 128, 84, 117, 92, 109, 122])
    assert regress_main(["--history", str(path)]) == 0


def test_cli_missing_history_is_soft(tmp_path):
    assert regress_main(["--history", str(tmp_path / "none.jsonl")]) == 2


def test_cli_fail_on_none_reports_only(tmp_path):
    path = tmp_path / HISTORY_FILE
    _series(path, [100, 100, 100, 100, 400])
    assert regress_main(["--history", str(path),
                         "--fail-on", "none"]) == 0
