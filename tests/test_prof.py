"""Device-profiling layer contract tests.

* ``profile_op``/``profiled_op`` read the XLA cost model off an AOT
  compile and record the full ``prof.*`` gauge family (flops, bytes,
  arithmetic intensity, achieved rates, roofline utilization, peak
  working set) — asserted end-to-end for the acceptance paths: the
  analytics quantile op, the construction path, and the Pallas kernel
  descent, with the gauges surviving a snapshot.json round trip.
* the hardware model honors env overrides; utilization is bound_time /
  measured_time so it must land in (0, 1] on a sane run.
* non-strict profiling degrades to an error record + counter instead of
  raising (profiling must never take serving down).
* ``analyze_hlo`` stays importable from its old ``launch.hlo_analysis``
  home (back-compat shim) and agrees with the moved implementation.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.obs.prof import (HW_MODELS, compiled_cost, compiled_memory,
                            hw_model, live_memory_stats, profile_op,
                            profiled_op, record_memory_gauges)


@pytest.fixture(autouse=True)
def _clean_registry():
    obs.REGISTRY.reset()
    obs.reset_shape_tracking()
    yield
    obs.REGISTRY.reset()


def test_hw_model_env_override(monkeypatch):
    peak, bw = hw_model("cpu")
    assert (peak, bw) == HW_MODELS["cpu"]
    monkeypatch.setenv("REPRO_PEAK_FLOPS", "1e12")
    monkeypatch.setenv("REPRO_HBM_BW", "2e11")
    assert hw_model("cpu") == (1e12, 2e11)
    assert hw_model("tpu") == (1e12, 2e11)      # override wins everywhere


def test_compiled_cost_and_memory_of_matmul():
    compiled = jax.jit(lambda a, b: a @ b).lower(
        jnp.ones((64, 64)), jnp.ones((64, 64))).compile()
    cost = compiled_cost(compiled)
    # 2·64³ FLOPs for the dot, 3·64²·4 bytes in+out
    assert cost["flops"] == pytest.approx(2 * 64 ** 3)
    assert cost["bytes_accessed"] >= 3 * 64 * 64 * 4
    mem = compiled_memory(compiled)
    assert mem["peak_bytes"] > 0
    assert mem["peak_bytes"] == pytest.approx(
        mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
        - mem["alias_bytes"])


def test_profile_op_records_roofline_family():
    out, stats = profile_op("t.mm", lambda a, b: a @ b,
                            jnp.ones((32, 32)), jnp.ones((32, 32)),
                            iters=2, work_elements=32 * 32)
    assert out is not None and "error" not in stats
    assert stats["flops"] == pytest.approx(2 * 32 ** 3)
    assert 0 < stats["roofline_util"] <= 1.0
    assert stats["bound"] in ("compute", "memory")
    assert stats["melem_per_s"] > 0
    snap = obs.REGISTRY.snapshot()
    g = snap["gauges"]
    for field in ("flops", "bytes_accessed", "ai", "achieved_flops_s",
                  "roofline_util", "peak_bytes", "steady_s",
                  "melem_per_s"):
        assert f"prof.{field}{{op=t.mm}}" in g, field
    assert snap["counters"][
        f"prof.bound{{op=t.mm,term={stats['bound']}}}"] == 1


def test_profile_op_nonstrict_degrades():
    def boom(x):
        raise RuntimeError("nope")
    out, stats = profile_op("t.bad", boom, jnp.ones(4))
    assert out is None and "error" in stats
    snap = obs.REGISTRY.snapshot()
    assert snap["counters"]["prof.error{op=t.bad}"] == 1
    with pytest.raises(RuntimeError):
        profile_op("t.bad", boom, jnp.ones(4), strict=True)


def test_profiled_op_emits_both_families():
    out, steady_s, compile_s = profiled_op(
        "analytics", "mm", lambda a, b: a @ b,
        jnp.ones((16, 16)), jnp.ones((16, 16)), batch=16, iters=2)
    assert out is not None and steady_s > 0 and compile_s > 0
    snap = obs.REGISTRY.snapshot()
    assert snap["histograms"]["serve.analytics.mm.latency_s"]["count"] == 1
    assert snap["gauges"]["serve.analytics.mm.batch"] == 16
    assert snap["counters"]["serve.analytics.mm.calls"] == 3
    assert "prof.roofline_util{op=analytics.mm}" in snap["gauges"]


def test_memory_gauges():
    keep = jnp.ones((256, 256))            # held alive across the snapshot
    stats = record_memory_gauges()
    assert stats["live_arrays"] >= 1
    assert stats["live_bytes"] >= keep.size * keep.dtype.itemsize
    snap = obs.REGISTRY.snapshot()
    assert snap["gauges"]["prof.mem.live_arrays"] >= 1
    assert live_memory_stats()["live_bytes"] > 0


def test_acceptance_paths_in_snapshot(tmp_path):
    """The quantile, construction, and kernel paths must all land
    roofline-utilization and peak-memory gauges in snapshot.json."""
    from repro.analytics import build_sharded_analytics
    from repro.core.wavelet_matrix import build_wavelet_matrix
    from repro.data import make_corpus

    toks = np.asarray(make_corpus(1 << 12, 256, seed=0), np.int64)
    eng = build_sharded_analytics(toks, 256, shard_bits=10)
    lo = jnp.arange(8, dtype=jnp.int32)
    hi = lo + 64
    k = jnp.full((8,), 3, jnp.int32)

    _, s_q = profile_op("analytics.quantile",
                        lambda e, a, b, c: e.range_quantile(a, b, c),
                        eng, lo, hi, k, work_elements=8.0)
    _, s_k = profile_op(
        "analytics.quantile_kernel",
        lambda e, a, b, c: e.range_quantile(a, b, c, use_kernel=True),
        eng, lo, hi, k, work_elements=8.0)
    sub = jnp.asarray(toks[:1024], jnp.int32)
    _, s_c = profile_op("analytics.construct_shard",
                        lambda s: build_wavelet_matrix(s, 256), sub,
                        work_elements=1024.0)
    for s in (s_q, s_k, s_c):
        assert "error" not in s, s
        assert 0 < s["roofline_util"] <= 1.0
        assert s["peak_bytes"] > 0

    obs.write_snapshot(tmp_path)
    snap = obs.read_snapshot(tmp_path)
    for op in ("analytics.quantile", "analytics.quantile_kernel",
               "analytics.construct_shard"):
        assert snap["gauges"][f"prof.roofline_util{{op={op}}}"] > 0
        assert snap["gauges"][f"prof.peak_bytes{{op={op}}}"] > 0
    assert snap["gauges"]["prof.mem.live_bytes"] > 0


def test_kernel_work_gauges():
    """The jitted kernel wrappers record trace-time work-size gauges."""
    from repro.kernels.ops import bitpack
    bits = jnp.asarray(np.random.default_rng(0).integers(0, 2, 96),
                       jnp.int32)
    bitpack(bits)
    snap = obs.REGISTRY.snapshot()
    assert snap["gauges"]["kernels.work.elements{op=bitpack}"] == 96.0
    assert snap["gauges"]["kernels.work.bits{op=bitpack}"] == 96.0


def test_trace_capture_writes_profile(tmp_path):
    from repro.obs.prof import start_trace, stop_trace, trace
    assert start_trace(None) is False
    assert stop_trace() is False             # nothing running
    with trace(tmp_path / "prof"):
        jnp.ones((8, 8)) @ jnp.ones((8, 8))
    assert stop_trace() is False             # context already stopped it
    assert any((tmp_path / "prof").rglob("*"))


def test_hlo_analysis_shim_back_compat():
    from repro.launch.hlo_analysis import analyze_hlo as shim
    from repro.obs.prof import analyze_hlo
    assert shim is analyze_hlo
    hlo = jax.jit(lambda a, b: a @ b).lower(
        jnp.ones((8, 8)), jnp.ones((8, 8))).compile().as_text()
    res = analyze_hlo(hlo)
    assert res["dot_flops_per_device"] == pytest.approx(2 * 8 ** 3)
