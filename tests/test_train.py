"""Training runtime: grad accumulation, NaN-skip, loss decrease, resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data import TokenBatcher, make_corpus
from repro.models.model import build_model
from repro.train import Trainer, init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen2_0_5b", smoke=True)
    model = build_model(cfg)
    toks = make_corpus(1 << 17, cfg.vocab_size, seed=0)
    return cfg, model, toks


def test_loss_decreases(tiny_setup):
    cfg, model, toks = tiny_setup
    batcher = TokenBatcher(tokens=toks, batch=8, seq_len=128, seed=0)
    trainer = Trainer(model, batcher, log_every=5, base_lr=1e-3,
                      warmup=5, total_steps=60)
    hist = trainer.run(60)
    first = np.mean([h["loss"] for h in hist[:2]])
    last = np.mean([h["loss"] for h in hist[-2:]])
    assert last < first - 0.1, (first, last)


def test_grad_accum_matches_full_batch(tiny_setup):
    cfg, model, toks = tiny_setup
    batcher = TokenBatcher(tokens=toks, batch=8, seq_len=64, seed=1)
    batch = {"tokens": jnp.asarray(batcher.batch_at(0))}
    s1 = init_train_state(model, 0)
    s2 = init_train_state(model, 0)
    step1 = make_train_step(model, grad_accum=1, base_lr=1e-3)
    step4 = make_train_step(model, grad_accum=4, base_lr=1e-3)
    n1, m1 = step1(s1, batch)
    n4, m4 = step4(s2, batch)
    # same data, same update (microbatch mean == full-batch mean for the
    # mean-CE loss since microbatches are equal-sized)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-2)
    for a, b in zip(jax.tree.leaves(n1.params), jax.tree.leaves(n4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_nan_skip(tiny_setup):
    cfg, model, toks = tiny_setup
    batcher = TokenBatcher(tokens=toks, batch=4, seq_len=64, seed=2)
    batch = {"tokens": jnp.asarray(batcher.batch_at(0))}
    state = init_train_state(model, 0)
    step = make_train_step(model, base_lr=1e-3, nan_skip=True)
    # poison the params of a copy → loss/grads go NaN → update must skip
    poisoned = jax.tree.map(
        lambda p: p.at[(0,) * p.ndim].set(jnp.nan) if p.size else p,
        state.params)
    pstate = init_train_state(model, 0)
    pstate = jax.tree_util.tree_map(lambda x: x, pstate)  # copy
    pstate = type(pstate)(params=poisoned, opt=pstate.opt, ef=pstate.ef)
    new_state, metrics = step(pstate, batch)
    assert int(metrics["skipped"]) == 1
    assert int(new_state.opt.step) == int(pstate.opt.step)  # not advanced
    # healthy state advances
    new_state, metrics = step(state, batch)
    assert int(metrics["skipped"]) == 0
    assert int(new_state.opt.step) == 1


def test_trainer_checkpoint_resume(tiny_setup, tmp_path):
    cfg, model, toks = tiny_setup
    batcher = TokenBatcher(tokens=toks, batch=4, seq_len=64, seed=3)
    t1 = Trainer(model, batcher, ckpt_dir=str(tmp_path), ckpt_every=5,
                 log_every=5, base_lr=1e-3)
    t1.run(10)
    # new trainer resumes at step 10 and continues
    t2 = Trainer(model, batcher, ckpt_dir=str(tmp_path), ckpt_every=5,
                 log_every=5, base_lr=1e-3)
    assert t2.maybe_resume() == 10
    assert int(t2.state.opt.step) == 10
    # parameters match bit-for-bit
    for a, b in zip(jax.tree.leaves(t1.state.params),
                    jax.tree.leaves(t2.state.params)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
    t2.run(5)
    assert int(t2.state.opt.step) == 15


def test_compressed_training_still_learns(tiny_setup):
    cfg, model, toks = tiny_setup
    batcher = TokenBatcher(tokens=toks, batch=8, seq_len=128, seed=4)
    trainer = Trainer(model, batcher, log_every=10, base_lr=1e-3,
                      warmup=5, total_steps=60, compress_bits=6)
    hist = trainer.run(60)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.05


def test_deterministic_replay(tiny_setup):
    """Two trainers over the same seed produce identical trajectories —
    the property that makes replacement hosts bitwise-consistent."""
    cfg, model, toks = tiny_setup
    h = []
    for _ in range(2):
        batcher = TokenBatcher(tokens=toks, batch=4, seq_len=64, seed=5)
        tr = Trainer(model, batcher, log_every=5, base_lr=1e-3)
        h.append(tr.run(10))
    assert h[0][-1]["loss"] == h[1][-1]["loss"]
