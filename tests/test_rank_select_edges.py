"""Edge cases for the σ-ary select paths: out-of-range k, alphabets that
don't fill a power of two, and length-1 / padding-dominated inputs.

Pure-numpy oracles — runs in minimal environments without hypothesis.
Contract pinned here: out-of-range ``k`` (k ≥ count, or symbol absent)
returns a *clamped position in [0, n)*, never out-of-bounds garbage;
callers detect overflow by comparing k against rank(c, n).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (build_generalized, build_wavelet_matrix,
                        generalized_rank, generalized_select, wm_access,
                        wm_rank, wm_select)


def test_wm_select_out_of_range_k_is_clamped():
    rng = np.random.default_rng(0)
    n = 100
    seq = rng.integers(0, 5, n)
    wm = build_wavelet_matrix(jnp.asarray(seq), 5)
    occ = np.flatnonzero(seq == 2)
    got = np.asarray(wm_select(wm, 2, jnp.arange(len(occ))))
    assert np.array_equal(got, occ)
    oob = np.asarray(wm_select(wm, 2, jnp.asarray(
        [len(occ), len(occ) + 5, 10 ** 6])))
    assert ((oob >= 0) & (oob < n)).all()
    # absent symbol (6 ≥ σ but < 2^nbits): in-range, no crash
    absent = np.asarray(wm_select(wm, 6, jnp.asarray([0, 3])))
    assert ((absent >= 0) & (absent < n)).all()


def test_wm_sigma_not_power_of_two():
    rng = np.random.default_rng(1)
    for sigma in (3, 5, 1000):
        n = 200
        seq = rng.integers(0, sigma, n)
        wm = build_wavelet_matrix(jnp.asarray(seq), sigma)
        assert np.array_equal(np.asarray(wm_access(wm, jnp.arange(n))), seq)
        for c in np.unique(seq)[:4]:
            idx = np.arange(0, n + 1, 17)
            got = np.asarray(wm_rank(wm, jnp.full(len(idx), int(c)),
                                     jnp.asarray(idx)))
            want = np.array([(seq[:i] == c).sum() for i in idx])
            assert np.array_equal(got, want), (sigma, c)
            occ = np.flatnonzero(seq == c)
            got = np.asarray(wm_select(wm, int(c), jnp.arange(len(occ))))
            assert np.array_equal(got, occ), (sigma, c)


def test_wm_length_one():
    wm = build_wavelet_matrix(jnp.asarray(np.array([3])), 5)
    assert int(wm_access(wm, jnp.int32(0))) == 3
    assert int(wm_rank(wm, jnp.int32(3), jnp.int32(1))) == 1
    assert int(wm_select(wm, jnp.int32(3), jnp.int32(0))) == 0
    # out-of-range k on a 1-element sequence stays in [0, 1)
    assert int(wm_select(wm, jnp.int32(3), jnp.int32(7))) == 0
    assert int(wm_select(wm, jnp.int32(4), jnp.int32(0))) == 0


def test_generalized_select_out_of_range_and_sparse_alphabet():
    rng = np.random.default_rng(2)
    n = 200
    # width-4 fields but only symbols 0..9 occur: "σ not a power of two"
    seq = rng.integers(0, 10, n).astype(np.uint32)
    g = build_generalized(jnp.asarray(seq), 4, n)
    for c in (0, 3, 9):
        occ = np.flatnonzero(seq == c)
        got = np.asarray(generalized_select(g, jnp.full(len(occ), c),
                                            jnp.arange(len(occ))))
        assert np.array_equal(got, occ), c
    oob = np.asarray(generalized_select(
        g, jnp.asarray([3, 3, 15]),
        jnp.asarray([int((seq == 3).sum()), 10 ** 6, 0])))
    assert ((oob >= 0) & (oob < n)).all()
    # symbol 15 never occurs; rank confirms, select stays clamped
    assert int(generalized_rank(g, jnp.int32(15), jnp.int32(n))) == 0


def test_generalized_length_one_and_scalar_queries():
    g = build_generalized(jnp.asarray(np.array([2], np.uint32)), 4, 1)
    assert int(generalized_rank(g, jnp.int32(2), jnp.int32(1))) == 1
    assert int(generalized_select(g, jnp.int32(2), jnp.int32(0))) == 0
    # out-of-range k clamps inside the 1-symbol sequence
    assert int(generalized_select(g, jnp.int32(2), jnp.int32(5))) == 0
    assert int(generalized_select(g, jnp.int32(7), jnp.int32(0))) == 0


def test_generalized_rank_beyond_padding():
    """Chunk padding (n not a multiple of chunk_syms) must stay invisible."""
    rng = np.random.default_rng(3)
    n = 130                                  # chunk_syms=128 → 126 pad slots
    seq = rng.integers(0, 4, n).astype(np.uint32)
    g = build_generalized(jnp.asarray(seq), 2, n)
    for c in range(4):
        assert int(generalized_rank(g, jnp.int32(c), jnp.int32(n))) == \
            int((seq == c).sum()), c
