"""Per-architecture smoke tests: every assigned arch instantiates a reduced
config and runs forward/train/decode steps on CPU with finite outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHITECTURES, get_config
from repro.models.model import (abstract_params, build_model, count_params,
                                param_specs, zero_cache)


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(0)
    b, s = 2, 64
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)),
                         jnp.int32)
    extras = {k: jnp.zeros(shp, jnp.bfloat16)
              for k, shp in model.extras_shapes(b).items()} or None
    loss, grads = jax.value_and_grad(model.loss_fn)(params, tokens, extras)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(0)
    b, s = 2, 32
    cache = zero_cache(cfg, b, s)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, new_cache = model.decode_step(params, tok, cache,
                                          jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, cfg.padded_vocab), arch
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache), arch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expect = {
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "deepseek_7b": (30, 4096, 32, 32, 11008, 102400),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen2_0_5b": (24, 896, 14, 2, 4864, 151936),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536),
        "llama_3_2_vision_90b": (100, 8192, 64, 8, 28672, 128256),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, (arch, got, expect)
    # MoE / SSM extras
    if arch == "arctic_480b":
        assert (cfg.num_experts, cfg.experts_per_token,
                cfg.moe_dense_residual) == (128, 2, True)
    if arch == "dbrx_132b":
        assert (cfg.num_experts, cfg.experts_per_token) == (16, 4)
    if arch == "jamba_v0_1_52b":
        assert (cfg.num_experts, cfg.experts_per_token,
                cfg.attn_every, cfg.moe_every) == (16, 2, 8, 2)
    if arch == "mamba2_370m":
        assert cfg.ssm_state == 128
    if arch == "qwen2_0_5b":
        assert cfg.qkv_bias
    if arch == "whisper_medium":
        assert (cfg.encoder_layers, cfg.encoder_frames) == (24, 1500)
    if arch == "llama_3_2_vision_90b":
        assert cfg.cross_attn_every == 5


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_param_specs_cover_all_leaves(arch):
    """Every parameter leaf gets a PartitionSpec of matching rank."""
    cfg = get_config(arch)
    shapes = abstract_params(cfg)
    specs = param_specs(cfg, {"data": 16, "model": 16})
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: hasattr(x, "_normalized_spec"))
    assert len(flat_s) == len(flat_p)
    import math
    for s, p in zip(flat_s, flat_p):
        assert len(p) <= len(s.shape), (arch, s.shape, p)
        for dim, ax in zip(s.shape, tuple(p) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = math.prod({"data": 16, "model": 16}.get(a, 1)
                             for a in axes)
            assert dim % size == 0, (arch, s.shape, p)


def test_param_count_sane():
    """Full-config parameter counts are in the right ballpark."""
    approx = {
        "qwen2_0_5b": (0.3e9, 0.8e9),
        "deepseek_7b": (6e9, 8e9),
        "granite_3_8b": (7e9, 10e9),
        "internlm2_20b": (17e9, 23e9),
        "arctic_480b": (400e9, 520e9),
        "dbrx_132b": (110e9, 145e9),
        "mamba2_370m": (0.25e9, 0.5e9),
        "jamba_v0_1_52b": (45e9, 60e9),
        # whisper-medium is 769M with tied embeddings; ours unties lm_head
        # (+53M) and counts both encoder and decoder stacks.
        "whisper_medium": (0.7e9, 0.9e9),
        "llama_3_2_vision_90b": (80e9, 105e9),
    }
    for arch, (lo, hi) in approx.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_uses_paper_dispatch():
    """The MoE layer routes through core.sort.bucket_ranks (paper primitive)
    and respects capacity semantics."""
    from repro.core.sort import bucket_ranks
    e, cap = 4, 3
    flat_e = jnp.asarray([0, 0, 0, 0, 1, 2, 0], jnp.int32)
    slots = np.asarray(bucket_ranks(flat_e, e))
    assert slots.tolist() == [0, 1, 2, 3, 0, 0, 4]
    keep = slots < cap
    assert keep.tolist() == [True, True, True, False, True, True, False]


def test_mamba2_train_decode_consistency():
    """SSD chunked scan (train) and O(1) recurrent decode agree step-wise."""
    cfg = get_config("mamba2_370m", smoke=True)
    model = build_model(cfg)
    params = model.init(0)
    b, s = 1, 16
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    # train-mode logits at every position
    from repro.models.model import forward_train
    full = forward_train(params, cfg, tokens, q_chunk=None)
    # decode token-by-token
    cache = zero_cache(cfg, b, s)
    outs = []
    for i in range(s):
        logits, cache = model.decode_step(params, tokens[:, i:i + 1], cache,
                                          jnp.full((b,), i, jnp.int32))
        outs.append(np.asarray(logits, np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32), dec,
                               rtol=0.05, atol=0.05)


def test_gqa_prefill_decode_consistency():
    """Attention prefill and KV-cache decode produce matching logits."""
    cfg = get_config("qwen2_0_5b", smoke=True)
    model = build_model(cfg)
    params = model.init(0)
    b, s = 1, 12
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    from repro.models.model import forward_train
    full = np.asarray(forward_train(params, cfg, tokens, q_chunk=None),
                      np.float32)
    cache = zero_cache(cfg, b, s)
    outs = []
    for i in range(s):
        logits, cache = model.decode_step(params, tokens[:, i:i + 1], cache,
                                          jnp.full((b,), i, jnp.int32))
        outs.append(np.asarray(logits, np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(full, dec, rtol=0.05, atol=0.05)
