"""Wavelet tree / matrix construction + queries vs naive numpy oracles.

Covers all construction variants of paper Theorems 4.1, 4.2, 4.5:
τ-chunked (all big_step backends), levelwise baseline, domain decomposition.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.wavelet_matrix import (build_wavelet_matrix,
                                       build_wavelet_matrix_levelwise,
                                       wm_access, wm_rank, wm_select)
from repro.core.wavelet_tree import (build_wavelet_tree,
                                     build_wavelet_tree_dd,
                                     build_wavelet_tree_levelwise, wt_access,
                                     wt_rank, wt_select)


def _check(seq, t, acc, rank, select, rng, tag):
    n = len(seq)
    assert np.array_equal(np.asarray(acc(t, jnp.arange(n))), seq), tag
    for c in np.unique(rng.choice(seq, size=min(4, n))):
        idx = np.unique(rng.integers(0, n + 1, 16))
        r = np.asarray(rank(t, jnp.full(len(idx), int(c)), jnp.asarray(idx)))
        expect = np.array([(seq[:i] == c).sum() for i in idx])
        assert np.array_equal(r, expect), (tag, "rank", c)
        occ = np.flatnonzero(seq == c)
        ks = np.unique(rng.integers(0, len(occ), 8))
        s = np.asarray(select(t, jnp.full(len(ks), int(c)), jnp.asarray(ks)))
        assert np.array_equal(s, occ[ks]), (tag, "select", c)


@given(st.integers(2, 3000), st.integers(2, 300),
       st.sampled_from([2, 3, 8]), st.sampled_from(["compose", "radix", "xla"]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=12)
def test_wavelet_tree_tau(n, sigma, tau, big_step, seed):
    rng = np.random.default_rng(seed)
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    t = build_wavelet_tree(jnp.asarray(seq), sigma, tau=tau,
                           big_step=big_step, sample_rate=128)
    _check(seq, t, wt_access, wt_rank, wt_select, rng,
           f"wt tau={tau} {big_step}")


@given(st.integers(2, 2000), st.integers(2, 300), st.integers(0, 2**32 - 1))
@settings(max_examples=10)
def test_wavelet_tree_levelwise(n, sigma, seed):
    rng = np.random.default_rng(seed)
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    t = build_wavelet_tree_levelwise(jnp.asarray(seq), sigma,
                                     sample_rate=128)
    _check(seq, t, wt_access, wt_rank, wt_select, rng, "wt levelwise")


@given(st.integers(1, 200), st.sampled_from([2, 4, 8]),
       st.integers(2, 100), st.integers(0, 2**32 - 1))
@settings(max_examples=10)
def test_wavelet_tree_domain_decomposition(m, chunks, sigma, seed):
    rng = np.random.default_rng(seed)
    n = m * chunks
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    t = build_wavelet_tree_dd(jnp.asarray(seq), sigma, chunks,
                              sample_rate=128)
    _check(seq, t, wt_access, wt_rank, wt_select, rng, f"wt dd P={chunks}")


def test_tree_variants_identical_bitmaps():
    """All construction variants must produce identical level bitmaps."""
    rng = np.random.default_rng(5)
    n, sigma = 1024, 97
    seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
    ts = [build_wavelet_tree(seq, sigma, tau=3),
          build_wavelet_tree(seq, sigma, tau=8, big_step="radix"),
          build_wavelet_tree(seq, sigma, tau=4, big_step="xla"),
          build_wavelet_tree_levelwise(seq, sigma),
          build_wavelet_tree_dd(seq, sigma, 8)]
    ref_words = np.asarray(ts[0].bitvectors.rank.words)
    for t in ts[1:]:
        assert np.array_equal(np.asarray(t.bitvectors.rank.words), ref_words)


@given(st.integers(2, 3000), st.integers(2, 300),
       st.sampled_from([2, 3, 8]), st.sampled_from(["compose", "radix", "xla"]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=12)
def test_wavelet_matrix_tau(n, sigma, tau, big_step, seed):
    rng = np.random.default_rng(seed)
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    t = build_wavelet_matrix(jnp.asarray(seq), sigma, tau=tau,
                             big_step=big_step, sample_rate=128)
    _check(seq, t, wm_access, wm_rank, wm_select, rng,
           f"wm tau={tau} {big_step}")


def test_matrix_variants_identical_bitmaps():
    rng = np.random.default_rng(6)
    n, sigma = 1024, 97
    seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
    ts = [build_wavelet_matrix(seq, sigma, tau=3),
          build_wavelet_matrix(seq, sigma, tau=8, big_step="radix"),
          build_wavelet_matrix(seq, sigma, tau=4, big_step="xla"),
          build_wavelet_matrix_levelwise(seq, sigma)]
    ref_words = np.asarray(ts[0].bitvectors.rank.words)
    for t in ts[1:]:
        assert np.array_equal(np.asarray(t.bitvectors.rank.words), ref_words)


@pytest.mark.parametrize("sigma", [2, 3, 4, 5])
def test_tiny_alphabets(sigma):
    rng = np.random.default_rng(1)
    seq = rng.integers(0, sigma, 257).astype(np.uint32)
    t = build_wavelet_tree(jnp.asarray(seq), sigma, tau=8, sample_rate=128)
    _check(seq, t, wt_access, wt_rank, wt_select, rng, f"sigma={sigma}")
    m = build_wavelet_matrix(jnp.asarray(seq), sigma, tau=8, sample_rate=128)
    _check(seq, m, wm_access, wm_rank, wm_select, rng, f"wm sigma={sigma}")
