"""Sharding-policy and vocab-padding tests (§Perf iterations 2–3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHITECTURES, ModelConfig, get_config
from repro.models.model import (abstract_params, build_model, count_params,
                                param_specs)


def test_padded_vocab_multiple_of_256():
    for arch in ARCHITECTURES:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 256


def test_padding_never_predicted_and_loss_finite():
    """Pad logits are masked: loss finite, pad-row lm_head grads ~0."""
    cfg = ModelConfig(name="padtest", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=1,
                      d_ff=64, vocab_size=250)       # pads to 256
    assert cfg.padded_vocab == 256
    model = build_model(cfg)
    params = model.init(0)
    assert params["lm_head"].shape == (32, 256)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, 250, (2, 17)), jnp.int32)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, tokens, None)
    assert np.isfinite(float(loss))
    # pad columns get zero probability → zero gradient signal
    pad_grad = np.abs(np.asarray(grads["lm_head"][:, 250:], np.float32))
    real_grad = np.abs(np.asarray(grads["lm_head"][:, :250], np.float32))
    assert pad_grad.max() < 1e-6
    assert real_grad.max() > 0
    # decode logits for pad ids are -inf-ish
    from repro.models.model import zero_cache
    logits, _ = model.decode_step(params, tokens[:, :1],
                                  zero_cache(cfg, 2, 8),
                                  jnp.zeros((2,), jnp.int32))
    assert np.all(np.asarray(logits[:, 250:]) < -1e29)


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_decode_mode_specs_fit_and_cover(arch):
    """Decode-mode specs: rank-compatible, divisible, and (for non-FSDP
    fallbacks) free of contraction-dim 'data' sharding on weight matmuls."""
    import math
    cfg = get_config(arch)
    sizes = {"data": 16, "model": 16}
    shapes = abstract_params(cfg)
    specs = param_specs(cfg, sizes, mode="decode")
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: hasattr(x, "_normalized_spec"))
    assert len(flat_s) == len(flat_p)
    for s, p in zip(flat_s, flat_p):
        for dim, ax in zip(s.shape, tuple(p) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = math.prod(sizes.get(a, 1) for a in axes)
            assert dim % size == 0, (arch, s.shape, p)


def test_decode_mode_capacity_fallback():
    """Large dense shards keep FSDP sharding at decode (llama-vision 11.25
    GB/device TP; arctic replicates 8.2 GB of 56-head attention weights);
    dbrx (dense remainder ~5B, experts 2D) takes TP-only mode."""
    sizes = {"data": 16, "model": 16}
    for arch in ("llama_3_2_vision_90b", "arctic_480b"):
        cfg = get_config(arch)
        specs_decode = param_specs(cfg, sizes, mode="decode")
        specs_train = param_specs(cfg, sizes, mode="train")
        assert jax.tree_util.tree_all(jax.tree.map(
            lambda a, b: a == b, specs_decode, specs_train,
            is_leaf=lambda x: hasattr(x, "_normalized_spec"))), arch
    cfg = get_config("dbrx_132b")
    sd = param_specs(cfg, sizes, mode="decode")
    st = param_specs(cfg, sizes, mode="train")
    assert sd["lm_head"] != st["lm_head"]


def test_decode_mode_small_arch_is_tp_only():
    cfg = get_config("qwen2_0_5b")
    specs = param_specs(cfg, {"data": 16, "model": 16}, mode="decode")
    # embed (V, d): V over model; lm_head (d, V): V over model; no "data"
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: hasattr(x, "_normalized_spec"))
    for p in flat:
        for ax in p:
            axes = ax if isinstance(ax, tuple) else (ax,)
            assert "data" not in axes, p


def test_moe_global_dispatch_matches_vmap_path():
    """The s==1 global dispatch and the train vmap path agree numerically
    (same routing, same experts) when capacity is not binding."""
    from repro.models.moe import moe_layer
    cfg = get_config("dbrx_132b", smoke=True)
    model = build_model(cfg)
    params = model.init(0)
    bp = jax.tree.map(lambda a: a[0], params["blocks"])["moe"]
    rng = np.random.default_rng(0)
    x1 = jnp.asarray(rng.normal(size=(4, 1, cfg.d_model)), jnp.float32)
    out_decode = moe_layer(x1, bp, cfg, capacity_factor=64.0)
    # simulate the train path by tiling the token to sequence length 2
    # and comparing position 0 of a (4, 2, D) batch whose second token is
    # identical — routing per-token, so outputs must match
    x2 = jnp.concatenate([x1, x1], axis=1)
    out_train = moe_layer(x2, bp, cfg, capacity_factor=64.0)
    np.testing.assert_allclose(np.asarray(out_decode[:, 0], np.float32),
                               np.asarray(out_train[:, 0], np.float32),
                               rtol=2e-2, atol=2e-3)
