"""Unit + property tests for the packed-word substrate (core.bitops)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import bitops


@given(st.integers(1, 4000), st.integers(0, 2**32 - 1))
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    words = bitops.pack_bits(bitops.pad_bits(jnp.asarray(bits)))
    assert words.shape[0] == bitops.num_words(n)
    back = np.asarray(bitops.unpack_bits(words, n))
    assert np.array_equal(back, bits)


@given(st.integers(1, 2000), st.integers(0, 2**32 - 1))
def test_word_prefix_popcount(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    words = bitops.pack_bits(bitops.pad_bits(jnp.asarray(bits)))
    prefix = np.asarray(bitops.word_prefix_popcount(words))
    w = bitops.num_words(n)
    padded = np.zeros(w * 32, np.uint8)
    padded[:n] = bits
    expect = np.concatenate([[0], np.cumsum(padded.reshape(w, 32).sum(1))])[:-1]
    assert np.array_equal(prefix, expect)


@given(st.integers(0, 2**32 - 1))
def test_select_in_word(seed):
    rng = np.random.default_rng(seed)
    word = np.uint32(rng.integers(0, 2**32, dtype=np.uint64))
    ones = [i for i in range(32) if (int(word) >> i) & 1]
    for k, pos in enumerate(ones):
        got = int(bitops.select_in_word(jnp.uint32(word), jnp.int32(k)))
        assert got == pos, (hex(int(word)), k)


@given(st.integers(0, 33))
def test_mask_below(k):
    m = int(bitops.mask_below(jnp.uint32(min(k, 32))))
    assert m == (1 << min(k, 32)) - 1


@given(st.sampled_from([1, 2, 4, 8, 16]), st.integers(1, 1000),
       st.integers(0, 2**32 - 1))
def test_pack_fields_roundtrip(width, n, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 1 << width, n).astype(np.uint32)
    words = bitops.pack_fields(jnp.asarray(vals), width)
    assert words.shape[0] == (n * width + 31) // 32
    back = np.asarray(bitops.unpack_fields(words, width, n))
    assert np.array_equal(back, vals)


def test_extract_field_and_bit():
    vals = jnp.asarray([0b101101, 0b011010], jnp.uint32)
    assert np.array_equal(np.asarray(bitops.extract_bit(vals, jnp.uint32(0))),
                          [1, 0])
    assert np.array_equal(
        np.asarray(bitops.extract_field(vals, jnp.uint32(2), 3)),
        [0b011, 0b110])


@given(st.integers(1, 300), st.integers(0, 2**32 - 1))
def test_rank1_word_matches_popcount_prefix(n, seed):
    rng = np.random.default_rng(seed)
    word = jnp.uint32(rng.integers(0, 2**32, dtype=np.uint64))
    bits = [(int(word) >> i) & 1 for i in range(32)]
    for i in (0, 1, 7, 31, 32):
        assert int(bitops.rank1_word(word, jnp.uint32(i))) == sum(bits[:i])
