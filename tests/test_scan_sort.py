"""Property tests for prefix-sum primitives and stable integer sorting."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.scan import (exclusive_sum, segmented_exclusive_sum,
                             stable_partition_indices)
from repro.core.sort import (bucket_ranks, counting_rank, radix_sort_stable,
                             sort_pass, sort_permutation)


@given(st.integers(1, 500), st.integers(0, 2**32 - 1))
def test_exclusive_sum(n, seed):
    x = np.random.default_rng(seed).integers(0, 100, n)
    got = np.asarray(exclusive_sum(jnp.asarray(x, jnp.int32)))
    expect = np.concatenate([[0], np.cumsum(x)[:-1]])
    assert np.array_equal(got, expect)


@given(st.integers(2, 300), st.integers(0, 2**32 - 1))
def test_segmented_exclusive_sum(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 10, n)
    starts = (rng.random(n) < 0.2).astype(np.int32)
    starts[0] = 1
    got = np.asarray(segmented_exclusive_sum(jnp.asarray(x, jnp.int32),
                                             jnp.asarray(starts)))
    expect = np.zeros(n, np.int64)
    acc = 0
    for i in range(n):
        if starts[i]:
            acc = 0
        expect[i] = acc
        acc += x[i]
    assert np.array_equal(got, expect)


@given(st.integers(1, 400), st.integers(0, 2**32 - 1))
def test_stable_partition(n, seed):
    flags = np.random.default_rng(seed).integers(0, 2, n).astype(np.int32)
    dest = np.asarray(stable_partition_indices(jnp.asarray(flags)))
    assert sorted(dest.tolist()) == list(range(n))    # a permutation
    out = np.empty(n, np.int64)
    out[dest] = np.arange(n)
    # zeros first in original order, then ones in original order
    expect = np.concatenate([np.flatnonzero(flags == 0),
                             np.flatnonzero(flags == 1)])
    assert np.array_equal(out, expect)


@given(st.integers(1, 3000), st.integers(2, 64), st.integers(0, 2**32 - 1))
def test_counting_rank_is_stable_sort(n, nb, seed):
    digits = np.random.default_rng(seed).integers(0, nb, n).astype(np.int32)
    dest = np.asarray(counting_rank(jnp.asarray(digits), nb))
    assert sorted(dest.tolist()) == list(range(n))
    inv = np.empty(n, np.int64)
    inv[dest] = np.arange(n)
    assert np.array_equal(inv, np.argsort(digits, kind="stable"))


@given(st.integers(1, 800), st.integers(2, 32), st.integers(0, 2**32 - 1))
def test_bucket_ranks(n, nb, seed):
    digits = np.random.default_rng(seed).integers(0, nb, n).astype(np.int32)
    got = np.asarray(bucket_ranks(jnp.asarray(digits), nb))
    seen = {}
    for i, d in enumerate(digits):
        assert got[i] == seen.get(d, 0)
        seen[d] = seen.get(d, 0) + 1


@given(st.integers(1, 1500), st.sampled_from([4, 8, 13, 16]),
       st.sampled_from([3, 5, 8]), st.sampled_from(["counting", "xla"]),
       st.integers(0, 2**32 - 1))
def test_radix_sort_stable(n, key_bits, bpp, backend, seed):
    keys = np.random.default_rng(seed).integers(
        0, 1 << key_bits, n).astype(np.uint32)
    vals = np.arange(n, dtype=np.int32)
    sk, (sv,) = radix_sort_stable(jnp.asarray(keys), key_bits,
                                  values=(jnp.asarray(vals),),
                                  bits_per_pass=bpp, backend=backend)
    order = np.argsort(keys, kind="stable")
    assert np.array_equal(np.asarray(sk), keys[order])
    assert np.array_equal(np.asarray(sv), order)     # stability


@given(st.integers(1, 1000), st.integers(0, 2**32 - 1))
def test_sort_permutation_backends_agree(n, seed):
    digits = np.random.default_rng(seed).integers(0, 16, n).astype(np.int32)
    p1 = np.asarray(sort_permutation(jnp.asarray(digits), 16, "counting"))
    p2 = np.asarray(sort_permutation(jnp.asarray(digits), 16, "xla"))
    assert np.array_equal(p1, p2)


def test_counting_rank_blocked_path():
    """Force the blocked path (n > 4*block and many buckets)."""
    rng = np.random.default_rng(7)
    n, nb = 5000, 256
    digits = rng.integers(0, nb, n).astype(np.int32)
    dest = np.asarray(counting_rank(jnp.asarray(digits), nb))
    inv = np.empty(n, np.int64)
    inv[dest] = np.arange(n)
    assert np.array_equal(inv, np.argsort(digits, kind="stable"))


@given(st.sampled_from([2100, 5000, 70000]), st.sampled_from([256, 1000]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=6, deadline=None)
def test_counting_rank_blocked_grouped(n, nb, seed):
    """Blocked path across group sizes: stable permutation property holds
    whether the within-block one-hots run as one fused op or under
    lax.map over groups."""
    digits = np.random.default_rng(seed).integers(0, nb, n).astype(np.int32)
    dest = np.asarray(counting_rank(jnp.asarray(digits), nb))
    assert sorted(dest.tolist()) == list(range(n))
    inv = np.empty(n, np.int64)
    inv[dest] = np.arange(n)
    assert np.array_equal(inv, np.argsort(digits, kind="stable"))


def test_counting_rank_kernel_route_matches():
    """The Pallas radix_rank route (interpret off-TPU) == the XLA route."""
    rng = np.random.default_rng(23)
    n, nb = 6000, 200
    digits = rng.integers(0, nb, n).astype(np.int32)
    a = np.asarray(counting_rank(jnp.asarray(digits), nb, use_kernel=False))
    b = np.asarray(counting_rank(jnp.asarray(digits), nb, use_kernel=True))
    assert np.array_equal(a, b)


@given(st.sampled_from([3000, 20000]), st.sampled_from([64, 300, 1024]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=6, deadline=None)
def test_bucket_ranks_large_buckets(n, nb, seed):
    """Large-B bucket_ranks routes through the blocked path (no O(n·B)
    one-hot) and still returns exact arrival-order ranks."""
    digits = np.random.default_rng(seed).integers(0, nb, n).astype(np.int32)
    got = np.asarray(bucket_ranks(jnp.asarray(digits), nb))
    order = np.argsort(digits, kind="stable")
    expect = np.empty(n, np.int64)
    counts = np.zeros(nb, np.int64)
    for i in order:                    # arrival order within each bucket
        expect[i] = counts[digits[i]]
        counts[digits[i]] += 1
    assert np.array_equal(got, expect)
