"""Segmented select-gather fast path vs the scatter baselines.

The acceptance contract for PR 5's fused tree-family builders: every
fused build (`build_wavelet_tree` τ-chunk, levelwise, domain-decomposed,
Huffman-shaped, multiary d-way) must be *bit-identical* to its
``fused=False`` scatter baseline — across alphabet sizes, τ, big-step
backends, degrees, and awkward (odd / non-block-multiple) lengths — and
the ``segmented_partition_gather`` primitives must match a stable-sort
oracle directly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops
from repro.core.huffman import build_huffman_wavelet_tree, huffman_codebook
from repro.core.multiary import build_multiary_wavelet_tree
from repro.core.rank_select import (segmented_partition_gather,
                                    segmented_partition_gather_fields)
from repro.core.scan import segment_ids_from_starts
from repro.core.wavelet_tree import (build_wavelet_tree,
                                     build_wavelet_tree_dd,
                                     build_wavelet_tree_levelwise)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# --------------------------------------------------------------------------
# primitive vs stable-sort oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 33, 777, 1025])
@pytest.mark.parametrize("nodes", [1, 4, 16])
def test_segmented_partition_gather_oracle(n, nodes):
    rng = np.random.default_rng(n * 31 + nodes)
    nid = np.sort(rng.integers(0, nodes, n)).astype(np.int32)
    bit = rng.integers(0, 2, n).astype(np.int32)
    starts = np.searchsorted(nid, np.arange(nodes)).astype(np.int32)
    words = bitops.pack_bits(bitops.pad_bits(jnp.asarray(bit, jnp.uint8)))
    g = np.asarray(segmented_partition_gather(
        words, jnp.asarray(nid), jnp.asarray(starts), n))
    oracle = np.argsort(nid * 2 + bit, kind="stable")
    assert np.array_equal(g, oracle)
    sid = np.asarray(segment_ids_from_starts(jnp.asarray(starts), n))
    assert np.array_equal(sid, nid)


@pytest.mark.parametrize("n", [1, 33, 777, 1025])
@pytest.mark.parametrize("width", [1, 2, 4])        # d in {2, 4, 16}
def test_segmented_partition_gather_fields_oracle(n, width):
    rng = np.random.default_rng(n * 7 + width)
    d = 1 << width
    nodes = 8
    nid = np.sort(rng.integers(0, nodes, n)).astype(np.int32)
    dig = rng.integers(0, d, n).astype(np.int32)
    starts = np.searchsorted(nid, np.arange(nodes)).astype(np.int32)
    g = np.asarray(segmented_partition_gather_fields(
        jnp.asarray(dig), width, jnp.asarray(nid), jnp.asarray(starts), n))
    oracle = np.argsort(nid * d + dig, kind="stable")
    assert np.array_equal(g, oracle)


# --------------------------------------------------------------------------
# fused builders vs scatter baselines (bit-identical pytrees)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sigma", [2, 256, 1 << 16])
@pytest.mark.parametrize("tau", [4, 8])
@pytest.mark.parametrize("big_step", ["compose", "radix", "xla"])
def test_fused_tree_matches_steps(sigma, tau, big_step):
    rng = np.random.default_rng(sigma * 13 + tau)
    for n in (1, 33, 777, 1025):               # odd / non-block-multiple n
        seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
        fused = build_wavelet_tree(seq, sigma, tau=tau, big_step=big_step,
                                   sample_rate=128)
        steps = build_wavelet_tree(seq, sigma, tau=tau, big_step=big_step,
                                   sample_rate=128, fused=False)
        assert _leaves_equal(fused, steps), (n, sigma, tau, big_step)


def test_fused_levelwise_and_dd_match():
    rng = np.random.default_rng(5)
    for n, sigma in ((501, 2), (1337, 256), (900, 1 << 16)):
        seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
        fused = build_wavelet_tree_levelwise(seq, sigma, sample_rate=128)
        steps = build_wavelet_tree_levelwise(seq, sigma, sample_rate=128,
                                             fused=False)
        assert _leaves_equal(fused, steps), (n, sigma)
    for m, chunks, sigma in ((7, 4, 17), (128, 8, 256), (50, 16, 1000)):
        seq = jnp.asarray(rng.integers(0, sigma, m * chunks)
                          .astype(np.uint32))
        fused = build_wavelet_tree_dd(seq, sigma, chunks, sample_rate=128)
        steps = build_wavelet_tree_dd(seq, sigma, chunks, sample_rate=128,
                                      fused=False)
        assert _leaves_equal(fused, steps), (m, chunks, sigma)


def test_fused_tree_kernel_path_matches():
    """use_kernels=True (Pallas wt_level, interpret off-TPU) is
    bit-identical; deep levels past the kernel's bucket bound exercise
    the mixed kernel/XLA route."""
    rng = np.random.default_rng(11)
    for n, sigma, tau in ((1500, 256, 8), (900, 37, 4), (1025, 1 << 16, 8)):
        seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
        fused = build_wavelet_tree(seq, sigma, tau=tau, sample_rate=128)
        kern = build_wavelet_tree(seq, sigma, tau=tau, sample_rate=128,
                                  use_kernels=True)
        assert _leaves_equal(fused, kern), (n, sigma, tau)


@pytest.mark.parametrize("sigma,zipf", [(2, 1.0), (17, 1.5), (64, 1.2),
                                        (256, 0.8)])
def test_fused_huffman_matches(sigma, zipf):
    rng = np.random.default_rng(sigma)
    for n in (1, 333, 1337):
        p = np.arange(1, sigma + 1) ** (-zipf)
        seq = rng.choice(sigma, size=n, p=p / p.sum()).astype(np.uint32)
        freqs = np.bincount(seq, minlength=sigma) + 1
        codes, lengths, max_len = huffman_codebook(freqs)
        fused = build_huffman_wavelet_tree(
            jnp.asarray(seq), jnp.asarray(codes), jnp.asarray(lengths),
            max_len)
        steps = build_huffman_wavelet_tree(
            jnp.asarray(seq), jnp.asarray(codes), jnp.asarray(lengths),
            max_len, fused=False)
        assert _leaves_equal(fused, steps), (sigma, zipf, n)


def test_huffman_traced_codebook_falls_back():
    """Tracing the codewords (jit without closing over them) still works
    via the scatter path and produces the same tree."""
    rng = np.random.default_rng(9)
    sigma, n = 40, 700
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    freqs = np.bincount(seq, minlength=sigma) + 1
    codes, lengths, max_len = huffman_codebook(freqs)
    import functools
    f = jax.jit(functools.partial(build_huffman_wavelet_tree,
                                  max_len=max_len))
    traced = f(jnp.asarray(seq), jnp.asarray(codes), jnp.asarray(lengths))
    fused = build_huffman_wavelet_tree(jnp.asarray(seq), jnp.asarray(codes),
                                       jnp.asarray(lengths), max_len)
    assert _leaves_equal(traced, fused)


@pytest.mark.parametrize("width", [2, 4])           # d in {4, 16}
@pytest.mark.parametrize("sigma", [2, 256, 1 << 16])
def test_fused_multiary_matches(width, sigma):
    rng = np.random.default_rng(width * 100 + 1)
    for n in (1, 333, 1025):
        seq = jnp.asarray(rng.integers(0, sigma, n).astype(np.uint32))
        fused = build_multiary_wavelet_tree(seq, sigma, width=width)
        steps = build_multiary_wavelet_tree(seq, sigma, width=width,
                                            fused=False)
        assert _leaves_equal(fused, steps), (width, sigma, n)


def test_fused_tree_queries_end_to_end():
    """access/rank/select answers on fused builds are exact."""
    from repro.core.wavelet_tree import wt_access, wt_rank, wt_select
    rng = np.random.default_rng(4)
    n, sigma = 2000, 300
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    wt = build_wavelet_tree(jnp.asarray(seq), sigma, sample_rate=128)
    assert np.array_equal(np.asarray(wt_access(wt, jnp.arange(n))), seq)
    c = int(seq[0])
    idx = np.unique(rng.integers(0, n + 1, 32))
    r = np.asarray(wt_rank(wt, jnp.full(len(idx), c), jnp.asarray(idx)))
    assert np.array_equal(r, [(seq[:i] == c).sum() for i in idx])
    occ = np.flatnonzero(seq == c)
    ks = np.arange(min(8, len(occ)))
    s = np.asarray(wt_select(wt, jnp.full(len(ks), c), jnp.asarray(ks)))
    assert np.array_equal(s, occ[ks])
