"""Range-analytics engine vs numpy oracles (np.sort / np.unique /
np.bincount on the decoded range).

Coverage per the acceptance criteria: uniform, skewed (Zipf) and all-equal
symbol distributions; σ ∈ {4, 256, 1000}; empty ranges and lo == hi;
single-matrix and sharded paths; a ≥1024-query mixed batch under one jit
trace; parallel (vmapped) shard builds bit-identical to the loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import (ShardedAnalytics, build_sharded_analytics,
                             range_count, range_distinct, range_histogram,
                             range_quantile, range_topk, range_topk_greedy)
from repro.core import build_wavelet_matrix
from repro.data import build_compressed_corpus


def _texts(n: int, sigma: int, seed: int = 0):
    """The three acceptance distributions."""
    rng = np.random.default_rng(seed)
    return {
        "uniform": rng.integers(0, sigma, n).astype(np.uint32),
        "zipf": (rng.zipf(1.4, n) % sigma).astype(np.uint32),
        "all_equal": np.full(n, sigma - 1, np.uint32),
    }


def _ranges(n: int, num: int, rng):
    """Random query ranges incl. empty, lo == hi, full-span, end-hugging."""
    lo = rng.integers(0, n + 1, num).astype(np.int64)
    hi = rng.integers(0, n + 1, num).astype(np.int64)
    lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
    lo[0], hi[0] = 0, n          # full span
    lo[1], hi[1] = 5, 5          # empty (lo == hi)
    lo[2], hi[2] = n, n          # empty at the end
    if num > 3:
        lo[3], hi[3] = n - 1, n  # single element
    return lo, hi


def _check_all_ops(seq, wm_ops, sigma, rng, tag, topk_k=6):
    """``wm_ops``: dict of callables mirroring the op signatures."""
    n = len(seq)
    lo, hi = _ranges(n, 12, rng)
    for i in range(len(lo)):
        sl = np.sort(seq[lo[i]:hi[i]])
        # quantile (k in-range, k clamped high, k=0)
        for k in (0, max(0, len(sl) // 2), len(sl) + 3):
            got = int(wm_ops["quantile"](lo[i], hi[i], k))
            want = -1 if len(sl) == 0 else sl[min(k, len(sl) - 1)]
            assert got == want, (tag, "quantile", lo[i], hi[i], k)
        # orthogonal count over random + degenerate symbol bands
        for sl_, sh_ in [(0, sigma), (sigma // 2, sigma // 2),
                         tuple(sorted(rng.integers(0, sigma + 3, 2)))]:
            got = int(wm_ops["count"](lo[i], hi[i], sl_, sh_))
            seg = seq[lo[i]:hi[i]]
            want = int(((seg >= sl_) & (seg < sh_)).sum())
            assert got == want, (tag, "count", lo[i], hi[i], sl_, sh_)
        # distinct
        got = int(wm_ops["distinct"](lo[i], hi[i]))
        assert got == len(np.unique(seq[lo[i]:hi[i]])), (tag, "distinct")
        # top-k: counts must match the oracle's sorted top-k multiset and
        # every reported (symbol, count) pair must be truthful
        syms, cnts = map(np.asarray, wm_ops["topk"](lo[i], hi[i], topk_k))
        bc = np.bincount(seq[lo[i]:hi[i]], minlength=sigma + 1)
        want_c = np.sort(bc[bc > 0])[::-1][:topk_k]
        valid = syms >= 0
        assert np.array_equal(cnts[valid], want_c), (tag, "topk", lo[i],
                                                     hi[i])
        assert (cnts[~valid] == 0).all(), (tag, "topk pad")
        for s, c in zip(syms[valid], cnts[valid]):
            assert bc[s] == c, (tag, "topk pair", s, c)


# ---------------------------------------------------------------------------
# single wavelet matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sigma", [4, 256, 1000])
def test_single_matrix_ops_match_numpy(sigma):
    n = 700
    for name, seq in _texts(n, sigma, seed=sigma).items():
        wm = build_wavelet_matrix(jnp.asarray(seq), sigma, sample_rate=128)
        rng = np.random.default_rng(sigma + 1)
        ops = {
            "quantile": lambda a, b, k: range_quantile(wm, a, b, k),
            "count": lambda a, b, s0, s1: range_count(wm, a, b, s0, s1),
            "distinct": lambda a, b: range_distinct(wm, a, b),
            "topk": lambda a, b, k: range_topk(wm, a, b, k),
        }
        _check_all_ops(seq, ops, sigma, rng, f"single/{name}/σ{sigma}")


def test_histogram_matches_bincount():
    n, sigma = 900, 97
    seq = _texts(n, sigma, seed=3)["zipf"]
    wm = build_wavelet_matrix(jnp.asarray(seq), sigma, sample_rate=128)
    for lo, hi in [(0, n), (100, 101), (50, 50), (123, 877)]:
        h = np.asarray(range_histogram(wm, lo, hi))
        want = np.bincount(seq[lo:hi], minlength=len(h))
        assert np.array_equal(h, want), (lo, hi)


def test_topk_greedy_exact_with_full_budget():
    """With a budget covering the whole tree the greedy walk is exact even
    on the adversarial uniform distribution."""
    n, sigma = 600, 37
    for name, seq in _texts(n, sigma, seed=7).items():
        wm = build_wavelet_matrix(jnp.asarray(seq), sigma, sample_rate=128)
        pow2 = 1 << wm.nbits
        syms, cnts = map(np.asarray,
                         range_topk_greedy(wm, 50, 550, 5, budget=2 * pow2))
        bc = np.bincount(seq[50:550], minlength=sigma)
        want_c = np.sort(bc[bc > 0])[::-1][:5]
        valid = syms >= 0
        assert np.array_equal(cnts[valid], want_c), name
        for s, c in zip(syms[valid], cnts[valid]):
            assert bc[s] == c, name


def test_topk_greedy_default_budget_on_skewed():
    """The default k·(logσ+1) pop budget is exact on Zipf-like traffic."""
    rng = np.random.default_rng(13)
    n, sigma = 1500, 256
    seq = (rng.zipf(1.6, n) % sigma).astype(np.uint32)
    wm = build_wavelet_matrix(jnp.asarray(seq), sigma, sample_rate=128)
    syms, cnts = map(np.asarray, range_topk_greedy(wm, 0, n, 4))
    bc = np.bincount(seq, minlength=sigma)
    want_c = np.sort(bc[bc > 0])[::-1][:4]
    valid = syms >= 0
    assert np.array_equal(cnts[valid], want_c)


def test_topk_greedy_pruned_matches_exact():
    """Lower-bound pruning (ceil(weight / leaves-below) per frontier node)
    never changes an exact answer: the pruned greedy path matches the
    exact histogram top-k on both zipf and uniform traffic, with and
    without the frontier pruning enabled."""
    from repro.analytics import range_topk
    rng = np.random.default_rng(21)
    n, sigma, k = 1200, 64, 5
    texts = {
        "zipf": (rng.zipf(1.5, n) % sigma).astype(np.uint32),
        "uniform": rng.integers(0, sigma, n).astype(np.uint32),
    }
    for name, seq in texts.items():
        wm = build_wavelet_matrix(jnp.asarray(seq), sigma, sample_rate=128)
        budget = None if name == "zipf" else 2 * (1 << wm.nbits)
        want_s, want_c = map(np.asarray, range_topk(wm, 100, 1100, k))
        got_s, got_c = map(np.asarray, range_topk_greedy(
            wm, 100, 1100, k, budget=budget, prune=True))
        assert np.array_equal(got_c, want_c), name
        bc = np.bincount(seq[100:1100], minlength=sigma)
        for s, c in zip(got_s[got_s >= 0], got_c[got_s >= 0]):
            assert bc[s] == c, name
        raw_s, raw_c = map(np.asarray, range_topk_greedy(
            wm, 100, 1100, k, budget=budget, prune=False))
        assert np.array_equal(raw_c, want_c), name


# ---------------------------------------------------------------------------
# sharded engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sigma", [4, 256, 1000])
def test_sharded_ops_match_numpy(sigma):
    n, sb = 2100, 9              # 5 shards of 512, cross-shard ranges
    for name, seq in _texts(n, sigma, seed=sigma + 5).items():
        eng = build_sharded_analytics(seq, sigma, shard_bits=sb,
                                      sample_rate=128)
        assert eng.num_shards == 5
        rng = np.random.default_rng(sigma + 2)
        ops = {
            "quantile": lambda a, b, k: eng.range_quantile(a, b, k),
            "count": lambda a, b, s0, s1: eng.range_count(a, b, s0, s1),
            "distinct": lambda a, b: eng.range_distinct(a, b),
            "topk": lambda a, b, k: eng.range_topk(a, b, k),
        }
        _check_all_ops(seq, ops, sigma, rng, f"sharded/{name}/σ{sigma}")


def test_sharded_greedy_topk_is_global():
    """The greedy frontier weighs nodes by the summed width across shards:
    a symbol frequent only via many shards still wins."""
    n, sigma, sb = 2048, 16, 9
    rng = np.random.default_rng(21)
    seq = (rng.zipf(1.5, n) % sigma).astype(np.uint32)
    eng = build_sharded_analytics(seq, sigma, shard_bits=sb,
                                  sample_rate=128)
    syms, cnts = map(np.asarray,
                     eng.range_topk_greedy(100, 1900, 3, budget=64))
    bc = np.bincount(seq[100:1900], minlength=sigma)
    want_c = np.sort(bc[bc > 0])[::-1][:3]
    assert np.array_equal(cnts[syms >= 0], want_c)


def test_engine_adopts_corpus_shards():
    """ShardedAnalytics.from_corpus shares the CompressedCorpus pytree and
    the corpus's own analytics methods agree with the engine's."""
    n, sigma = 1500, 64
    seq = _texts(n, sigma, seed=9)["zipf"]
    corpus = build_compressed_corpus(seq, sigma, shard_bits=9)
    eng = ShardedAnalytics.from_corpus(corpus)
    assert eng.num_shards == corpus.num_shards
    lo, hi, k = 37, 1402, 200
    assert int(eng.range_quantile(lo, hi, k)) == np.sort(seq[lo:hi])[k]
    assert int(corpus.range_quantile(lo, hi, k)) == np.sort(seq[lo:hi])[k]
    assert (int(corpus.range_distinct(lo, hi))
            == len(np.unique(seq[lo:hi])))
    s, c = corpus.range_topk(lo, hi, 3)
    bc = np.bincount(seq[lo:hi], minlength=sigma)
    assert np.array_equal(np.asarray(c), np.sort(bc[bc > 0])[::-1][:3])


# ---------------------------------------------------------------------------
# batched serving: ≥1024 mixed queries, one jit trace
# ---------------------------------------------------------------------------

def test_batch_1024_mixed_queries_single_trace():
    n, sigma, sb, B = 4096, 64, 10, 1024
    seq = _texts(n, sigma, seed=17)["zipf"]
    eng = build_sharded_analytics(seq, sigma, shard_bits=sb,
                                  sample_rate=128)
    traces = []

    def serve(e, lo, hi, k, s0, s1):
        traces.append(1)
        return (e.range_quantile(lo, hi, k),
                e.range_count(lo, hi, s0, s1),
                e.range_topk(lo, hi, 4),
                e.range_distinct(lo, hi))

    f = jax.jit(serve)
    rng = np.random.default_rng(23)

    def batch(seed):
        r = np.random.default_rng(seed)
        lo = r.integers(0, n, B).astype(np.int32)
        hi = np.minimum(lo + r.integers(1, n // 2, B), n).astype(np.int32)
        k = r.integers(0, n, B).astype(np.int32)
        s0 = r.integers(0, sigma, B).astype(np.int32)
        return (jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(k),
                jnp.asarray(s0), jnp.asarray(np.minimum(s0 + 7, sigma)))

    a1 = f(eng, *batch(1))
    a2 = f(eng, *batch(2))            # new values, same shapes
    jax.block_until_ready(a2)
    assert len(traces) == 1, "batched serving retraced per call"

    # spot-verify the second batch against numpy
    lo, hi, k, s0, s1 = [np.asarray(x) for x in batch(2)]
    quant, cnt, (tsyms, tcnts), dist = [np.asarray(x) if not isinstance(x, tuple)
                                        else x for x in a2]
    tsyms, tcnts = np.asarray(tsyms), np.asarray(tcnts)
    for i in rng.integers(0, B, 32):
        sl = seq[lo[i]:hi[i]]
        ss = np.sort(sl)
        assert quant[i] == (ss[min(k[i], len(ss) - 1)] if len(ss) else -1)
        assert cnt[i] == ((sl >= s0[i]) & (sl < s1[i])).sum()
        assert dist[i] == len(np.unique(sl))
        bc = np.bincount(sl, minlength=sigma)
        assert np.array_equal(tcnts[i][tsyms[i] >= 0],
                              np.sort(bc[bc > 0])[::-1][:4])


# ---------------------------------------------------------------------------
# parallel shard builds
# ---------------------------------------------------------------------------

def test_parallel_shard_build_identical_to_loop():
    n, sigma = 3000, 128
    seq = _texts(n, sigma, seed=31)["uniform"]
    loop = build_compressed_corpus(seq, sigma, shard_bits=9, parallel=False)
    traced = build_compressed_corpus(seq, sigma, shard_bits=9, parallel=True)
    for a, b in zip(jax.tree.leaves(loop.shards),
                    jax.tree.leaves(traced.shards)):
        assert a.dtype == b.dtype and np.array_equal(np.asarray(a),
                                                     np.asarray(b))


def test_parallel_fm_shard_build_identical_to_loop():
    from repro.index import build_sharded_index
    rng = np.random.default_rng(33)
    toks = rng.integers(0, 32, 1200).astype(np.int64)
    loop = build_sharded_index(toks, 32, shard_bits=9, sample_rate=16,
                               parallel=False)
    traced = build_sharded_index(toks, 32, shard_bits=9, sample_rate=16,
                                 parallel=True)
    for a, b in zip(jax.tree.leaves(loop.shards),
                    jax.tree.leaves(traced.shards)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
