"""Launch/dry-run machinery tests that run on a single CPU device.

The full 512-device lower+compile sweep is exercised by
``python -m repro.launch.dryrun --all`` (results under results/dryrun);
here we test the pure pieces: input specs, shape gating, HLO parsing, and a
real (1,1)-mesh jit with the production sharding rules.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCHITECTURES, SHAPES, get_config,
                                smoke_shape, supports_shape)
from repro.launch.hlo_analysis import analyze_hlo

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def test_shape_gating():
    assert supports_shape("mamba2_370m", "long_500k")
    assert supports_shape("jamba_v0_1_52b", "long_500k")
    for arch in ARCHITECTURES:
        if arch not in ("mamba2_370m", "jamba_v0_1_52b"):
            assert not supports_shape(arch, "long_500k"), arch
        for shp in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports_shape(arch, shp)


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_input_specs_abstract(arch):
    """input_specs returns ShapeDtypeStructs only (no allocation)."""
    from repro.launch.dryrun import input_specs
    cfg = get_config(arch)
    for shape_name, shape in SHAPES.items():
        if not supports_shape(arch, shape_name):
            continue
        specs = input_specs(cfg, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, shape_name)
        if shape.kind == "train":
            assert specs["batch"]["tokens"].shape == \
                (shape.global_batch, shape.seq_len + 1)
        elif shape.kind == "prefill":
            assert specs["batch"]["tokens"].shape == \
                (shape.global_batch, shape.seq_len)
        else:
            assert specs["tokens"].shape == (shape.global_batch, 1)


def test_hlo_collective_parsing():
    hlo = """
HloModule test
%cond (x: s32[]) -> pred[] {
  %c = s32[] constant(12)
  ROOT %r = pred[] compare(%x, %c), direction=LT
}
ENTRY %main (p: f32[128,256]) -> f32[128,256] {
  %ag = f32[128,256]{1,0} all-gather(%p), replica_groups={}
  %ar = bf16[64]{0} all-reduce(%x), to_apply=%add
  ROOT %out = f32[128,256] add(%ag, %ag)
}
"""
    from repro.launch.dryrun import collective_bytes_per_device
    got = collective_bytes_per_device(hlo)
    assert got["all-gather"] == 128 * 256 * 4
    assert got["all-reduce"] == 64 * 2


def test_hlo_analysis_dot_flops_and_trip_counts():
    hlo = """
HloModule m
%body (t: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %lhs = f32[8,32]{1,0} parameter(0)
  %rhs = f32[32,16]{1,0} constant(0)
  %d = f32[8,16]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = (s32[], f32[8,16]) tuple(%i, %d)
}
%cond (t: (s32[], f32[8,16])) -> pred[] {
  ROOT %p = pred[] constant(true)
}
ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %g = f32[8,16] get-tuple-element(%w), index=1
}
"""
    res = analyze_hlo(hlo)
    # dot: 2 * 8*16 * 32 = 8192 flops × 10 trips
    assert res["dot_flops_per_device"] == 8192 * 10


@pytest.mark.skipif(not RESULTS.exists(), reason="dry-run results absent")
def test_dryrun_results_all_cells_ok():
    """Every produced cell compiled (ok) or is an explicit long_500k skip."""
    files = list(RESULTS.glob("*.json"))
    assert len(files) >= 80, f"expected ≥80 cells, found {len(files)}"
    bad = []
    for f in files:
        rec = json.loads(f.read_text())
        if not rec.get("ok") and "skipped" not in rec:
            bad.append(f.name)
    assert not bad, bad


def test_host_mesh_train_step_with_production_shardings():
    """End-to-end jit with NamedShardings from the production rules on a
    (1,1) host mesh — same code path as the 256-chip launch."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import dp_axes, make_host_mesh, set_mesh
    from repro.models import shard_ctx
    from repro.models.model import build_model, param_specs
    from repro.train import init_train_state, make_train_step

    cfg = get_config("qwen2_0_5b", smoke=True)
    model = build_model(cfg)
    mesh = make_host_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shard_ctx.set_mesh_context(dp_axes(mesh), sizes)
    try:
        with set_mesh(mesh):
            specs = param_specs(cfg, sizes)
            state = init_train_state(model, 0)
            pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))
            params = jax.tree.map(jax.device_put, state.params, pshard)
            state = type(state)(params=params, opt=state.opt, ef=state.ef)
            step = jax.jit(make_train_step(model, base_lr=1e-3))
            rng = np.random.default_rng(0)
            batch = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (2, 65)), jnp.int32)}
            new_state, metrics = step(state, batch)
            assert np.isfinite(float(metrics["loss"]))
    finally:
        shard_ctx.clear_mesh_context()
