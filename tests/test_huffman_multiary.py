"""Arbitrary-shape (Huffman) and multiary wavelet trees (Theorems 4.3, 4.4)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bitops
from repro.core.huffman import (build_huffman_wavelet_tree, canonical_codes,
                                huffman_code_lengths, huffman_codebook,
                                reference_huffman_levels)
from repro.core.multiary import (build_multiary_wavelet_tree, mwt_access,
                                 mwt_rank, mwt_select)


def test_huffman_codes_prefix_free():
    rng = np.random.default_rng(0)
    freqs = rng.integers(1, 1000, 57)
    codes, lengths, max_len = huffman_codebook(freqs)
    # Kraft equality for a full binary tree
    assert sum(2.0 ** -l for l in lengths) == 1.0
    # prefix-freedom: no codeword is a prefix of another
    strs = [format(c, "0" + str(l) + "b") for c, l in zip(codes, lengths)]
    for i, a in enumerate(strs):
        for j, b in enumerate(strs):
            if i != j:
                assert not b.startswith(a)


@given(st.integers(2, 40), st.integers(10, 1500), st.floats(0.5, 2.0),
       st.integers(0, 2**32 - 1))
@settings(max_examples=10)
def test_huffman_tree_levels_match_oracle(sigma, n, zipf, seed):
    rng = np.random.default_rng(seed)
    p = np.arange(1, sigma + 1) ** (-zipf)
    seq = rng.choice(sigma, size=n, p=p / p.sum()).astype(np.uint32)
    freqs = np.bincount(seq, minlength=sigma) + 1
    codes, lengths, max_len = huffman_codebook(freqs)
    t = build_huffman_wavelet_tree(jnp.asarray(seq), jnp.asarray(codes),
                                   jnp.asarray(lengths), max_len)
    ref = reference_huffman_levels(seq.astype(np.int64), codes, lengths,
                                   max_len)
    for l, rl in enumerate(ref):
        got = np.asarray(bitops.unpack_bits(t.level(l).words, len(rl)))
        assert np.array_equal(got, rl), f"level {l}"
        assert int(t.active[l]) == len(rl)
    # compressed size equals sum of code lengths
    assert int(t.total_bits) == int(lengths[seq].sum())


def test_huffman_beats_balanced_on_skewed_data():
    """The point of Theorem 4.3: entropy-shaped trees store fewer bits."""
    rng = np.random.default_rng(1)
    sigma, n = 64, 4096
    p = np.arange(1, sigma + 1) ** (-1.5)
    seq = rng.choice(sigma, size=n, p=p / p.sum()).astype(np.uint32)
    freqs = np.bincount(seq, minlength=sigma) + 1
    codes, lengths, max_len = huffman_codebook(freqs)
    t = build_huffman_wavelet_tree(jnp.asarray(seq), jnp.asarray(codes),
                                   jnp.asarray(lengths), max_len)
    balanced_bits = n * 6                      # ceil(log2 64) per symbol
    assert int(t.total_bits) < 0.8 * balanced_bits


@given(st.integers(2, 200), st.sampled_from([1, 2, 4]),
       st.integers(2, 1500), st.integers(0, 2**32 - 1))
@settings(max_examples=12)
def test_multiary_tree_queries(sigma, width, n, seed):
    rng = np.random.default_rng(seed)
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    t = build_multiary_wavelet_tree(jnp.asarray(seq), sigma, width=width)
    assert np.array_equal(np.asarray(mwt_access(t, jnp.arange(n))), seq)
    for c in np.unique(rng.choice(seq, size=min(3, n))):
        idx = np.unique(rng.integers(0, n + 1, 12))
        r = np.asarray(mwt_rank(t, jnp.full(len(idx), int(c)),
                                jnp.asarray(idx)))
        expect = np.array([(seq[:i] == c).sum() for i in idx])
        assert np.array_equal(r, expect), ("rank", c)
        occ = np.flatnonzero(seq == c)
        ks = np.unique(rng.integers(0, len(occ), 6))
        s = np.asarray(mwt_select(t, jnp.full(len(ks), int(c)),
                                  jnp.asarray(ks)))
        assert np.array_equal(s, occ[ks]), ("select", c)


def test_multiary_degrees_consistent():
    """Same sequence through d=2/4/16 trees answers identically."""
    rng = np.random.default_rng(2)
    sigma, n = 100, 777
    seq = rng.integers(0, sigma, n).astype(np.uint32)
    idx = jnp.asarray(np.arange(0, n, 13))
    outs = []
    for width in (1, 2, 4):
        t = build_multiary_wavelet_tree(jnp.asarray(seq), sigma, width=width)
        outs.append(np.asarray(mwt_access(t, idx)))
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])
